"""Minimal metrics logging: CSV + stdout, no external deps."""

from __future__ import annotations

import csv
import os
import sys
import time
from typing import Any, Dict, Optional


class MetricLogger:
    def __init__(self, path: Optional[str] = None, print_every: int = 1):
        self.path = path
        self.print_every = print_every
        self._writer = None
        self._file = None
        self._t0 = time.time()
        self._n = 0

    def log(self, step: int, metrics: Dict[str, Any]) -> None:
        row = {"step": step, "wall_s": round(time.time() - self._t0, 3)}
        row.update({
            k: (float(v) if hasattr(v, "__float__") else v)
            for k, v in metrics.items()
        })
        if self.path:
            if self._writer is None:
                os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
                self._file = open(self.path, "w", newline="")
                self._writer = csv.DictWriter(
                    self._file, fieldnames=list(row)
                )
                self._writer.writeheader()
            self._writer.writerow(row)
            self._file.flush()
        self._n += 1
        if self._n % self.print_every == 0:
            msg = " ".join(
                f"{k}={v:.5g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in row.items()
            )
            print(msg, file=sys.stderr)

    def close(self) -> None:
        if self._file:
            self._file.close()
