"""Minimal metrics logging: CSV + stdout, no external deps.

The CSV schema may *evolve*: later rows can introduce keys the first row
did not have (the fused round engine logs ``up_floats``/``down_floats``
per-round while a warmup row may not).  The writer keeps the union of all
keys seen and rewrites the file with the widened header when a new key
appears; missing values render as empty cells.
"""

from __future__ import annotations

import csv
import os
import sys
import time
from typing import Any, Dict, List, Optional


class MetricLogger:
    def __init__(self, path: Optional[str] = None, print_every: int = 1):
        self.path = path
        self.print_every = print_every
        self._writer = None
        self._file = None
        self._fieldnames: List[str] = []
        self._t0 = time.time()
        self._n = 0

    def _reopen(self, extra_rows: List[Dict[str, Any]]) -> None:
        """Rewrite the file with the current (widened) header: previously
        written rows are re-read from disk, so steady-state memory is O(1)
        no matter how long the run logs."""
        old_rows: List[Dict[str, Any]] = []
        if self._file is not None:
            self._file.close()
            with open(self.path, newline="") as f:
                old_rows = list(csv.DictReader(f))
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        self._file = open(self.path, "w", newline="")
        self._writer = csv.DictWriter(
            self._file, fieldnames=self._fieldnames, restval=""
        )
        self._writer.writeheader()
        self._writer.writerows(old_rows)
        self._writer.writerows(extra_rows)

    def log(self, step: int, metrics: Dict[str, Any]) -> None:
        row = {"step": step, "wall_s": round(time.time() - self._t0, 3)}
        row.update({
            k: (float(v) if hasattr(v, "__float__") else v)
            for k, v in metrics.items()
        })
        if self.path:
            new_keys = [k for k in row if k not in self._fieldnames]
            if self._file is None or new_keys:
                self._fieldnames.extend(new_keys)
                self._reopen([row])
            else:
                self._writer.writerow(row)
            self._file.flush()
        self._n += 1
        if self._n % self.print_every == 0:
            msg = " ".join(
                f"{k}={v:.5g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in row.items()
            )
            print(msg, file=sys.stderr)

    def close(self) -> None:
        if self._file:
            self._file.close()
