"""Quantized wire format for the distributed comm path (UpCom/DownCom).

TAMUNA's permutation sparsifier decides WHICH coordinates travel; this
module decides HOW WIDE they are.  Four wire kinds narrow the payload
lanes (``f32``/``bf16``/``f16`` deterministic casts, ``int8``/``int4``
unbiased stochastic rounding with per-chunk scales), plus a
size-adaptive ``auto`` policy following the Hivemind
``SizeAdaptiveCompression`` prior: leaves below ``SIZE_THRESHOLD``
elements go f16, larger leaves go 8-bit stochastic.

Determinism contract: the stochastic rounding draw is a counter-based
uint32 hash of ``(round seed, leaf index, client row id, leaf
coordinate id)`` — a pure elementwise function with no carried RNG
state — so every comm implementation (dense / ws / pallas / shard
engine) that quantizes the same payload row produces bitwise-identical
wire values, whether the leaf lives whole on one host or sharded
across a mesh.  Replay with the same ``comm_round_key`` stream is
exact.

Fault-guard contract (PR 6 composition): quantization runs on the
*sanitized* payload (idle/faulted rows already zeroed, ``Q(0) == 0``
exactly), and a nonfinite coordinate is never quantized into a finite
value — float kinds pass it through, int kinds poison the containing
chunk's scale to NaN so dequantization propagates the NaN.

This module is self-contained on purpose: pure jnp, no pallas, and no
import of :mod:`repro.core.compression` (which enables x64 at import
time — the dist stack must stay x32).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "WIRE_KINDS",
    "WIRE_POLICIES",
    "CHUNK",
    "SIZE_THRESHOLD",
    "LEVELS",
    "WIDTH_BYTES",
    "resolve_kind",
    "is_wire",
    "n_chunks",
    "leaf_up_bytes",
    "leaf_down_bytes",
    "fold_seed",
    "uniform01",
    "leaf_scales",
    "leaf_scales_at",
    "narrow",
    "quantize",
    "quantize_to_int",
    "round_seed",
]

WIRE_KINDS = ("f32", "bf16", "f16", "int8", "int4")
WIRE_POLICIES = ("auto",) + WIRE_KINDS

CHUNK = 256                   # coordinates per stochastic-rounding scale
SIZE_THRESHOLD = 2 ** 16 + 1  # auto policy: leaves below this go f16
LEVELS = {"int8": 127, "int4": 7}
WIDTH_BYTES = {"f32": 4.0, "bf16": 2.0, "f16": 2.0, "int8": 1.0, "int4": 0.5}
_F_DTYPES = {"bf16": jnp.bfloat16, "f16": jnp.float16}
_F16_MAX = 65504.0            # finite payloads must stay finite on the wire

# pseudo row id for the (single, shared) DownCom broadcast quantization
DOWN_ROW = 0xFFFFFFFF

# fold_in constant separating the wire stream from the cohort/permutation
# streams derived from the same per-round key (see tamuna_dp.make_comm_step)
WIRE_FOLD = 0x517E


def resolve_kind(d: int, policy: Optional[str]) -> str:
    """Per-leaf wire kind for a leaf of ``d`` coordinates under ``policy``."""
    if policy is None:
        return "f32"
    if policy == "auto":
        return "f16" if d < SIZE_THRESHOLD else "int8"
    if policy not in WIRE_KINDS:
        raise ValueError(
            f"unknown wire policy {policy!r}; expected one of {WIRE_POLICIES}")
    return policy


def is_wire(policy: Optional[str]) -> bool:
    """True iff ``policy`` can change any payload (i.e. not the f32 path)."""
    return policy is not None and policy != "f32"


def kind_bits(kind: str) -> int:
    return int(WIDTH_BYTES[kind] * 8)


def n_chunks(d: int) -> int:
    return -(-d // CHUNK)


def leaf_up_bytes(nnz: int, d: int, c: int, kind: str) -> float:
    """UpCom wire bytes one round costs for a leaf: ``nnz`` owner-coordinate
    pairs at ``kind`` width; int kinds add the per-chunk f32 scales each of
    the ``c`` cohort clients ships alongside its codes."""
    b = nnz * WIDTH_BYTES[kind]
    if kind in LEVELS:
        b += c * n_chunks(d) * 4.0
    return float(b)


def leaf_down_bytes(d: int, kind: str) -> float:
    """DownCom wire bytes for one broadcast of a ``d``-coordinate leaf."""
    b = d * WIDTH_BYTES[kind]
    if kind in LEVELS:
        b += n_chunks(d) * 4.0
    return float(b)


# --------------------------------------------------------------------------
# counter-based uniform draw: pure elementwise uint32 hash, no RNG state
# --------------------------------------------------------------------------


def _avalanche(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    return h


def round_seed(key) -> jax.Array:
    """Collapse a jax PRNG key into the uint32 wire seed for one round."""
    kd = jax.random.key_data(key).reshape(-1).astype(jnp.uint32)
    return _avalanche(kd[0] ^ _avalanche(kd[-1]))


def fold_seed(seed, leaf_index: int) -> jax.Array:
    """Fold a static per-leaf index into the round seed so identical
    (row, coord) pairs in different leaves draw independent uniforms."""
    s = jnp.asarray(seed, jnp.uint32)
    return _avalanche(s ^ (jnp.uint32(leaf_index) * jnp.uint32(0x9E3779B9)))


def uniform01(seed, row_ids, coord_ids) -> jax.Array:
    """U[0,1) keyed on (seed, row, coordinate); shapes broadcast."""
    h = jnp.asarray(seed, jnp.uint32)
    h = _avalanche(
        h ^ (jnp.asarray(row_ids, jnp.uint32) * jnp.uint32(0x9E3779B9)))
    h = _avalanche(
        h ^ (jnp.asarray(coord_ids, jnp.uint32) * jnp.uint32(0x85EBCA6B)))
    return h.astype(jnp.float32) * jnp.float32(2.0 ** -32)


# --------------------------------------------------------------------------
# per-chunk scales
# --------------------------------------------------------------------------


def leaf_scales(x2: jax.Array, kind: str) -> Optional[jax.Array]:
    """Per-row per-chunk scales for int kinds: ``(rows, d) -> (rows,
    n_chunks(d))``.  Nonfinite entries are excluded from the chunk max
    (they pass through the quantizer untouched); all-zero chunks clamp
    to 1e-12 so ``0/scale`` stays exact."""
    if kind not in LEVELS:
        return None
    rows, d = x2.shape
    nc = n_chunks(d)
    a = jnp.where(jnp.isfinite(x2), jnp.abs(x2), 0.0)
    pad = nc * CHUNK - d
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
    mx = a.reshape(rows, nc, CHUNK).max(axis=2)
    return jnp.maximum(mx / LEVELS[kind], 1e-12)


def leaf_scales_at(
    x2: jax.Array, coord_ids: jax.Array, nc: int, kind: str,
    axis_names=(),
) -> jax.Array:
    """Scatter-max form of :func:`leaf_scales` for model-sharded leaves:
    local values with their GLOBAL coordinate ids; ``pmax`` over the
    leaf's model axes merges chunks that straddle shard boundaries.
    max is exact, so this is bitwise-equal to :func:`leaf_scales` on the
    gathered leaf."""
    a = jnp.where(jnp.isfinite(x2), jnp.abs(x2), 0.0)
    mx = jnp.zeros((x2.shape[0], nc), jnp.float32)
    mx = mx.at[:, coord_ids // CHUNK].max(a)
    for name in axis_names:
        mx = jax.lax.pmax(mx, name)
    return jnp.maximum(mx / LEVELS[kind], 1e-12)


# --------------------------------------------------------------------------
# quantize / dequantize
# --------------------------------------------------------------------------


def narrow(x2: jax.Array, kind: str) -> jax.Array:
    """Cast a payload to the narrow float wire dtype (the workspace lane
    dtype).  f16 clips finite values into range so the wire never turns
    a finite payload into an inf; nonfinite passes through."""
    y = x2
    if kind == "f16":
        lim = jnp.float32(_F16_MAX)
        y = jnp.where(jnp.isfinite(x2), jnp.clip(x2, -lim, lim), x2)
    return y.astype(_F_DTYPES[kind])


def _codes(x2, sc, seed, row_ids, coord_ids, levels):
    z = x2 / sc
    low = jnp.floor(z)
    u = uniform01(seed, row_ids, coord_ids)
    q = low + (u < (z - low)).astype(jnp.float32)
    return jnp.clip(q, -float(levels), float(levels))


def quantize(
    x2: jax.Array, kind: str, seed=None, row_ids=None, coord_ids=None,
    scales: Optional[jax.Array] = None, chunk_ids: Optional[jax.Array] = None,
) -> jax.Array:
    """Quantize-dequantize a ``(rows, d)`` f32 payload matrix at ``kind``.

    ``row_ids`` (e.g. ``(rows, 1)``) and ``coord_ids`` (e.g. ``(d,)``)
    broadcast against ``x2`` and key the stochastic draw; ``scales``
    ``(rows, nchunk)`` and ``chunk_ids`` ``(d,)`` select the per-chunk
    scale (both derived from ``x2`` when omitted).  Nonfinite inputs
    pass through untouched."""
    if kind == "f32":
        return x2
    if kind in _F_DTYPES:
        return narrow(x2, kind).astype(jnp.float32)
    if scales is None:
        scales = leaf_scales(x2, kind)
    if chunk_ids is None:
        chunk_ids = jnp.arange(x2.shape[-1], dtype=jnp.int32) // CHUNK
    sc = jnp.take(scales, chunk_ids, axis=1)
    q = _codes(x2, sc, seed, row_ids, coord_ids, LEVELS[kind])
    return jnp.where(jnp.isfinite(x2), q * sc, x2)


def quantize_to_int(
    x2: jax.Array, kind: str, seed, row_ids, coord_ids,
    scales: jax.Array, chunk_ids: jax.Array,
):
    """Integer codes for the packed wire workspace (int8 container, int4
    codes stay within ±7).  Returns ``(codes int8, scales f32)`` where a
    chunk containing nonfinite payload has its scale poisoned to NaN —
    dequantization (``codes * scale``) then propagates the NaN instead
    of ever minting a finite value from one."""
    sc = jnp.take(scales, chunk_ids, axis=1)
    q = _codes(x2, sc, seed, row_ids, coord_ids, LEVELS[kind])
    q = jnp.where(jnp.isfinite(x2), q, 0.0)
    bad = jnp.zeros(scales.shape, jnp.bool_)
    bad = bad.at[:, chunk_ids].max(~jnp.isfinite(x2))
    scales = jnp.where(bad, jnp.float32(jnp.nan), scales)
    return q.astype(jnp.int8), scales
