"""Deterministic fault plans and payload guards for fault-tolerant rounds.

TAMUNA's partial-participation story assumes every *sampled* client
completes its round; in practice a cohort member does its local steps and
then its uplink never lands (mid-round dropout), lands late (straggler),
or lands corrupted (NaN/Inf payloads, scaled blow-ups).  This module is
the robustness substrate (DESIGN.md §12) shared by the round driver's
fault policies (``rounds.run_rounds``), the survivor-aware aggregation of
``comm_ws`` (arrival masks), the fault-injection example
(``examples/availability_sim.py --faults``) and the fault benchmark
(``benchmarks/faults_bench.py``):

``FaultPlan``
    deterministic, replayable per-round fault draws keyed exactly like
    ``cohort.CohortPlan``: every draw is a pure function of
    ``(seed, round, attempt)`` via ``np.random.SeedSequence`` — global-
    round indexed (a restored checkpoint replays the identical fault
    trajectory), independent of query order, and *attempt*-indexed so a
    quorum retry re-draws the round's faults (the retried round is a new
    communication attempt, with new failures).

``nonfinite_clients`` / ``corrupt_rows``
    the device-side halves: per-client nonfinite (or magnitude) payload
    detection over a stacked state tree, and the matching injection
    (what a corrupted uplink payload looks like).  ``rounds`` wires the
    detector in front of the comm step (the payload guard) and the
    injector behind the fault plan.

All host outputs are numpy; the driver uploads the tiny ``(n,)`` masks per
round, exactly like the cohort plan's arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

__all__ = [
    "FaultModel",
    "FaultPlan",
    "EmpiricalDelays",
    "nonfinite_clients",
    "corrupt_rows",
    "adversarial_rows",
    "CORRUPT_MODES",
    "ADVERSARIES",
]

CORRUPT_MODES = ("nan", "inf", "blowup")

# Byzantine behaviours: unlike corruption (accidental, per-round draws),
# adversaries are a *persistent* set of f_byz * n clients whose uplinks
# arrive finite and plausible-looking every round they participate
ADVERSARIES = ("none", "sign_flip", "scale", "inlier")

# SeedSequence stream tags: disjoint from cohort.py's (53, 59, 211) so a
# shared seed never correlates availability with faults
_TAG_DROP = 101
_TAG_CORRUPT = 103
_TAG_DELAY = 107
_TAG_BASE = 109
_TAG_EMPIRICAL = 113
_TAG_BYZ = 127


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Static description of a fleet's failure behaviour.

    ``p_drop``       per-client per-attempt probability that the uplink
                     never lands (mid-round dropout).
    ``p_corrupt``    per-client per-attempt probability that the uplink
                     payload arrives corrupted (``corrupt_mode``).
    ``corrupt_mode`` "nan" | "inf" (nonfinite, caught by the payload
                     guard) | "blowup" (finite scaled blow-up by
                     ``blowup`` — only caught by a magnitude guard,
                     see ``nonfinite_clients(max_abs=...)``).
    ``delay_*``      straggler model: per-client persistent base latency
                     (lognormal(mu, sigma); ``straggler_frac`` of the
                     fleet is ``straggler_scale`` slower) times a fresh
                     per-round lognormal jitter — the
                     ``examples/availability_sim.py`` latency model, now
                     replayable.  ``delays`` are in simulated seconds;
                     the ``deadline`` round policy admits uplinks under
                     its cutoff.
    ``adversary``    Byzantine behaviour of a *persistent* ``f_byz``
                     fraction of the fleet (DESIGN.md §15): "sign_flip"
                     negates the payload, "scale" multiplies it by
                     ``byz_scale``, "inlier" is the collusive ALIE-style
                     attack — adversaries agree on ``honest_mean -
                     byz_z * honest_std`` per coordinate, small enough
                     to pass any magnitude guard while dragging the
                     mean.  All finite: only the robust combiners
                     (and, for large ``byz_scale``, the adaptive
                     magnitude guard) catch them.
    """

    p_drop: float = 0.0
    p_corrupt: float = 0.0
    corrupt_mode: str = "nan"
    blowup: float = 1e8
    delay_mu: float = 0.0
    delay_sigma: float = 0.2
    straggler_frac: float = 0.0
    straggler_scale: float = 10.0
    adversary: str = "none"
    f_byz: float = 0.0
    byz_scale: float = -10.0
    byz_z: float = 1.5

    def __post_init__(self):
        if not (0.0 <= self.p_drop <= 1.0):
            raise ValueError(f"p_drop={self.p_drop} outside [0, 1]")
        if not (0.0 <= self.p_corrupt <= 1.0):
            raise ValueError(f"p_corrupt={self.p_corrupt} outside [0, 1]")
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(
                f"unknown corrupt_mode {self.corrupt_mode!r}; want one of "
                f"{CORRUPT_MODES}"
            )
        if self.adversary not in ADVERSARIES:
            raise ValueError(
                f"unknown adversary {self.adversary!r}; want one of "
                f"{ADVERSARIES}"
            )
        if not (0.0 <= self.f_byz < 1.0):
            raise ValueError(f"f_byz={self.f_byz} outside [0, 1)")
        if self.f_byz > 0.0 and self.adversary == "none":
            raise ValueError("f_byz > 0 needs an adversary model")

    @property
    def adversarial(self) -> bool:
        """Whether a Byzantine set actually exists under this model."""
        return self.adversary != "none" and self.f_byz > 0.0


class FaultPlan:
    """Replayable per-round fault draws for ``n`` clients.

    Every query is a pure function of ``(seed, round, attempt)`` — no
    internal mutable state at all, so draws are independent of query
    order and a fresh instance replayed at any round matches a live one
    (the checkpoint-restore path needs exactly this).  ``attempt``
    indexes quorum retries: attempt 0 is the round's first communication
    try, each retry re-draws drops/corruption/delays under the same
    model (a resampled cohort fails independently).
    """

    def __init__(self, seed: int, n: int,
                 model: Optional[FaultModel] = None, **kw):
        if model is not None and kw:
            raise ValueError("pass a FaultModel or kwargs, not both")
        self.seed, self.n = int(seed), int(n)
        self.model = model if model is not None else FaultModel(**kw)
        # persistent per-client straggler identity: a function of the
        # seed alone (round-independent), like availability_sim's base
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, _TAG_BASE])
        )
        base = rng.lognormal(self.model.delay_mu, self.model.delay_sigma,
                             size=self.n)
        base[rng.random(self.n) < self.model.straggler_frac] *= \
            self.model.straggler_scale
        self._base = base

    @classmethod
    def zero(cls, n: int, seed: int = 0) -> "FaultPlan":
        """The zero-fault plan: nothing drops, corrupts, or straggles.
        ``rounds.run_rounds`` under this plan (policy ``wait_all``) is
        bitwise identical to the fault-free engine."""
        return cls(seed, n, FaultModel())

    @property
    def is_zero(self) -> bool:
        m = self.model
        return (m.p_drop == 0.0 and m.p_corrupt == 0.0
                and m.straggler_frac == 0.0 and not m.adversarial)

    @property
    def byzantine(self) -> np.ndarray:
        """(n,) bool: the persistent Byzantine set — the first
        ``round(f_byz * n)`` clients of a seeded permutation, a function
        of the seed alone (an adversary stays an adversary across rounds
        and checkpoint restores)."""
        m = self.model
        mask = np.zeros(self.n, bool)
        if not m.adversarial:
            return mask
        k = int(round(m.f_byz * self.n))
        if k == 0:
            return mask
        perm = np.random.default_rng(
            np.random.SeedSequence([self.seed, _TAG_BYZ])
        ).permutation(self.n)
        mask[perm[:k]] = True
        return mask

    def _rng(self, tag: int, rnd: int, attempt: int):
        return np.random.default_rng(
            np.random.SeedSequence(
                [self.seed, tag, int(rnd), int(attempt)]
            )
        )

    def drops(self, rnd: int, attempt: int = 0) -> np.ndarray:
        """(n,) bool: client ``i``'s uplink never lands this attempt."""
        if self.model.p_drop == 0.0:
            return np.zeros(self.n, bool)
        u = self._rng(_TAG_DROP, rnd, attempt).random(self.n)
        return u < self.model.p_drop

    def corrupts(self, rnd: int, attempt: int = 0) -> np.ndarray:
        """(n,) bool: client ``i``'s payload arrives corrupted."""
        if self.model.p_corrupt == 0.0:
            return np.zeros(self.n, bool)
        u = self._rng(_TAG_CORRUPT, rnd, attempt).random(self.n)
        return u < self.model.p_corrupt

    def delays(self, rnd: int, attempt: int = 0) -> np.ndarray:
        """(n,) float64 simulated uplink-arrival delays: the persistent
        per-client base times a fresh per-attempt lognormal jitter."""
        jit = self._rng(_TAG_DELAY, rnd, attempt).lognormal(
            0.0, self.model.delay_sigma, size=self.n
        )
        return self._base * jit

    @property
    def base_delays(self) -> np.ndarray:
        """(n,) persistent per-client base latency (straggler identity)."""
        return self._base.copy()


class EmpiricalDelays:
    """Replayable per-round latency draws resampled from a *measured*
    per-step latency sample set.

    ``examples/availability_sim.py --dist`` exports the per-client
    per-local-step latencies its wall-clock model actually drew (the
    straggler tail as measured, not a parametric fit); this class
    bootstraps per-round fleet latencies from those samples with the same
    ``SeedSequence`` determinism as :class:`FaultPlan` — ``delays(rnd,
    attempt)`` is a pure function of ``(seed, rnd, attempt)``, so
    restored runs replay the identical straggler trajectory.  The
    pipelined round driver (``rounds.run_rounds_pipelined``) multiplies
    these per-step draws by the round's local-step count ``L`` to get
    uplink-arrival offsets, exactly the availability_sim cost model.
    """

    def __init__(self, samples, n: int, seed: int = 0):
        samples = np.asarray(samples, np.float64).reshape(-1)
        if samples.size == 0:
            raise ValueError("EmpiricalDelays needs at least one sample")
        if not np.all(np.isfinite(samples)) or np.any(samples < 0):
            raise ValueError("latency samples must be finite and >= 0")
        self.samples = samples
        self.n, self.seed = int(n), int(seed)

    @classmethod
    def from_json(cls, path: str, n: int, seed: int = 0
                  ) -> "EmpiricalDelays":
        """Load the ``availability_sim --dist`` export (key
        ``per_step_latency_s``)."""
        import json

        with open(path) as f:
            blob = json.load(f)
        return cls(blob["per_step_latency_s"], n=n, seed=seed)

    def delays(self, rnd: int, attempt: int = 0) -> np.ndarray:
        """(n,) float64 per-step latency draws for the round (bootstrap
        resample of the measured distribution)."""
        rng = np.random.default_rng(
            np.random.SeedSequence(
                [self.seed, _TAG_EMPIRICAL, int(rnd), int(attempt)]
            )
        )
        return self.samples[rng.integers(0, self.samples.size, self.n)]

    def quantile(self, q) -> np.ndarray:
        """Tail summary of the measured distribution (for reporting)."""
        return np.quantile(self.samples, q)


# --------------------------------------------------------------------------
# device-side halves: payload guard + injection
# --------------------------------------------------------------------------


def nonfinite_clients(tree: Any, max_abs: Optional[float] = None):
    """(n,) bool: client rows whose payload fails the guard — any
    nonfinite value in any leaf, or (``max_abs`` given) any magnitude
    above it (the blow-up guard).  One fused reduction pass over the
    stacked state; pure jnp, jit/shard-safe."""
    import jax
    import jax.numpy as jnp

    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    bad = jnp.zeros((n,), bool)
    for a in leaves:
        f = a.astype(jnp.float32).reshape(n, -1)
        ok = jnp.isfinite(f)
        if max_abs is not None:
            ok = ok & (jnp.abs(f) <= max_abs)
        bad = bad | ~ok.all(axis=1)
    return bad


def corrupt_rows(tree: Any, mask, mode: str = "nan", blowup: float = 1e8):
    """Inject payload corruption into the ``mask``'ed client rows of a
    stacked tree (what a corrupted uplink looks like to the server):
    ``nan``/``inf`` overwrite the row, ``blowup`` scales it by
    ``blowup``.  Rows outside ``mask`` pass through bit-exactly."""
    import jax
    import jax.numpy as jnp

    if mode not in CORRUPT_MODES:
        raise ValueError(f"unknown corrupt mode {mode!r}")
    mask = jnp.asarray(mask).astype(bool)

    def leaf(a):
        m = mask.reshape((a.shape[0],) + (1,) * (a.ndim - 1))
        if mode == "blowup":
            return jnp.where(m, (a.astype(jnp.float32)
                                 * blowup).astype(a.dtype), a)
        val = jnp.asarray(
            jnp.nan if mode == "nan" else jnp.inf, jnp.float32
        ).astype(a.dtype)
        return jnp.where(m, val, a)

    return jax.tree.map(leaf, tree)


def adversarial_rows(tree: Any, byz, honest, mode: str,
                     byz_scale: float = -10.0, byz_z: float = 1.5):
    """Inject Byzantine payloads into the ``byz`` client rows (what an
    adversarial uplink looks like to the server).  ``honest`` masks the
    rows the "inlier" attack colludes against (member & arrived & ~byz):
    adversaries agree on ``mean(honest) - byz_z * std(honest)`` per
    coordinate — finite, magnitude-plausible, invisible to any norm
    guard, designed to drag the plain mean (the ALIE construction).
    ``sign_flip`` negates, ``scale`` multiplies by ``byz_scale``.  Rows
    outside ``byz`` pass through bit-exactly; pure jnp, jit/shard-safe.
    """
    import jax
    import jax.numpy as jnp

    if mode not in ADVERSARIES or mode == "none":
        raise ValueError(f"unknown adversary mode {mode!r}")
    byz = jnp.asarray(byz).astype(bool)
    honest = jnp.asarray(honest).astype(bool) & ~byz

    def leaf(a):
        m = byz.reshape((a.shape[0],) + (1,) * (a.ndim - 1))
        f = a.astype(jnp.float32)
        if mode == "sign_flip":
            v = -f
        elif mode == "scale":
            v = f * byz_scale
        else:  # inlier: collude on honest_mean - z * honest_std
            hm = honest.reshape(m.shape)
            cnt = jnp.maximum(hm.sum(), 1).astype(jnp.float32)
            mu = jnp.where(hm, f, 0.0).sum(axis=0, keepdims=True) / cnt
            var = jnp.where(hm, (f - mu) ** 2, 0.0).sum(
                axis=0, keepdims=True) / cnt
            v = jnp.broadcast_to(mu - byz_z * jnp.sqrt(var), f.shape)
        return jnp.where(m, v.astype(a.dtype), a)

    return jax.tree.map(leaf, tree)
