"""Distributed TAMUNA engine: sharding rules, the TAMUNA-DP trainer, the
fused round engine, the reduce-scatter blocked uplink, and the
family-dispatching model API.

  sharding     mesh helpers + PartitionSpec derivation (clients = data axes)
  tamuna_dp    DistTamunaConfig / init_state / local + comm step builders,
               cohort gather/scatter (elastic PP, §11)
  cohort       host-side cohort plans + availability models (§11)
  faults       deterministic fault plans: dropout / corruption / delays /
               Byzantine adversaries (§12/§15)
  robust       per-coordinate robust combiners (trimmed / median), the
               adaptive magnitude guard, anomaly scores + EWMA reputation
               feeding quarantine (§15)
  rounds       donated scanned round engine (make_round_fn / run_rounds)
  comm_ws      flat comm workspace: the mask-free fused comm step (§9)
  block_uplink ``block_rs_aggregate``: contiguous-block ownership uplink
  model_api    init / loss / prefill / make_cache / decode over the zoo
"""

from repro.dist import (
    block_uplink,
    cohort,
    comm_ws,
    faults,
    model_api,
    robust,
    rounds,
    sharding,
    tamuna_dp,
)

__all__ = [
    "block_uplink",
    "cohort",
    "comm_ws",
    "faults",
    "model_api",
    "robust",
    "rounds",
    "sharding",
    "tamuna_dp",
]
