"""Host-visible per-round cohort plans and client availability models.

TAMUNA's partial participation samples a cohort of ``c`` of the ``n``
clients every round.  The elastic round engine (DESIGN.md §11) needs the
cohort *before* the round's local steps (it gathers exactly those rows),
and the DownCom needs the *next* round's cohort (only joining clients
download ``x_bar``), so cohort selection is a per-round **plan** shared by
every layer — the round engine, the data pipeline (batches are sampled for
cohort clients only), the trainers, and the replay/reference paths:

  uniform   no plan object at all: the engine derives the round's cohort
            *on device* from the round's comm key
            (``tamuna_dp.round_cohort(comm_round_key(base, round), n, c)``)
            — fold_in-keyed, replayable from ``(comm_key, round)`` alone,
            zero host plumbing.

  non-uniform  a :class:`CohortPlan` on the host: per-round Gumbel-top-c
            selection over client log-weights, optionally gated by an
            availability model (Bernoulli or Markov up/down streams).
            Unavailable clients are only drafted when fewer than ``c``
            clients are up (the paper requires exactly ``c`` participants
            per round).  ``plan.cohort(r)`` is deterministic in
            ``(seed, r)`` (the Markov chain advances lazily and is cached),
            so a restored checkpoint replays the identical schedule:
            ``run_rounds`` indexes the plan by the GLOBAL round counter
            (``state.round``), not the loop index.

All outputs are numpy (host-visible); ``run_rounds`` uploads the tiny
``(c,)`` cohort / ``(n,)`` down-mask arrays per round.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = [
    "BernoulliAvailability",
    "MarkovAvailability",
    "CohortPlan",
]

# weight floor for unavailable clients: small enough that an unavailable
# client is only ever drafted when fewer than c clients are up, large
# enough that the draft among unavailable clients is still a (seeded)
# random choice rather than an argsort tie-break
_DOWN_LOG_WEIGHT = -80.0


@dataclasses.dataclass(frozen=True)
class BernoulliAvailability:
    """Independent per-round availability: client ``i`` is up with
    probability ``p_up[i]`` each round (no memory).  ``states(r)`` is a
    pure function of ``(seed, r)``."""

    p_up: np.ndarray  # (n,) in [0, 1]
    seed: int = 0

    def states(self, rnd: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 53, int(rnd)])
        )
        return rng.random(len(self.p_up)) < self.p_up


class MarkovAvailability:
    """Two-state up/down chain per client: ``P(up->down) = p_fail``,
    ``P(down->up) = p_recover``.  Bursty outages (a client that just
    failed tends to stay down), the standard straggler/churn model.

    ``states(r)`` advances the chain lazily from round 0 and caches every
    visited round, so access is random but the stream is the unique
    deterministic trajectory of ``seed`` — replayable across restarts.
    """

    def __init__(self, p_fail, p_recover, n: Optional[int] = None,
                 seed: int = 0):
        p_fail = np.asarray(p_fail, np.float64)
        p_recover = np.asarray(p_recover, np.float64)
        if p_fail.ndim == 0:
            assert n is not None, "scalar rates need an explicit n"
            p_fail = np.full(n, float(p_fail))
        if p_recover.ndim == 0:
            p_recover = np.full(len(p_fail), float(p_recover))
        self.p_fail, self.p_recover = p_fail, p_recover
        self.n = len(p_fail)
        self.seed = seed
        self._states: Dict[int, np.ndarray] = {0: np.ones(self.n, bool)}
        self._frontier = 0

    def states(self, rnd: int) -> np.ndarray:
        rnd = int(rnd)
        while self._frontier < rnd:
            r = self._frontier
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, 59, r])
            )
            up = self._states[r]
            u = rng.random(self.n)
            nxt = np.where(up, u >= self.p_fail, u < self.p_recover)
            self._states[r + 1] = nxt
            self._frontier = r + 1
        return self._states[rnd]


class CohortPlan:
    """Replayable per-round cohort plan: Gumbel-top-``c`` over client
    log-weights, availability-gated.

    ``weights`` biases selection among *available* clients (e.g. inverse
    latency so fast clients participate more — the non-uniform sampling
    the availability scenarios drive).  ``cohort(r)`` returns the round's
    sorted ``(c,)`` client ids; ``member_mask(r)`` its ``(n,)`` bool
    membership (what the engine's DownCom targets for round ``r - 1``).
    """

    def __init__(self, seed: int, n: int, c: int, *,
                 availability=None, weights=None):
        if not (2 <= c <= n):
            raise ValueError(f"need 2 <= c <= n, got c={c} n={n}")
        self.seed, self.n, self.c = int(seed), int(n), int(c)
        self.availability = availability
        logw = np.zeros(n) if weights is None else np.log(
            np.asarray(weights, np.float64)
        )
        self._logw = logw
        # non-uniform selection without 1/(n p_i) reweighting biases the
        # aggregate; run_rounds reads this flag to warn (DESIGN.md §11)
        self.weighted = weights is not None
        self._cache: Dict[tuple, np.ndarray] = {}
        # (ids, first, last) quarantine windows — payload-guard feedback
        self._quarantine: list = []

    def cohort(self, rnd: int, attempt: int = 0) -> np.ndarray:
        """The (sorted) cohort of round ``rnd``.  ``attempt`` indexes
        quorum *retries* of the fault-tolerant driver (DESIGN.md §12):
        each retry resamples the cohort from a fresh stream; attempt 0
        keys exactly as before, so existing schedules replay unchanged."""
        rnd, attempt = int(rnd), int(attempt)
        key = (rnd, attempt)
        got = self._cache.get(key)
        if got is not None:
            return got
        g = self._gumbel(rnd, attempt)
        top = np.argpartition(-g, self.c - 1)[:self.c]
        out = np.sort(top).astype(np.int32)
        self._cache[key] = out
        return out

    def _gumbel(self, rnd: int, attempt: int) -> np.ndarray:
        """The round's availability/quarantine-gated Gumbel scores (the
        draw behind ``cohort``), exposed so busy-aware selection reuses
        the identical stream."""
        words = ([self.seed, 211, rnd] if attempt == 0
                 else [self.seed, 211, rnd, attempt])
        rng = np.random.default_rng(np.random.SeedSequence(words))
        g = rng.gumbel(size=self.n) + self._logw
        if self.availability is not None:
            g = np.where(self.availability.states(rnd), g,
                         g + _DOWN_LOG_WEIGHT)
        for ids, first, last in self._quarantine:
            if first <= rnd <= last:
                g[ids] = g[ids] + _DOWN_LOG_WEIGHT
        return g

    def member_mask(self, rnd: int, attempt: int = 0) -> np.ndarray:
        mask = np.zeros(self.n, bool)
        mask[self.cohort(rnd, attempt)] = True
        return mask

    def cohort_excluding(self, rnd: int, busy, attempt: int = 0
                         ) -> np.ndarray:
        """The round's cohort with ``busy`` clients barred outright.

        The pipelined round driver (DESIGN.md §14) keeps up to ``τ``
        rounds in flight; a client mid-round physically cannot join a new
        cohort, so in-flight clients are excluded with a *hard* ``-inf``
        (unlike the availability gate's soft floor — an unavailable
        client may still be drafted to keep exactly ``c`` participants, a
        busy one never).  The Gumbel stream is the same draw ``cohort``
        uses, so whenever the plan's top-``c`` happens to avoid the busy
        set the two selections agree; with no busy clients this *is*
        ``cohort`` (cached, replay-identical).  Deterministic in
        ``(seed, rnd, attempt, busy)``; results are not cached (the busy
        set is itself a pure function of the pipeline schedule).
        """
        busy = np.asarray(busy, bool)
        if busy.shape != (self.n,):
            raise ValueError(f"busy mask shape {busy.shape} != ({self.n},)")
        if not busy.any():
            return self.cohort(rnd, attempt)
        if int((~busy).sum()) < self.c:
            raise ValueError(
                f"only {int((~busy).sum())} free clients for c={self.c} "
                f"at round {rnd}: staleness too deep for this fleet "
                f"(need c * (tau + 1) <= n)"
            )
        g = self._gumbel(int(rnd), int(attempt))
        g = np.where(busy, -np.inf, g)
        top = np.argpartition(-g, self.c - 1)[:self.c]
        return np.sort(top).astype(np.int32)

    def quarantine(self, clients, first_round: int,
                   last_round: int) -> None:
        """Penalize ``clients`` by the unavailability weight floor for
        rounds ``[first_round, last_round]`` (inclusive) — the payload
        guard's feedback into selection (DESIGN.md §12): a client whose
        uplink failed the nonfinite guard sits out R rounds, drafted
        again only when fewer than ``c`` healthy clients remain (same
        soft-floor semantics as the availability gate, so the paper's
        exactly-``c``-participants invariant holds throughout).  Cached
        draws inside the window are purged; the driver quarantines from
        detection round + 2 (cohort ``g+1`` is already committed as round
        ``g``'s DownCom target), so no *executed* round is rewritten."""
        ids = np.asarray(clients, np.int64).reshape(-1)
        if ids.size == 0:
            return
        first_round, last_round = int(first_round), int(last_round)
        self._quarantine.append((ids, first_round, last_round))
        for k in [k for k in self._cache
                  if first_round <= k[0] <= last_round]:
            del self._cache[k]
