"""Mask-free comm step: sparse closed-form uplinks + the flat workspace.

The reference comm step walks the client-stacked state leaf by leaf and
materializes a dense ``(n, D)`` ownership mask per leaf, multiplies it in,
and reduces over all ``n`` client rows — the memory-traffic profile of an
*uncompressed* round, exactly the cost TAMUNA's sparse templates exist to
avoid.  This module replaces it with two mask-free implementations that
compute ownership on the fly from static per-coordinate tables, plus the
dense path itself (``impl="dense"``) kept as the property-tested ground
truth:

``impl="ws"`` — the sparse fused path (production default off-TPU).
  Every coordinate has exactly ``s`` owners at *closed-form* positions
  (template row property), so UpCom never has to scan the client axis:

    x_bar[k] = (1/s) * sum_t  x[owner_row(t, k), k]

  is ``s`` row-gathers per leaf — ``O(s d)`` reads, independent of ``n``
  (``owner_row`` = a static ``(s, D)`` column table pushed through the
  round's column->client scatter for the cyclic template, or the shifted
  block ids for the blocked template).  The h-update + DownCom broadcast
  are one fused elementwise pass per leaf with the ownership predicate
  ``(slot - band[k]) mod m < s`` evaluated inside the fusion off a static
  int32 band table — never materialized.  Measured on the 2-core CPU host
  (BENCH_comm_step.json): the dense reference's extra mask passes grow
  with ``n`` while this path stays at the read-x/read-h/write-h/write-x
  floor, ~2 passes over ``(n, d_total)`` state.

  ``meshed=True`` (what ``make_comm_step`` passes): when the client axis
  is *sharded across devices*, the owner rows live on other shards and
  GSPMD turns a row-gather into an ``(n, d)``-sized all-reduce (measured
  2-4x the collective bytes and 2.5x the wall time of the dense path on
  the 4x2 host mesh).  Meshed mode therefore keeps the UpCom in the
  d-sized-psum shape — the minimal collective — with the ownership
  predicate fused into the local partial sum, and the sparse gathers are
  reserved for unsharded stacked state (the bench, single-device sims).

``impl="pallas"`` — the workspace kernel path (TPU production).
  Unsharded state: all leaves packed once into a single ``(n, d_total)``
  f32 buffer with a static leaf-offset table (``WorkspaceSpec``), then two
  Pallas kernels (``repro.kernels.uplink``) do the whole comm math:
  ``masked_sum`` (per-VMEM-tile ownership fused with the ``1/s`` rebuild)
  and ``h_update`` (reads x, h, x_bar once; writes h_new AND the broadcast
  x_new in the same pass).  No ``(n, d)`` or ``(d, c)`` mask exists at any
  point in the lowering (regression-tested in tests/test_comm_ws.py).  On
  CPU the kernels run in interpret mode (correctness smokes only: the
  interpreter unrolls the grid, and the pack itself costs a full
  read+write pass that XLA's leafwise fusion avoids — measured, see
  DESIGN.md §9 — which is why ``auto`` resolves to ``"ws"`` off-TPU).

  ``meshed=True`` + a ``mesh`` handle: the **shard-resident engine**
  (DESIGN.md §10).  The whole comm step runs inside ``shard_map`` over
  the client-hosting (dp) mesh axes: each shard packs only its *local*
  client rows into a per-shard workspace and runs the uplink kernels on
  them (TPU; off-TPU the per-shard math is fused jnp — coarse per-block
  chunk gathers for the blocked template, masked local partials for the
  cyclic one), and the shards combine with d-sized ``psum``s of the
  ``1/s``-folded partials — one for the packed kernel workspace, per
  leaf on the jnp path — the reduce-scatter-shaped minimum, never an
  ``(n, d)``-sized collective.  ``h_update``/DownCom then run per shard
  on local rows reading the combined ``x_bar`` once.  Ownership bands for
  model-sharded leaves are recomputed per shard from the global
  coordinate index (``sharding.spec_dim_axes`` offsets), so tensor
  parallelism keeps its d/model-sized partial.  This is the layer PR 3
  deferred: ``effective_impl("pallas", meshed=True, mesh=...)`` no longer
  demotes.

One band table encodes BOTH templates:

  cyclic   band[k] = (s * k_leaf) mod c,   m = c,
           slot[i] = template column of client i's cohort slot
           (``perm[slot_of[i]]``, -1 when idle) — coordinate-identical to
           ``masks.mask_from_permutation`` per leaf (both Fig. 1 regimes;
           the tall-and-thin regime ``D s < c`` keeps its own closed form
           on the ``ws`` path and falls back to dense under ``pallas``),
  blocked  band[k] = k_leaf // ceil(D/m),  m = c (the COHORT size — ``n``
           under full participation), ownership
           ``(band[k] - slot_of[i] - off) mod c < s``: the contiguous
           per-block bands laid over the round's c cohort *slots*, so the
           reduce-scatter-shaped uplink works at any ``c <= n``
           (DESIGN.md §11); idle clients (``slot_of = -1``) own nothing.

Both templates take an optional ``down`` row mask: the DownCom writes
``x_bar`` only to those rows (the NEXT round's cohort under elastic
partial participation — idle clients' ``x`` passes through bit-exactly);
``down=None`` broadcasts to every row, the full-participation behaviour.

Fault tolerance (DESIGN.md §12): both templates also take an optional
``arrived`` mask over the client rows — a cohort member whose uplink never
lands is demoted to idle (``slot = -1``: owns nothing, contributes
nothing, NaN payloads included).  With ``correct=True`` (survivor-aware
aggregation) the exact ``1/s`` rebuild becomes the per-coordinate
``1/(arrived owner count)`` — unbiased whenever dropout is independent of
the payload — and *uncovered* coordinates (every owner dropped) are left
bitwise untouched in BOTH h and x, extending §11's idle-row semantics to
single coordinates; ``correct=False`` keeps the ``1/s`` division and the
full DownCom (the biased wait-all-with-drops control the fault benchmark
measures against).  Under an all-``True`` arrival mask the corrected path
computes bit-identical values to ``arrived=None`` on the dense and ws
paths; the kernel path's two-output counts kernel lets XLA reassociate
the client-axis reduction (≤1 ulp — which is why the round driver passes
``arrived=None`` outright for a zero-fault plan, keeping the program
itself identical).

All functions are pure jnp over the stacked client axis (mesh-free and
mesh-agnostic); callers pick ``meshed`` per placement, and ``impl`` per
backend (``resolve_impl``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import robust as _robust
from repro.dist import wire as _wire

__all__ = [
    "WorkspaceSpec",
    "workspace_spec",
    "pack",
    "unpack",
    "resolve_impl",
    "effective_impl",
    "COMM_IMPLS",
    "cyclic_comm",
    "blocked_comm",
    "uncovered_coords",
]

COMM_IMPLS = ("auto", "dense", "ws", "pallas")


def resolve_impl(impl: Optional[str]) -> str:
    """``auto`` -> Pallas workspace kernels on TPU, sparse fused jnp
    elsewhere (see module docstring for the measured rationale)."""
    impl = impl or "auto"
    if impl not in COMM_IMPLS:
        raise ValueError(f"unknown comm impl {impl!r}; want one of "
                         f"{COMM_IMPLS}")
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ws"
    return impl


def effective_impl(impl: Optional[str], *, meshed: bool = False,
                   mesh=None) -> str:
    """The impl that will actually execute.  ``pallas`` on a meshed
    placement runs the shard-resident engine (shard_map'd per-shard
    kernels + one d-sized psum of the partials, DESIGN.md §10), which
    needs the mesh handle for its axis names; a meshed call *without* a
    mesh falls back to the psum-shaped ``ws`` path (the pre-shard_map
    behaviour).  The single source of truth for that rule — launch
    reporting uses it too (pass the mesh there)."""
    impl = resolve_impl(impl)
    if impl == "pallas" and meshed and mesh is None:
        return "ws"
    return impl


# --------------------------------------------------------------------------
# workspace pack / unpack (the Pallas path's layout)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkspaceSpec:
    """Static leaf-offset table of a packed ``(n, d_total)`` workspace.

    Under the shard-resident engine the spec describes ONE shard's
    resident block: ``n``/``dims``/``offsets`` are the shard-local row
    count and flat-axis layout (built from the shard's local leaves inside
    the ``shard_map`` body), while ``rows_total`` records the global
    client-row count the blocks tile (``rows_total == n`` off-mesh)."""

    n: int
    shapes: Tuple[tuple, ...]  # stacked shapes (n, *param), shard-local
    dtypes: Tuple[Any, ...]  # storage dtypes, restored by unpack
    dims: Tuple[int, ...]  # flattened per-leaf param dims D
    offsets: Tuple[int, ...]  # leaf start offsets in the flat axis
    d_total: int
    rows_total: int = -1  # global client rows (== n when unsharded)
    wire_kinds: Tuple[str, ...] = ()  # per-leaf wire kind (empty: all f32)


def workspace_spec(
    leaves: Sequence[Any], rows_total: Optional[int] = None,
    wire: Optional[str] = None, wire_dims: Optional[Sequence[int]] = None,
) -> WorkspaceSpec:
    """Offset table for a list of stacked leaves (arrays or structs).
    ``rows_total`` marks a shard-local spec with the global row count.
    ``wire`` resolves the size-adaptive per-leaf wire precision at spec
    build time (``dist/wire.py``): ``wire_kinds[i]`` is leaf i's payload
    dtype on the UpCom wire.  ``wire_dims`` overrides the leaf sizes the
    policy sees (the GLOBAL dims under the shard engine, where the local
    block is smaller than the leaf)."""
    shapes = tuple(tuple(a.shape) for a in leaves)
    dims = tuple(int(np.prod(s[1:])) for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + dims)[:-1])
    n = int(shapes[0][0]) if shapes else 0
    pdims = tuple(wire_dims) if wire_dims is not None else dims
    return WorkspaceSpec(
        n=n,
        shapes=shapes,
        dtypes=tuple(a.dtype for a in leaves),
        dims=dims,
        offsets=offsets,
        d_total=int(sum(dims)),
        rows_total=n if rows_total is None else int(rows_total),
        wire_kinds=tuple(_wire.resolve_kind(D, wire) for D in pdims),
    )


def pack(leaves: Sequence[jax.Array], spec: WorkspaceSpec) -> jax.Array:
    """All leaves -> one ``(n, d_total)`` f32 buffer (a single fused op;
    under donation the leaf buffers are dead immediately after)."""
    flat = [
        a.reshape(spec.n, -1).astype(jnp.float32) for a in leaves
    ]
    return flat[0] if len(flat) == 1 else jnp.concatenate(flat, axis=1)


def unpack(ws: jax.Array, spec: WorkspaceSpec) -> List[jax.Array]:
    """``(n, d_total)`` buffer -> leaves in storage dtype/shape."""
    return [
        ws[:, o:o + d].astype(dt).reshape(sh)
        for o, d, dt, sh in zip(spec.offsets, spec.dims, spec.dtypes,
                                spec.shapes)
    ]


# --------------------------------------------------------------------------
# static per-coordinate tables (cached on the leaf-dim signature)
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _cyclic_leaf_tables_np(D: int, c: int, s: int):
    """(owner-column table (s, D), band (D,), tall?) for one leaf.

    cols[t, k] = the t-th template column owning coordinate k: the cyclic
    band ``(s k + t) mod c`` when ``D s >= c`` (paper Fig. 1 left), else
    the tall-and-thin columns ``k + t D`` (all < D s <= c; columns past
    ``D s`` own nothing).  band[k] = (s k) mod c drives the ownership
    predicate of the cyclic regime."""
    k = np.arange(D, dtype=np.int64)
    tall = D * s < c
    if tall:
        cols = np.stack([k + t * D for t in range(s)])
    else:
        cols = np.stack([(s * k + t) % c for t in range(s)])
    band = ((s * k) % c).astype(np.int32)
    return cols.astype(np.int32), band, tall


@functools.lru_cache(maxsize=None)
def _block_leaf_band_np(D: int, n: int) -> np.ndarray:
    """band[k] = k // ceil(D/n): the leaf-local chunk (block) id."""
    return (np.arange(D, dtype=np.int64) // (-(-D // n))).astype(np.int32)


@functools.lru_cache(maxsize=None)
def _cyclic_band_np(dims: Tuple[int, ...], c: int, s: int) -> np.ndarray:
    """Packed-workspace band: (-s * k_leaf) mod c per coordinate, so the
    kernels' shared ``(slot + band) mod m < s`` predicate applies."""
    parts = [
        ((-(s * (np.arange(D, dtype=np.int64) % c))) % c).astype(np.int32)
        for D in dims
    ]
    return np.concatenate(parts) if parts else np.zeros((0,), np.int32)


@functools.lru_cache(maxsize=None)
def _block_band_np(dims: Tuple[int, ...], n: int) -> np.ndarray:
    """Packed-workspace block ids (leaf-local chunking)."""
    parts = [_block_leaf_band_np(D, n) for D in dims]
    return np.concatenate(parts) if parts else np.zeros((0,), np.int32)


@functools.lru_cache(maxsize=None)
def _cyclic_band_counts_np(D: int, c: int, s: int) -> np.ndarray:
    """(c,) int64: coordinates per cyclic band value ``(s k) mod c``
    (non-tall regime only)."""
    band = (s * np.arange(D, dtype=np.int64)) % c
    return np.bincount(band, minlength=c)


@functools.lru_cache(maxsize=None)
def _block_band_counts_np(D: int, m: int) -> np.ndarray:
    """(m,) int64: coordinates per block id for one leaf."""
    return np.bincount(_block_leaf_band_np(D, m), minlength=m)


def uncovered_coords(template: str, dims: Tuple[int, ...], m: int, s: int,
                     slot: jax.Array) -> jax.Array:
    """int32 scalar: coordinates with NO surviving owner this round.

    ``slot`` is the per-client final slot/column assignment the comm step
    aggregates with (``-1`` = idle or demoted by the arrival mask): the
    cyclic template column for ``template="cyclic"`` or the folded
    ``(-(slot_of + off)) mod c`` blocked slot for ``template="blocked"``.
    Under the survivor-aware rebuild (DESIGN.md §12) exactly these
    coordinates pass through ``x``/``h`` bitwise untouched, so the count
    is the per-round coverage loss the bounded-staleness driver traces
    (§14) — dropped-late uplinks show up here, admitted ones don't.

    Pure jnp over the (m,) slot-occupancy vector plus static per-leaf
    band counts; O(s·m + tall-leaf coords) device work, no dependence on
    the payload itself."""
    if template not in ("cyclic", "blocked"):
        raise ValueError(f"unknown template {template!r}")
    slot = jnp.asarray(slot, jnp.int32)
    # slot-value occupancy; -1 rows land in the m overflow cell
    pres = jnp.zeros((m + 1,), bool).at[
        jnp.where(slot >= 0, slot, m)
    ].set(True)[:m]
    total = jnp.int32(0)
    if template == "cyclic":
        # covered band b iff any owner column (b + t) mod c, t < s, has an
        # arriving client; tall leaves (D s < c) use their explicit
        # owner-column table instead (cols k + t D)
        cov_band = jnp.zeros((m,), bool)
        for t in range(s):
            cov_band = cov_band | jnp.roll(pres, -t)
        for D in dims:
            cols, _, tall = _cyclic_leaf_tables_np(D, m, s)
            if tall:
                cov = pres[jnp.asarray(cols)].any(axis=0)
                total = total + (D - cov.sum()).astype(jnp.int32)
            else:
                cnt = jnp.asarray(_cyclic_band_counts_np(D, m, s))
                total = total + jnp.where(
                    cov_band, 0, cnt
                ).sum().astype(jnp.int32)
    else:
        # blocked ownership is (slot + block) mod m < s, so block b is
        # covered iff any arriving slot value equals (t - b) mod m
        pres_rev = jnp.roll(pres[::-1], 1)  # pres_rev[b] = pres[(-b) % m]
        cov_band = jnp.zeros((m,), bool)
        for t in range(s):
            cov_band = cov_band | jnp.roll(pres_rev, t)
        for D in dims:
            cnt = jnp.asarray(_block_band_counts_np(D, m))
            total = total + jnp.where(
                cov_band, 0, cnt
            ).sum().astype(jnp.int32)
    return total


# --------------------------------------------------------------------------
# quantized wire (dist/wire.py fused into every impl — DESIGN.md §13)
#
# The one rule all four impls share: quantization is a PER-ROW function of
# the leaf payload (row r's wire values depend only on row r, keyed on
# (round seed, leaf, global row id, leaf coordinate id)), applied to the
# UpCom numerator ONLY — the h-update and the DownCom passthrough read the
# raw f32 payload, mirroring the convergence-validated core path
# (core/tamuna.py: X_up feeds aggregate_masked, h updates against X).
# Q(0) == 0 exactly, so idle/faulted rows need no special casing, and the
# survivor-aware 1/(arrived owner count) rebuild divides AFTER
# dequantization — PR 6's fault semantics are unchanged.
# --------------------------------------------------------------------------


def _wire_policy(wire: Optional[str]) -> Optional[str]:
    """None/"f32" -> None: the f32 path takes the PR 6 code verbatim."""
    return wire if _wire.is_wire(wire) else None


def _wire_seed(wire_seed) -> jax.Array:
    if wire_seed is None:
        return jnp.uint32(0)
    return jnp.asarray(wire_seed).astype(jnp.uint32)


def _leaf_quant(kind, seed, li, D, row0=None, coords=None, axes=()):
    """Closure quantize-dequantizing one leaf's ``(rows, D_local)`` f32
    payload at ``kind`` (None when the leaf stays f32).  ``coords`` is
    the block's GLOBAL coordinate index for model-sharded leaves (``D``
    is the global leaf dim there, ``axes`` its model mesh axes);
    ``row0`` offsets the global client-row ids under the shard engine."""
    if kind == "f32":
        return None
    sl = _wire.fold_seed(seed, li)

    def quant(xf):
        rid = jnp.arange(xf.shape[0], dtype=jnp.int32)
        if row0 is not None:
            rid = rid + row0
        rid = rid.astype(jnp.uint32)[:, None]
        kk = (jnp.arange(D, dtype=jnp.int32) if coords is None else coords)
        if kind in _wire.LEVELS and coords is not None:
            scales = _wire.leaf_scales_at(
                xf, kk, _wire.n_chunks(D), kind, axes
            )
            return _wire.quantize(
                xf, kind, sl, rid, kk, scales, kk // _wire.CHUNK
            )
        return _wire.quantize(xf, kind, sl, rid, kk)

    return quant


def _down_quant(kind, seed, li, D, coords=None, axes=()):
    """The DownCom broadcast quantizer (LoCoDL-style bidirectional
    compression): ONE shared quantization of ``x_bar`` per leaf — a
    pseudo row id keys the draw, independent of every uplink row — so
    all clients apply the same ``Q(x_bar)`` and the control-variate
    invariant holds with ``x_bar`` replaced by ``Q(x_bar)``."""
    if kind == "f32":
        return None
    sl = _wire.fold_seed(seed, li)

    def quant(xb):
        x2 = xb[None, :]
        rid = jnp.full((1, 1), _wire.DOWN_ROW, jnp.uint32)
        kk = (jnp.arange(D, dtype=jnp.int32) if coords is None else coords)
        if kind in _wire.LEVELS and coords is not None:
            scales = _wire.leaf_scales_at(
                x2, kk, _wire.n_chunks(D), kind, axes
            )
            return _wire.quantize(
                x2, kind, sl, rid, kk, scales, kk // _wire.CHUNK
            )[0]
        return _wire.quantize(x2, kind, sl, rid, kk)[0]

    return quant


def _make_xbar_tx(offsets, ldims, gdims, idxs, kinds, seed,
                  coords=None, axes=None):
    """Workspace-level DownCom quantizer: split the flat ``x_bar`` at the
    packed leaf offsets, quantize each leaf with its own kind/seed, and
    re-concatenate.  ``ldims`` are the packed (local) dims, ``gdims`` the
    global leaf dims the chunk layout follows."""
    def tx(xb):
        parts = []
        for j, i in enumerate(idxs):
            dq = _down_quant(
                kinds[i], seed, i, gdims[j],
                None if coords is None else coords[j],
                () if axes is None else axes[j],
            )
            seg = xb[offsets[j]:offsets[j] + ldims[j]]
            parts.append(seg if dq is None else dq(seg))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return tx


@functools.lru_cache(maxsize=None)
def _wire_chunkcol_np(dims: Tuple[int, ...]) -> np.ndarray:
    """Packed-workspace scale-column table: per coordinate, the column of
    the concatenated per-leaf chunk-scale array its dequant reads."""
    parts, off = [], 0
    for D in dims:
        parts.append(np.arange(D, dtype=np.int64) // _wire.CHUNK + off)
        off += _wire.n_chunks(D)
    return (np.concatenate(parts) if parts
            else np.zeros((0,), np.int64)).astype(np.int32)


def _wire_pack(flats, leaf_ids, gdims, kind, seed, row0=None,
               coords=None, axes=None):
    """Pack one kind-group's wire payload from per-leaf f32 ``(rows, D)``
    matrices.  Float kinds: one narrow-dtype lane buffer (scales/chunk
    table None).  Int kinds: ``(rows, d)`` int8 codes + ``(rows,
    nchunk_total)`` scales + the ``(d,)`` scale-column table.  ``gdims``
    are the GLOBAL leaf dims (the chunk layout); ``coords``/``axes``
    handle model-sharded blocks under the shard engine."""
    if kind in ("bf16", "f16"):
        vals = [_wire.narrow(f, kind) for f in flats]
        w = vals[0] if len(vals) == 1 else jnp.concatenate(vals, axis=1)
        return w, None, None
    codes_l, scales_l, chunk_l = [], [], []
    for j, f in enumerate(flats):
        D = gdims[j]
        sl = _wire.fold_seed(seed, leaf_ids[j])
        rid = jnp.arange(f.shape[0], dtype=jnp.int32)
        if row0 is not None:
            rid = rid + row0
        rid = rid.astype(jnp.uint32)[:, None]
        kk = None if coords is None else coords[j]
        if kk is None:
            kk = jnp.arange(D, dtype=jnp.int32)
            scales = _wire.leaf_scales(f, kind)
        else:
            scales = _wire.leaf_scales_at(
                f, kk, _wire.n_chunks(D), kind,
                () if axes is None else axes[j],
            )
        cc = kk // _wire.CHUNK
        q, sc = _wire.quantize_to_int(f, kind, sl, rid, kk, scales, cc)
        codes_l.append(q)
        scales_l.append(sc)
        chunk_l.append(cc)
    static = coords is None or all(k is None for k in coords)
    if static:
        chunkcol = jnp.asarray(_wire_chunkcol_np(tuple(gdims)))
    else:
        off = np.cumsum([0] + [_wire.n_chunks(D) for D in gdims])[:-1]
        chunkcol = jnp.concatenate([
            c + jnp.int32(int(o)) for c, o in zip(chunk_l, off)
        ])
    codes = (codes_l[0] if len(codes_l) == 1
             else jnp.concatenate(codes_l, axis=1))
    scales = (scales_l[0] if len(scales_l) == 1
              else jnp.concatenate(scales_l, axis=1))
    return codes, scales, chunkcol


# --------------------------------------------------------------------------
# dense per-leaf reference (the old comm-step math, kept as ground truth)
# --------------------------------------------------------------------------


def _dense_blocked_leaf(xl, hl, slot, m: int, s: int, scale, down=None,
                        sanitize=False, survivor=False, quant=None,
                        down_quant=None, robust=None):
    """One leaf of the dense-mask blocked reference: materialized
    ``(n, D)`` ownership (``(slot_i + block(k)) mod m < s``, the shifted
    blocked template over the ``m`` cohort slots — under full
    participation ``slot_i = (-(i + off)) mod n`` recovers the original
    ``(block(k) - i - off) mod n < s``; idle rows ``slot = -1`` own
    nothing), masked sum over all client rows, 1/s rebuild, masked
    h-update, DownCom.  ``sanitize`` zeroes idle rows before the
    multiply-mask math (this path multiplies by ``qf`` instead of
    selecting, and ``NaN * 0 = NaN`` — a dropped client's corrupted
    payload would otherwise poison x_bar); ``survivor`` switches to the
    per-coordinate arrived-owner-count rebuild.  ``quant`` quantizes the
    UpCom payload (after the sanitize zeroing; h reads the raw rows) and
    ``down_quant`` the rebuilt broadcast — see the wire section above."""
    n = xl.shape[0]
    D = int(np.prod(xl.shape[1:]))
    band = jnp.asarray(_block_leaf_band_np(D, m))[None, :]  # (1, D)
    sl = slot[:, None]
    qf = ((sl >= 0) & (((sl + band) % m) < s)).astype(jnp.float32)
    xf = xl.reshape(n, D).astype(jnp.float32)
    if sanitize:
        xf = jnp.where(sl >= 0, xf, 0.0)
    xq = xf if quant is None else quant(xf)
    if robust is not None:
        # robust combine over the dense owner stack: the (n, D) mask IS
        # the validity mask (robust stats on dequantized values, §13)
        x_bar, rcnt = _robust.robust_combine_stack(xq, qf > 0, *robust)
        covered = (rcnt > 0) if survivor else None
    elif survivor:
        x_bar, covered = _survivor_bar((xq * qf).sum(axis=0),
                                       qf.sum(axis=0))
    else:
        x_bar, covered = (xq * qf).sum(axis=0) / s, None
    if down_quant is not None:
        x_bar = down_quant(x_bar)
    h_new = hl.reshape(n, D).astype(jnp.float32) + scale * qf * (
        x_bar[None] - xf
    )
    return (
        _downcom(xl, x_bar, down, covered),
        h_new.astype(hl.dtype).reshape(hl.shape),
    )


def _dense_cyclic_leaf(xl, hl, slot, c: int, s: int, scale, down=None,
                       sanitize=False, survivor=False, quant=None,
                       down_quant=None, robust=None):
    """One leaf of the reference masked_psum comm step: materialized
    ``(n, D)`` mask (both template regimes of paper Fig. 1), masked sum,
    1/s rebuild, masked h-update, broadcast.  The mask is derived from the
    property-tested ``masks.mask_from_permutation`` (identity permutation:
    ``slot`` already IS the template column), so this ground truth never
    drifts from the algorithm spec the fused paths are tested against.
    ``sanitize``/``survivor``/``quant``/``down_quant``: see
    ``_dense_blocked_leaf``."""
    from repro.core import masks  # jax/np only; no x64 side effect

    n = xl.shape[0]
    D = int(np.prod(xl.shape[1:]))
    sl = slot[:, None]
    q = masks.mask_from_permutation(
        jnp.arange(c, dtype=jnp.int32), D, c, s
    ).astype(bool)  # (D, c) template
    qf = (
        q.T[jnp.clip(slot, 0)] & (sl >= 0) & (sl < c)
    ).astype(jnp.float32)
    xf = xl.reshape(n, D).astype(jnp.float32)
    if sanitize:
        xf = jnp.where(sl >= 0, xf, 0.0)
    xq = xf if quant is None else quant(xf)
    if robust is not None:
        # robust combine over the dense owner stack: the (n, D) mask IS
        # the validity mask (robust stats on dequantized values, §13)
        x_bar, rcnt = _robust.robust_combine_stack(xq, qf > 0, *robust)
        covered = (rcnt > 0) if survivor else None
    elif survivor:
        x_bar, covered = _survivor_bar((xq * qf).sum(axis=0),
                                       qf.sum(axis=0))
    else:
        x_bar, covered = (xq * qf).sum(axis=0) / s, None
    if down_quant is not None:
        x_bar = down_quant(x_bar)
    h_new = hl.reshape(n, D).astype(jnp.float32) + scale * qf * (
        x_bar[None] - xf
    )
    return (
        _downcom(xl, x_bar, down, covered),
        h_new.astype(hl.dtype).reshape(hl.shape),
    )


# --------------------------------------------------------------------------
# the sparse fused path (impl="ws")
# --------------------------------------------------------------------------


def _wrapped_lt(diff, m: int, s: int):
    """Branch-free ``diff mod m < s`` for ``diff in (-m, m)``: integer mod
    lowers to a hardware divide per element on CPU; two compares don't."""
    return ((diff >= 0) & (diff < s)) | (diff < s - m)


def _wrapped_owned(slot2, band, m: int, s: int):
    """Kernel-convention ownership ``(slot + band) mod m < s`` as two
    compares (no per-element integer divide), idle rows (``slot < 0``)
    excluded.  ``slot2`` broadcasts against ``band``; both in ``[0, m)``."""
    sb = slot2 + band
    return (slot2 >= 0) & (slot2 < m) & (
        (sb < s) | ((sb >= m) & (sb < m + s))
    )


def _downcom(xl, x_bar, down, covered=None):
    """DownCom of one leaf: ``down`` rows (all when None) receive
    ``x_bar`` in storage dtype; every other row keeps its ``x``
    bit-exactly (idle clients under elastic PP, DESIGN.md §11).
    ``covered`` additionally gates per coordinate: columns with no
    arrived owner keep their ``x`` bit-exactly (§12)."""
    n = xl.shape[0]
    D = x_bar.shape[0]
    bar = x_bar.astype(xl.dtype)[None]
    if covered is None:
        if down is None:
            return jnp.broadcast_to(bar, (n, D)).reshape(xl.shape)
        return jnp.where(
            down[:, None], bar, xl.reshape(n, D)
        ).reshape(xl.shape)
    dm = (jnp.ones((n, 1), bool) if down is None else down[:, None])
    return jnp.where(
        dm & covered[None, :], bar, xl.reshape(n, D)
    ).reshape(xl.shape)


def _finish_leaf(xl, hl, xf, x_bar, owned, scale, down=None, covered=None):
    """The fused h-update + DownCom shared by both uplinks: reads x, h
    once, writes h_new and x_new — ownership is the branch-free predicate
    evaluated inside the fusion, ``down`` the DownCom row mask,
    ``covered`` the survivor-aware per-coordinate DownCom gate (the
    h-update needs no gate: an uncovered coordinate has no arrived owner,
    so ``owned`` is already false on every row there)."""
    n = xl.shape[0]
    D = xf.shape[1]
    h_new = hl.reshape(n, D).astype(jnp.float32) + scale * jnp.where(
        owned, x_bar[None] - xf, 0.0
    )
    return (
        _downcom(xl, x_bar, down, covered),
        h_new.astype(hl.dtype).reshape(hl.shape),
    )


def _survivor_bar(num, cnt):
    """``x_bar = num / max(cnt, 1)`` + the covered mask: the per-
    coordinate 1/(arrived owner count) rebuild.  Under zero drops
    ``cnt == s`` everywhere, so the division is bit-identical to the
    static ``num / s``."""
    return num / jnp.maximum(cnt, 1.0), cnt > 0


def _pallas_comm(xw, hw, slot, band, m: int, s: int, scale, block: int,
                 down=None, survivor=False, wire_x=None, wire_scales=None,
                 wire_chunk=None, xbar_tx=None, robust=None):
    from repro.kernels import compress as _compress
    from repro.kernels import uplink  # lazy: keep dist importable w/o pallas

    def _msum(counts):
        # wire lanes: int codes dequantize in-tile against their chunk
        # scales; narrow float lanes cast per tile — either way the
        # accumulation (and the psum shape upstream) stays f32
        if wire_scales is not None:
            return uplink.masked_sum_dequant(
                wire_x, wire_scales, wire_chunk, slot, band, m, s,
                counts=counts, block=block,
            )
        xin = xw if wire_x is None else wire_x
        return uplink.masked_sum(
            xin, slot, band, m, s, counts=counts, block=block
        )

    if robust is not None:
        # robust stats run on DEQUANTIZED values (§13 rule): int-wire
        # codes expand through the shared dequant before the kernel;
        # narrow float lanes just cast — order statistics are per value,
        # so there is no in-tile accumulation to keep quantized
        if wire_scales is not None:
            xin = _compress.wire_dequant(wire_x, wire_scales, wire_chunk)
        else:
            xin = xw if wire_x is None else wire_x.astype(jnp.float32)
        x_bar, rcnt = uplink.robust_sum(
            xin, slot, band, m, s, kind=robust[0], k=robust[1],
            block=block,
        )
        covered = (rcnt > 0) if survivor else None
    elif survivor:
        num, cnt = _msum(True)
        # survivor rebuild AFTER dequantization: PR 6 semantics unchanged
        x_bar, covered = _survivor_bar(num, cnt)
    else:
        x_bar, covered = _msum(False), None
    if xbar_tx is not None:
        x_bar = xbar_tx(x_bar)
    h_new, x_new = uplink.h_update(
        xw, hw, x_bar, slot, band, m, s, float(scale), down=down,
        covered=covered, block=block,
    )
    return x_bar, h_new, x_new


# --------------------------------------------------------------------------
# the shard-resident engine (impl="pallas", meshed=True — DESIGN.md §10)
# --------------------------------------------------------------------------


def _use_shard_kernels(flag: Optional[bool]) -> bool:
    """None -> Pallas kernels per shard on TPU, fused-jnp sparse gathers
    elsewhere (interpret-mode kernels unroll the grid on CPU — a
    correctness path the tests force, not the production one)."""
    if flag is None:
        return jax.default_backend() == "tpu"
    return bool(flag)


def _leaf_trail_specs(xflat: Sequence[jax.Array], pspecs) -> List[tuple]:
    """Per-leaf trailing-dim PartitionSpec entries (client entry dropped,
    right-padded with None to the leaf rank).  ``pspecs=None`` means only
    the client axis is split (generic stacked trees)."""
    from jax.sharding import PartitionSpec as P

    if pspecs is None:
        return [(None,) * (a.ndim - 1) for a in xflat]
    specs = jax.tree.leaves(pspecs, is_leaf=lambda sp: isinstance(sp, P))
    out = []
    for a, sp in zip(xflat, specs):
        tr = tuple(sp)[1:]
        out.append(tr + (None,) * (a.ndim - 1 - len(tr)))
    return out


def _shard_coords(local_trail: tuple, global_trail: tuple, entries: tuple,
                  mesh):
    """Global flat coordinate index ((d_local,) int32, row-major over the
    GLOBAL trailing dims) of the executing shard's block of one leaf —
    or None when the block IS the whole leaf (static tables apply).  The
    per-dim offsets come from the mesh axis indices of the dims'
    PartitionSpec entries, so model-parallel leaves get the right bands.
    Only valid inside ``shard_map``."""
    from repro.dist import sharding as _shr

    if tuple(local_trail) == tuple(global_trail):
        return None
    strides, acc = [], 1
    for g in reversed(global_trail):
        strides.append(acc)
        acc *= int(g)
    strides.reverse()
    k = None
    for d, (loc, st, entry) in enumerate(
            zip(local_trail, strides, entries)):
        off = jnp.int32(0)
        for name in _shr.spec_dim_axes(entry):
            off = off * mesh.shape[name] + jax.lax.axis_index(name)
        idx = (jax.lax.iota(jnp.int32, loc) + off * loc) * jnp.int32(st)
        shape = [1] * len(local_trail)
        shape[d] = loc
        idx = idx.reshape(shape)
        k = idx if k is None else k + idx
    return jnp.broadcast_to(k, tuple(local_trail)).reshape(-1)


def _shard_comm(
    x: Any,
    h: Any,
    slot: jax.Array,  # (n,) int32 owner column per client; -1 = idle
    m: int,  # template modulus: c (the cohort size; == n at full PP)
    s: int,
    scale,
    *,
    template: str,  # "cyclic" | "blocked"
    mesh,
    pspecs,  # pytree of PartitionSpec matching x (None: client split only)
    block: int,
    use_kernels: Optional[bool],
    down: Optional[jax.Array] = None,  # (n,) DownCom rows; None = all
    faulted: bool = False,  # an arrival mask was applied to ``slot``
    survivor: bool = False,  # per-coordinate arrived-owner-count rebuild
    wire: Optional[str] = None,  # wire policy; None/"f32" = f32 lanes
    wire_seed=None,  # uint32 round seed for the stochastic draws
    wire_down: bool = False,  # quantize the DownCom broadcast too
    robust: Optional[Tuple[str, int]] = None,  # normalized robust spec
) -> Tuple[Any, Any]:
    """The shard-resident comm step: one ``shard_map`` over the dp axes.

    Per shard: UpCom partials over the LOCAL client rows only — Pallas
    ``masked_sum`` on the per-shard workspace (TPU), or fused jnp off-TPU
    (coarse whole-chunk gathers for the blocked template's contiguous
    ownership, masked local-row sums for the cyclic one — see
    ``local_partial`` for the measured why) — then the shards combine
    with d-sized ``psum``s of the ``1/s``-folded partials (one for the
    packed kernel workspace; per leaf on the jnp path — measured, see the
    body comment), and ``h_update`` + the DownCom broadcast run per shard
    on local rows.  No ``(n, d)``-sized collective appears at any point
    (HLO-regression-tested); the client axis is padded to the dp extent
    with idle rows when it does not divide.

    ``robust`` (a normalized ``robust.normalize_robust`` spec) switches
    the UpCom from the 1/s (or survivor) partial-sum rebuild to a
    per-coordinate robust combine.  Order statistics do not decompose
    over shards, so the partial-sum psum is replaced by an
    ``(s, d_local)``-bounded owner-value exchange: each shard gathers
    the owner rows it hosts into the stack (zeros elsewhere), ONE psum
    of the stack assembles all ``s`` owner values per coordinate on
    every shard — bounded by ``s``, never ``(n, d)`` — and the combine
    runs in jnp per shard (kernel grouping is disabled for robust
    leaves; the HLO regression test pins the collective bound)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.dist import sharding as _shr

    xflat, treedef = jax.tree.flatten(x)
    hflat = jax.tree.leaves(h)
    n = int(xflat[0].shape[0])
    dp_names = _shr.dp_axis_names(mesh)
    dp = _shr.dp_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp_names] or [1]))
    kernels = _use_shard_kernels(use_kernels)
    trail = _leaf_trail_specs(xflat, pspecs)

    # column -> owner client row, built on the GLOBAL slot and replicated
    # into every shard (tiny).  Cyclic: every template column in [0, c)
    # has exactly one cohort owner.  Blocked: slot is a permutation of
    # [0, c) over the COHORT rows (idle rows -1), and the owner of block
    # j at shift t is the client whose slot equals (t - j) mod c.
    client_of = (
        jnp.zeros((m + 1,), jnp.int32)
        .at[jnp.where(slot >= 0, slot, m)]
        .set(jnp.arange(n, dtype=jnp.int32))[:m]
    )
    # under faults a dropped owner's column has NO live row, but
    # client_of defaults it to row 0 — col_ok marks the live columns so
    # the coarse per-block gathers can gate the phantom contribution
    # (the predicate-based paths need no gate: slot -1 owns nothing)
    col_ok = None
    if faulted:
        col_ok = (
            jnp.zeros((m + 1,), bool)
            .at[jnp.where(slot >= 0, slot, m)]
            .set(True)[:m]
        )

    # pad the client axis to the dp extent: padded rows are idle (slot -1,
    # zero state) — never owners, never owned — and sliced off after.
    # jnp.pad, NOT jnp.concatenate: on this jax, GSPMD reshards a concat
    # feeding a shard_map via a dynamic-update-slice + all-reduce over ALL
    # mesh axes, writing each block once per model replica and
    # double-counting the state (measured; pad lowers clean).
    pad = (-n) % dp_total
    dwn = (jnp.ones((n,), bool) if down is None
           else jnp.asarray(down).astype(bool))
    if pad:
        xflat = [
            jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
            for a in xflat
        ]
        hflat = [
            jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
            for a in hflat
        ]
        slot = jnp.pad(slot, (0, pad), constant_values=-1)
        dwn = jnp.pad(dwn, (0, pad), constant_values=False)
    rows = (n + pad) // dp_total

    # global trailing dims per leaf (the inputs to shard_map are global;
    # inside the body the blocks are these divided by the split factors)
    gtrail = [tuple(int(d) for d in a.shape[1:]) for a in xflat]
    gD = [int(np.prod(g)) if g else 1 for g in gtrail]
    tall = [template == "cyclic" and D * s < m for D in gD]

    # the wire policy resolves on the GLOBAL leaf dims — the same kinds
    # every unsharded impl resolves, so quantized values agree bitwise
    wirep = _wire_policy(wire)
    wseed = _wire_seed(wire_seed) if wirep is not None else None
    wdown = bool(wire_down) and wirep is not None
    kinds = [
        _wire.resolve_kind(D, wirep) if wirep is not None else "f32"
        for D in gD
    ]

    def _leaf_axes(i):
        names = []
        for entry in trail[i]:
            names.extend(_shr.spec_dim_axes(entry))
        return tuple(names)

    leaf_specs = tuple(P(dp, *tr) for tr in trail)

    def _leaf_band(i, k_arr):
        """Per-coordinate kernel-convention band of leaf i's shard block:
        static np table when the block is the whole leaf, recomputed from
        the global coordinate index when model-sharded.  Shared by the
        jnp ownership predicate AND the kernel operands — the single
        source of the band formula per template."""
        D = gD[i]
        if template == "blocked":
            if k_arr is None:
                return jnp.asarray(_block_leaf_band_np(D, m))
            return k_arr // (-(-D // m))
        if k_arr is None:
            return jnp.asarray(_cyclic_band_np((D,), m, s))
        return (-(s * (k_arr % m))) % m

    def _owned(i, k_arr, sl2):
        """Local-row ownership predicate (n_loc, d_loc), branch-free: two
        compares against the leaf band.  NOTE the off-mesh ws path's
        repeat-expanded block predicate is NOT used here: ``jnp.repeat``
        inside shard_map lowers pathologically on CPU (measured ~10x the
        whole comm step; the band-compare form is flat)."""
        D = gD[i]
        if tall[i]:
            kk = (jnp.asarray(np.arange(D, dtype=np.int32))
                  if k_arr is None else k_arr)
            return (sl2 >= 0) & (sl2 < D * s) & (sl2 % D == kk[None, :])
        return _wrapped_owned(sl2, _leaf_band(i, k_arr)[None, :], m, s)

    def body(xs, hs, sl, cof, *rest):
        cok, dw = rest if faulted else (None, rest[0])
        row0 = _shr.dp_shard_index(mesh) * rows
        sl2 = sl[:, None]
        coords = [
            _shard_coords(tuple(a.shape[1:]), gtrail[i], trail[i], mesh)
            for i, a in enumerate(xs)
        ]
        xfs = [a.reshape(rows, -1).astype(jnp.float32) for a in xs]
        # quantized UpCom payloads (local rows, global row ids/coords —
        # bitwise the unsharded impls' rows).  Unused entries (f32 leaves,
        # kernel-covered leaves packing their own codes) are dead code XLA
        # drops; h/DownCom keep reading the raw xfs.
        xqs = list(xfs)
        if wirep is not None:
            for i in range(len(xs)):
                q = _leaf_quant(
                    kinds[i], wseed, i, gD[i], row0=row0,
                    coords=coords[i], axes=_leaf_axes(i),
                )
                if q is not None:
                    xqs[i] = q(xfs[i])

        def local_partial(i, counts=False):
            """This shard's UpCom partial, 1/s folded in (``counts=True``,
            the survivor path: raw sum + per-coordinate count of LOCALLY
            resident arrived owners — each owner lives on exactly one
            shard, so the psum'd counts are the global arrived-owner
            counts).

            Blocked template on an unsharded leaf with more local rows
            than shifts: ownership contiguity means block j's owners at
            the s shifts are whole-chunk reads, so the partial is s
            coarse (block, chunk) gathers over the LOCAL rows — O(s d)
            reads vs the masked form's O(rows d), a measured 2x at
            n=32 on the host mesh (at rows < s the masked form reads
            less and wins, so the gate is static).  Everything else:
            masked local-row sum with the fused ownership predicate —
            per-element row-gathers lower pathologically inside shard_map
            on CPU (measured 12x slower than the same gather outside),
            and per shard the row count is tiny, so the masked form IS
            the cheap one; on TPU the Pallas kernels cover these leaves
            instead.
            """
            xf = xqs[i]  # the wire payload (== xfs[i] on the f32 path)
            if (template == "blocked" and coords[i] is None
                    and rows >= s):
                D = gD[i]
                chunk = -(-D // m)
                nf, tailn = divmod(D, chunk)
                xm = xf[:, :nf * chunk].reshape(rows, nf, chunk)
                jf = np.arange(nf, dtype=np.int32)
                accm = jnp.zeros((nf, chunk), jnp.float32)
                acct = jnp.zeros((tailn,), jnp.float32)
                cntm = jnp.zeros((nf,), jnp.float32)
                cntt = jnp.zeros((), jnp.float32)
                for t in range(s):
                    # owner of block j at shift t: the client whose slot
                    # is (t - j) mod n — local rows contribute, the rest
                    # land on their own shards
                    own = cof[jnp.asarray((t - jf) % m)]
                    loc = (own >= row0) & (own < row0 + rows)
                    if cok is not None:
                        loc = loc & cok[jnp.asarray((t - jf) % m)]
                    rr = jnp.clip(own - row0, 0, rows - 1)
                    accm = accm + jnp.where(loc[:, None], xm[rr, jf], 0.0)
                    if counts:
                        cntm = cntm + loc.astype(jnp.float32)
                    if tailn:
                        ot = cof[(t - nf) % m]
                        lt = (ot >= row0) & (ot < row0 + rows)
                        if cok is not None:
                            lt = lt & cok[(t - nf) % m]
                        rt = jnp.clip(ot - row0, 0, rows - 1)
                        acct = acct + jnp.where(lt, xf[rt, nf * chunk:], 0.0)
                        if counts:
                            cntt = cntt + lt.astype(jnp.float32)
                flat = (jnp.concatenate([accm.reshape(-1), acct])
                        if tailn else accm.reshape(-1))
                if counts:
                    cnt = jnp.repeat(cntm, chunk)
                    cnt = (jnp.concatenate(
                        [cnt, jnp.broadcast_to(cntt, (tailn,))])
                        if tailn else cnt)
                    return flat, cnt
                return flat / s
            # predicate recomputed here AND in the finish (not cached):
            # sharing it across the psum boundary forces XLA to
            # materialize a (rows, d) pred buffer; recomputed, it stays
            # two compares inside each fusion (what the ws path does)
            owned_loc = _owned(i, coords[i], sl2)
            num = jnp.where(owned_loc, xf, 0.0).sum(axis=0)
            if counts:
                return num, owned_loc.astype(jnp.float32).sum(axis=0)
            return num / s

        def _psum(v):
            return jax.lax.psum(v, dp_names) if dp_names else v

        # Per-shard UpCom partials -> d-sized psums.  The kernel path's
        # partial is the packed workspace's masked_sum output — already
        # one flat vector, ONE psum.  The jnp leaves psum per leaf:
        # concatenating them into a single flat psum measured ~5x slower
        # on CPU (the concat write + per-leaf slice reads break XLA's
        # leafwise fusion); per-leaf psums keep each leaf's partial,
        # combine, and finish in one fused pipeline, and XLA's collective
        # combiner can still merge the all-reduces on real backends.
        out_x: List[Any] = [None] * len(xs)
        out_h: List[Any] = [None] * len(xs)
        # robust leaves always take the jnp owner-value exchange: the
        # kernel masked_sum psums a PARTIAL sum, but order statistics
        # need the full owner stack on every shard
        covered = [i for i in range(len(xs))
                   if robust is None and kernels and not tall[i]]
        rest = [i for i in range(len(xs)) if i not in covered]
        if covered:
            from repro.kernels import uplink

            # one workspace (and one d-sized psum) per wire kind: the f32
            # path is a single group taking the PR 6 code verbatim; under
            # "auto" at most two (f16 + int8)
            if wirep is None:
                groups = [(None, covered)]
            else:
                gmap: dict = {}
                for i in covered:
                    gmap.setdefault(kinds[i], []).append(i)
                groups = sorted(gmap.items())
            for gkind, idxs in groups:
                gdims = [gD[i] for i in idxs]
                spec = workspace_spec([xs[i] for i in idxs],
                                      rows_total=n + pad, wire=wirep,
                                      wire_dims=gdims)
                hspec = workspace_spec([hs[i] for i in idxs],
                                       rows_total=n + pad)
                xw = pack([xs[i] for i in idxs], spec)
                hw = pack([hs[i] for i in idxs], hspec)
                band_parts = [_leaf_band(i, coords[i]) for i in idxs]
                band_ws = (band_parts[0] if len(band_parts) == 1
                           else jnp.concatenate(band_parts))
                wx = wsc = wcc = tx = None
                if gkind is not None:
                    flats = [xw[:, o:o + D]
                             for o, D in zip(spec.offsets, spec.dims)]
                    wx, wsc, wcc = _wire_pack(
                        flats, idxs, gdims, gkind, wseed, row0=row0,
                        coords=[coords[i] for i in idxs],
                        axes=[_leaf_axes(i) for i in idxs],
                    )
                if wdown:
                    tx = _make_xbar_tx(
                        spec.offsets, spec.dims, gdims, idxs, kinds,
                        wseed, coords=[coords[i] for i in idxs],
                        axes=[_leaf_axes(i) for i in idxs],
                    )

                def _msum(counts, _xw=xw, _wx=wx, _wsc=wsc, _wcc=wcc,
                          _band=band_ws):
                    if _wsc is not None:
                        return uplink.masked_sum_dequant(
                            _wx, _wsc, _wcc, sl, _band, m, s,
                            counts=counts, block=block,
                        )
                    xin = _xw if _wx is None else _wx
                    return uplink.masked_sum(
                        xin, sl, _band, m, s, counts=counts, block=block
                    )

                if survivor:
                    num_ws, cnt_ws = _msum(True)
                    xbar_ws, cov_ws = _survivor_bar(
                        _psum(num_ws), _psum(cnt_ws)
                    )
                    if tx is not None:
                        xbar_ws = tx(xbar_ws)
                    h_new_ws, x_new_ws = uplink.h_update(
                        xw, hw, xbar_ws, sl, band_ws, m, s, float(scale),
                        down=dw, covered=cov_ws, block=block,
                    )
                else:
                    xbar_ws = _psum(_msum(False))
                    if tx is not None:
                        xbar_ws = tx(xbar_ws)
                    h_new_ws, x_new_ws = uplink.h_update(
                        xw, hw, xbar_ws, sl, band_ws, m, s, float(scale),
                        down=dw, block=block,
                    )
                xs_un = unpack(x_new_ws, spec)
                hs_un = unpack(h_new_ws, hspec)
                for j, i in enumerate(idxs):
                    out_x[i], out_h[i] = xs_un[j], hs_un[j]
        for i in rest:
            if robust is not None:
                # the (s, d_local)-bounded owner-value exchange: owner
                # columns derive from the band ((t - band) mod m owns
                # coordinate k at shift t — the inverse of the shared
                # (slot + band) mod m < s predicate), each shard fills
                # the stack rows whose owner it hosts, and ONE psum of
                # the (s, d_local) stack replicates all owner values —
                # never an (n, d)-sized collective
                xf = xqs[i]
                if tall[i]:
                    kk = (jnp.asarray(np.arange(gD[i], dtype=np.int32))
                          if coords[i] is None else coords[i])
                    colz = jnp.stack(
                        [kk + t * gD[i] for t in range(s)])
                else:
                    bd = _leaf_band(i, coords[i])
                    colz = jnp.stack([(t - bd) % m for t in range(s)])
                own = cof[colz]  # (s, d_local) global owner row
                okm = (jnp.ones(colz.shape, bool) if cok is None
                       else cok[colz])
                loc = (own >= row0) & (own < row0 + rows) & okm
                rr = jnp.clip(own - row0, 0, rows - 1)
                stack = jnp.where(
                    loc, jnp.take_along_axis(xf, rr, axis=0), 0.0)
                stack = _psum(stack)
                x_bar, rcnt = _robust.robust_combine_stack(
                    stack, okm, *robust)
                cov = (rcnt > 0) if survivor else None
            elif survivor:
                num, cnt = local_partial(i, counts=True)
                x_bar, cov = _survivor_bar(_psum(num), _psum(cnt))
            else:
                x_bar, cov = _psum(local_partial(i)), None
            if wdown:
                x_bar = _down_quant(
                    kinds[i], wseed, i, gD[i], coords[i], _leaf_axes(i)
                )(x_bar)
            out_x[i], out_h[i] = _finish_leaf(
                xs[i], hs[i], xfs[i], x_bar, _owned(i, coords[i], sl2),
                scale, dw, cov,
            )
        return tuple(out_x), tuple(out_h)

    if faulted:
        in_specs = (leaf_specs, leaf_specs, P(dp), P(), P(), P(dp))
        operands = (tuple(xflat), tuple(hflat), slot, client_of, col_ok,
                    dwn)
    else:
        in_specs = (leaf_specs, leaf_specs, P(dp), P(), P(dp))
        operands = (tuple(xflat), tuple(hflat), slot, client_of, dwn)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(leaf_specs, leaf_specs),
        check_rep=False,
    )
    xs_out, hs_out = fn(*operands)
    if pad:
        xs_out = [a[:n] for a in xs_out]
        hs_out = [a[:n] for a in hs_out]
    return (
        jax.tree.unflatten(treedef, list(xs_out)),
        jax.tree.unflatten(treedef, list(hs_out)),
    )


def cyclic_comm(
    x: Any,
    h: Any,
    slot: jax.Array,  # (n,) int32 template column per client; -1 = idle
    c: int,
    s: int,
    scale,
    impl: str = "ws",
    *,
    down: Optional[jax.Array] = None,
    arrived: Optional[jax.Array] = None,
    correct: bool = True,
    block: int = 4096,
    meshed: bool = False,
    mesh=None,
    pspecs=None,
    shard_kernels: Optional[bool] = None,
    wire: Optional[str] = None,
    wire_seed=None,
    wire_down: bool = False,
    robust: Optional[Tuple[str, int]] = None,
) -> Tuple[Any, Any]:
    """masked_psum UpCom + h-update + DownCom for the cyclic template.

    Coordinate-identical to the per-leaf dense reference (``impl="dense"``)
    for every leaf and both Fig. 1 template regimes; see the module
    docstring for the three implementations.  ``down`` is the DownCom row
    mask ((n,) bool; None broadcasts to every row) — the elastic engine
    passes the NEXT round's cohort so idle rows stay untouched (§11).
    ``arrived``/``correct`` are the fault-tolerant aggregation inputs
    (§12, module docstring): rows outside ``arrived`` are demoted to idle
    and, with ``correct=True``, the rebuild divides by the per-coordinate
    arrived-owner count with uncovered coordinates left untouched.
    ``meshed=True`` with a ``mesh`` handle and ``impl="pallas"`` runs the
    shard-resident engine (``pspecs``: the stacked state's PartitionSpecs,
    client split only when None; ``shard_kernels``: force/suppress the
    per-shard Pallas kernels, default per backend).

    ``wire`` narrows the UpCom payload per the §13 wire format (policy
    from ``repro.dist.wire``; ``None``/``"f32"`` take the PR 6 code paths
    verbatim), ``wire_seed`` is the round's uint32 quantization seed
    (``wire.round_seed``), and ``wire_down`` additionally quantizes the
    DownCom broadcast.  All four impls quantize the same (row, coord)
    payload with the same counter-hash draw, so they agree to float-sum
    reordering exactly as on the f32 path.

    ``robust`` replaces the arrived-owner mean with a per-coordinate
    robust combine over the owner-value stack (DESIGN.md §15): pass the
    normalized ``robust.normalize_robust(kind, k, s)`` spec — ``None``
    (mean, or trimmed with k=0) runs the existing paths verbatim,
    bitwise.  Robust stats are computed on DEQUANTIZED wire values and
    compose with ``arrived``/``correct`` (uncovered coordinates still
    pass through untouched) and ``down``.
    """
    impl = effective_impl(impl, meshed=meshed, mesh=mesh)
    faulted = arrived is not None
    survivor = faulted and correct
    if faulted:
        slot = jnp.where(
            jnp.asarray(arrived).astype(bool), slot, -1
        ).astype(jnp.int32)
    if impl == "pallas" and meshed:
        return _shard_comm(
            x, h, slot, c, s, scale, template="cyclic", mesh=mesh,
            pspecs=pspecs, block=block, use_kernels=shard_kernels,
            down=down, faulted=faulted, survivor=survivor,
            wire=wire, wire_seed=wire_seed, wire_down=wire_down,
            robust=robust,
        )
    xflat, treedef = jax.tree.flatten(x)
    hflat = jax.tree.leaves(h)
    dims = [int(np.prod(a.shape[1:])) for a in xflat]
    n = xflat[0].shape[0] if xflat else 0
    out_x: List[Any] = [None] * len(xflat)
    out_h: List[Any] = [None] * len(xflat)
    wirep = _wire_policy(wire)
    wseed = _wire_seed(wire_seed) if wirep is not None else None
    wdown = bool(wire_down) and wirep is not None
    kinds = [_wire.resolve_kind(D, wirep) if wirep is not None else "f32"
             for D in dims]

    if impl == "ws":
        client_of = None
        col_ok = None
        if not meshed or robust is not None:
            # column -> client row of this round (idle writes land in the
            # dropped overflow slot; every column has exactly one owner).
            # Robust combines need the owner-value STACK even when the
            # client axis is meshed: the psum-shaped partial sum cannot
            # express an order statistic, so the gather form applies
            # (GSPMD pays gather collectives here; the HLO-gated meshed
            # placement is the shard engine, not this path).
            client_of = (
                jnp.zeros((c + 1,), jnp.int32)
                .at[jnp.where(slot >= 0, slot, c)]
                .set(jnp.arange(n, dtype=jnp.int32))[:c]
            )
            if faulted:
                # columns whose owner dropped default to row 0 in
                # client_of — col_ok gates those phantom gathers
                col_ok = (
                    jnp.zeros((c + 1,), bool)
                    .at[jnp.where(slot >= 0, slot, c)]
                    .set(True)[:c]
                )
        sl = slot[:, None]
        for i, (xl, hl) in enumerate(zip(xflat, hflat)):
            D = dims[i]
            cols, band, tall = _cyclic_leaf_tables_np(D, c, s)
            xf = xl.reshape(n, D).astype(jnp.float32)
            quant = _leaf_quant(kinds[i], wseed, i, D)
            # UpCom reads the wire payload; the h-update below reads the
            # raw rows (core/tamuna.py quantizes the numerator only).
            # Masking is where-select, so quantizing unsanitized idle rows
            # is safe — an owner row's payload is identical in every impl.
            xq = xf if quant is None else quant(xf)
            if tall:
                kj = jnp.arange(D, dtype=jnp.int32)[None, :]
                owned = (sl < D * s) & (sl % D == kj)
            else:
                owned = _wrapped_lt(sl - jnp.asarray(band)[None, :], c, s)
            owned = owned & (sl >= 0)
            if robust is not None:
                if not faulted and not tall:
                    # gather-free owner stack: the cyclic owner column
                    # (s k + t) mod c only depends on k mod c, so stack
                    # row t is a constant-mask select chain over the
                    # slot-ordered rows xq[client_of] — all elementwise,
                    # so the whole combine stays one parallelizable
                    # fusion (an elementwise consumer of the (s, D)
                    # take_along_axis form drags the per-element gather
                    # into a serial loop body and costs ~3x the mean
                    # step at production widths)
                    xs = xq[client_of]  # (c, D) row permutation
                    resid = np.arange(D, dtype=np.int64) % c
                    masks = [resid == r for r in range(c)]
                    stack = []
                    for t in range(s):
                        y = xs[(s * (c - 1) + t) % c]
                        for r in range(c - 2, -1, -1):
                            y = jnp.where(
                                jnp.asarray(masks[r]),
                                xs[(s * r + t) % c], y)
                        stack.append(y)
                    vals = jnp.stack(stack)
                    ok = None
                else:
                    # robust combine over the (s, D) owner-row gather
                    # stack (same gathers the mean path reads; tall
                    # leaves use their explicit owner-column table)
                    rows = client_of[jnp.asarray(cols)]
                    vals = jnp.take_along_axis(xq, rows, axis=0)
                    ok = col_ok[jnp.asarray(cols)] if faulted else None
                x_bar, rcnt = _robust.robust_combine_stack(
                    vals, ok, *robust)
                cov = (rcnt > 0) if survivor else None
            elif meshed:
                # client axis sharded across devices: the owner rows live
                # on other shards, so a gather would all-gather (n, D) --
                # keep the psum shape (a d-sized all-reduce, the minimum)
                # with the predicate fused into the local partial sum
                num = jnp.where(owned, xq, 0.0).sum(axis=0)
                if survivor:
                    x_bar, cov = _survivor_bar(
                        num, owned.astype(jnp.float32).sum(axis=0)
                    )
                else:
                    x_bar, cov = num / s, None
            else:
                # sparse UpCom: s row-gathers + 1/s rebuild, O(s D) reads
                rows = client_of[jnp.asarray(cols)]  # (s, D) owner rows
                vals = jnp.take_along_axis(xq, rows, axis=0)
                if faulted:
                    ok = col_ok[jnp.asarray(cols)]  # (s, D) owner arrived
                    num = jnp.where(ok, vals, 0.0).sum(axis=0)
                    if survivor:
                        x_bar, cov = _survivor_bar(
                            num, ok.astype(jnp.float32).sum(axis=0)
                        )
                    else:
                        x_bar, cov = num / s, None
                else:
                    x_bar, cov = vals.sum(axis=0) / s, None
            if wdown:
                x_bar = _down_quant(kinds[i], wseed, i, D)(x_bar)
            out_x[i], out_h[i] = _finish_leaf(
                xl, hl, xf, x_bar, owned, scale, down, cov
            )
        return (
            jax.tree.unflatten(treedef, out_x),
            jax.tree.unflatten(treedef, out_h),
        )

    if impl == "dense":
        covered: List[int] = []
    else:  # pallas: tall-regime leaves keep the dense closed form
        covered = [i for i, D in enumerate(dims) if D * s >= c]
    fallback = [i for i in range(len(xflat)) if i not in covered]

    for i in fallback:
        out_x[i], out_h[i] = _dense_cyclic_leaf(
            xflat[i], hflat[i], slot, c, s, scale, down,
            sanitize=faulted, survivor=survivor,
            quant=_leaf_quant(kinds[i], wseed, i, dims[i]),
            down_quant=(_down_quant(kinds[i], wseed, i, dims[i])
                        if wdown else None),
            robust=robust,
        )

    if covered:
        # one workspace per wire kind (see _shard_comm): the f32 path is
        # the single group (None, covered) running the PR 6 code verbatim
        if wirep is None:
            groups = [(None, covered)]
        else:
            gmap: dict = {}
            for i in covered:
                gmap.setdefault(kinds[i], []).append(i)
            groups = sorted(gmap.items())
        for gkind, idxs in groups:
            spec = workspace_spec([xflat[i] for i in idxs], wire=wirep)
            hspec = workspace_spec([hflat[i] for i in idxs])
            xw = pack([xflat[i] for i in idxs], spec)
            hw = pack([hflat[i] for i in idxs], hspec)
            band = jnp.asarray(_cyclic_band_np(spec.dims, c, s))
            wx = wsc = wcc = tx = None
            if gkind is not None:
                flats = [xw[:, o:o + D]
                         for o, D in zip(spec.offsets, spec.dims)]
                wx, wsc, wcc = _wire_pack(
                    flats, idxs, list(spec.dims), gkind, wseed
                )
            if wdown:
                tx = _make_xbar_tx(
                    spec.offsets, spec.dims, list(spec.dims), idxs,
                    kinds, wseed,
                )
            _, h_new_ws, x_new_ws = _pallas_comm(
                xw, hw, slot, band, c, s, scale, block, down=down,
                survivor=survivor, wire_x=wx, wire_scales=wsc,
                wire_chunk=wcc, xbar_tx=tx, robust=robust,
            )
            xs = unpack(x_new_ws, spec)
            hs = unpack(h_new_ws, hspec)
            for j, i in enumerate(idxs):
                out_x[i], out_h[i] = xs[j], hs[j]

    return (
        jax.tree.unflatten(treedef, out_x),
        jax.tree.unflatten(treedef, out_h),
    )


def blocked_comm(
    x: Any,
    h: Any,
    off: jax.Array,  # int32 scalar: cyclic shift of the ownership bands
    n: int,
    s: int,
    scale,
    impl: str = "ws",
    *,
    c: Optional[int] = None,
    slot_of: Optional[jax.Array] = None,
    down: Optional[jax.Array] = None,
    arrived: Optional[jax.Array] = None,
    correct: bool = True,
    block: int = 4096,
    meshed: bool = False,
    mesh=None,
    pspecs=None,
    shard_kernels: Optional[bool] = None,
    wire: Optional[str] = None,
    wire_seed=None,
    wire_down: bool = False,
    robust: Optional[Tuple[str, int]] = None,
) -> Tuple[Any, Any]:
    """block_rs UpCom + h-update + DownCom for the blocked template.

    The old per-leaf path padded each leaf to ``(n, n, chunk)`` and
    materialized an ownership-sized delta; the sparse path gathers, per
    block column and shift ``t``, the one client row that owns it (``s``
    rolled adds, ``O(s d)`` reads) and fuses the h-update mask-free.

    ``c``/``slot_of`` generalize the template to partial participation
    (DESIGN.md §11): coordinates are chunked into ``c`` blocks (not
    ``n``) and the contiguous ownership bands are laid over the round's
    cohort *slots* — ``slot_of[i]`` is client ``i``'s slot in ``[0, c)``
    (-1 idle) — so ownership is ``(block(k) - slot_of[i] - off) mod c <
    s``: every coordinate still has exactly ``s`` owners, all of them
    cohort members.  The defaults (``c=None``, ``slot_of=None``) are full
    participation with identity slots, bit-identical to the original
    template.  ``down`` is the DownCom row mask and ``arrived``/
    ``correct`` the fault-tolerant aggregation inputs (see
    ``cyclic_comm``): a dropped owner leaves its block columns uncovered,
    and with ``correct=True`` those coordinates pass through h and x
    bitwise untouched.

    ``meshed=True`` + ``mesh`` + ``impl="pallas"``: the shard-resident
    engine (see ``cyclic_comm``) — the contiguous per-block gathers run on
    each shard's local rows and the block partials combine in one psum,
    the true reduce-scatter decomposition of the blocked uplink.

    ``wire``/``wire_seed``/``wire_down``: the quantized wire (§13); see
    ``cyclic_comm``.  ``robust``: the normalized robust-combiner spec
    (§15); see ``cyclic_comm``.
    """
    impl = effective_impl(impl, meshed=meshed, mesh=mesh)
    off = jnp.asarray(off, jnp.int32)
    m = n if c is None else int(c)
    # fold the shift into per-client slots ((slot + band) mod m < s
    # <=> (band - slot_of - off) mod m < s, the block_uplink closed
    # form; identity slot_of recovers the original (band - i - off))
    if slot_of is None:
        if m != n:
            raise ValueError(
                f"blocked_comm with c={m} < n={n} needs slot_of (the "
                f"per-client cohort slots)"
            )
        slot = (-(jnp.arange(n, dtype=jnp.int32) + off)) % m
    else:
        slot = jnp.where(
            slot_of >= 0, (-(slot_of + off)) % m, -1
        ).astype(jnp.int32)
    faulted = arrived is not None
    survivor = faulted and correct
    if faulted:
        slot = jnp.where(
            jnp.asarray(arrived).astype(bool), slot, -1
        ).astype(jnp.int32)
    if impl == "pallas" and meshed:
        return _shard_comm(
            x, h, slot, m, s, scale, template="blocked", mesh=mesh,
            pspecs=pspecs, block=block, use_kernels=shard_kernels,
            down=down, faulted=faulted, survivor=survivor,
            wire=wire, wire_seed=wire_seed, wire_down=wire_down,
            robust=robust,
        )
    xflat, treedef = jax.tree.flatten(x)
    hflat = jax.tree.leaves(h)
    dims = [int(np.prod(a.shape[1:])) for a in xflat]
    wirep = _wire_policy(wire)
    wseed = _wire_seed(wire_seed) if wirep is not None else None
    wdown = bool(wire_down) and wirep is not None
    kinds = [_wire.resolve_kind(D, wirep) if wirep is not None else "f32"
             for D in dims]

    if impl == "dense":
        pairs = [
            _dense_blocked_leaf(
                xl, hl, slot, m, s, scale, down,
                sanitize=faulted, survivor=survivor,
                quant=_leaf_quant(kinds[i], wseed, i, dims[i]),
                down_quant=(_down_quant(kinds[i], wseed, i, dims[i])
                            if wdown else None),
                robust=robust,
            )
            for i, (xl, hl) in enumerate(zip(xflat, hflat))
        ]
        return (
            jax.tree.unflatten(treedef, [a for a, _ in pairs]),
            jax.tree.unflatten(treedef, [b for _, b in pairs]),
        )

    if impl == "pallas":
        out_x = [None] * len(xflat)
        out_h = [None] * len(xflat)
        if wirep is None:
            groups = [(None, list(range(len(xflat))))]
        else:
            gmap: dict = {}
            for i in range(len(xflat)):
                gmap.setdefault(kinds[i], []).append(i)
            groups = sorted(gmap.items())
        for gkind, idxs in groups:
            spec = workspace_spec([xflat[i] for i in idxs], wire=wirep)
            hspec = workspace_spec([hflat[i] for i in idxs])
            xw = pack([xflat[i] for i in idxs], spec)
            hw = pack([hflat[i] for i in idxs], hspec)
            band = jnp.asarray(_block_band_np(spec.dims, m))
            wx = wsc = wcc = tx = None
            if gkind is not None:
                flats = [xw[:, o:o + D]
                         for o, D in zip(spec.offsets, spec.dims)]
                wx, wsc, wcc = _wire_pack(
                    flats, idxs, list(spec.dims), gkind, wseed
                )
            if wdown:
                tx = _make_xbar_tx(
                    spec.offsets, spec.dims, list(spec.dims), idxs,
                    kinds, wseed,
                )
            _, h_new_ws, x_new_ws = _pallas_comm(
                xw, hw, slot, band, m, s, scale, block, down=down,
                survivor=survivor, wire_x=wx, wire_scales=wsc,
                wire_chunk=wcc, xbar_tx=tx, robust=robust,
            )
            xs = unpack(x_new_ws, spec)
            hs = unpack(h_new_ws, hspec)
            for j, i in enumerate(idxs):
                out_x[i], out_h[i] = xs[j], hs[j]
        return (
            jax.tree.unflatten(treedef, out_x),
            jax.tree.unflatten(treedef, out_h),
        )

    # impl == "ws": s rolled adds (contiguous per-block gathers, no pad)
    # + the fused h-update, leaf by leaf
    client_of = None
    col_ok = None
    if not meshed or robust is not None:
        # block-slot -> owner client row (idle writes land in the dropped
        # overflow slot; cohort slots are a permutation of [0, m)).
        # Robust combines need the owner-value stack even when meshed —
        # see cyclic_comm.
        client_of = (
            jnp.zeros((m + 1,), jnp.int32)
            .at[jnp.where(slot >= 0, slot, m)]
            .set(jnp.arange(n, dtype=jnp.int32))[:m]
        )
        if faulted:
            # dropped owners' slots default to row 0 in client_of —
            # col_ok gates those phantom chunk gathers
            col_ok = (
                jnp.zeros((m + 1,), bool)
                .at[jnp.where(slot >= 0, slot, m)]
                .set(True)[:m]
            )
    sl = slot[:, None]
    out_x: List[Any] = [None] * len(xflat)
    out_h: List[Any] = [None] * len(xflat)
    for i, (xl, hl) in enumerate(zip(xflat, hflat)):
        D = dims[i]
        chunk = -(-D // m)
        nf, tail = divmod(D, chunk)  # full blocks + ragged tail block
        nb = nf + (1 if tail else 0)
        xf = xl.reshape(n, D).astype(jnp.float32)
        quant = _leaf_quant(kinds[i], wseed, i, D)
        xq = xf if quant is None else quant(xf)  # wire payload; h reads xf
        # blocked ownership is block-granular: evaluate the predicate at
        # (n, nb) (tiny) and expand to coordinates with a repeat — beats
        # recomputing an (n, D) predicate (measured, DESIGN.md §9)
        jb = jnp.arange(nb, dtype=jnp.int32)[None, :]
        own_nb = _wrapped_owned(sl, jb, m, s)
        owned = jnp.repeat(own_nb, chunk, axis=1)[:, :D]
        cov = None
        if robust is not None:
            # robust combine over the s contiguous shift-gathers: stack
            # the per-shift owner rows (the same whole-chunk reads the
            # mean path accumulates) instead of summing them
            jf = jnp.arange(nf, dtype=jnp.int32)
            xm = xq[:, :nf * chunk].reshape(n, nf, chunk)
            vals_l, ok_l = [], []
            for t in range(s):
                cf = (t - jf) % m
                v = xm[client_of[cf], jf].reshape(-1)
                okv = (col_ok[cf] if faulted
                       else jnp.ones((nf,), bool))
                okv = jnp.repeat(okv, chunk)
                if tail:
                    ct = (t - nf) % m
                    v = jnp.concatenate(
                        [v, xq[client_of[ct], nf * chunk:]])
                    okt = (col_ok[ct] if faulted else jnp.bool_(True))
                    okv = jnp.concatenate(
                        [okv, jnp.broadcast_to(okt, (tail,))])
                vals_l.append(v)
                ok_l.append(okv)
            x_bar, rcnt = _robust.robust_combine_stack(
                jnp.stack(vals_l), jnp.stack(ok_l), *robust)
            if survivor:
                cov = rcnt > 0
        elif meshed:
            # sharded client axis: keep the d-sized all-reduce shape (see
            # cyclic_comm); the predicate fuses into the partial sum
            num = jnp.where(owned, xq, 0.0).sum(axis=0)
            if survivor:
                x_bar, cov = _survivor_bar(
                    num, owned.astype(jnp.float32).sum(axis=0)
                )
            else:
                x_bar = num / s
        else:
            xm = xq[:, :nf * chunk].reshape(n, nf, chunk)
            jf = jnp.arange(nf, dtype=jnp.int32)
            acc = jnp.zeros((nf, chunk), jnp.float32)
            acc_t = jnp.zeros((tail,), jnp.float32)
            cnt_f = jnp.zeros((nf,), jnp.float32)
            cnt_t = jnp.zeros((), jnp.float32)
            for t in range(s):
                # owner row of block j at shift t: the client whose slot
                # is (t - j) mod m — one contiguous chunk per block, the
                # reduce-scatter shape
                if faulted:
                    ok = col_ok[(t - jf) % m]
                    acc = acc + jnp.where(
                        ok[:, None], xm[client_of[(t - jf) % m], jf], 0.0
                    )
                    cnt_f = cnt_f + ok.astype(jnp.float32)
                else:
                    acc = acc + xm[client_of[(t - jf) % m], jf]
                if tail:
                    if faulted:
                        ok_t = col_ok[(t - nf) % m]
                        acc_t = acc_t + jnp.where(
                            ok_t, xq[client_of[(t - nf) % m],
                                     nf * chunk:], 0.0
                        )
                        cnt_t = cnt_t + ok_t.astype(jnp.float32)
                    else:
                        acc_t = acc_t + xq[client_of[(t - nf) % m],
                                           nf * chunk:]
            num = jnp.concatenate([acc.reshape(-1), acc_t]) \
                if tail else acc.reshape(-1)
            if survivor:
                cnt = jnp.repeat(cnt_f, chunk)
                if tail:
                    cnt = jnp.concatenate(
                        [cnt, jnp.broadcast_to(cnt_t, (tail,))]
                    )
                x_bar, cov = _survivor_bar(num, cnt)
            else:
                x_bar = num / s
        if wdown:
            x_bar = _down_quant(kinds[i], wseed, i, D)(x_bar)
        out_x[i], out_h[i] = _finish_leaf(xl, hl, xf, x_bar, owned, scale,
                                          down, cov)
    return (
        jax.tree.unflatten(treedef, out_x),
        jax.tree.unflatten(treedef, out_h),
    )
