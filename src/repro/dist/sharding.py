"""Mesh helpers and PartitionSpec derivation for the TAMUNA-DP engine.

The engine runs on a ``("data", "model")`` mesh (optionally with a leading
``"pod"`` axis for multi-pod runs).  Every non-``model`` axis hosts clients:
client ``i`` of TAMUNA *is* data-shard ``i`` of the mesh, so the stacked
client axis of the training state (leading dim ``n``) is sharded over the
data axes and each parameter leaf is tensor-parallel over ``model``.

All derivation here is *rule-based over pytree paths + shapes* so it covers
the whole model zoo (dense / MoE / RWKV / Mamba-hybrid / enc-dec) without
per-architecture tables.  Rules only ever propose a sharding when the dim is
divisible by the mesh-axis size; otherwise the dim is left unconstrained
(replicated hint) and GSPMD decides — correctness never depends on these
hints, only collective volume does.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"

__all__ = [
    "MODEL_AXIS",
    "dp_axis_names",
    "dp_axes",
    "dp_shard_index",
    "spec_dim_axes",
    "spec_dim_factor",
    "n_clients",
    "model_size",
    "train_batch_pspec",
    "params_pspecs",
    "params_shardings",
    "stacked_params_pspecs",
    "cache_pspecs",
    "prefill_input_pspecs",
    "serve_input_pspecs",
]


# --------------------------------------------------------------------------
# mesh helpers
# --------------------------------------------------------------------------


def dp_axis_names(mesh: Mesh) -> tuple:
    """All client-hosting (non-model) axis names, mesh order preserved."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


def dp_axes(mesh: Mesh):
    """The PartitionSpec entry for the client axis: a single name or a
    tuple of names (multi-pod: the client dim shards over pod x data)."""
    names = dp_axis_names(mesh)
    if not names:
        return None
    return names[0] if len(names) == 1 else names


def n_clients(mesh: Mesh) -> int:
    """Population size n = product of the client-hosting axis sizes."""
    return int(np.prod([mesh.shape[a] for a in dp_axis_names(mesh)] or [1]))


def dp_shard_index(mesh: Mesh):
    """Linear client-shard id of the executing shard, row-major over the
    dp axes — the order a ``P((a, b))`` client-dim split enumerates blocks.
    Only valid inside ``shard_map`` over this mesh (uses ``axis_index``)."""
    import jax.numpy as jnp

    idx = jnp.int32(0)
    for name in dp_axis_names(mesh):
        idx = idx * mesh.shape[name] + jax.lax.axis_index(name)
    return idx


def spec_dim_axes(entry) -> tuple:
    """A PartitionSpec entry -> the tuple of mesh axis names it shards
    over (empty for ``None``/unconstrained dims)."""
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def spec_dim_factor(entry, mesh: Mesh) -> int:
    """How many ways a PartitionSpec entry splits its dim on ``mesh``."""
    return int(np.prod([mesh.shape[a] for a in spec_dim_axes(entry)] or [1]))


def model_size(mesh: Mesh) -> int:
    return int(mesh.shape.get(MODEL_AXIS, 1))


def train_batch_pspec(mesh: Mesh) -> P:
    """Per-client batches (n, b, ...): client dim over the data axes."""
    return P(dp_axes(mesh))


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# pytrees whose leaves carry a leading stacked-layer axis that must never be
# sharded over `model` (it is scanned over)
_STACKED_KEYS = ("blocks", "enc_blocks", "dec_blocks")
# weight names whose *output* feature dim is sharded (column parallel)
_COL_PARALLEL = ("wq", "wk", "wv", "bq", "bk", "bv", "w_gate", "w_up",
                 "lm_head", "prefix_proj", "router")
# weight names whose *input* feature dim is sharded (row parallel: the
# matching contraction of a column-parallel producer)
_ROW_PARALLEL = ("wo", "w_down")


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _div(dim: int, m: int) -> bool:
    return m > 1 and dim >= m and dim % m == 0


def _leaf_pspec(
    path_str: str,
    shape: tuple,
    cfg,
    msize: int,
    moe_expert_parallel: bool,
) -> P:
    """Model-parallel spec for one parameter leaf (no client axis)."""
    spec = [None] * len(shape)
    if msize <= 1 or not shape:
        return P(*spec)
    off = 1 if any(f"'{k}'" in path_str for k in _STACKED_KEYS) else 0
    nd = len(shape) - off  # logical rank without the stacked-layer axis

    def done():
        return P(*spec)

    # embeddings: vocab dim is padded to 128 so it always shards
    if "'embed'" in path_str and nd == 2:
        if _div(shape[off], msize):
            spec[off] = MODEL_AXIS
        return done()

    # MoE expert stacks (E, d, f): expert-parallel for training, feature-
    # parallel for serving (gather dispatch needs local experts)
    if "'moe'" in path_str and nd == 3:
        e_dim, last = off, off + 2
        if moe_expert_parallel and _div(shape[e_dim], msize):
            spec[e_dim] = MODEL_AXIS
            return done()
        f_dim = last if any(f"'{n}'" in path_str for n in ("w_gate", "w_up")) \
            else off + 1
        if _div(shape[f_dim], msize):
            spec[f_dim] = MODEL_AXIS
        return done()

    name_hit_col = any(f"'{n}'" in path_str for n in _COL_PARALLEL)
    name_hit_row = any(f"'{n}'" in path_str for n in _ROW_PARALLEL)
    if name_hit_col and nd >= 1 and _div(shape[-1], msize):
        spec[-1] = MODEL_AXIS
        return done()
    if name_hit_row and nd >= 2 and _div(shape[-2], msize):
        spec[-2] = MODEL_AXIS
        return done()

    # generic fallback: norms/scalars replicated; matrices shard the last
    # divisible feature dim
    if nd >= 2:
        for dim in (len(shape) - 1, len(shape) - 2):
            if _div(shape[dim], msize):
                spec[dim] = MODEL_AXIS
                break
    return done()


def params_pspecs(
    params: Any,
    cfg,
    mesh: Mesh,
    moe_expert_parallel: bool = True,
) -> Any:
    """PartitionSpec tree for a (single-replica) parameter pytree."""
    msize = model_size(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [
        _leaf_pspec(_path_str(p), tuple(x.shape), cfg, msize,
                    moe_expert_parallel)
        for p, x in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def stacked_params_pspecs(
    stacked: Any,
    cfg,
    mesh: Mesh,
    moe_expert_parallel: bool = True,
) -> Any:
    """Specs for client-stacked parameter trees (leaves ``(n, ...)``):
    client dim over the data axes, the rest per the parameter rules."""
    msize = model_size(mesh)
    dp = dp_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(stacked)
    specs = [
        P(dp, *_leaf_pspec(_path_str(p), tuple(x.shape[1:]), cfg, msize,
                           moe_expert_parallel))
        for p, x in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def params_shardings(
    params: Any, cfg, mesh: Mesh, moe_expert_parallel: bool = True
) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        params_pspecs(params, cfg, mesh, moe_expert_parallel),
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------------
# serving specs
# --------------------------------------------------------------------------


def cache_pspecs(cfg, mesh: Mesh, batch: int) -> Dict[str, P]:
    """Decode-cache specs: batch dim (always dim 1) over the data axes, KV
    heads over ``model`` when divisible."""
    from repro.dist import model_api  # local import; avoids a cycle

    msize = model_size(mesh)
    dp = dp_axes(mesh) if batch % max(1, _dp_size(mesh)) == 0 else None
    struct = jax.eval_shape(lambda: model_api.make_cache(cfg, batch, 8))

    def leaf(path, x):
        spec = [None] * x.ndim
        if x.ndim >= 2 and dp is not None:
            spec[1] = dp
        name = _path_str(path)
        # (L, b, S, kvh, hd) KV tensors: shard the head dim if divisible
        if x.ndim == 5 and any(f"'{k}'" in name for k in ("k", "v", "xk",
                                                          "xv")):
            if _div(x.shape[3], msize):
                spec[3] = MODEL_AXIS
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(struct)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf(p, x) for p, x in flat]
    )


def _dp_size(mesh: Mesh) -> int:
    return n_clients(mesh)


def prefill_input_pspecs(cfg, mesh: Mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    return {
        "tokens": P(dp),
        "labels": P(dp),
        "frames": P(dp),
        "prefix_embeds": P(dp),
    }


def serve_input_pspecs(cfg, mesh: Mesh, batch: int) -> Dict[str, Any]:
    tok = P(dp_axes(mesh)) if batch % max(1, _dp_size(mesh)) == 0 else P()
    return {
        "cache": cache_pspecs(cfg, mesh, batch),
        "token": tok,
    }
