"""Byzantine-robust aggregation: per-coordinate robust combiners,
adaptive payload guards, and reputation-driven quarantine (DESIGN.md §15).

PR 6's survivor-aware aggregation handles *crash*-style faults: dropped
uplinks demote to idle slots and nonfinite payloads are zeroed by the
guard.  A *finite* adversarial uplink (sign-flip, scaling, collusive
inliers, or a ``blowup`` row when ``guard_max_abs`` is unset) passes both
and poisons every coordinate that client owns.  TAMUNA's sparse uplink
gives each coordinate exactly ``s`` arrived-owner values, so coordinate-
wise robust statistics are well-posed over the same ``(s, d)`` owner
stacks ``comm_ws`` already materializes.  This module is the shared
substrate:

``normalize_robust``
    config normalization with a hard bitwise contract: ``mean`` and
    ``trimmed`` with ``k == 0`` normalize to ``None`` — the comm impls
    take ``robust=None`` to mean "run the existing mean path verbatim"
    (a sort-based k=0 trim would reassociate the float reduction), so
    the robust feature at its identity settings is bitwise-invisible.

``robust_combine_stack``
    the one combiner every impl calls: coordinate-wise trimmed mean /
    median over a stacked candidate axis with a validity mask —
    non-arrived entries sort to ``+inf`` past the per-coordinate count,
    trimmed means are prefix-sum windows (O(m log m), no host sync),
    medians average the two middle order statistics.  Works on the
    ``(s, D)`` owner-gather stacks (ws), the ``(n, D)`` masked dense
    stacks, and the shard engine's psum'd ``(s, d_local)`` exchange.

``magnitude_outliers`` / ``payload_norms`` / ``masked_median``
    the adaptive magnitude guard: per-client payload L2 norms, flagged
    above ``median + nu * 1.4826 * MAD`` of the arrived members (with a
    relative floor so a zero-MAD fleet never flags itself).  Replaces
    the static ``guard_max_abs`` threshold nobody sets correctly —
    a 1e8-scaled row is ~1e8 fleet medians away regardless of scale.

``anomaly_scores`` + ``Reputation``
    the feedback loop: per-client distance to the coordinate-wise robust
    aggregate (normalized by the cohort's median distance, so honest
    clients score ~1), ridden through the device trace buffers into a
    host-side EWMA reputation that emits escalating
    ``CohortPlan.quarantine`` windows.  ``state_dict`` round-trips the
    EWMA/strike state so restored checkpoints replay the identical
    quarantine schedule.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = [
    "ROBUST_AGGS",
    "normalize_robust",
    "robust_combine_stack",
    "payload_norms",
    "masked_median",
    "magnitude_outliers",
    "anomaly_scores",
    "recenter_h",
    "Reputation",
]

ROBUST_AGGS = ("mean", "trimmed", "median")

# MAD -> sigma consistency constant for a normal population; the guard's
# threshold is med + nu * _MAD_SIGMA * MAD
_MAD_SIGMA = 1.4826


def normalize_robust(kind: str, k: int, s: int
                     ) -> Optional[Tuple[str, int]]:
    """Validate and normalize a robust-combiner spec to what the comm
    impls consume: ``None`` (run the untouched mean path — bitwise
    identity) or ``("trimmed", k)`` / ``("median", 0)``.

    ``k`` values trimmed per *side*; TAMUNA guarantees at most ``s``
    owner values per coordinate, so ``2 k < s`` keeps at least one
    untrimmed value even at full arrival.
    """
    if kind not in ROBUST_AGGS:
        raise ValueError(
            f"unknown robust_agg {kind!r}; want one of {ROBUST_AGGS}")
    k = int(k)
    if k < 0:
        raise ValueError(f"trim_k={k} must be >= 0")
    if kind == "mean":
        if k:
            raise ValueError("robust_agg='mean' takes no trim_k")
        return None
    if kind == "median":
        if k:
            raise ValueError("robust_agg='median' takes no trim_k")
        return ("median", 0)
    if 2 * k >= int(s):
        raise ValueError(
            f"trimmed combiner needs 2*trim_k < s (k={k}, s={s}): "
            f"trimming would discard every owner value")
    if k == 0:
        return None  # bitwise-mean contract (see module docstring)
    return ("trimmed", k)


def _oem_pairs(m: int):
    """Batcher odd-even mergesort compare-exchange schedule for ``m``
    lanes (O(m log^2 m) exchanges, each a vectorized min/max)."""
    pairs = []
    p = 1
    while p < m:
        k = p
        while k >= 1:
            for j in range(k % p, m - k, 2 * k):
                for i in range(min(k, m - j - k)):
                    if (i + j) // (2 * p) == (i + j + k) // (2 * p):
                        pairs.append((i + j, i + j + k))
            k //= 2
        p *= 2
    return pairs


# above this stack height the generic XLA sort wins over the unrolled
# network (comm stacks are s- or n-sized, well below it)
_NETWORK_MAX = 32


def _sort_stack(v):
    """Sort ``v`` along axis 0: an unrolled min/max sorting network for
    small stacks (XLA's variadic sort is ~20x slower on the short-axis
    (m, D) shapes the comm paths produce — the network fuses into plain
    elementwise code), the generic sort beyond ``_NETWORK_MAX``."""
    import jax.numpy as jnp

    m = v.shape[0]
    if m > _NETWORK_MAX:
        return jnp.sort(v, axis=0)
    rows = [v[i] for i in range(m)]
    for a, b in _oem_pairs(m):
        lo = jnp.minimum(rows[a], rows[b])
        rows[b] = jnp.maximum(rows[a], rows[b])
        rows[a] = lo
    return jnp.stack(rows, axis=0)


def robust_combine_stack(vals, ok, kind: str, k: int):
    """Coordinate-wise robust combine over a stacked candidate axis.

    ``vals``  (m, ...) candidate values, axis 0 the stack.
    ``ok``    bool, broadcastable to ``vals``: which entries are real
              (arrived owner values); the rest are ignored.  ``None``
              declares every entry valid STATICALLY — the window indices
              become Python constants and the whole combine collapses to
              the sorting network plus one add chain (the fault-free
              uplink path; an all-true array keeps the dynamic-count
              machinery and costs ~3x more in per-op dispatch).
    Returns ``(x_bar, cnt)``: the combined value per coordinate (0 where
    ``cnt == 0`` — callers gate on coverage exactly like the survivor
    mean) and the int32 valid count.

    Invalid entries sort to ``+inf`` past ``cnt``; the trimmed mean sums
    the order-statistic window ``[k_eff, cnt - k_eff)`` with ``k_eff =
    min(k, (cnt-1)//2)`` so partially-arrived coordinates degrade to
    shallower trims instead of empty windows; the median averages the
    two middle order statistics (exact for odd counts).  Everything is
    masked elementwise over the sorted stack — no gathers — so the whole
    combine fuses into one elementwise pass after the sorting network.
    """
    import jax
    import jax.numpy as jnp

    if kind not in ("trimmed", "median"):
        raise ValueError(f"robust_combine_stack kind {kind!r}")
    vals = jnp.asarray(vals)
    m = vals.shape[0]
    zero = jnp.zeros((), vals.dtype)
    pinf = jnp.asarray(jnp.inf, vals.dtype)
    small = m <= _NETWORK_MAX
    if ok is None:
        # static full-stack window: cnt == m everywhere, so lo/hi are
        # Python ints and the masked-window/extreme-count machinery
        # drops out entirely — sort (network for small m) + add the
        # kept rows in ascending order (matching the dynamic path's
        # accumulation order bit for bit)
        if kind == "median":
            lo, hi = (m - 1) // 2, m // 2
        else:
            k_eff = min(max(k, 0), (m - 1) // 2)
            lo, hi = k_eff, m - k_eff - 1
        cnt = jnp.full(vals.shape[1:], m, jnp.int32)
        den = jnp.asarray(hi - lo + 1, vals.dtype)
        if small:
            srows = [vals[i] for i in range(m)]
            for a, b in _oem_pairs(m):
                sa = jnp.minimum(srows[a], srows[b])
                srows[b] = jnp.maximum(srows[a], srows[b])
                srows[a] = sa
            # the opaque window mask is load-bearing: with the window
            # visible as a constant, the simplifier folds the sum into
            # plain adds, the combine becomes pure elementwise, and the
            # CPU emitter then re-computes it inside EVERY consumer
            # fusion of the comm step (both (n, d) update fusions —
            # ~2.3x the mean step).  Hidden behind the barrier the
            # window sum stays a real reduce thunk whose output the
            # consumers read once, and the robust step prices like the
            # mean step.
            win = jax.lax.optimization_barrier(
                jnp.asarray([lo <= i <= hi for i in range(m)]).reshape(
                    (m,) + (1,) * (vals.ndim - 1)))
            num = jnp.where(win, jnp.stack(srows), zero).sum(axis=0)
        else:
            num = jnp.sort(vals, axis=0)[lo:hi + 1].sum(axis=0)
        return num / den, cnt
    ok = jnp.broadcast_to(jnp.asarray(ok, bool), vals.shape)
    # XLA's axis-0 reductions (min/max/sort, and bool sums) lower to
    # scalarized loops on short stacked shapes — unrolled per-row chains
    # of vectorized ops are ~5x faster, so every small-m path below
    # works on the row list, never a stacked (m, D) temporary
    vrows = [vals[i] for i in range(m)] if small else None
    orows = [ok[i] for i in range(m)] if small else None
    if small:
        cnt = orows[0].astype(jnp.int32)
        for o in orows[1:]:
            cnt = cnt + o.astype(jnp.int32)
    else:
        cnt = ok.sum(axis=0).astype(jnp.int32)
    if small and (kind == "trimmed" and k == 1
                  or kind == "median" and m <= 4):
        # sort-free fast path: the k=1 trimmed window is "drop one min
        # and one max" at every cnt (k_eff = 0 below cnt 3), and the
        # median coincides with it for stacks of <= 4 (the two middles
        # at cnt 4, the middle at 3, the full mean at 1-2).  Summing
        # the total and subtracting the extremes would cancel
        # catastrophically against a blowup-scale outlier (the honest
        # mass vanishes below the outlier's ulp), so instead the sum
        # covers only the STRICT middle (mn < v < mx) and the surplus
        # extreme multiplicities are added back exactly — no term ever
        # cancels, so any admitted magnitude (up to +-inf) combines as
        # exactly as the sorted path.
        mn = jnp.where(orows[0], vrows[0], pinf)
        mx = jnp.where(orows[0], vrows[0], -pinf)
        for v_, o_ in zip(vrows[1:], orows[1:]):
            mn = jnp.minimum(mn, jnp.where(o_, v_, pinf))
            mx = jnp.maximum(mx, jnp.where(o_, v_, -pinf))
        c_mn = jnp.zeros((), jnp.int32)
        c_mx = jnp.zeros((), jnp.int32)
        mid = zero
        for v_, o_ in zip(vrows, orows):
            c_mn = c_mn + (o_ & (v_ == mn)).astype(jnp.int32)
            c_mx = c_mx + (o_ & (v_ == mx)).astype(jnp.int32)
            mid = mid + jnp.where(o_ & (v_ > mn) & (v_ < mx), v_, zero)
        trim = (cnt >= 3).astype(jnp.int32)
        # 0 * inf guards: only multiply an extreme by a nonzero count
        keep_mn = c_mn - trim
        keep_mx = c_mx - trim
        ext = (jnp.where(keep_mn > 0, keep_mn.astype(vals.dtype) * mn,
                         zero)
               + jnp.where(keep_mx > 0, keep_mx.astype(vals.dtype) * mx,
                           zero))
        # all ok entries equal (mn == mx): both counts saw every entry
        num = jnp.where(mn == mx,
                        jnp.where(cnt - 2 * trim > 0,
                                  (cnt - 2 * trim).astype(vals.dtype)
                                  * mn, zero),
                        mid + ext)
        den = jnp.maximum(cnt - 2 * trim, 1).astype(vals.dtype)
        return jnp.where(cnt > 0, num / den, zero), cnt
    safe = jnp.maximum(cnt, 1)
    if kind == "median":
        # the median is the order-statistic window [(cnt-1)//2, cnt//2]
        # — one entry at odd counts, the two middles at even counts —
        # so it shares the single masked window-sum with the trimmed
        # path (window < cnt wherever cnt > 0, so the +inf tail never
        # lands in a kept lane)
        lo, hi = (safe - 1) // 2, safe // 2
    else:
        k_eff = jnp.clip(jnp.minimum(k, (cnt - 1) // 2), 0)
        lo, hi = k_eff, cnt - k_eff - 1
    if small:
        srows = [jnp.where(o_, v_, pinf) for v_, o_ in zip(vrows, orows)]
        for a, b in _oem_pairs(m):
            sa = jnp.minimum(srows[a], srows[b])
            srows[b] = jnp.maximum(srows[a], srows[b])
            srows[a] = sa
        num = zero
        for i, r in enumerate(srows):  # row index is static: the window
            num = num + jnp.where((lo <= i) & (i <= hi), r, zero)
    else:
        v = jnp.sort(jnp.where(ok, vals, pinf), axis=0)
        idx = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 0)
        num = jnp.where((idx >= lo[None]) & (idx <= hi[None]),
                        v, zero).sum(axis=0)
    den = jnp.maximum(hi - lo + 1, 1).astype(vals.dtype)
    return jnp.where(cnt > 0, num / den, zero), cnt


# --------------------------------------------------------------------------
# adaptive magnitude guard
# --------------------------------------------------------------------------


def payload_norms(tree):
    """(n,) f32 per-client payload L2 norms over all leaves.  Nonfinite
    entries count as 1e30 so a NaN/Inf row lands at the top of the norm
    order (the nonfinite guard flags it anyway; this keeps the median/
    MAD statistics of the *other* rows meaningful)."""
    import jax
    import jax.numpy as jnp

    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    tot = jnp.zeros((n,), jnp.float32)
    for a in leaves:
        f = a.astype(jnp.float32).reshape(n, -1)
        f = jnp.where(jnp.isfinite(f), f, 1e30)
        tot = tot + (f * f).sum(axis=1)
    return jnp.sqrt(tot)


def masked_median(v, mask):
    """Median of ``v`` over ``mask`` entries (0.0 when none)."""
    import jax.numpy as jnp

    v = jnp.asarray(v)
    mask = jnp.asarray(mask, bool)
    sv = jnp.sort(jnp.where(mask, v, jnp.asarray(jnp.inf, v.dtype)))
    cnt = mask.sum()
    safe = jnp.maximum(cnt, 1)
    med = 0.5 * (sv[(safe - 1) // 2] + sv[safe // 2])
    return jnp.where(cnt > 0, med, jnp.zeros((), v.dtype))


def magnitude_outliers(tree, mask, nu: float = 6.0):
    """(n,) bool adaptive magnitude guard: ``mask``'ed clients whose
    payload norm exceeds ``median + nu * 1.4826 * MAD`` of the masked
    norms, with a 5%-of-median floor on the band so a near-deterministic
    fleet (MAD ~ 0) never flags honest jitter.  Scale-free: catches the
    finite ``blowup`` rows the static ``guard_max_abs`` threshold misses
    whenever nobody tuned it (faults.py's admitted gap)."""
    import jax.numpy as jnp

    mask = jnp.asarray(mask, bool)
    norms = payload_norms(tree)
    med = masked_median(norms, mask)
    mad = masked_median(jnp.abs(norms - med), mask)
    band = jnp.maximum(nu * _MAD_SIGMA * mad, 0.05 * med)
    return mask & (norms > med + band)


# --------------------------------------------------------------------------
# anomaly scores + EWMA reputation -> quarantine windows
# --------------------------------------------------------------------------


def anomaly_scores(tree, mask):
    """(n,) f32 per-client anomaly: L2 distance of the client's payload
    to the coordinate-wise median of the ``mask``'ed rows, normalized by
    the masked median distance (honest clients score ~1, a sign-flipped
    or shifted row scores far above).  0 outside ``mask``; nonfinite
    payload entries are treated as 0 (the nonfinite guard already flags
    those rows — their distance should not poison the center).

    The denominator is floored at 5% of the center-payload norm: once
    the fleet reaches consensus the median distance collapses toward 0,
    and a bare ``dist / med`` z-score would flag any honest client with
    a slightly stale control variate as an extreme outlier (scores grow
    without bound as the honest spread shrinks).  The floor keeps the
    score scale-free while the updates are heterogeneous (``med``
    dominates early) but pins "anomalous" to *payload-scale* deviation
    at consensus — a sign-flipped row still sits O(2 ||center||) away
    and scores ~40, while consensus-phase honest jitter scores << 1."""
    import jax
    import jax.numpy as jnp

    mask = jnp.asarray(mask, bool)
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    d2 = jnp.zeros((n,), jnp.float32)
    c2 = jnp.zeros((), jnp.float32)
    for a in leaves:
        f = a.astype(jnp.float32).reshape(n, -1)
        f = jnp.where(jnp.isfinite(f), f, 0.0)
        center, _ = robust_combine_stack(f, mask[:, None], "median", 0)
        d2 = d2 + ((f - center[None, :]) ** 2).sum(axis=1)
        c2 = c2 + (center * center).sum()
    dist = jnp.sqrt(d2)
    med = masked_median(dist, mask)
    floor = 0.05 * jnp.sqrt(c2)
    return jnp.where(mask, dist / (jnp.maximum(med, floor) + 1e-12), 0.0)


def recenter_h(h_tree, active):
    """Project the control variates back onto the zero-sum subspace over
    the ``active`` clients: ``h_i <- h_i - mean_active(h)`` for active
    rows, quarantined/inactive rows untouched.

    TAMUNA's convergence to the population optimizer rides on the
    invariant ``sum_i h_i = 0`` — with the *mean* combiner the comm
    step's h update preserves it exactly (the update directions
    ``x_bar - x_i`` sum to zero by construction).  A robust combiner
    breaks that identity: whenever the trimmed/median aggregate differs
    from the arrived mean (any round where clients still disagree), the
    h updates acquire a common-mode component, the invariant drifts, and
    the loop converges to a *biased* consensus point — the drift freezes
    once the fleet agrees, so the bias is permanent, not transient.
    Re-centering after each robust round continuously repairs the
    invariant over the clients that still participate; at the fixed
    point (consensus) it is a no-op.  Server-side: needs the per-client
    h table, which the simulated engine and the §10 shard engine both
    hold."""
    import jax
    import jax.numpy as jnp

    active = jnp.asarray(active, bool)
    cnt = jnp.maximum(active.sum(), 1)

    def fix(a):
        am = active.reshape((-1,) + (1,) * (a.ndim - 1))
        mean = jnp.where(am, a, 0).sum(axis=0, keepdims=True) / cnt.astype(
            a.dtype if jnp.issubdtype(a.dtype, jnp.floating) else jnp.float32
        )
        return jnp.where(am, a - mean.astype(a.dtype), a)

    return jax.tree.map(fix, h_tree)


class Reputation:
    """Host-side EWMA reputation over per-round anomaly scores, emitting
    escalating quarantine windows.

    ``update(anomaly, arrived)`` folds a round's (n,) anomaly row into
    per-client EWMAs (only arrived clients move — a quarantined or
    dropped client's reputation neither decays nor grows) and returns
    ``[(client, window_rounds), ...]`` for every client whose EWMA
    crossed ``threshold``: window = ``base_rounds * 2**strikes`` (capped
    at ``2**max_doublings``), the strike counter increments, and the
    EWMA resets so the client re-earns its way back after the window.

    Pure host state, deterministic in the update sequence; ``state_dict``
    / ``from_state_dict`` round-trip everything, so a restored checkpoint
    fed the identical trace replay emits the identical windows.
    """

    def __init__(self, n: int, *, alpha: float = 0.5,
                 threshold: float = 3.0, base_rounds: int = 4,
                 max_doublings: int = 6):
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha={alpha} outside (0, 1]")
        if threshold <= 1.0:
            raise ValueError(
                f"threshold={threshold} <= 1: honest clients score ~1")
        self.n = int(n)
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.base_rounds = int(base_rounds)
        self.max_doublings = int(max_doublings)
        self.scores = np.zeros(self.n, np.float64)
        self.strikes = np.zeros(self.n, np.int64)

    def update(self, anomaly, arrived):
        an = np.asarray(anomaly, np.float64)
        arr = np.asarray(arrived, bool)
        a = self.alpha
        self.scores[arr] = (1.0 - a) * self.scores[arr] + a * an[arr]
        out = []
        for i in np.nonzero(arr & (self.scores > self.threshold))[0]:
            w = self.base_rounds * (
                2 ** min(int(self.strikes[i]), self.max_doublings))
            self.strikes[i] += 1
            self.scores[i] = 0.0
            out.append((int(i), int(w)))
        return out

    def state_dict(self) -> dict:
        return {
            "n": self.n, "alpha": self.alpha,
            "threshold": self.threshold,
            "base_rounds": self.base_rounds,
            "max_doublings": self.max_doublings,
            "scores": self.scores.tolist(),
            "strikes": self.strikes.tolist(),
        }

    @classmethod
    def from_state_dict(cls, d: dict) -> "Reputation":
        rep = cls(d["n"], alpha=d["alpha"], threshold=d["threshold"],
                  base_rounds=d["base_rounds"],
                  max_doublings=d["max_doublings"])
        rep.scores = np.asarray(d["scores"], np.float64).copy()
        rep.strikes = np.asarray(d["strikes"], np.int64).copy()
        return rep
