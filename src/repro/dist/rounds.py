"""Fused round engine: the round, not the local step, is the unit of
compiled execution.

The seed driver dispatched one un-donated jit call per local step, blocked
on a host-side sampler between steps, and synced the loss to the host every
round.  Here a whole round runs as donated compiled programs:

  * ``make_round_fn(cfg, tcfg, mesh)`` compiles one donated program per
    round-length *bucket*: ``B`` local steps under ``jax.lax.scan`` followed
    by the comm step behind ``lax.cond``.  A host-sampled geometric length
    ``L`` is decomposed into descending powers of two
    (``round_chunks``), every chunk but the last runs with the comm branch
    off, so across any sequence of rounds at most ``log2(max_L) + 1``
    distinct programs ever compile (the cache is inspectable as
    ``round_fn.cache``).
  * Data is sampled **on device** inside the scan body
    (``repro.data.pipeline.device_sample_batch``) from PRNG keys folded out
    of the scan carry: ``data_step_key(base, t)`` for local step ``t`` and
    ``comm_round_key(base, round)`` for the round's comm step.  Steady-state
    training performs zero host->device transfers.
  * ``run_rounds`` drives multiple rounds with on-device metric
    accumulation: per-round loss / L / comm-float traces are written with
    ``.at[slot]`` updates inside the donated programs and drained to a
    ``MetricLogger`` every ``flush_every`` rounds — the drain is the only
    host sync.
  * Both uplinks route through the mask-free comm paths of
    ``repro.dist.comm_ws`` (``tcfg.comm_impl``, default auto: sparse fused
    uplink off-TPU, flat-workspace Pallas kernels on TPU — DESIGN.md §9),
    so the fused round program's comm step never materializes a dense
    ownership mask or scans all ``n`` client rows for the UpCom.  With
    ``comm_impl="pallas"`` the meshed comm step is the shard-resident
    engine (§10): ``make_comm_step`` hands the mesh and the stacked state
    specs to ``comm_ws``, which shard_maps the kernels over the dp axes
    inside the same donated round program — per-shard uplinks, one
    d-sized psum of the partials, behind the same ``lax.cond``.

The key-derivation helpers are public so the per-step reference path (and
the equivalence tests) can replay the exact same schedule.  See DESIGN.md
§8.
"""

from __future__ import annotations

import os
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import tamuna_dp
from repro.dist.tamuna_dp import _as_key
from repro.models.transformer import ModelConfig

__all__ = [
    "RoundCarry",
    "round_chunks",
    "data_step_key",
    "comm_round_key",
    "make_round_fn",
    "make_fused_round",
    "init_carry",
    "run_rounds",
]

# Batch sampler contract: ``sample_batch(data, key) -> {"tokens": ..., ...}``
# where ``data`` is a device-resident pytree passed alongside the donated
# carry as a read-only argument (uploaded once, never baked into programs,
# never donated — the caller's handle stays valid).
SampleFn = Callable[[Any, jax.Array], Dict[str, jax.Array]]

TRACE_KEYS = ("loss_sum", "steps", "up_floats", "down_floats")


class RoundCarry(NamedTuple):
    """Everything a round program owns; donated wholesale every call.  The
    pipeline tables stay OUTSIDE the carry (a separate, read-only argument)
    so donation never invalidates the caller's ``device_data()`` handle."""

    state: tamuna_dp.DistTamunaState
    t: jax.Array  # int32 scalar: total local steps taken so far
    data_key: jax.Array  # (2,) uint32 base key-data for data sampling
    comm_key: jax.Array  # (2,) uint32 base key-data for comm steps
    traces: Dict[str, jax.Array]  # per-round device traces, slot-indexed


def round_chunks(L: int, max_L: int = 16) -> list:
    """Decompose a round length into descending power-of-two chunks.

    ``sum(round_chunks(L)) == min(L, max_L)`` exactly, and the set of chunk
    sizes that can ever appear is ``{1, 2, ..., 2^floor(log2(max_L))}`` —
    the compile cache is bounded by ``log2(max_L) + 1`` programs.
    """
    L = max(1, min(int(L), int(max_L)))
    return [1 << b for b in range(L.bit_length() - 1, -1, -1)
            if (L >> b) & 1]


def data_step_key(base: jax.Array, t) -> jax.Array:
    """Key for the batch of global local-step ``t`` (typed PRNG key)."""
    return jax.random.fold_in(_as_key(base), t)


def comm_round_key(base: jax.Array, rnd) -> jax.Array:
    """Key for the comm step ending round ``rnd`` (``state.round``)."""
    return jax.random.fold_in(_as_key(base), rnd)


def _zero_traces(flush_every: int) -> Dict[str, jax.Array]:
    return {
        "loss_sum": jnp.zeros((flush_every,), jnp.float32),
        "steps": jnp.zeros((flush_every,), jnp.int32),
        "up_floats": jnp.zeros((flush_every,), jnp.float32),
        "down_floats": jnp.zeros((flush_every,), jnp.float32),
    }


def _scan_local(local, sample_batch: SampleFn, state, data, dkey, t, B: int):
    """``B`` local steps under ``lax.scan``, batches sampled on device from
    ``fold_in(dkey, t)``; returns (state, t, summed loss)."""

    def body(inner, _):
        st, tt, acc = inner
        batch = sample_batch(data, jax.random.fold_in(dkey, tt))
        st, m = local(st, **batch)
        return (st, tt + 1, acc + m["loss"]), None

    (state, t, loss_sum), _ = jax.lax.scan(
        body, (state, t, jnp.float32(0.0)), None, length=B
    )
    return state, t, loss_sum


def make_round_fn(
    cfg: ModelConfig,
    tcfg: tamuna_dp.DistTamunaConfig,
    mesh,
    *,
    sample_batch: SampleFn,
    max_L: int = 16,
):
    """Build ``round_fn(carry, data, L, slot) -> carry`` running one round.

    ``data`` is the device-resident pipeline table pytree (read-only, never
    donated); ``L`` is the (host-sampled) number of local steps; ``slot`` is
    the trace row this round writes (``global_round % flush_every``).  The
    callable exposes ``round_fn.cache`` (bucket -> compiled program) and
    ``round_fn.max_L``.
    """
    local = tamuna_dp.make_local_step(cfg, tcfg)
    comm = tamuna_dp.make_comm_step(cfg, tcfg, mesh)

    def chunk_fn(B: int, carry: RoundCarry, data, do_comm,
                 slot) -> RoundCarry:
        state, t, dk, ck, traces = carry
        state, t, loss_sum = _scan_local(
            local, sample_batch, state, data, _as_key(dk), t, B
        )

        def with_comm(st):
            ckey = comm_round_key(ck, st.round)
            return comm(st, jax.random.key_data(ckey))

        state = jax.lax.cond(do_comm, with_comm, lambda st: st, state)
        traces = {
            "loss_sum": traces["loss_sum"].at[slot].add(loss_sum),
            "steps": traces["steps"].at[slot].add(B),
            "up_floats": traces["up_floats"].at[slot].set(state.up_floats),
            "down_floats": traces["down_floats"].at[slot].set(
                state.down_floats
            ),
        }
        return RoundCarry(state, t, dk, ck, traces)

    cache: Dict[int, Callable] = {}

    def program(B: int):
        if B not in cache:
            cache[B] = jax.jit(partial(chunk_fn, B), donate_argnums=(0,))
        return cache[B]

    def round_fn(carry: RoundCarry, data, L: int, slot) -> RoundCarry:
        chunks = round_chunks(L, max_L)
        slot = jnp.asarray(slot, jnp.int32)
        for i, B in enumerate(chunks):
            do_comm = jnp.asarray(i == len(chunks) - 1)
            carry = program(B)(carry, data, do_comm, slot)
        return carry

    round_fn.cache = cache
    round_fn.max_L = max_L
    return round_fn


def make_fused_round(
    cfg: ModelConfig,
    tcfg: tamuna_dp.DistTamunaConfig,
    mesh,
    *,
    sample_batch: SampleFn,
    L: int,
):
    """Static-``L`` fused round ``fn(state, key_data, data) -> (state, loss)``
    with an unconditional comm step — the shape the dry-run lowers so the
    roofline artifacts see the scanned round, and the bench times."""
    local = tamuna_dp.make_local_step(cfg, tcfg)
    comm = tamuna_dp.make_comm_step(cfg, tcfg, mesh)

    def fn(state, key_data, data):
        kd, kc = jax.random.split(_as_key(key_data))
        state, _, loss_sum = _scan_local(
            local, sample_batch, state, data, kd,
            jnp.zeros((), jnp.int32), L,
        )
        ckey = comm_round_key(jax.random.key_data(kc), state.round)
        state = comm(state, jax.random.key_data(ckey))
        return state, loss_sum / L

    return fn


def init_carry(
    state: tamuna_dp.DistTamunaState,
    key: jax.Array,
    flush_every: int,
) -> RoundCarry:
    kd, kc = jax.random.split(_as_key(key))
    return RoundCarry(
        state=state,
        t=jnp.zeros((), jnp.int32),
        data_key=jax.random.key_data(kd),
        comm_key=jax.random.key_data(kc),
        traces=_zero_traces(flush_every),
    )


def run_rounds(
    state: tamuna_dp.DistTamunaState,
    *,
    round_fn,
    data: Any,
    key: jax.Array,
    rounds: int,
    rng,
    p: float,
    flush_every: int = 10,
    logger=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    max_L: Optional[int] = None,
) -> Tuple[tamuna_dp.DistTamunaState, Dict[str, Any]]:
    """Multi-round driver: geometric ``L`` per round (host ``rng``), fused
    rounds on device, metrics drained every ``flush_every`` rounds.

    Steady state does no per-local-step host->device transfer and no
    per-round host sync; the only blocking points are the trace drain (once
    per flush) and checkpoint saves.  Returns the final state and the last
    drained per-round metrics row.
    """
    # never sample past the engine's bucket cap: round_fn silently clamps
    # executed steps to its own max_L, so a larger caller cap would desync
    # the host-side L from the executed count
    engine_cap = getattr(round_fn, "max_L", None)
    max_L = max_L or engine_cap or 16
    if engine_cap:
        max_L = min(max_L, engine_cap)
    flush_every = max(1, min(flush_every, rounds))
    carry = init_carry(state, key, flush_every)
    pending = []  # global round indices awaiting drain
    total_steps = 0
    last: Dict[str, Any] = {}
    for r in range(rounds):
        L = tamuna_dp.sample_round_length(rng, p, max_L=max_L)
        slot = len(pending)
        carry = round_fn(carry, data, L, slot)
        pending.append(r)
        if len(pending) == flush_every or r == rounds - 1:
            tr = jax.device_get(carry.traces)  # the only host sync
            for i, gr in enumerate(pending):
                executed = int(tr["steps"][i])  # device truth, not host L
                total_steps += executed
                last = {
                    "round": gr,
                    "L": executed,
                    "loss": float(tr["loss_sum"][i]) / max(executed, 1),
                    "local_steps": total_steps,
                    "up_floats": float(tr["up_floats"][i]),
                    "down_floats": float(tr["down_floats"][i]),
                }
                if logger is not None:
                    logger.log(gr, last)
            pending = []
            carry = carry._replace(traces=_zero_traces(flush_every))
        if (checkpoint_dir and checkpoint_every
                and (r + 1) % checkpoint_every == 0):
            from repro import checkpoint

            checkpoint.save(
                os.path.join(checkpoint_dir, f"step_{r + 1}"),
                carry.state, r + 1,
            )
    return carry.state, last
