"""Fused round engine: the round, not the local step, is the unit of
compiled execution.

The seed driver dispatched one un-donated jit call per local step, blocked
on a host-side sampler between steps, and synced the loss to the host every
round.  Here a whole round runs as donated compiled programs:

  * ``make_round_fn(cfg, tcfg, mesh)`` compiles one donated program per
    round-length *bucket*: ``B`` local steps under ``jax.lax.scan`` followed
    by the comm step behind ``lax.cond``.  A host-sampled geometric length
    ``L`` is decomposed into descending powers of two
    (``round_chunks``), every chunk but the last runs with the comm branch
    off, so across any sequence of rounds at most ``log2(max_L) + 1``
    distinct programs ever compile (the cache is inspectable as
    ``round_fn.cache``).
  * Data is sampled **on device** inside the scan body
    (``repro.data.pipeline.device_sample_batch``) from PRNG keys folded out
    of the scan carry: ``data_step_key(base, t)`` for local step ``t`` and
    ``comm_round_key(base, round)`` for the round's comm step.  Steady-state
    training performs zero host->device transfers.
  * ``run_rounds`` drives multiple rounds with on-device metric
    accumulation: per-round loss / L / comm-float traces are written with
    ``.at[slot]`` updates inside the donated programs and drained to a
    ``MetricLogger`` every ``flush_every`` rounds — the drain is the only
    host sync.
  * **Elastic partial participation** (DESIGN.md §11): at ``c < n`` —
    where cohort rows can vacate hardware (single-device client axis or
    stacked clients; gated default, see ``make_round_fn``) — each chunk
    gathers the round's cohort rows into a compact ``(c, ...)`` state,
    runs its local steps there (O(c·L) compute and gradient memory —
    idle clients do nothing), scatters back, and the comm step's DownCom
    writes only the NEXT round's cohort.  Cohorts come from the round's
    comm key on device (uniform) or a host ``CohortPlan``
    (availability-driven, ``run_rounds(plan=...)``).
  * Both uplinks route through the mask-free comm paths of
    ``repro.dist.comm_ws`` (``tcfg.comm_impl``, default auto: sparse fused
    uplink off-TPU, flat-workspace Pallas kernels on TPU — DESIGN.md §9),
    so the fused round program's comm step never materializes a dense
    ownership mask or scans all ``n`` client rows for the UpCom.  With
    ``comm_impl="pallas"`` the meshed comm step is the shard-resident
    engine (§10): ``make_comm_step`` hands the mesh and the stacked state
    specs to ``comm_ws``, which shard_maps the kernels over the dp axes
    inside the same donated round program — per-shard uplinks, one
    d-sized psum of the partials, behind the same ``lax.cond``.

The key-derivation helpers are public so the per-step reference path (and
the equivalence tests) can replay the exact same schedule.  See DESIGN.md
§8.
"""

from __future__ import annotations

import os
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import sharding, tamuna_dp
from repro.dist.tamuna_dp import _as_key
from repro.models.transformer import ModelConfig

__all__ = [
    "RoundCarry",
    "round_chunks",
    "data_step_key",
    "comm_round_key",
    "default_elastic",
    "make_round_fn",
    "make_fused_round",
    "init_carry",
    "run_rounds",
]


def default_elastic(n: int, c: int, dp_total: int) -> bool:
    """Whether the engine gathers by default: only where cohort rows can
    actually vacate hardware — a single-device client axis, or stacked
    clients (``n > dp``) whose cohort divides the dp extent.  With one
    client per device the compact ``(c, ...)`` state cannot shard over
    dp: GSPMD replicates the cohort's gradient work onto every shard and
    remats the gather (measured ~500x round bytes on the pod16x16
    dry-run — DESIGN.md §11, EXPERIMENTS §Perf 9).  Shared by
    ``make_round_fn``, ``make_fused_round``, and the per-step trainer."""
    return c < n and (dp_total == 1 or (n > dp_total and c % dp_total == 0))

# Batch sampler contract: ``sample_batch(data, key) -> {"tokens": ..., ...}``
# where ``data`` is a device-resident pytree passed alongside the donated
# carry as a read-only argument (uploaded once, never baked into programs,
# never donated — the caller's handle stays valid).
SampleFn = Callable[[Any, jax.Array], Dict[str, jax.Array]]

TRACE_KEYS = ("loss_sum", "steps", "up_floats", "down_floats",
              "up_bytes", "down_bytes")
# extra per-round device traces of the fault-tolerant driver (present in
# the carry only when ``init_carry(robust_n=...)`` > 0): arrivals = cohort
# members whose uplink was aggregated, corrupted = members zeroed by the
# payload guard, bad = the (flush_every, n) guard mask the quarantine
# feedback reads
FAULT_TRACE_KEYS = ("arrivals", "corrupted", "bad")
ROUND_POLICIES = ("wait_all", "quorum", "deadline")


class RoundCarry(NamedTuple):
    """Everything a round program owns; donated wholesale every call.  The
    pipeline tables stay OUTSIDE the carry (a separate, read-only argument)
    so donation never invalidates the caller's ``device_data()`` handle."""

    state: tamuna_dp.DistTamunaState
    t: jax.Array  # int32 scalar: total local steps taken so far
    data_key: jax.Array  # (2,) uint32 base key-data for data sampling
    comm_key: jax.Array  # (2,) uint32 base key-data for comm steps
    traces: Dict[str, jax.Array]  # per-round device traces, slot-indexed


def round_chunks(L: int, max_L: int = 16) -> list:
    """Decompose a round length into descending power-of-two chunks.

    ``sum(round_chunks(L)) == min(L, max_L)`` exactly, and the set of chunk
    sizes that can ever appear is ``{1, 2, ..., 2^floor(log2(max_L))}`` —
    the compile cache is bounded by ``log2(max_L) + 1`` programs.
    """
    L = max(1, min(int(L), int(max_L)))
    return [1 << b for b in range(L.bit_length() - 1, -1, -1)
            if (L >> b) & 1]


def data_step_key(base: jax.Array, t) -> jax.Array:
    """Key for the batch of global local-step ``t`` (typed PRNG key)."""
    return jax.random.fold_in(_as_key(base), t)


def comm_round_key(base: jax.Array, rnd) -> jax.Array:
    """Key for the comm step ending round ``rnd`` (``state.round``)."""
    return jax.random.fold_in(_as_key(base), rnd)


def _zero_traces(flush_every: int, robust_n: int = 0) -> Dict[str, jax.Array]:
    traces = {
        "loss_sum": jnp.zeros((flush_every,), jnp.float32),
        "steps": jnp.zeros((flush_every,), jnp.int32),
        "up_floats": jnp.zeros((flush_every,), jnp.float32),
        "down_floats": jnp.zeros((flush_every,), jnp.float32),
        "up_bytes": jnp.zeros((flush_every,), jnp.float32),
        "down_bytes": jnp.zeros((flush_every,), jnp.float32),
    }
    if robust_n:
        traces["arrivals"] = jnp.zeros((flush_every,), jnp.int32)
        traces["corrupted"] = jnp.zeros((flush_every,), jnp.int32)
        traces["bad"] = jnp.zeros((flush_every, robust_n), bool)
    return traces


def _scan_local(local, sample_batch: SampleFn, state, data, dkey, t, B: int,
                clients=None):
    """``B`` local steps under ``lax.scan``, batches sampled on device from
    ``fold_in(dkey, t)``; returns (state, t, summed loss).  ``clients``
    restricts the sample to the round's cohort rows (the state is then the
    compact ``(c, ...)`` gather and per-client streams stay keyed by the
    ACTUAL client ids, invariant to who else participates)."""

    def body(inner, _):
        st, tt, acc = inner
        key = jax.random.fold_in(dkey, tt)
        batch = (sample_batch(data, key) if clients is None
                 else sample_batch(data, key, clients=clients))
        st, m = local(st, **batch)
        return (st, tt + 1, acc + m["loss"]), None

    (state, t, loss_sum), _ = jax.lax.scan(
        body, (state, t, jnp.float32(0.0)), None, length=B
    )
    return state, t, loss_sum


def make_round_fn(
    cfg: ModelConfig,
    tcfg: tamuna_dp.DistTamunaConfig,
    mesh,
    *,
    sample_batch: SampleFn,
    max_L: int = 16,
    n: Optional[int] = None,
    elastic: Optional[bool] = None,
):
    """Build ``round_fn(carry, data, L, slot, cohort=None, down=None) ->
    carry`` running one round.

    ``data`` is the device-resident pipeline table pytree (read-only, never
    donated); ``L`` is the (host-sampled) number of local steps; ``slot`` is
    the trace row this round writes (``global_round % flush_every``).  The
    callable exposes ``round_fn.cache`` (bucket -> compiled program),
    ``round_fn.max_L``, ``round_fn.n``, ``round_fn.c``, ``round_fn.elastic``.

    **Elastic partial participation** (default whenever ``tcfg.c < n``,
    DESIGN.md §11): every chunk gathers the round's ``c`` cohort rows into
    a compact ``(c, ...)`` state, runs its local steps there (batches
    sampled for cohort clients only), and scatters back — local compute
    and gradient memory are O(c·L), idle clients do nothing.  The cohort
    is derived on device from the round's comm key
    (``tamuna_dp.round_cohort(comm_round_key(base, round), n, c)`` — every
    chunk of a round sees the same ``state.round``, hence the same
    cohort), unless the caller passes an explicit ``cohort`` (host plans:
    ``repro.dist.cohort.CohortPlan`` for availability-driven sampling).
    The comm step's DownCom then targets only the NEXT round's cohort
    (``down``; device-derived symmetrically when None), so clients sitting
    out a round are bitwise untouched.

    The default only goes elastic where cohort rows can actually vacate
    hardware: a single-device client axis, or stacked clients
    (``n > dp``) whose cohort divides the dp extent.  With one client per
    device (``n == dp``) the compact ``(c, ...)`` state cannot shard over
    the dp axis — GSPMD replicates the cohort's gradient work onto every
    shard and remats the gather (measured on the pod16x16 dry-run:
    ~500x the round's memory traffic, EXPERIMENTS §Perf 9) — so those
    placements keep the all-rows body, whose DownCom must broadcast
    (every row trains, every row re-syncs to ``x_bar``).  ``elastic=``
    overrides the default either way.
    """
    n = n or sharding.n_clients(mesh)
    c = tcfg.c
    if elastic is None:
        elastic = default_elastic(n, c, sharding.n_clients(mesh))
    local = tamuna_dp.make_local_step(cfg, tcfg)
    comm = tamuna_dp.make_comm_step(cfg, tcfg, mesh, n=n)

    def chunk_fn(B: int, carry: RoundCarry, data, do_comm, slot,
                 cohort, down, arrived=None, corrupt=None, *,
                 correct: bool = True, guard: bool = False,
                 corrupt_mode: str = "nan", blowup: float = 1e8,
                 guard_max_abs: Optional[float] = None) -> RoundCarry:
        state, t, dk, ck, traces = carry
        if elastic:
            if cohort is None:
                cohort = tamuna_dp.round_cohort(
                    comm_round_key(ck, state.round), n, c
                )
            if down is None:
                down = tamuna_dp.member_mask(
                    tamuna_dp.round_cohort(
                        comm_round_key(ck, state.round + 1), n, c
                    ), n,
                )
            compact = tamuna_dp.gather_cohort(state, cohort)
            compact, t, loss_sum = _scan_local(
                local, sample_batch, compact, data, _as_key(dk), t, B,
                clients=cohort,
            )
            state = tamuna_dp.scatter_cohort(state, compact, cohort)
        else:
            # all-rows body: every row trains, so every row must re-sync
            # to x_bar at comm time — a masked DownCom would leave
            # non-cohort rows on their (discarded) local trajectories
            down = None
            state, t, loss_sum = _scan_local(
                local, sample_batch, state, data, _as_key(dk), t, B
            )

        if arrived is None:
            def with_comm(st):
                ckey = comm_round_key(ck, st.round)
                return comm(st, jax.random.key_data(ckey), cohort=cohort,
                            down=down)

            state = jax.lax.cond(do_comm, with_comm, lambda st: st, state)
            new_traces = None
        else:
            # the fault-tolerant comm branch (DESIGN.md §12): corruption
            # is injected into the would-be uplink payload, the payload
            # guard demotes nonfinite members to non-arrived (and zeroes
            # their rows so leftover garbage can't reach a later loss),
            # and the comm step aggregates survivors only
            from repro.dist import faults as faults_mod

            member = jnp.zeros((n,), bool).at[cohort].set(True)

            def with_comm(st):
                ckey = comm_round_key(ck, st.round)
                stx = st
                if corrupt is not None:
                    stx = stx._replace(x=faults_mod.corrupt_rows(
                        stx.x, corrupt, corrupt_mode, blowup
                    ))
                arr = arrived & member
                if guard:
                    bad = faults_mod.nonfinite_clients(
                        stx.x, guard_max_abs
                    ) & member
                    arr = arr & ~bad
                    stx = stx._replace(x=jax.tree.map(
                        lambda a: jnp.where(
                            bad.reshape((n,) + (1,) * (a.ndim - 1)),
                            jnp.zeros((), a.dtype), a,
                        ),
                        stx.x,
                    ))
                else:
                    bad = jnp.zeros((n,), bool)
                st2 = comm(stx, jax.random.key_data(ckey), cohort=cohort,
                           down=down, arrived=arr, correct=correct)
                return st2, arr.sum().astype(jnp.int32), bad

            def no_comm(st):
                return st, jnp.int32(0), jnp.zeros((n,), bool)

            state, arr_cnt, badm = jax.lax.cond(
                do_comm, with_comm, no_comm, state
            )
            new_traces = {
                "arrivals": traces["arrivals"].at[slot].set(arr_cnt),
                "corrupted": traces["corrupted"].at[slot].set(
                    badm.sum().astype(jnp.int32)
                ),
                "bad": traces["bad"].at[slot].set(badm),
            }
        out_traces = {
            "loss_sum": traces["loss_sum"].at[slot].add(loss_sum),
            "steps": traces["steps"].at[slot].add(B),
            "up_floats": traces["up_floats"].at[slot].set(state.up_floats),
            "down_floats": traces["down_floats"].at[slot].set(
                state.down_floats
            ),
            "up_bytes": traces["up_bytes"].at[slot].set(state.up_bytes),
            "down_bytes": traces["down_bytes"].at[slot].set(
                state.down_bytes
            ),
        }
        if new_traces is not None:
            out_traces.update(new_traces)
        return RoundCarry(state, t, dk, ck, out_traces)

    cache: Dict[Any, Callable] = {}

    def program(B: int, with_plan: bool, fkey=None):
        key = (B, with_plan, fkey)
        if key not in cache:
            if fkey is None:
                cache[key] = jax.jit(
                    partial(chunk_fn, B), donate_argnums=(0,)
                )
            else:
                correct, guard, mode, blowup, gmax = fkey
                cache[key] = jax.jit(
                    partial(chunk_fn, B, correct=correct, guard=guard,
                            corrupt_mode=mode, blowup=blowup,
                            guard_max_abs=gmax),
                    donate_argnums=(0,),
                )
        return cache[key]

    def round_fn(carry: RoundCarry, data, L: int, slot,
                 cohort=None, down=None, arrived=None, corrupt=None,
                 correct: bool = True, guard: bool = False,
                 corrupt_mode: str = "nan", blowup: float = 1e8,
                 guard_max_abs: Optional[float] = None) -> RoundCarry:
        chunks = round_chunks(L, max_L)
        slot = jnp.asarray(slot, jnp.int32)
        with_plan = cohort is not None
        if with_plan and down is None:
            # a host plan must pin the DownCom too: without it the engine
            # would derive a (different) uniform next cohort on device
            raise ValueError("explicit cohort needs an explicit down mask")
        if arrived is None:
            if corrupt is not None:
                raise ValueError("corrupt mask needs an arrived mask")
            for i, B in enumerate(chunks):
                do_comm = jnp.asarray(i == len(chunks) - 1)
                carry = program(B, with_plan)(carry, data, do_comm, slot,
                                              cohort, down)
            return carry
        # fault-tolerant rounds carry the arrival mask into every chunk
        # (only the comm chunk consumes it) plus the static fault config
        # in the compile key; the carry must have been built with
        # init_carry(robust_n=n)
        if not with_plan:
            raise ValueError("fault injection needs an explicit cohort "
                             "(resolve it host-side, see run_rounds)")
        fkey = (bool(correct), bool(guard), str(corrupt_mode),
                float(blowup),
                None if guard_max_abs is None else float(guard_max_abs))
        arrived = jnp.asarray(arrived).astype(bool)
        if corrupt is not None:
            corrupt = jnp.asarray(corrupt).astype(bool)
        for i, B in enumerate(chunks):
            do_comm = jnp.asarray(i == len(chunks) - 1)
            carry = program(B, with_plan, fkey)(
                carry, data, do_comm, slot, cohort, down, arrived, corrupt
            )
        return carry

    round_fn.cache = cache
    round_fn.max_L = max_L
    round_fn.n = n
    round_fn.c = c
    round_fn.elastic = elastic
    return round_fn


def make_fused_round(
    cfg: ModelConfig,
    tcfg: tamuna_dp.DistTamunaConfig,
    mesh,
    *,
    sample_batch: SampleFn,
    L: int,
    n: Optional[int] = None,
    elastic: Optional[bool] = None,
):
    """Static-``L`` fused round ``fn(state, key_data, data) -> (state, loss)``
    with an unconditional comm step — the shape the dry-run lowers so the
    roofline artifacts see the scanned round, and the bench times.  At
    ``c < n`` this is the elastic round (cohort gather -> O(c·L) local
    compute -> scatter -> comm; ``elastic=False`` forces the all-rows
    contrast), with the cohort derived in-program from the comm key, so
    the lowered HLO's gradient FLOPs scale with ``c`` — the artifact the
    idle-clients-do-no-work regression checks.  Default elasticity is
    ``default_elastic`` (gathering is a pessimization when cohort rows
    cannot vacate hardware)."""
    n = n or sharding.n_clients(mesh)
    c = tcfg.c
    if elastic is None:
        elastic = default_elastic(n, c, sharding.n_clients(mesh))
    local = tamuna_dp.make_local_step(cfg, tcfg)
    comm = tamuna_dp.make_comm_step(cfg, tcfg, mesh, n=n)

    def fn(state, key_data, data):
        kd, kc = jax.random.split(_as_key(key_data))
        t0 = jnp.zeros((), jnp.int32)
        ckey = comm_round_key(jax.random.key_data(kc), state.round)
        if elastic:
            cohort = tamuna_dp.round_cohort(ckey, n, c)
            compact = tamuna_dp.gather_cohort(state, cohort)
            compact, _, loss_sum = _scan_local(
                local, sample_batch, compact, data, kd, t0, L,
                clients=cohort,
            )
            state = tamuna_dp.scatter_cohort(state, compact, cohort)
            # DownCom broadcasts here (down=None): each call of this
            # static round derives cohorts from ITS OWN key, so a mask
            # aimed at "this key's next cohort" would not match the
            # cohort the NEXT call actually draws — a client could then
            # enter a round without ever receiving x_bar.  The chunked
            # engine (make_round_fn) can target the true next cohort
            # because its comm key base is fixed in the carry.
            state = comm(state, jax.random.key_data(ckey), cohort=cohort)
        else:
            state, _, loss_sum = _scan_local(
                local, sample_batch, state, data, kd, t0, L,
            )
            state = comm(state, jax.random.key_data(ckey))
        return state, loss_sum / L

    return fn


def init_carry(
    state: tamuna_dp.DistTamunaState,
    key: jax.Array,
    flush_every: int,
    robust_n: int = 0,
) -> RoundCarry:
    kd, kc = jax.random.split(_as_key(key))
    return RoundCarry(
        state=state,
        t=jnp.zeros((), jnp.int32),
        data_key=jax.random.key_data(kd),
        comm_key=jax.random.key_data(kc),
        traces=_zero_traces(flush_every, robust_n),
    )


def run_rounds(
    state: tamuna_dp.DistTamunaState,
    *,
    round_fn,
    data: Any,
    key: jax.Array,
    rounds: int,
    rng,
    p: float,
    flush_every: int = 10,
    logger=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    max_L: Optional[int] = None,
    plan=None,
    faults=None,
    policy: str = "wait_all",
    quorum: Optional[int] = None,
    max_retries: int = 3,
    backoff0: float = 1.0,
    deadline: Optional[float] = None,
    quarantine_rounds: int = 0,
    guard: Optional[bool] = None,
    guard_max_abs: Optional[float] = None,
) -> Tuple[tamuna_dp.DistTamunaState, Dict[str, Any]]:
    """Multi-round driver: geometric ``L`` per round (host ``rng``), fused
    rounds on device, metrics drained every ``flush_every`` rounds.

    Steady state does no per-local-step host->device transfer and no
    per-round host sync; the only blocking points are the trace drain (once
    per flush) and checkpoint saves.  Returns the final state and the last
    drained per-round metrics row.

    ``plan`` (a ``repro.dist.cohort.CohortPlan``) drives *non-uniform*
    cohort sampling — availability models, latency weighting — from the
    host: the plan is indexed by the GLOBAL round counter (``state.round``
    at entry plus the loop index), so a restored checkpoint replays the
    identical schedule; per round it uploads the tiny ``(c,)`` cohort and
    ``(n,)`` DownCom mask.  ``plan=None`` (the default) keeps cohort
    selection on device, derived from the comm key (uniform).

    ``faults`` (a ``repro.dist.faults.FaultPlan``) turns on the
    fault-tolerant round path (DESIGN.md §12).  Per round the plan's
    deterministic draws decide which cohort members drop their uplink,
    which corrupt their payload, and each member's latency; the driver
    resolves the round's *survivors* host-side (the draws are replayable,
    so a failed attempt never executes on device) and runs exactly one
    device round per global round with the arrival mask:

      wait_all  accept whatever arrives, but aggregate with the legacy
                1/s semantics (``correct=False``) — the biased control.
                Under a zero-fault plan this passes ``arrived=None`` and
                is bitwise identical to the fault-free driver.
      quorum    require ``quorum`` arrivals (default ``c // 2 + 1``);
                on a miss, resample the cohort (``plan.cohort(g, attempt)``
                or the attempt-folded comm key) and redraw faults, up to
                ``max_retries`` times with capped exponential backoff
                (``backoff0 * 2**attempt`` simulated seconds, accounted in
                the metrics, never slept).  Survivor-aware aggregation
                (``correct=True``).
      deadline  admit only members whose drawn latency is ``<= deadline``
                (and that didn't drop); survivor-aware aggregation.

    ``guard`` (default: on iff the fault model corrupts payloads) enables
    the nonfinite payload guard: corrupted members are demoted to
    non-arrived before aggregation and, when ``quarantine_rounds > 0`` and
    a ``plan`` is given, quarantined from selection for that many rounds
    starting at detection + 2 (the next round's cohort is already
    committed as this round's DownCom target).
    """
    # never sample past the engine's bucket cap: round_fn silently clamps
    # executed steps to its own max_L, so a larger caller cap would desync
    # the host-side L from the executed count
    engine_cap = getattr(round_fn, "max_L", None)
    max_L = max_L or engine_cap or 16
    if engine_cap:
        max_L = min(max_L, engine_cap)
    flush_every = max(1, min(flush_every, rounds))

    import numpy as np

    n = getattr(round_fn, "n", None)
    c = getattr(round_fn, "c", None)
    if policy not in ROUND_POLICIES:
        raise ValueError(f"unknown policy {policy!r}; pick from "
                         f"{ROUND_POLICIES}")
    if guard is None:
        guard = faults is not None and faults.model.p_corrupt > 0
    faulted = faults is not None and (
        not faults.is_zero or policy != "wait_all"
        or quarantine_rounds > 0 or bool(guard)
    )
    if faults is None and (policy != "wait_all" or quarantine_rounds > 0):
        raise ValueError("round policies and quarantine need a fault plan")
    if policy == "deadline" and deadline is None:
        raise ValueError("deadline policy needs a deadline (seconds)")
    if quarantine_rounds > 0 and plan is None:
        raise ValueError("quarantine needs a CohortPlan to feed back into")
    if faulted:
        if n is None or c is None:
            raise ValueError("fault-tolerant rounds need a round_fn built "
                             "by make_round_fn (n and c attributes)")
        if faults.n != n:
            raise ValueError(f"fault plan covers {faults.n} clients, "
                             f"round_fn has n={n}")

    start_round = int(state.round) if (plan is not None or faulted) else 0
    carry = init_carry(state, key, flush_every, robust_n=n if faulted else 0)
    q = quorum if quorum is not None else (c // 2 + 1 if c else None)

    if faulted and plan is None:
        # replay the engine's on-device uniform cohorts host-side so the
        # arrival mask lines up with the rows the round actually trains
        ck0 = np.asarray(jax.device_get(carry.comm_key))

    def host_cohort(g: int, attempt: int = 0) -> np.ndarray:
        if plan is not None:
            return np.asarray(plan.cohort(g, attempt))
        ckey = comm_round_key(jnp.asarray(ck0), g)
        if attempt > 0:
            ckey = jax.random.fold_in(ckey, attempt)
        return np.asarray(jax.device_get(
            tamuna_dp.round_cohort(ckey, n, c)
        ))

    resolved: Dict[int, Any] = {}

    def resolve(g: int):
        """The round's survivors, after the policy's retries: a dict with
        cohort/member/arrived/corrupt masks plus host-side accounting."""
        got = resolved.get(g)
        if got is not None:
            return got
        attempt, backoff, quorum_miss = 0, 0.0, 0
        while True:
            cohort = host_cohort(g, attempt)
            member = np.zeros(n, bool)
            member[cohort] = True
            arrived = member & ~faults.drops(g, attempt)
            if policy == "deadline":
                arrived &= faults.delays(g, attempt) <= deadline
            if (policy == "quorum" and int(arrived.sum()) < q
                    and attempt < max_retries):
                quorum_miss += 1
                backoff += backoff0 * (2.0 ** attempt)
                attempt += 1
                continue
            break
        res = {
            "cohort": cohort,
            "member": member,
            "arrived": arrived,
            "corrupt": faults.corrupts(g, attempt) & member,
            "retries": attempt,
            "backoff": backoff,
            "quorum_miss": quorum_miss,
        }
        resolved[g] = res
        return res

    pending = []  # global round indices awaiting drain
    fmeta = []  # per-pending-round host-side fault accounting
    total_steps = 0
    last: Dict[str, Any] = {}
    for r in range(rounds):
        L = tamuna_dp.sample_round_length(rng, p, max_L=max_L)
        slot = len(pending)
        g = start_round + r
        if faulted:
            res = resolve(g)
            nxt = resolve(g + 1)
            carry = round_fn(
                carry, data, L, slot,
                cohort=jnp.asarray(res["cohort"], jnp.int32),
                down=jnp.asarray(nxt["member"]),
                arrived=jnp.asarray(res["arrived"]),
                corrupt=(jnp.asarray(res["corrupt"])
                         if faults.model.p_corrupt > 0 else None),
                correct=(policy != "wait_all"),
                guard=bool(guard),
                corrupt_mode=faults.model.corrupt_mode,
                blowup=faults.model.blowup,
                guard_max_abs=guard_max_abs,
            )
            fmeta.append({
                "retries": res["retries"],
                "backoff_s": res["backoff"],
                "quorum_miss": res["quorum_miss"],
                "round_latency_s": float(
                    faults.delays(g, res["retries"])[res["arrived"]].max()
                    if res["arrived"].any() else 0.0
                ) + res["backoff"],
            })
            if quarantine_rounds > 0:
                # drain this round's guard verdict NOW: quarantine must
                # land before round g+2's cohort is resolved
                bad = np.asarray(
                    jax.device_get(carry.traces["bad"][slot])
                )
                if bad.any():
                    ids = np.where(bad)[0]
                    plan.quarantine(ids, g + 2, g + 1 + quarantine_rounds)
                    for k in [k for k in resolved if k >= g + 2]:
                        del resolved[k]
        elif plan is not None:
            carry = round_fn(
                carry, data, L, slot,
                cohort=jnp.asarray(plan.cohort(g), jnp.int32),
                down=jnp.asarray(plan.member_mask(g + 1)),
            )
        else:
            carry = round_fn(carry, data, L, slot)
        pending.append(r)
        if len(pending) == flush_every or r == rounds - 1:
            tr = jax.device_get(carry.traces)  # the only host sync
            for i, gr in enumerate(pending):
                executed = int(tr["steps"][i])  # device truth, not host L
                total_steps += executed
                last = {
                    "round": gr,
                    "L": executed,
                    "loss": float(tr["loss_sum"][i]) / max(executed, 1),
                    "local_steps": total_steps,
                    "up_floats": float(tr["up_floats"][i]),
                    "down_floats": float(tr["down_floats"][i]),
                    "up_bytes": float(tr["up_bytes"][i]),
                    "down_bytes": float(tr["down_bytes"][i]),
                }
                if faulted:
                    last.update({
                        "arrivals": int(tr["arrivals"][i]),
                        "corrupted": int(tr["corrupted"][i]),
                        **fmeta[i],
                    })
                if logger is not None:
                    logger.log(gr, last)
            pending = []
            fmeta = []
            carry = carry._replace(
                traces=_zero_traces(flush_every, n if faulted else 0)
            )
        if (checkpoint_dir and checkpoint_every
                and (r + 1) % checkpoint_every == 0):
            from repro import checkpoint

            checkpoint.save(
                os.path.join(checkpoint_dir, f"step_{r + 1}"),
                carry.state, r + 1,
            )
    return carry.state, last
