"""Fused round engine: the round, not the local step, is the unit of
compiled execution.

The seed driver dispatched one un-donated jit call per local step, blocked
on a host-side sampler between steps, and synced the loss to the host every
round.  Here a whole round runs as donated compiled programs:

  * ``make_round_fn(cfg, tcfg, mesh)`` compiles one donated program per
    round-length *bucket*: ``B`` local steps under ``jax.lax.scan`` followed
    by the comm step behind ``lax.cond``.  A host-sampled geometric length
    ``L`` is decomposed into descending powers of two
    (``round_chunks``), every chunk but the last runs with the comm branch
    off, so across any sequence of rounds at most ``log2(max_L) + 1``
    distinct programs ever compile (the cache is inspectable as
    ``round_fn.cache``).
  * Data is sampled **on device** inside the scan body
    (``repro.data.pipeline.device_sample_batch``) from PRNG keys folded out
    of the scan carry: ``data_step_key(base, t)`` for local step ``t`` and
    ``comm_round_key(base, round)`` for the round's comm step.  Steady-state
    training performs zero host->device transfers.
  * ``run_rounds`` drives multiple rounds with on-device metric
    accumulation: per-round loss / L / comm-float traces are written with
    ``.at[slot]`` updates inside the donated programs and drained to a
    ``MetricLogger`` every ``flush_every`` rounds — the drain is the only
    host sync.
  * **Elastic partial participation** (DESIGN.md §11): at ``c < n`` —
    where cohort rows can vacate hardware (single-device client axis or
    stacked clients; gated default, see ``make_round_fn``) — each chunk
    gathers the round's cohort rows into a compact ``(c, ...)`` state,
    runs its local steps there (O(c·L) compute and gradient memory —
    idle clients do nothing), scatters back, and the comm step's DownCom
    writes only the NEXT round's cohort.  Cohorts come from the round's
    comm key on device (uniform) or a host ``CohortPlan``
    (availability-driven, ``run_rounds(plan=...)``).
  * Both uplinks route through the mask-free comm paths of
    ``repro.dist.comm_ws`` (``tcfg.comm_impl``, default auto: sparse fused
    uplink off-TPU, flat-workspace Pallas kernels on TPU — DESIGN.md §9),
    so the fused round program's comm step never materializes a dense
    ownership mask or scans all ``n`` client rows for the UpCom.  With
    ``comm_impl="pallas"`` the meshed comm step is the shard-resident
    engine (§10): ``make_comm_step`` hands the mesh and the stacked state
    specs to ``comm_ws``, which shard_maps the kernels over the dp axes
    inside the same donated round program — per-shard uplinks, one
    d-sized psum of the partials, behind the same ``lax.cond``.

  * **Pipelined rounds under bounded staleness** (DESIGN.md §14): the
    bulk-synchronous barrier above pays the slowest cohort member's
    straggler tail every round.  ``make_pipelined_round_fn`` splits the
    round into separately donated *stage* (cohort gather + ``L`` local
    steps into a compact ping-pong payload buffer) and *commit* (scatter
    + UpCom/h-update/DownCom) programs, and ``run_rounds_pipelined``
    keeps up to ``τ`` rounds in flight: round ``t``'s commit is deferred
    to pipeline slot ``t+τ`` so its stragglers get ``τ`` rounds of
    wall-clock grace (late uplinks admitted into the deferred rebuild, or
    demoted to dropped through PR 6's ``arrived``-mask survivor
    aggregation), the DownCom prefetches ``x_bar`` to the cohort that
    joins next (global-round indexed, known at dispatch time), and a
    host-side simulated clock driven by ``FaultPlan``/``EmpiricalDelays``
    latency draws prices the overlap.  In-flight cohorts are pairwise
    disjoint (a client mid-round cannot join a new cohort), which is what
    makes the deferred commit exact: nothing touches a staged cohort's
    rows between its gather and its commit.  ``τ=0`` runs the identical
    op sequence as the synchronous engine (stage, then commit
    immediately) — equivalence-tested to ≤1e-6 for both uplinks.

The key-derivation helpers are public so the per-step reference path (and
the equivalence tests) can replay the exact same schedule.  See DESIGN.md
§8.
"""

from __future__ import annotations

import os
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import sharding, tamuna_dp
from repro.dist.tamuna_dp import _as_key
from repro.models.transformer import ModelConfig

__all__ = [
    "RoundCarry",
    "round_chunks",
    "data_step_key",
    "comm_round_key",
    "default_elastic",
    "make_round_fn",
    "make_fused_round",
    "init_carry",
    "run_rounds",
    "make_pipelined_round_fn",
    "run_rounds_pipelined",
    "pipeline_checkpoint_save",
    "pipeline_checkpoint_restore",
    "pipeline_latest_step",
]


def default_elastic(n: int, c: int, dp_total: int) -> bool:
    """Whether the engine gathers by default: only where cohort rows can
    actually vacate hardware — a single-device client axis, or stacked
    clients (``n > dp``) whose cohort divides the dp extent.  With one
    client per device the compact ``(c, ...)`` state cannot shard over
    dp: GSPMD replicates the cohort's gradient work onto every shard and
    remats the gather (measured ~500x round bytes on the pod16x16
    dry-run — DESIGN.md §11, EXPERIMENTS §Perf 9).  Shared by
    ``make_round_fn``, ``make_fused_round``, and the per-step trainer."""
    return c < n and (dp_total == 1 or (n > dp_total and c % dp_total == 0))

# Batch sampler contract: ``sample_batch(data, key) -> {"tokens": ..., ...}``
# where ``data`` is a device-resident pytree passed alongside the donated
# carry as a read-only argument (uploaded once, never baked into programs,
# never donated — the caller's handle stays valid).
SampleFn = Callable[[Any, jax.Array], Dict[str, jax.Array]]

TRACE_KEYS = ("loss_sum", "steps", "up_floats", "down_floats",
              "up_bytes", "down_bytes")
# extra per-round device traces of the fault-tolerant driver (present in
# the carry only when ``init_carry(robust_n=...)`` > 0): arrivals = cohort
# members whose uplink was aggregated, corrupted = members zeroed by the
# payload guard, bad = the (flush_every, n) guard mask the quarantine
# feedback reads
FAULT_TRACE_KEYS = ("arrivals", "corrupted", "bad")
ROUND_POLICIES = ("wait_all", "quorum", "deadline")


class RoundCarry(NamedTuple):
    """Everything a round program owns; donated wholesale every call.  The
    pipeline tables stay OUTSIDE the carry (a separate, read-only argument)
    so donation never invalidates the caller's ``device_data()`` handle."""

    state: tamuna_dp.DistTamunaState
    t: jax.Array  # int32 scalar: total local steps taken so far
    data_key: jax.Array  # (2,) uint32 base key-data for data sampling
    comm_key: jax.Array  # (2,) uint32 base key-data for comm steps
    traces: Dict[str, jax.Array]  # per-round device traces, slot-indexed


def round_chunks(L: int, max_L: int = 16) -> list:
    """Decompose a round length into descending power-of-two chunks.

    ``sum(round_chunks(L)) == min(L, max_L)`` exactly, and the set of chunk
    sizes that can ever appear is ``{1, 2, ..., 2^floor(log2(max_L))}`` —
    the compile cache is bounded by ``log2(max_L) + 1`` programs.
    """
    L = max(1, min(int(L), int(max_L)))
    return [1 << b for b in range(L.bit_length() - 1, -1, -1)
            if (L >> b) & 1]


def data_step_key(base: jax.Array, t) -> jax.Array:
    """Key for the batch of global local-step ``t`` (typed PRNG key)."""
    return jax.random.fold_in(_as_key(base), t)


def comm_round_key(base: jax.Array, rnd) -> jax.Array:
    """Key for the comm step ending round ``rnd`` (``state.round``)."""
    return jax.random.fold_in(_as_key(base), rnd)


def _zero_traces(flush_every: int, robust_n: int = 0,
                 coverage: bool = False,
                 anomaly: bool = False) -> Dict[str, jax.Array]:
    traces = {
        "loss_sum": jnp.zeros((flush_every,), jnp.float32),
        "steps": jnp.zeros((flush_every,), jnp.int32),
        "up_floats": jnp.zeros((flush_every,), jnp.float32),
        "down_floats": jnp.zeros((flush_every,), jnp.float32),
        "up_bytes": jnp.zeros((flush_every,), jnp.float32),
        "down_bytes": jnp.zeros((flush_every,), jnp.float32),
    }
    if robust_n:
        traces["arrivals"] = jnp.zeros((flush_every,), jnp.int32)
        traces["corrupted"] = jnp.zeros((flush_every,), jnp.int32)
        traces["bad"] = jnp.zeros((flush_every, robust_n), bool)
        if coverage:
            # per-round count of coordinates the survivor-aware UpCom
            # left uncovered (no arrived owner) — the staleness/quality
            # signal of the pipelined driver (DESIGN.md §14)
            traces["uncovered"] = jnp.zeros((flush_every,), jnp.int32)
        if anomaly:
            # per-client distance-to-robust-aggregate scores
            # (robust.anomaly_scores) feeding the EWMA reputation that
            # drives escalating quarantine windows (DESIGN.md §15)
            traces["anomaly"] = jnp.zeros((flush_every, robust_n),
                                          jnp.float32)
    return traces


def _scan_local(local, sample_batch: SampleFn, state, data, dkey, t, B: int,
                clients=None):
    """``B`` local steps under ``lax.scan``, batches sampled on device from
    ``fold_in(dkey, t)``; returns (state, t, summed loss).  ``clients``
    restricts the sample to the round's cohort rows (the state is then the
    compact ``(c, ...)`` gather and per-client streams stay keyed by the
    ACTUAL client ids, invariant to who else participates)."""

    def body(inner, _):
        st, tt, acc = inner
        key = jax.random.fold_in(dkey, tt)
        batch = (sample_batch(data, key) if clients is None
                 else sample_batch(data, key, clients=clients))
        st, m = local(st, **batch)
        return (st, tt + 1, acc + m["loss"]), None

    (state, t, loss_sum), _ = jax.lax.scan(
        body, (state, t, jnp.float32(0.0)), None, length=B
    )
    return state, t, loss_sum


def make_round_fn(
    cfg: ModelConfig,
    tcfg: tamuna_dp.DistTamunaConfig,
    mesh,
    *,
    sample_batch: SampleFn,
    max_L: int = 16,
    n: Optional[int] = None,
    elastic: Optional[bool] = None,
):
    """Build ``round_fn(carry, data, L, slot, cohort=None, down=None) ->
    carry`` running one round.

    ``data`` is the device-resident pipeline table pytree (read-only, never
    donated); ``L`` is the (host-sampled) number of local steps; ``slot`` is
    the trace row this round writes (``global_round % flush_every``).  The
    callable exposes ``round_fn.cache`` (bucket -> compiled program),
    ``round_fn.max_L``, ``round_fn.n``, ``round_fn.c``, ``round_fn.elastic``.

    **Elastic partial participation** (default whenever ``tcfg.c < n``,
    DESIGN.md §11): every chunk gathers the round's ``c`` cohort rows into
    a compact ``(c, ...)`` state, runs its local steps there (batches
    sampled for cohort clients only), and scatters back — local compute
    and gradient memory are O(c·L), idle clients do nothing.  The cohort
    is derived on device from the round's comm key
    (``tamuna_dp.round_cohort(comm_round_key(base, round), n, c)`` — every
    chunk of a round sees the same ``state.round``, hence the same
    cohort), unless the caller passes an explicit ``cohort`` (host plans:
    ``repro.dist.cohort.CohortPlan`` for availability-driven sampling).
    The comm step's DownCom then targets only the NEXT round's cohort
    (``down``; device-derived symmetrically when None), so clients sitting
    out a round are bitwise untouched.

    The default only goes elastic where cohort rows can actually vacate
    hardware: a single-device client axis, or stacked clients
    (``n > dp``) whose cohort divides the dp extent.  With one client per
    device (``n == dp``) the compact ``(c, ...)`` state cannot shard over
    the dp axis — GSPMD replicates the cohort's gradient work onto every
    shard and remats the gather (measured on the pod16x16 dry-run:
    ~500x the round's memory traffic, EXPERIMENTS §Perf 9) — so those
    placements keep the all-rows body, whose DownCom must broadcast
    (every row trains, every row re-syncs to ``x_bar``).  ``elastic=``
    overrides the default either way.
    """
    n = n or sharding.n_clients(mesh)
    c = tcfg.c
    if elastic is None:
        elastic = default_elastic(n, c, sharding.n_clients(mesh))
    local = tamuna_dp.make_local_step(cfg, tcfg)
    comm = tamuna_dp.make_comm_step(cfg, tcfg, mesh, n=n)

    def chunk_fn(B: int, carry: RoundCarry, data, do_comm, slot,
                 cohort, down, arrived=None, corrupt=None, byz=None, *,
                 correct: bool = True, guard: bool = False,
                 guard_mode: str = "nonfinite",
                 corrupt_mode: str = "nan", blowup: float = 1e8,
                 guard_max_abs: Optional[float] = None,
                 adversary: str = "none", byz_scale: float = -10.0,
                 byz_z: float = 1.5) -> RoundCarry:
        state, t, dk, ck, traces = carry
        if elastic:
            if cohort is None:
                cohort = tamuna_dp.round_cohort(
                    comm_round_key(ck, state.round), n, c
                )
            if down is None:
                down = tamuna_dp.member_mask(
                    tamuna_dp.round_cohort(
                        comm_round_key(ck, state.round + 1), n, c
                    ), n,
                )
            compact = tamuna_dp.gather_cohort(state, cohort)
            compact, t, loss_sum = _scan_local(
                local, sample_batch, compact, data, _as_key(dk), t, B,
                clients=cohort,
            )
            state = tamuna_dp.scatter_cohort(state, compact, cohort)
        else:
            # all-rows body: every row trains, so every row must re-sync
            # to x_bar at comm time — a masked DownCom would leave
            # non-cohort rows on their (discarded) local trajectories
            down = None
            state, t, loss_sum = _scan_local(
                local, sample_batch, state, data, _as_key(dk), t, B
            )

        if arrived is None:
            def with_comm(st):
                ckey = comm_round_key(ck, st.round)
                return comm(st, jax.random.key_data(ckey), cohort=cohort,
                            down=down)

            state = jax.lax.cond(do_comm, with_comm, lambda st: st, state)
            new_traces = None
        else:
            # the fault-tolerant comm branch (DESIGN.md §12/§15):
            # corruption and adversarial payloads are injected into the
            # would-be uplink, the payload guard demotes nonfinite (and,
            # in adaptive mode, magnitude-outlier) members to non-arrived
            # (and zeroes their rows so leftover garbage can't reach a
            # later loss), and the comm step aggregates survivors only
            from repro.dist import faults as faults_mod
            from repro.dist import robust as robust_mod

            member = jnp.zeros((n,), bool).at[cohort].set(True)
            want_anom = "anomaly" in traces

            def with_comm(st):
                ckey = comm_round_key(ck, st.round)
                stx = st
                if corrupt is not None:
                    stx = stx._replace(x=faults_mod.corrupt_rows(
                        stx.x, corrupt, corrupt_mode, blowup
                    ))
                arr = arrived & member
                if byz is not None:
                    # Byzantine rows only matter if they arrive; the
                    # inlier attack colludes against the arrived honest
                    stx = stx._replace(x=faults_mod.adversarial_rows(
                        stx.x, byz & arr, arr & ~byz, adversary,
                        byz_scale=byz_scale, byz_z=byz_z,
                    ))
                if guard:
                    bad = faults_mod.nonfinite_clients(
                        stx.x, guard_max_abs
                    ) & member
                    if guard_mode == "adaptive":
                        bad = bad | (robust_mod.magnitude_outliers(
                            stx.x, arr & ~bad
                        ) & member)
                    arr = arr & ~bad
                    stx = stx._replace(x=jax.tree.map(
                        lambda a: jnp.where(
                            bad.reshape((n,) + (1,) * (a.ndim - 1)),
                            jnp.zeros((), a.dtype), a,
                        ),
                        stx.x,
                    ))
                else:
                    bad = jnp.zeros((n,), bool)
                anom = (robust_mod.anomaly_scores(stx.x, arr)
                        if want_anom else jnp.zeros((n,), jnp.float32))
                st2 = comm(stx, jax.random.key_data(ckey), cohort=cohort,
                           down=down, arrived=arr, correct=correct)
                return st2, arr.sum().astype(jnp.int32), bad, anom

            def no_comm(st):
                return (st, jnp.int32(0), jnp.zeros((n,), bool),
                        jnp.zeros((n,), jnp.float32))

            state, arr_cnt, badm, anom = jax.lax.cond(
                do_comm, with_comm, no_comm, state
            )
            new_traces = {
                "arrivals": traces["arrivals"].at[slot].set(arr_cnt),
                "corrupted": traces["corrupted"].at[slot].set(
                    badm.sum().astype(jnp.int32)
                ),
                "bad": traces["bad"].at[slot].set(badm),
            }
            if want_anom:
                new_traces["anomaly"] = traces["anomaly"].at[slot].set(
                    anom
                )
        out_traces = {
            "loss_sum": traces["loss_sum"].at[slot].add(loss_sum),
            "steps": traces["steps"].at[slot].add(B),
            "up_floats": traces["up_floats"].at[slot].set(state.up_floats),
            "down_floats": traces["down_floats"].at[slot].set(
                state.down_floats
            ),
            "up_bytes": traces["up_bytes"].at[slot].set(state.up_bytes),
            "down_bytes": traces["down_bytes"].at[slot].set(
                state.down_bytes
            ),
        }
        if new_traces is not None:
            out_traces.update(new_traces)
        return RoundCarry(state, t, dk, ck, out_traces)

    cache: Dict[Any, Callable] = {}

    def program(B: int, with_plan: bool, fkey=None):
        key = (B, with_plan, fkey)
        if key not in cache:
            if fkey is None:
                cache[key] = jax.jit(
                    partial(chunk_fn, B), donate_argnums=(0,)
                )
            else:
                (correct, guard, gmode, mode, blowup, gmax,
                 adversary, bscale, bz) = fkey
                cache[key] = jax.jit(
                    partial(chunk_fn, B, correct=correct, guard=guard,
                            guard_mode=gmode, corrupt_mode=mode,
                            blowup=blowup, guard_max_abs=gmax,
                            adversary=adversary, byz_scale=bscale,
                            byz_z=bz),
                    donate_argnums=(0,),
                )
        return cache[key]

    def round_fn(carry: RoundCarry, data, L: int, slot,
                 cohort=None, down=None, arrived=None, corrupt=None,
                 byz=None, correct: bool = True, guard: bool = False,
                 guard_mode: str = "nonfinite",
                 corrupt_mode: str = "nan", blowup: float = 1e8,
                 guard_max_abs: Optional[float] = None,
                 adversary: str = "none", byz_scale: float = -10.0,
                 byz_z: float = 1.5) -> RoundCarry:
        chunks = round_chunks(L, max_L)
        slot = jnp.asarray(slot, jnp.int32)
        with_plan = cohort is not None
        if with_plan and down is None:
            # a host plan must pin the DownCom too: without it the engine
            # would derive a (different) uniform next cohort on device
            raise ValueError("explicit cohort needs an explicit down mask")
        if arrived is None:
            if corrupt is not None:
                raise ValueError("corrupt mask needs an arrived mask")
            for i, B in enumerate(chunks):
                do_comm = jnp.asarray(i == len(chunks) - 1)
                carry = program(B, with_plan)(carry, data, do_comm, slot,
                                              cohort, down)
            return carry
        # fault-tolerant rounds carry the arrival mask into every chunk
        # (only the comm chunk consumes it) plus the static fault config
        # in the compile key; the carry must have been built with
        # init_carry(robust_n=n)
        if not with_plan:
            raise ValueError("fault injection needs an explicit cohort "
                             "(resolve it host-side, see run_rounds)")
        fkey = (bool(correct), bool(guard), str(guard_mode),
                str(corrupt_mode), float(blowup),
                None if guard_max_abs is None else float(guard_max_abs),
                str(adversary), float(byz_scale), float(byz_z))
        arrived = jnp.asarray(arrived).astype(bool)
        if corrupt is not None:
            corrupt = jnp.asarray(corrupt).astype(bool)
        if byz is not None:
            byz = jnp.asarray(byz).astype(bool)
        for i, B in enumerate(chunks):
            do_comm = jnp.asarray(i == len(chunks) - 1)
            carry = program(B, with_plan, fkey)(
                carry, data, do_comm, slot, cohort, down, arrived,
                corrupt, byz
            )
        return carry

    round_fn.cache = cache
    round_fn.max_L = max_L
    round_fn.n = n
    round_fn.c = c
    round_fn.elastic = elastic
    return round_fn


def make_fused_round(
    cfg: ModelConfig,
    tcfg: tamuna_dp.DistTamunaConfig,
    mesh,
    *,
    sample_batch: SampleFn,
    L: int,
    n: Optional[int] = None,
    elastic: Optional[bool] = None,
):
    """Static-``L`` fused round ``fn(state, key_data, data) -> (state, loss)``
    with an unconditional comm step — the shape the dry-run lowers so the
    roofline artifacts see the scanned round, and the bench times.  At
    ``c < n`` this is the elastic round (cohort gather -> O(c·L) local
    compute -> scatter -> comm; ``elastic=False`` forces the all-rows
    contrast), with the cohort derived in-program from the comm key, so
    the lowered HLO's gradient FLOPs scale with ``c`` — the artifact the
    idle-clients-do-no-work regression checks.  Default elasticity is
    ``default_elastic`` (gathering is a pessimization when cohort rows
    cannot vacate hardware)."""
    n = n or sharding.n_clients(mesh)
    c = tcfg.c
    if elastic is None:
        elastic = default_elastic(n, c, sharding.n_clients(mesh))
    local = tamuna_dp.make_local_step(cfg, tcfg)
    comm = tamuna_dp.make_comm_step(cfg, tcfg, mesh, n=n)

    def fn(state, key_data, data):
        kd, kc = jax.random.split(_as_key(key_data))
        t0 = jnp.zeros((), jnp.int32)
        ckey = comm_round_key(jax.random.key_data(kc), state.round)
        if elastic:
            cohort = tamuna_dp.round_cohort(ckey, n, c)
            compact = tamuna_dp.gather_cohort(state, cohort)
            compact, _, loss_sum = _scan_local(
                local, sample_batch, compact, data, kd, t0, L,
                clients=cohort,
            )
            state = tamuna_dp.scatter_cohort(state, compact, cohort)
            # DownCom broadcasts here (down=None): each call of this
            # static round derives cohorts from ITS OWN key, so a mask
            # aimed at "this key's next cohort" would not match the
            # cohort the NEXT call actually draws — a client could then
            # enter a round without ever receiving x_bar.  The chunked
            # engine (make_round_fn) can target the true next cohort
            # because its comm key base is fixed in the carry.
            state = comm(state, jax.random.key_data(ckey), cohort=cohort)
        else:
            state, _, loss_sum = _scan_local(
                local, sample_batch, state, data, kd, t0, L,
            )
            state = comm(state, jax.random.key_data(ckey))
        return state, loss_sum / L

    return fn


def init_carry(
    state: tamuna_dp.DistTamunaState,
    key: jax.Array,
    flush_every: int,
    robust_n: int = 0,
    coverage: bool = False,
    anomaly: bool = False,
) -> RoundCarry:
    kd, kc = jax.random.split(_as_key(key))
    return RoundCarry(
        state=state,
        t=jnp.zeros((), jnp.int32),
        data_key=jax.random.key_data(kd),
        comm_key=jax.random.key_data(kc),
        traces=_zero_traces(flush_every, robust_n, coverage, anomaly),
    )


def _make_fault_resolver(faults, *, n: int, policy: str, q, max_retries: int,
                         backoff0: float, deadline, host_cohort):
    """Host-side survivor resolution shared by the synchronous and the
    τ=0 pipelined drivers (identical retry/backoff semantics, so the two
    admit bit-identical arrival masks).  ``resolve(g)`` returns a dict
    with cohort/member/arrived/corrupt masks plus retry accounting;
    results are memoized in ``resolve.cache`` (the quarantine feedback
    purges entries past the detection round)."""
    resolved: Dict[int, Any] = {}

    def resolve(g: int):
        import numpy as np

        got = resolved.get(g)
        if got is not None:
            return got
        attempt, backoff, quorum_miss = 0, 0.0, 0
        while True:
            cohort = host_cohort(g, attempt)
            member = np.zeros(n, bool)
            member[cohort] = True
            arrived = member & ~faults.drops(g, attempt)
            if policy == "deadline":
                arrived &= faults.delays(g, attempt) <= deadline
            if (policy == "quorum" and int(arrived.sum()) < q
                    and attempt < max_retries):
                quorum_miss += 1
                backoff += backoff0 * (2.0 ** attempt)
                attempt += 1
                continue
            break
        res = {
            "cohort": cohort,
            "member": member,
            "arrived": arrived,
            "corrupt": faults.corrupts(g, attempt) & member,
            "retries": attempt,
            "backoff": backoff,
            "quorum_miss": quorum_miss,
        }
        resolved[g] = res
        return res

    resolve.cache = resolved
    return resolve


def run_rounds(
    state: tamuna_dp.DistTamunaState,
    *,
    round_fn,
    data: Any,
    key: jax.Array,
    rounds: int,
    rng,
    p: float,
    flush_every: int = 10,
    logger=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    max_L: Optional[int] = None,
    plan=None,
    faults=None,
    policy: str = "wait_all",
    quorum: Optional[int] = None,
    max_retries: int = 3,
    backoff0: float = 1.0,
    deadline: Optional[float] = None,
    quarantine_rounds: int = 0,
    guard: Optional[bool] = None,
    guard_max_abs: Optional[float] = None,
    guard_mode: Optional[str] = None,
    reputation=None,
) -> Tuple[tamuna_dp.DistTamunaState, Dict[str, Any]]:
    """Multi-round driver: geometric ``L`` per round (host ``rng``), fused
    rounds on device, metrics drained every ``flush_every`` rounds.

    Steady state does no per-local-step host->device transfer and no
    per-round host sync; the only blocking points are the trace drain (once
    per flush) and checkpoint saves.  Returns the final state and the last
    drained per-round metrics row.

    ``plan`` (a ``repro.dist.cohort.CohortPlan``) drives *non-uniform*
    cohort sampling — availability models, latency weighting — from the
    host: the plan is indexed by the GLOBAL round counter (``state.round``
    at entry plus the loop index), so a restored checkpoint replays the
    identical schedule; per round it uploads the tiny ``(c,)`` cohort and
    ``(n,)`` DownCom mask.  ``plan=None`` (the default) keeps cohort
    selection on device, derived from the comm key (uniform).

    ``faults`` (a ``repro.dist.faults.FaultPlan``) turns on the
    fault-tolerant round path (DESIGN.md §12).  Per round the plan's
    deterministic draws decide which cohort members drop their uplink,
    which corrupt their payload, and each member's latency; the driver
    resolves the round's *survivors* host-side (the draws are replayable,
    so a failed attempt never executes on device) and runs exactly one
    device round per global round with the arrival mask:

      wait_all  accept whatever arrives, but aggregate with the legacy
                1/s semantics (``correct=False``) — the biased control.
                Under a zero-fault plan this passes ``arrived=None`` and
                is bitwise identical to the fault-free driver.
      quorum    require ``quorum`` arrivals (default ``c // 2 + 1``);
                on a miss, resample the cohort (``plan.cohort(g, attempt)``
                or the attempt-folded comm key) and redraw faults, up to
                ``max_retries`` times with capped exponential backoff
                (``backoff0 * 2**attempt`` simulated seconds, accounted in
                the metrics, never slept).  Survivor-aware aggregation
                (``correct=True``).
      deadline  admit only members whose drawn latency is ``<= deadline``
                (and that didn't drop); survivor-aware aggregation.

    ``guard`` (default: on iff the fault model corrupts payloads or
    carries a Byzantine adversary) enables the payload guard: flagged
    members are demoted to non-arrived before aggregation and, when
    ``quarantine_rounds > 0`` and a ``plan`` is given, quarantined from
    selection for that many rounds starting at detection + 2 (the next
    round's cohort is already committed as this round's DownCom target).
    ``guard_mode`` picks the detector: ``"nonfinite"`` (NaN/Inf rows
    only) or ``"adaptive"`` (nonfinite plus the median + k·MAD payload
    norm outlier band of ``robust.magnitude_outliers``).  The default is
    adaptive whenever the fault model can emit FINITE garbage that the
    nonfinite check waves through — ``corrupt_mode="blowup"`` with no
    ``guard_max_abs``, or any adversary model (DESIGN.md §15).

    ``reputation`` (``True`` or a ``robust.Reputation``; needs ``plan``
    and ``faults``) turns on the anomaly feedback loop: each round's
    per-client distance-to-robust-aggregate scores
    (``robust.anomaly_scores``, traced on device) feed an EWMA; clients
    whose EWMA crosses the threshold are quarantined for escalating
    windows (``base_rounds * 2**strikes``).  Pass a ``Reputation``
    restored via ``from_state_dict`` to resume the schedule bit-exactly.
    """
    # never sample past the engine's bucket cap: round_fn silently clamps
    # executed steps to its own max_L, so a larger caller cap would desync
    # the host-side L from the executed count
    engine_cap = getattr(round_fn, "max_L", None)
    max_L = max_L or engine_cap or 16
    if engine_cap:
        max_L = min(max_L, engine_cap)
    flush_every = max(1, min(flush_every, rounds))

    import numpy as np

    n = getattr(round_fn, "n", None)
    c = getattr(round_fn, "c", None)
    if policy not in ROUND_POLICIES:
        raise ValueError(f"unknown policy {policy!r}; pick from "
                         f"{ROUND_POLICIES}")
    adversarial = faults is not None and faults.model.adversarial
    if guard is None:
        guard = faults is not None and (faults.model.p_corrupt > 0
                                        or adversarial)
    if guard_mode is None:
        # ISSUE 9 fix: the nonfinite check admits FINITE corruption —
        # blowup rows (1e8-scaled, faults.py corrupt_rows) and
        # adversarial payloads pass it whenever guard_max_abs is unset,
        # so those models default to the adaptive magnitude guard
        guard_mode = ("adaptive" if bool(guard) and guard_max_abs is None
                      and faults is not None
                      and (adversarial
                           or (faults.model.p_corrupt > 0
                               and faults.model.corrupt_mode == "blowup"))
                      else "nonfinite")
    if guard_mode not in ("nonfinite", "adaptive"):
        raise ValueError(f"unknown guard_mode {guard_mode!r}; pick "
                         "'nonfinite' or 'adaptive'")
    faulted = faults is not None and (
        not faults.is_zero or policy != "wait_all"
        or quarantine_rounds > 0 or bool(guard)
    )
    if faults is None and (policy != "wait_all" or quarantine_rounds > 0):
        raise ValueError("round policies and quarantine need a fault plan")
    if policy == "deadline" and deadline is None:
        raise ValueError("deadline policy needs a deadline (seconds)")
    if quarantine_rounds > 0 and plan is None:
        raise ValueError("quarantine needs a CohortPlan to feed back into")
    if faulted:
        if n is None or c is None:
            raise ValueError("fault-tolerant rounds need a round_fn built "
                             "by make_round_fn (n and c attributes)")
        if faults.n != n:
            raise ValueError(f"fault plan covers {faults.n} clients, "
                             f"round_fn has n={n}")
    if plan is not None and getattr(plan, "weighted", False):
        import warnings

        # known bias, documented in DESIGN.md §11: aggregation never
        # reweights by 1/(n p_i), so non-uniform selection pulls the
        # fixed point toward frequently-sampled clients (full fix is a
        # future PR — this warning pins the gap)
        warnings.warn(
            "CohortPlan has non-uniform selection weights but run_rounds "
            "aggregates without 1/(n p_i) importance reweighting; the "
            "fixed point is biased toward frequently-sampled clients "
            "(DESIGN.md §11)",
            UserWarning, stacklevel=2,
        )
    rep = None
    if reputation is not None and reputation is not False:
        if plan is None or faults is None or not faulted:
            raise ValueError("reputation feedback needs a CohortPlan and "
                             "a fault plan")
        from repro.dist import robust as robust_mod

        rep = (reputation
               if isinstance(reputation, robust_mod.Reputation)
               else robust_mod.Reputation(n))
        if rep.n != n:
            raise ValueError(f"reputation covers {rep.n} clients, "
                             f"round_fn has n={n}")

    start_round = int(state.round) if (plan is not None or faulted) else 0
    carry = init_carry(state, key, flush_every,
                       robust_n=n if faulted else 0,
                       anomaly=rep is not None)
    q = quorum if quorum is not None else (c // 2 + 1 if c else None)
    byz_mask = (jnp.asarray(faults.byzantine) if faulted and adversarial
                else None)

    if faulted and plan is None:
        # replay the engine's on-device uniform cohorts host-side so the
        # arrival mask lines up with the rows the round actually trains
        ck0 = np.asarray(jax.device_get(carry.comm_key))

    def host_cohort(g: int, attempt: int = 0) -> np.ndarray:
        if plan is not None:
            return np.asarray(plan.cohort(g, attempt))
        ckey = comm_round_key(jnp.asarray(ck0), g)
        if attempt > 0:
            ckey = jax.random.fold_in(ckey, attempt)
        return np.asarray(jax.device_get(
            tamuna_dp.round_cohort(ckey, n, c)
        ))

    resolve = (_make_fault_resolver(
        faults, n=n, policy=policy, q=q, max_retries=max_retries,
        backoff0=backoff0, deadline=deadline, host_cohort=host_cohort,
    ) if faulted else None)

    pending = []  # global round indices awaiting drain
    fmeta = []  # per-pending-round host-side fault accounting
    total_steps = 0
    last: Dict[str, Any] = {}
    for r in range(rounds):
        L = tamuna_dp.sample_round_length(rng, p, max_L=max_L)
        slot = len(pending)
        g = start_round + r
        if faulted:
            res = resolve(g)
            nxt = resolve(g + 1)
            carry = round_fn(
                carry, data, L, slot,
                cohort=jnp.asarray(res["cohort"], jnp.int32),
                down=jnp.asarray(nxt["member"]),
                arrived=jnp.asarray(res["arrived"]),
                corrupt=(jnp.asarray(res["corrupt"])
                         if faults.model.p_corrupt > 0 else None),
                byz=byz_mask,
                correct=(policy != "wait_all"),
                guard=bool(guard),
                guard_mode=guard_mode,
                corrupt_mode=faults.model.corrupt_mode,
                blowup=faults.model.blowup,
                guard_max_abs=guard_max_abs,
                adversary=faults.model.adversary,
                byz_scale=faults.model.byz_scale,
                byz_z=faults.model.byz_z,
            )
            fmeta.append({
                "retries": res["retries"],
                "backoff_s": res["backoff"],
                "quorum_miss": res["quorum_miss"],
                "round_latency_s": float(
                    faults.delays(g, res["retries"])[res["arrived"]].max()
                    if res["arrived"].any() else 0.0
                ) + res["backoff"],
            })
            if quarantine_rounds > 0:
                # drain this round's guard verdict NOW: quarantine must
                # land before round g+2's cohort is resolved
                bad = np.asarray(
                    jax.device_get(carry.traces["bad"][slot])
                )
                if bad.any():
                    ids = np.where(bad)[0]
                    plan.quarantine(ids, g + 2, g + 1 + quarantine_rounds)
                    for k in [k for k in resolve.cache if k >= g + 2]:
                        del resolve.cache[k]
            if rep is not None:
                # same timing constraint as the guard feedback: the EWMA
                # verdict must land before round g+2's cohort resolves
                anom = np.asarray(
                    jax.device_get(carry.traces["anomaly"][slot])
                )
                badr = np.asarray(
                    jax.device_get(carry.traces["bad"][slot])
                )
                # guard-demoted rows were zeroed on device — their score
                # is a meaningless 0, so keep them out of the EWMA
                wins = rep.update(anom, res["arrived"] & ~badr)
                if wins:
                    for cid, w in wins:
                        plan.quarantine([cid], g + 2, g + 1 + w)
                    for k in [k for k in resolve.cache if k >= g + 2]:
                        del resolve.cache[k]
        elif plan is not None:
            carry = round_fn(
                carry, data, L, slot,
                cohort=jnp.asarray(plan.cohort(g), jnp.int32),
                down=jnp.asarray(plan.member_mask(g + 1)),
            )
        else:
            carry = round_fn(carry, data, L, slot)
        pending.append(r)
        if len(pending) == flush_every or r == rounds - 1:
            tr = jax.device_get(carry.traces)  # the only host sync
            for i, gr in enumerate(pending):
                executed = int(tr["steps"][i])  # device truth, not host L
                total_steps += executed
                last = {
                    "round": gr,
                    "L": executed,
                    "loss": float(tr["loss_sum"][i]) / max(executed, 1),
                    "local_steps": total_steps,
                    "up_floats": float(tr["up_floats"][i]),
                    "down_floats": float(tr["down_floats"][i]),
                    "up_bytes": float(tr["up_bytes"][i]),
                    "down_bytes": float(tr["down_bytes"][i]),
                }
                if faulted:
                    last.update({
                        "arrivals": int(tr["arrivals"][i]),
                        "corrupted": int(tr["corrupted"][i]),
                        **fmeta[i],
                    })
                    if rep is not None:
                        last["anomaly_max"] = float(tr["anomaly"][i].max())
                if logger is not None:
                    logger.log(gr, last)
            pending = []
            fmeta = []
            carry = carry._replace(
                traces=_zero_traces(flush_every, n if faulted else 0,
                                    anomaly=rep is not None)
            )
        if (checkpoint_dir and checkpoint_every
                and (r + 1) % checkpoint_every == 0):
            from repro import checkpoint

            checkpoint.save(
                os.path.join(checkpoint_dir, f"step_{r + 1}"),
                carry.state, r + 1,
            )
    return carry.state, last


# --------------------------------------------------------------------------
# pipelined rounds under bounded staleness (DESIGN.md §14)
# --------------------------------------------------------------------------

# SeedSequence tag for the busy-aware uniform cohort draw of the pipelined
# driver; disjoint from cohort.py (53/59/211) and faults.py (101..113)
_TAG_FREE = 223


def make_pipelined_round_fn(
    cfg: ModelConfig,
    tcfg: tamuna_dp.DistTamunaConfig,
    mesh,
    *,
    sample_batch: SampleFn,
    max_L: int = 16,
    n: Optional[int] = None,
    elastic: Optional[bool] = None,
    coverage: bool = True,
):
    """Build the split-phase round engine ``run_rounds_pipelined`` drives.

    Where ``make_round_fn`` fuses gather -> local steps -> scatter -> comm
    into one donated program per chunk, this engine compiles the round as
    two separately dispatchable halves so the driver can interleave rounds:

      ``stage(carry, data, L, cohort) -> (carry, buf)``
          gather the cohort rows into a compact ``(c, ...)`` payload
          buffer and run the round's ``L`` local steps there (same
          ``round_chunks`` bucketing and compile-cache bound as the fused
          engine).  The carry's full state and traces are passed through
          untouched — a staged round owns nothing but its compact buffer,
          its summed loss, and its step count, all returned in ``buf``.
          The pending buffers of in-flight rounds ARE the double-buffer:
          at ``τ=1`` two compact states ping-pong while the full state
          advances underneath them.

      ``commit(carry, buf, slot, cohort, down, ...) -> carry``
          scatter the staged rows back, run the comm step (UpCom,
          h-update, DownCom to ``down``), inject/guard faults when an
          ``arrived`` mask is given (identical semantics to the fused
          engine's fault branch, DESIGN.md §12), and write ALL of the
          round's traces at ``slot``.  Commits happen in round order, so
          ``state.round`` inside the program is exactly the committing
          round's global index — the comm key replays bit-identically to
          the synchronous engine.

    Soundness rests on the driver's no-overlap invariant: in-flight
    cohorts are pairwise disjoint, so between a round's gather and its
    commit nothing touches its cohort's rows — the deferred scatter+comm
    reads exactly the payload a synchronous round would have read.

    ``coverage=True`` additionally compiles the stats-reporting comm step
    (``tamuna_dp.make_comm_step(with_stats=True)``): fault-tolerant
    commits then trace the number of coordinates the survivor-aware UpCom
    left uncovered — the quality signal the staleness sweeps plot.

    Returns an engine namespace with ``stage``/``commit`` plus the same
    introspection attributes as the fused engine (``cache``, ``max_L``,
    ``n``, ``c``, ``elastic``, and ``coverage``).
    """
    import types

    n = n or sharding.n_clients(mesh)
    c = tcfg.c
    if elastic is None:
        elastic = default_elastic(n, c, sharding.n_clients(mesh))
    local = tamuna_dp.make_local_step(cfg, tcfg)
    comm = tamuna_dp.make_comm_step(cfg, tcfg, mesh, n=n)
    comm_stats = (tamuna_dp.make_comm_step(cfg, tcfg, mesh, n=n,
                                           with_stats=True)
                  if coverage else None)

    def stage_chunk(B: int, carry: RoundCarry, compact, loss, data, clients):
        state, t, dk, ck, traces = carry
        compact, t, ls = _scan_local(
            local, sample_batch, compact, data, _as_key(dk), t, B,
            clients=clients,
        )
        return RoundCarry(state, t, dk, ck, traces), compact, loss + ls

    def stage_chunk_full(B: int, carry: RoundCarry, loss, data):
        state, t, dk, ck, traces = carry
        state, t, ls = _scan_local(
            local, sample_batch, state, data, _as_key(dk), t, B
        )
        return RoundCarry(state, t, dk, ck, traces), loss + ls

    def commit_fn(carry: RoundCarry, compact, loss, steps, slot, cohort,
                  down, arrived=None, corrupt=None, byz=None, *,
                  correct: bool = True, guard: bool = False,
                  guard_mode: str = "nonfinite",
                  corrupt_mode: str = "nan", blowup: float = 1e8,
                  guard_max_abs: Optional[float] = None,
                  adversary: str = "none", byz_scale: float = -10.0,
                  byz_z: float = 1.5) -> RoundCarry:
        state, t, dk, ck, traces = carry
        if elastic:
            state = tamuna_dp.scatter_cohort(state, compact, cohort)
        else:
            # all-rows body: every row trained during stage, so the
            # DownCom must broadcast (see make_round_fn)
            down = None
        ckey = jax.random.key_data(comm_round_key(ck, state.round))
        if arrived is None:
            state = comm(state, ckey, cohort=cohort, down=down)
            new_traces = None
        else:
            from repro.dist import faults as faults_mod
            from repro.dist import robust as robust_mod

            member = jnp.zeros((n,), bool).at[cohort].set(True)
            stx = state
            if corrupt is not None:
                stx = stx._replace(x=faults_mod.corrupt_rows(
                    stx.x, corrupt, corrupt_mode, blowup
                ))
            arr = arrived & member
            if byz is not None:
                stx = stx._replace(x=faults_mod.adversarial_rows(
                    stx.x, byz & arr, arr & ~byz, adversary,
                    byz_scale=byz_scale, byz_z=byz_z,
                ))
            if guard:
                bad = faults_mod.nonfinite_clients(
                    stx.x, guard_max_abs
                ) & member
                if guard_mode == "adaptive":
                    bad = bad | (robust_mod.magnitude_outliers(
                        stx.x, arr & ~bad
                    ) & member)
                arr = arr & ~bad
                stx = stx._replace(x=jax.tree.map(
                    lambda a: jnp.where(
                        bad.reshape((n,) + (1,) * (a.ndim - 1)),
                        jnp.zeros((), a.dtype), a,
                    ),
                    stx.x,
                ))
            else:
                bad = jnp.zeros((n,), bool)
            if comm_stats is not None and "uncovered" in traces:
                state, stats = comm_stats(stx, ckey, cohort=cohort,
                                          down=down, arrived=arr,
                                          correct=correct)
                unc = stats["uncovered"]
            else:
                state = comm(stx, ckey, cohort=cohort, down=down,
                             arrived=arr, correct=correct)
                unc = None
            new_traces = {
                "arrivals": traces["arrivals"].at[slot].set(
                    arr.sum().astype(jnp.int32)
                ),
                "corrupted": traces["corrupted"].at[slot].set(
                    bad.sum().astype(jnp.int32)
                ),
                "bad": traces["bad"].at[slot].set(bad),
            }
            if unc is not None:
                new_traces["uncovered"] = traces["uncovered"].at[slot].set(
                    unc
                )
        out_traces = {
            "loss_sum": traces["loss_sum"].at[slot].set(loss),
            "steps": traces["steps"].at[slot].set(steps),
            "up_floats": traces["up_floats"].at[slot].set(state.up_floats),
            "down_floats": traces["down_floats"].at[slot].set(
                state.down_floats
            ),
            "up_bytes": traces["up_bytes"].at[slot].set(state.up_bytes),
            "down_bytes": traces["down_bytes"].at[slot].set(
                state.down_bytes
            ),
        }
        if new_traces is not None:
            out_traces.update(new_traces)
        return RoundCarry(state, t, dk, ck, out_traces)

    cache: Dict[Any, Callable] = {}

    def gather_prog():
        if "gather" not in cache:
            # NOT donated: the full state stays live in the carry
            cache["gather"] = jax.jit(tamuna_dp.gather_cohort)
        return cache["gather"]

    def stage_prog(B: int):
        key = ("stage", B)
        if key not in cache:
            fn = stage_chunk if elastic else stage_chunk_full
            dn = (0, 1, 2) if elastic else (0, 1)
            cache[key] = jax.jit(partial(fn, B), donate_argnums=dn)
        return cache[key]

    def commit_prog(fkey):
        # only the carry is donated: the (c, ...) compact payload cannot
        # alias any (n, ...) output, so donating it would just warn
        key = ("commit", fkey)
        if key not in cache:
            if fkey is None:
                cache[key] = jax.jit(commit_fn, donate_argnums=(0,))
            else:
                (correct, guard, gmode, mode, blowup, gmax,
                 adversary, bscale, bz) = fkey
                cache[key] = jax.jit(
                    partial(commit_fn, correct=correct, guard=guard,
                            guard_mode=gmode, corrupt_mode=mode,
                            blowup=blowup, guard_max_abs=gmax,
                            adversary=adversary, byz_scale=bscale,
                            byz_z=bz),
                    donate_argnums=(0,),
                )
        return cache[key]

    def stage(carry: RoundCarry, data, L: int, cohort=None):
        chunks = round_chunks(L, max_L)
        loss = jnp.float32(0.0)
        if elastic:
            if cohort is None:
                raise ValueError("elastic stage needs a host-resolved "
                                 "cohort (the driver owns the schedule)")
            cohort = jnp.asarray(cohort, jnp.int32)
            compact = gather_prog()(carry.state, cohort)
            for B in chunks:
                carry, compact, loss = stage_prog(B)(
                    carry, compact, loss, data, cohort
                )
            return carry, {"compact": compact, "loss": loss,
                           "steps": sum(chunks)}
        for B in chunks:
            carry, loss = stage_prog(B)(carry, loss, data)
        return carry, {"compact": None, "loss": loss, "steps": sum(chunks)}

    def commit(carry: RoundCarry, buf, slot, cohort=None, down=None,
               arrived=None, corrupt=None, byz=None,
               correct: bool = True,
               guard: bool = False, guard_mode: str = "nonfinite",
               corrupt_mode: str = "nan", blowup: float = 1e8,
               guard_max_abs: Optional[float] = None,
               adversary: str = "none", byz_scale: float = -10.0,
               byz_z: float = 1.5) -> RoundCarry:
        slot = jnp.asarray(slot, jnp.int32)
        steps = jnp.asarray(buf["steps"], jnp.int32)
        if elastic and cohort is None:
            raise ValueError("elastic commit needs the staged cohort")
        if cohort is not None:
            cohort = jnp.asarray(cohort, jnp.int32)
        if down is not None:
            down = jnp.asarray(down).astype(bool)
        if arrived is None:
            if corrupt is not None:
                raise ValueError("corrupt mask needs an arrived mask")
            return commit_prog(None)(
                carry, buf["compact"], buf["loss"], steps, slot, cohort,
                down,
            )
        if cohort is None:
            raise ValueError("fault-tolerant commit needs an explicit "
                             "cohort (resolve it host-side)")
        fkey = (bool(correct), bool(guard), str(guard_mode),
                str(corrupt_mode), float(blowup),
                None if guard_max_abs is None else float(guard_max_abs),
                str(adversary), float(byz_scale), float(byz_z))
        arrived = jnp.asarray(arrived).astype(bool)
        if corrupt is not None:
            corrupt = jnp.asarray(corrupt).astype(bool)
        if byz is not None:
            byz = jnp.asarray(byz).astype(bool)
        return commit_prog(fkey)(
            carry, buf["compact"], buf["loss"], steps, slot, cohort, down,
            arrived, corrupt, byz,
        )

    return types.SimpleNamespace(
        stage=stage, commit=commit, cache=cache, max_L=max_L, n=n, c=c,
        elastic=elastic, coverage=comm_stats is not None,
    )


def _uniform_cohort_host(ck0, g: int, n: int, c: int,
                         attempt: int = 0):
    """Host replay of the engine's on-device uniform cohort for round
    ``g`` — bit-identical to the in-program derivation (same key fold,
    same ``round_cohort``), so explicit upload preserves the fault-free
    schedule exactly."""
    import numpy as np

    ckey = comm_round_key(jnp.asarray(ck0), g)
    if attempt > 0:
        ckey = jax.random.fold_in(ckey, attempt)
    return np.asarray(jax.device_get(tamuna_dp.round_cohort(ckey, n, c)))


def _free_uniform_cohort(ck0, g: int, n: int, c: int, busy):
    """Uniform cohort over the FREE clients only: with rounds in flight a
    busy client physically cannot join a new cohort, so the pipelined
    driver draws round ``g``'s cohort uniformly from the complement of
    the in-flight set.  Deterministic in ``(comm_key, g, busy)`` — keyed
    off the same per-round comm key as the synchronous schedule, under a
    dedicated stream tag so it never correlates with other draws."""
    import numpy as np

    busy = np.asarray(busy, bool)
    free = np.where(~busy)[0]
    if free.size < c:
        raise ValueError(
            f"only {free.size} free clients for c={c} at round {g}: "
            f"staleness too deep for this fleet (need c*(tau+1) <= n)"
        )
    kd = np.asarray(jax.device_get(jax.random.key_data(
        comm_round_key(jnp.asarray(ck0), g)
    ))).reshape(-1)
    rng = np.random.default_rng(np.random.SeedSequence(
        [int(kd[0]), int(kd[1]), _TAG_FREE]
    ))
    pick = rng.choice(free.size, size=c, replace=False)
    return np.sort(free[pick]).astype(np.int32)


def run_rounds_pipelined(
    state: tamuna_dp.DistTamunaState,
    *,
    round_fn,
    data: Any,
    key: jax.Array,
    rounds: int,
    rng,
    p: float,
    staleness: int = 1,
    flush_every: int = 10,
    logger=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    max_L: Optional[int] = None,
    plan=None,
    faults=None,
    latency=None,
    policy: str = "wait_all",
    quorum: Optional[int] = None,
    max_retries: int = 3,
    backoff0: float = 1.0,
    deadline: Optional[float] = None,
    guard: Optional[bool] = None,
    guard_max_abs: Optional[float] = None,
    guard_mode: Optional[str] = None,
    resume: bool = False,
) -> Tuple[tamuna_dp.DistTamunaState, Dict[str, Any]]:
    """Pipelined multi-round driver: overlap local compute with
    communication under bounded staleness ``τ = staleness``.

    Pipeline step ``u`` first *stages* round ``u`` (cohort gather + local
    steps into a pending payload buffer) and then *commits* round
    ``u - τ`` (scatter + UpCom/h-update/DownCom + traces), so up to ``τ``
    rounds are in flight at once and a committing round's stragglers had
    ``τ`` extra rounds of wall-clock to land.  ``τ=0`` stages and commits
    the same round back to back — the identical op sequence (and, under a
    ``FaultPlan``, the identical host-side survivor resolution) as
    ``run_rounds``.

    Schedule invariants, all host-enforced:

      * **Disjoint in-flight cohorts** — round ``g``'s cohort is drawn
        from the clients NOT in the ``τ`` preceding uncommitted rounds
        (``plan.cohort_excluding`` / ``_free_uniform_cohort``; requires
        ``c·(τ+1) <= n`` and the elastic engine).  This is what makes the
        deferred commit exact: nothing touches a staged cohort's rows
        between gather and commit.
      * **DownCom prefetch** — commit of round ``g`` targets the cohort
        of round ``g+τ+1``, the round that stages immediately after this
        commit: joining clients receive ``x_bar`` exactly one commit
        before their gather, never earlier, never later.  (At ``τ=0``
        this is round ``g+1`` — the synchronous rule.)
      * **Bounded-staleness admission** — at ``τ>=1`` the simulated
        clock decides lateness: a member's uplink arrives at
        ``dispatch_g + delay_i(g)·L_g`` (per-step latency draws from
        ``latency`` — a ``faults.EmpiricalDelays`` or any object with
        ``.delays(rnd, attempt)`` — or from the ``FaultPlan``); the
        policy's cutoff (``wait_all`` = slowest member, ``quorum`` =
        q-th arrival, ``deadline`` = dispatch + deadline) admits rows
        into the deferred rebuild through PR 6's ``arrived``-mask
        survivor aggregation and demotes the rest to dropped — their
        coordinates stay bitwise untouched.  Unlike the synchronous
        quorum, a quorum miss never resamples (the pipeline cannot
        rewind a staged round); it commits whatever arrived.  At ``τ=0``
        with a ``FaultPlan`` the synchronous resolver (retries, backoff,
        resampling) is reused verbatim.

    The simulated wall clock (the benchmark's headline) advances as
    ``dispatch_u = max(commit_{u-τ-1}, dispatch_{u-1})`` and
    ``commit_g = max(commit_{g-1}, cutoff_g)`` — at ``τ=0``/``wait_all``
    this reproduces the bulk-synchronous sum-of-slowest-member cost
    model of ``examples/availability_sim.py``; at ``τ>=1`` a straggler
    only stalls the clock if it is still missing ``τ`` rounds later.
    Metrics rows gain ``staleness``/``dispatch_s``/``commit_s``/
    ``round_latency_s``/``admitted``/``late_dropped`` (plus
    ``uncovered`` when the engine traces coverage); the final row's
    ``commit_s`` is the run's total simulated seconds.

    ``checkpoint_every`` saves a *pipeline* checkpoint (the carry plus
    every in-flight payload buffer and the clock —
    ``pipeline_checkpoint_save``) at trace-drain boundaries while the
    pipeline is full; ``resume=True`` restores the latest one and
    continues bit-exactly (the host ``rng``'s skipped ``L`` draws are
    replayed deterministically).

    Caveat (documented, by design): AdamW's shared ``opt.count`` scalar
    is scattered back last-wins, so under pipelining its value can lag
    the true global step by up to ``τ·max_L`` — same order as the
    staleness the optimizer already tolerates.
    """
    import numpy as np

    engine = round_fn
    if not (hasattr(engine, "stage") and hasattr(engine, "commit")):
        raise ValueError("run_rounds_pipelined needs the split-phase "
                         "engine from make_pipelined_round_fn")
    tau = int(staleness)
    if tau < 0:
        raise ValueError(f"staleness must be >= 0, got {tau}")
    n, c = engine.n, engine.c
    engine_cap = engine.max_L
    max_L = min(max_L or engine_cap, engine_cap)
    flush_every = max(1, min(flush_every, rounds))
    if policy not in ROUND_POLICIES:
        raise ValueError(f"unknown policy {policy!r}; pick from "
                         f"{ROUND_POLICIES}")
    if policy == "deadline" and deadline is None:
        raise ValueError("deadline policy needs a deadline (seconds)")
    if tau >= 1:
        if not engine.elastic:
            raise ValueError(
                "pipelining (staleness >= 1) needs the elastic engine: "
                "all-rows rounds touch every client row, so in-flight "
                "rounds cannot be disjoint"
            )
        if c * (tau + 1) > n:
            raise ValueError(
                f"staleness {tau} needs c*(tau+1) <= n "
                f"(got c={c}, n={n}): in-flight cohorts must be disjoint"
            )
    adversarial = faults is not None and faults.model.adversarial
    if guard is None:
        guard = faults is not None and (faults.model.p_corrupt > 0
                                        or adversarial)
    if guard_mode is None:
        # same ISSUE 9 default as run_rounds: finite corruption needs
        # the adaptive magnitude guard, not just the nonfinite check
        guard_mode = ("adaptive" if bool(guard) and guard_max_abs is None
                      and faults is not None
                      and (adversarial
                           or (faults.model.p_corrupt > 0
                               and faults.model.corrupt_mode == "blowup"))
                      else "nonfinite")
    if guard_mode not in ("nonfinite", "adaptive"):
        raise ValueError(f"unknown guard_mode {guard_mode!r}; pick "
                         "'nonfinite' or 'adaptive'")
    byz_mask = jnp.asarray(faults.byzantine) if adversarial else None
    if faults is not None and faults.n != n:
        raise ValueError(f"fault plan covers {faults.n} clients, "
                         f"engine has n={n}")
    if policy != "wait_all" and faults is None and (tau == 0
                                                    or latency is None):
        raise ValueError("round policies need a fault plan "
                         "(or, at staleness >= 1, a latency model)")
    lat_n = getattr(latency, "n", None)
    if lat_n is not None and lat_n != n:
        raise ValueError(f"latency model covers {lat_n} clients, "
                         f"engine has n={n}")

    robust = (faults is not None and (
        not faults.is_zero or policy != "wait_all" or bool(guard)
    )) or (tau >= 1 and policy != "wait_all")
    sync_equiv = tau == 0 and robust  # reuse the synchronous resolver
    q = quorum if quorum is not None else c // 2 + 1
    coverage = bool(getattr(engine, "coverage", False)) and robust
    r0 = int(state.round)
    carry = init_carry(state, key, flush_every,
                       robust_n=n if robust else 0, coverage=coverage)
    ck0 = np.asarray(jax.device_get(carry.comm_key))

    def host_cohort(g: int, attempt: int = 0) -> np.ndarray:
        if plan is not None:
            return np.asarray(plan.cohort(g, attempt))
        return _uniform_cohort_host(ck0, g, n, c, attempt)

    resolve = (_make_fault_resolver(
        faults, n=n, policy=policy, q=q, max_retries=max_retries,
        backoff0=backoff0, deadline=deadline, host_cohort=host_cohort,
    ) if sync_equiv else None)

    cohorts: Dict[int, np.ndarray] = {}

    def resolve_cohort(g: int, busy: np.ndarray) -> np.ndarray:
        got = cohorts.get(g)
        if got is not None:
            return got
        if plan is not None:
            co = np.asarray(plan.cohort_excluding(g, busy) if tau >= 1
                            else plan.cohort(g))
        elif not busy.any():
            co = _uniform_cohort_host(ck0, g, n, c)
        else:
            co = _free_uniform_cohort(ck0, g, n, c, busy)
        cohorts[g] = co
        return co

    def busy_mask() -> np.ndarray:
        busy = np.zeros(n, bool)
        for e in pend:
            if e["cohort"] is not None:
                busy[e["cohort"]] = True
        return busy

    lat_src = latency if latency is not None else faults

    def arr_offsets(g: int, steps: int, attempt: int = 0) -> np.ndarray:
        """(n,) absolute arrival offsets: per-STEP latency draws times
        the round's local-step count (the availability_sim cost model)."""
        if lat_src is None:
            return np.zeros(n)
        return (np.asarray(lat_src.delays(g, attempt), np.float64)
                * max(int(steps), 1))

    pend: list = []  # in-flight staged rounds, oldest first
    window: list = []  # per-committed-round host meta awaiting drain
    dispatch: Dict[int, float] = {}
    committime: Dict[int, float] = {}
    total_steps = 0
    last: Dict[str, Any] = {}
    u0 = 0

    if resume:
        if not checkpoint_dir:
            raise ValueError("resume=True needs a checkpoint_dir")
        step = pipeline_latest_step(checkpoint_dir)
        if step is not None:
            blob = pipeline_checkpoint_restore(
                os.path.join(checkpoint_dir, f"pipe_step_{step}"),
                carry_like=carry, engine=engine,
            )
            carry = blob["carry"]._replace(
                traces=_zero_traces(flush_every, n if robust else 0,
                                    coverage)
            )
            for e in blob["pending"]:
                r = int(e["r"])
                co = (None if e["cohort"] is None
                      else np.asarray(e["cohort"], np.int32))
                if co is not None:
                    cohorts[r0 + r] = co
                d = float(e["dispatch"])
                dispatch[r] = d
                pend.append({
                    "r": r, "cohort": co, "dispatch": d,
                    "buf": {"compact": e["compact"], "loss": e["loss"],
                            "steps": int(e["steps"])},
                })
            u0 = step + len(pend)
            committime[step - 1] = float(blob["clock"]["last_commit"])
            if not pend:
                dispatch[u0 - 1] = float(blob["clock"]["last_dispatch"])
            total_steps = int(blob["clock"]["total_steps"])
            # replay (and discard) the L draws of already-staged rounds so
            # the host rng continues the original stream bit-exactly
            for _ in range(u0):
                tamuna_dp.sample_round_length(rng, p, max_L=max_L)

    for u in range(u0, rounds + tau):
        if u < rounds:
            # ---- stage round u
            L = tamuna_dp.sample_round_length(rng, p, max_L=max_L)
            g = r0 + u
            if sync_equiv:
                co = np.asarray(resolve(g)["cohort"])
            elif engine.elastic:
                co = resolve_cohort(g, busy_mask())
            elif plan is not None:
                co = np.asarray(plan.cohort(g))
            else:
                co = None
            carry, buf = engine.stage(carry, data, L, cohort=co)
            d = max(committime.get(u - tau - 1, 0.0),
                    dispatch.get(u - 1, 0.0))
            dispatch[u] = d
            pend.append({"r": u, "cohort": co, "buf": buf, "dispatch": d})
        rc = u - tau
        if not (0 <= rc < rounds):
            continue
        # ---- commit round rc
        ent = pend.pop(0)
        g = r0 + rc
        co, buf = ent["cohort"], ent["buf"]
        if engine.elastic:
            if sync_equiv:
                down = resolve(g + 1)["member"]
            else:
                nxt = resolve_cohort(g + tau + 1, busy_mask())
                down = np.zeros(n, bool)
                down[nxt] = True
        else:
            down = None
        kw: Dict[str, Any] = {}
        meta: Dict[str, Any] = {"staleness": tau}
        if sync_equiv:
            res = resolve(g)
            arr_off = arr_offsets(g, buf["steps"], res["retries"])
            arr = ent["dispatch"] + arr_off
            cutoff = (float(arr[res["arrived"]].max())
                      if res["arrived"].any() else ent["dispatch"])
            cutoff += res["backoff"]
            kw = dict(
                arrived=res["arrived"],
                corrupt=(res["corrupt"]
                         if faults.model.p_corrupt > 0 else None),
                byz=byz_mask,
                correct=(policy != "wait_all"), guard=bool(guard),
                guard_mode=guard_mode,
                corrupt_mode=faults.model.corrupt_mode,
                blowup=faults.model.blowup, guard_max_abs=guard_max_abs,
                adversary=faults.model.adversary,
                byz_scale=faults.model.byz_scale,
                byz_z=faults.model.byz_z,
            )
            meta.update(
                retries=res["retries"], backoff_s=res["backoff"],
                quorum_miss=res["quorum_miss"],
                admitted=int(res["arrived"].sum()), late_dropped=0,
            )
        elif robust:
            member = np.zeros(n, bool)
            member[co] = True
            dropped = (faults.drops(g, 0) if faults is not None
                       else np.zeros(n, bool))
            finite = member & ~dropped
            arr = np.where(finite,
                           ent["dispatch"] + arr_offsets(g, buf["steps"]),
                           np.inf)
            if policy == "wait_all":
                cutoff = (float(arr[finite].max()) if finite.any()
                          else ent["dispatch"])
                admitted = finite
            elif policy == "quorum":
                kq = min(q, int(finite.sum()))
                if kq == 0:
                    cutoff, admitted = ent["dispatch"], np.zeros(n, bool)
                else:
                    cutoff = float(np.sort(arr[finite])[kq - 1])
                    admitted = finite & (arr <= cutoff)
            else:
                # deadline cuts on simulated ARRIVAL time here (the
                # synchronous driver cuts on the raw per-round draw)
                cutoff = ent["dispatch"] + float(deadline)
                admitted = finite & (arr <= cutoff)
            kw = dict(
                arrived=admitted,
                corrupt=(faults.corrupts(g, 0) & member
                         if faults is not None
                         and faults.model.p_corrupt > 0 else None),
                byz=byz_mask,
                correct=(policy != "wait_all"), guard=bool(guard),
                guard_mode=guard_mode,
                corrupt_mode=(faults.model.corrupt_mode
                              if faults is not None else "nan"),
                blowup=(faults.model.blowup
                        if faults is not None else 1e8),
                guard_max_abs=guard_max_abs,
                adversary=(faults.model.adversary
                           if faults is not None else "none"),
                byz_scale=(faults.model.byz_scale
                           if faults is not None else -10.0),
                byz_z=(faults.model.byz_z
                       if faults is not None else 1.5),
            )
            meta.update(
                retries=0, backoff_s=0.0,
                quorum_miss=int(policy == "quorum"
                                and int(finite.sum()) < q),
                admitted=int(admitted.sum()),
                late_dropped=int((finite & ~admitted).sum()),
            )
        else:
            # no admission needed (everyone arrives): the clock still
            # waits for the slowest member — the wait_all barrier
            off = arr_offsets(g, buf["steps"])
            if co is not None:
                member = np.zeros(n, bool)
                member[co] = True
                cutoff = ent["dispatch"] + (
                    float(off[member].max()) if member.any() else 0.0
                )
                meta.update(admitted=int(member.sum()), late_dropped=0)
            else:
                cutoff = ent["dispatch"] + (
                    float(off.max()) if off.size else 0.0
                )
                meta.update(admitted=n, late_dropped=0)
        tc = max(committime.get(rc - 1, 0.0), cutoff)
        committime[rc] = tc
        carry = engine.commit(carry, buf, len(window), cohort=co,
                              down=down, **kw)
        meta.update({
            "round": rc, "dispatch_s": ent["dispatch"], "commit_s": tc,
            "round_latency_s": tc - ent["dispatch"],
        })
        window.append(meta)
        drained = False
        if len(window) == flush_every or rc == rounds - 1:
            tr = jax.device_get(carry.traces)  # the only host sync
            for i, m in enumerate(window):
                executed = int(tr["steps"][i])
                total_steps += executed
                last = {
                    "round": m["round"],
                    "L": executed,
                    "loss": float(tr["loss_sum"][i]) / max(executed, 1),
                    "local_steps": total_steps,
                    "up_floats": float(tr["up_floats"][i]),
                    "down_floats": float(tr["down_floats"][i]),
                    "up_bytes": float(tr["up_bytes"][i]),
                    "down_bytes": float(tr["down_bytes"][i]),
                }
                if robust:
                    last["arrivals"] = int(tr["arrivals"][i])
                    last["corrupted"] = int(tr["corrupted"][i])
                    if "uncovered" in tr:
                        last["uncovered"] = int(tr["uncovered"][i])
                last.update({k: v for k, v in m.items() if k != "round"})
                if logger is not None:
                    logger.log(m["round"], last)
            window = []
            carry = carry._replace(traces=_zero_traces(
                flush_every, n if robust else 0, coverage
            ))
            drained = True
        if (drained and checkpoint_dir and checkpoint_every
                and (rc + 1) % checkpoint_every == 0
                and len(pend) == tau and rc + 1 < rounds):
            pipeline_checkpoint_save(
                os.path.join(checkpoint_dir, f"pipe_step_{rc + 1}"),
                carry, pend,
                {"last_dispatch": np.float32(dispatch.get(u, 0.0)),
                 "last_commit": np.float32(tc),
                 "total_steps": np.int32(total_steps)},
                rc + 1,
            )
    return carry.state, last


def pipeline_checkpoint_save(path: str, carry: RoundCarry, pending,
                             clock, step: int) -> None:
    """Atomically checkpoint a pipelined run mid-flight: the donated
    carry, every in-flight payload buffer (compact state + loss + step
    count + cohort + dispatch time), and the simulated clock — one
    ``checkpoint.save`` tree, so a restored run continues bit-exactly
    with both buffers in flight.  Saved under ``pipe_step_<k>`` (``k``
    committed rounds), a namespace disjoint from the synchronous
    ``step_<k>`` state checkpoints."""
    import numpy as np

    from repro import checkpoint

    pend = tuple(
        {
            "compact": e["buf"]["compact"],
            "loss": e["buf"]["loss"],
            "steps": np.int32(e["buf"]["steps"]),
            "r": np.int32(e["r"]),
            "cohort": (None if e["cohort"] is None
                       else np.asarray(e["cohort"], np.int32)),
            "dispatch": np.float32(e["dispatch"]),
        }
        for e in pending
    )
    checkpoint.save(path, {"carry": carry, "pending": pend,
                           "clock": dict(clock)}, step)


def pipeline_checkpoint_restore(path: str, *, carry_like: RoundCarry,
                                engine):
    """Restore a ``pipeline_checkpoint_save`` blob.  The number of
    in-flight buffers is read from the checkpoint's own leaf names (the
    pipeline depth is a runtime choice, not a structural constant); the
    per-buffer ``like`` comes from ``jax.eval_shape`` of the engine's
    gather, so no device work happens until the arrays land."""
    import json
    import numpy as np

    from repro import checkpoint

    with open(os.path.join(path, "meta.json")) as f:
        names = json.load(f)["names"]
    idx = {int(nm.split("/")[1]) for nm in names
           if nm.startswith("pending/")}
    k = (max(idx) + 1) if idx else 0
    if engine.elastic:
        compact_like = jax.eval_shape(
            tamuna_dp.gather_cohort, carry_like.state,
            jax.ShapeDtypeStruct((engine.c,), jnp.int32),
        )
        cohort_like = np.zeros((engine.c,), np.int32)
    else:
        compact_like, cohort_like = None, None
    entry_like = {
        "compact": compact_like,
        "loss": jax.ShapeDtypeStruct((), jnp.float32),
        "steps": np.int32(0),
        "r": np.int32(0),
        "cohort": cohort_like,
        "dispatch": np.float32(0.0),
    }
    like = {
        "carry": carry_like,
        "pending": tuple(entry_like for _ in range(k)),
        "clock": {"last_dispatch": np.float32(0.0),
                  "last_commit": np.float32(0.0),
                  "total_steps": np.int32(0)},
    }
    return checkpoint.restore(path, like)


def pipeline_latest_step(root: str) -> Optional[int]:
    """Newest ``pipe_step_<k>`` checkpoint under ``root`` (committed
    rounds ``k``), or None."""
    if not os.path.isdir(root):
        return None
    steps = [
        int(d.split("_")[-1]) for d in os.listdir(root)
        if d.startswith("pipe_step_")
    ]
    return max(steps) if steps else None
