"""Blocked-template uplink: the reduce-scatter-shaped aggregation.

The cyclic permutation template scatters each client's owned coordinates
across the whole vector, which lowers to a full-width masked all-reduce.
The *blocked* template (``masks.block_template_mask``) keeps the exactly-
``s``-owners row property but gives every client ``s`` contiguous chunks,
so the uplink becomes reduce-scatter shaped: chunk ``j`` is the sum of the
``s`` owners' chunk-``j`` slices — ``s`` shifted adds over the client axis
instead of an ``n``-wide masked sum, and no dense ``(n, d)`` mask is ever
materialized in HBM (ownership is the closed form
``(chunk - client - off) mod n < s``).

The round permutation is restricted to cyclic shifts (``off``), which is
exactly the subgroup of column permutations that preserves block
contiguity; unbiasedness over the shift ensemble follows from the same
row-property argument as the paper's Appendix A.1 (see DESIGN.md §3).

Per-leaf coordinates are chunked in flat order, so with tensor parallelism
the template is a per-TP-shard row reordering of the global one — still a
valid exactly-``s``-owners template.

``block_rs_aggregate`` routes through the mask-free fused paths of
``comm_ws.blocked_comm`` by default: the ``(n, n, chunk)`` pad +
advanced-indexing gather + materialized ownership delta of
``_leaf_aggregate`` (this module's PR 1 implementation, kept below for the
benchmark's prior-path row) becomes ``s`` rolled adds straight off the
unpadded leaves plus one fused closed-form h-update pass — DESIGN.md §9.
The ``impl="dense"`` ground truth is the materialized-mask blocked
reference in ``comm_ws._dense_blocked_leaf``.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import comm_ws

__all__ = ["block_rs_aggregate"]


def _leaf_aggregate(
    xl: jax.Array,  # (n, *param_shape)
    hl: jax.Array,  # (n, *param_shape) control variates
    off: jax.Array,  # int32 scalar: cyclic shift of the ownership bands
    n: int,
    s: int,
    scale,  # eta / gamma
) -> Tuple[jax.Array, jax.Array]:
    rest = xl.shape[1:]
    D = int(np.prod(rest))
    chunk = -(-D // n)  # ceil; last chunk ragged
    pad = n * chunk - D

    xf = xl.reshape(n, D).astype(jnp.float32)
    hf = hl.reshape(n, D).astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad)))
        hf = jnp.pad(hf, ((0, 0), (0, pad)))
    xb = xf.reshape(n, n, chunk)  # (client, block, chunk)

    j = jnp.arange(n, dtype=jnp.int32)
    # out[j] = (1/s) sum_t x[(j - off - t) mod n, j]: s shifted diagonal
    # reads -- the reduce-scatter shape (each owner contributes one slice)
    acc = jnp.zeros((n, chunk), jnp.float32)
    for t in range(s):
        idx = (j - off - t) % n
        acc = acc + xb[idx, j]
    out = acc / s  # (block, chunk)

    # ownership: client i owns blocks (i+off) .. (i+off+s-1) mod n
    own = ((j[None, :] - j[:, None] - off) % n) < s  # (client, block)
    delta = scale * own[:, :, None].astype(jnp.float32) * (out[None] - xb)
    h_new = (hf.reshape(n, n, chunk) + delta).reshape(n, n * chunk)[:, :D]

    flat = out.reshape(-1)[:D]
    x_new = jnp.broadcast_to(flat[None], (n, D))
    return (
        x_new.astype(xl.dtype).reshape(xl.shape),
        h_new.astype(hl.dtype).reshape(hl.shape),
    )


def block_rs_aggregate(
    x: Any,
    h: Any,
    off: jax.Array,
    n: int,
    tcfg,
    eta: float,
    mesh: Optional[Any] = None,
    *,
    model_cfg=None,
    impl: str = "auto",
    block: int = 4096,
    meshed: Optional[bool] = None,
    pspecs=None,
    shard_kernels: Optional[bool] = None,
    c: Optional[int] = None,
    slot_of: Optional[Any] = None,
    down: Optional[Any] = None,
    arrived: Optional[Any] = None,
    correct: bool = True,
    wire: Optional[str] = None,
    wire_seed=None,
    wire_down: bool = False,
    robust=None,
) -> Tuple[Any, Any]:
    """Aggregate client-stacked pytrees under the blocked template.

    Returns ``(x_new, h_new)``: every DownCom'd client row of ``x_new``
    equals the owner-mean server model; ``h_new`` applies the
    control-variate update on owned blocks only, preserving
    ``sum_i h_i == 0`` exactly at the coordinate level (the per-coordinate
    deltas sum to ``s*x_bar - s*x_bar``).  Pure jnp over the stacked
    client axis, so under a data-sharded mesh GSPMD lowers the shifted
    adds to reduce-scatter / collective-permute traffic;
    ``mesh``/``model_cfg`` are accepted for API symmetry and future
    shard_map specialization.

    ``impl`` selects the mask-free paths of ``comm_ws.blocked_comm``
    (``"ws"``/``"pallas"``; ``"auto"`` resolves per backend) or the
    materialized-mask dense reference (``"dense"``).  ``meshed`` defaults
    to "a mesh was passed": with the client axis device-sharded the UpCom
    must keep a d-sized collective (comm_ws module docstring), so call
    sites that hand over their mesh get the right collective shape
    without remembering the flag — psum-shaped fused partials on the
    ``ws``/``dense`` paths, the shard-resident shard_map engine on
    ``pallas`` (per-shard contiguous block gathers + one psum of the
    block partials; ``pspecs``/``shard_kernels`` ride through).

    ``c``/``slot_of``/``down`` are the elastic partial-participation
    parameters (DESIGN.md §11): the ownership bands are laid over the
    ``c`` cohort slots (``slot_of[i]`` in ``[0, c)``, -1 idle) and the
    DownCom targets only the ``down`` rows.  Defaults = full
    participation, the original template.  ``arrived``/``correct`` are
    the fault-tolerant aggregation inputs (DESIGN.md §12, see
    ``comm_ws.blocked_comm``); ``wire``/``wire_seed``/``wire_down`` the
    quantized wire (§13, see ``comm_ws.cyclic_comm``); ``robust`` the
    normalized robust-combiner spec (§15, see ``comm_ws.cyclic_comm``).
    """
    del model_cfg
    if meshed is None:
        meshed = mesh is not None
    return comm_ws.blocked_comm(
        x, h, off, n, tcfg.s, eta / tcfg.gamma, impl=impl, block=block,
        c=c, slot_of=slot_of, down=down, arrived=arrived, correct=correct,
        meshed=meshed, mesh=mesh, pspecs=pspecs,
        shard_kernels=shard_kernels,
        wire=wire, wire_seed=wire_seed, wire_down=wire_down,
        robust=robust,
    )
