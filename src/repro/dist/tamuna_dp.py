"""TAMUNA-DP: the distributed (mesh-sharded) round engine for LM training.

Where ``repro.core.tamuna`` is the paper-faithful reference over flat
vectors, this module runs the same algorithm over *arbitrary parameter
pytrees* with the client population mapped onto the mesh's data axes
(client i == data-shard i, see ``repro.dist.sharding``).  A round is

  L x local  ``make_local_step``: per-client grads + local update.  No
             cross-client collectives — the common case is all-local.
  1 x comm   ``make_comm_step``: the only communication of the algorithm.
             ``uplink="masked_psum"``: permutation-masked sum over the
             client axis (each coordinate uploaded by exactly ``s`` of the
             ``c`` cohort members, reconstructed as ``(1/s) * psum``).
             ``uplink="block_rs"``: the contiguous-block template of
             ``masks.block_template_mask`` — the reduce-scatter-shaped
             variant (see ``block_uplink`` and DESIGN.md §3).

State leaves are stacked per client: ``x``/``h`` leaves are ``(n, *param)``
and shard over the data axes, so the masked sum lowers to an all-reduce
(psum) over clients and the blocked variant to reduce-scatter-shaped
collectives — communication scales with the cohort, never with tokens.

Both uplinks aggregate mask-free through ``repro.dist.comm_ws``: ownership
comes from static closed-form band tables fused into the aggregation
(``comm_impl="ws"``, meshed mode: the UpCom keeps the d-sized psum shape
since clients are device-sharded here) or the packed-workspace Pallas
kernels (``"pallas"``, TPU), with the per-leaf dense-mask reference
retained as ``comm_impl="dense"`` (DESIGN.md §9).

Partial participation is *elastic* (DESIGN.md §11): ``round_cohort``
derives the round's cohort from the comm key, ``gather_cohort`` /
``scatter_cohort`` give the round engine O(c·L) local compute, both
uplinks run at any ``c <= n`` (the blocked bands lie over cohort slots),
and the comm step's DownCom can target just the next round's cohort.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import masks, theory
from repro.dist import comm_ws, model_api, robust as _robust, \
    sharding, wire
from repro.models.transformer import ModelConfig
from repro.optim import optimizers

__all__ = [
    "DistTamunaConfig",
    "DistTamunaState",
    "init_state",
    "state_pspecs",
    "round_cohort",
    "member_mask",
    "gather_cohort",
    "scatter_cohort",
    "make_local_step",
    "make_comm_step",
    "sample_round_length",
]


@dataclasses.dataclass(frozen=True)
class DistTamunaConfig:
    gamma: float  # local stepsize (AdamW lr when local_opt="adamw")
    c: int  # cohort size (2 <= c <= n)
    s: int  # sparsity index (2 <= s <= c); s == c disables compression
    p: float  # inverse expected local steps per round
    eta: Optional[float] = None  # control stepsize; None -> Remark 2 default
    uplink: str = "masked_psum"  # "masked_psum" | "block_rs"
    microbatches: int = 1  # gradient accumulation steps per local step
    local_opt: str = "sgd"  # "sgd" (paper rule) | "adamw" (DESIGN.md §7)
    use_kernel: bool = False  # fused Pallas local-step update (kernels/)
    comm_impl: str = "auto"  # "auto" | "dense" | "ws" | "pallas" (§9)
    wire_precision: str = "f32"  # UpCom payload width (§13): "auto" |
    #   "f32" | "bf16" | "f16" | "int8" | "int4" — "f32" is bitwise the
    #   unquantized path, "auto" resolves per leaf size
    wire_down: bool = False  # also quantize the DownCom broadcast (§13)
    robust_agg: str = "mean"  # per-coordinate combiner (§15): "mean" |
    #   "trimmed" (trim_k per side) | "median" — "mean" (and trimmed at
    #   k=0) is bitwise the existing arrived-owner-mean path
    trim_k: int = 0  # values trimmed per side under robust_agg="trimmed"

    def __post_init__(self):
        if not (2 <= self.s <= self.c):
            raise ValueError(f"need 2 <= s <= c, got s={self.s} c={self.c}")
        if self.wire_precision not in wire.WIRE_POLICIES:
            raise ValueError(
                f"unknown wire_precision {self.wire_precision!r}; want one "
                f"of {wire.WIRE_POLICIES}"
            )
        if self.wire_down and not wire.is_wire(self.wire_precision):
            raise ValueError(
                "wire_down quantizes the DownCom broadcast; it needs a "
                f"non-f32 wire_precision, got {self.wire_precision!r}"
            )
        if self.uplink not in ("masked_psum", "block_rs"):
            raise ValueError(f"unknown uplink {self.uplink!r}")
        if self.comm_impl not in comm_ws.COMM_IMPLS:
            raise ValueError(
                f"unknown comm_impl {self.comm_impl!r}; want one of "
                f"{comm_ws.COMM_IMPLS}"
            )
        if self.local_opt not in ("sgd", "adamw"):
            raise ValueError(f"unknown local_opt {self.local_opt!r}")
        if self.use_kernel and self.local_opt != "sgd":
            raise ValueError(
                "use_kernel fuses the paper's SGD rule; it does not apply "
                f"to local_opt={self.local_opt!r}"
            )
        # validates robust_agg/trim_k against s (raises on bad specs)
        _robust.normalize_robust(self.robust_agg, self.trim_k, self.s)

    def robust_(self):
        """The normalized robust-combiner spec the comm impls consume:
        ``None`` (bitwise mean path) or ``("trimmed", k)``/``("median",
        0)`` — see ``repro.dist.robust.normalize_robust``."""
        return _robust.normalize_robust(self.robust_agg, self.trim_k,
                                        self.s)

    def eta_(self, n: int) -> float:
        """Control-variate stepsize: Remark 2's largest valid
        ``eta = p * chi_max(n, s)`` — same rule as the reference core's
        ``theory.TunedParams``."""
        if self.eta is not None:
            return self.eta
        return theory.recommended_eta(self.p, max(n, 2), self.s)


class DistTamunaState(NamedTuple):
    x: Any  # client-stacked params: leaves (n, *param_shape)
    h: Any  # control variates, f32, same structure; sum_i h_i == 0
    opt: Any  # local-optimizer state (() for sgd)
    round: jax.Array  # int32 scalar
    up_floats: jax.Array  # f32 scalar: cumulative uplink floats per client
    down_floats: jax.Array  # f32 scalar
    # dtype-aware wire accounting (§13): cumulative wire BYTES per client,
    # resolved from the per-leaf wire kinds at builder time.  On the f32
    # wire these are byte-identical to floats * 4.
    up_bytes: jax.Array = None  # f32 scalar
    down_bytes: jax.Array = None  # f32 scalar


# --------------------------------------------------------------------------
# init / sharding
# --------------------------------------------------------------------------


def init_state(
    key: jax.Array, cfg: ModelConfig, mesh: Mesh, tcfg: DistTamunaConfig,
    n: Optional[int] = None,
) -> DistTamunaState:
    """Client-stacked initial state.  ``n`` overrides the mesh-derived
    population (``sharding.n_clients``) for placements that stack more
    clients than devices — the client axis then holds ``n / dp`` rows per
    shard (single-device simulators pass a 1x1 mesh and any ``n``)."""
    n = n or sharding.n_clients(mesh)
    if tcfg.c > n:
        raise ValueError(f"cohort c={tcfg.c} exceeds population n={n}")
    params = model_api.init(key, cfg)
    x = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), params
    )
    h = jax.tree.map(
        lambda a: jnp.zeros((n,) + a.shape, jnp.float32), params
    )
    opt: Any = ()
    if tcfg.local_opt == "adamw":
        # elementwise moments live on the stacked leaves directly
        opt = optimizers.adamw(tcfg.gamma).init(x)
    return DistTamunaState(
        x=x, h=h, opt=opt,
        round=jnp.zeros((), jnp.int32),
        up_floats=jnp.zeros((), jnp.float32),
        down_floats=jnp.zeros((), jnp.float32),
        up_bytes=jnp.zeros((), jnp.float32),
        down_bytes=jnp.zeros((), jnp.float32),
    )


def state_pspecs(
    state: DistTamunaState, cfg: ModelConfig, mesh: Mesh
) -> DistTamunaState:
    """PartitionSpec pytree matching ``state`` exactly (scalars -> P())."""
    x_spec = sharding.stacked_params_pspecs(state.x, cfg, mesh)
    h_spec = sharding.stacked_params_pspecs(state.h, cfg, mesh)
    opt_spec: Any = ()
    if isinstance(state.opt, optimizers.AdamState):
        opt_spec = optimizers.AdamState(
            mu=sharding.stacked_params_pspecs(state.opt.mu, cfg, mesh),
            nu=sharding.stacked_params_pspecs(state.opt.nu, cfg, mesh),
            count=P(),
        )
    return DistTamunaState(
        x=x_spec, h=h_spec, opt=opt_spec,
        round=P(), up_floats=P(), down_floats=P(),
        up_bytes=P(), down_bytes=P(),
    )


# --------------------------------------------------------------------------
# local step
# --------------------------------------------------------------------------


def _client_grads(cfg: ModelConfig, x, batch, microbatches: int):
    """Per-client losses (n,) and grads (stacked tree) with optional
    gradient accumulation; exact mean over equal-size microbatches."""

    def loss0(params, b):
        return model_api.loss(params, cfg, **b)[0]

    gfun = jax.vmap(jax.value_and_grad(loss0))

    if microbatches == 1:
        return gfun(x, batch)

    M = microbatches

    def split(a):
        nb = a.shape[1]
        assert nb % M == 0, (nb, M)
        return jnp.swapaxes(
            a.reshape((a.shape[0], M, nb // M) + a.shape[2:]), 0, 1
        )

    mbs = jax.tree.map(split, batch)

    def body(carry, mb):
        tot_l, tot_g = carry
        l, g = gfun(x, mb)
        return (tot_l + l, jax.tree.map(jnp.add, tot_g, g)), None

    n = jax.tree.leaves(x)[0].shape[0]
    init = (
        jnp.zeros((n,), jnp.float32),
        jax.tree.map(lambda a: jnp.zeros((n,) + a.shape[1:], jnp.float32),
                     x),
    )
    (tot_l, tot_g), _ = jax.lax.scan(body, init, mbs)
    inv = 1.0 / M
    return tot_l * inv, jax.tree.map(lambda g: g * inv, tot_g)


def make_local_step(cfg: ModelConfig, tcfg: DistTamunaConfig):
    """Build ``fn(state, *, tokens, labels, ...) -> (state, metrics)``.

    The paper's local iteration ``x <- x - gamma*(g - h)`` (optionally via
    the fused Pallas kernel), or an AdamW step on the h-corrected gradient.
    Zero cross-client communication: everything is client-elementwise.
    """
    gamma = tcfg.gamma
    opt = optimizers.adamw(gamma) if tcfg.local_opt == "adamw" else None

    def fn(
        state: DistTamunaState,
        *,
        tokens: jax.Array,
        labels: jax.Array,
        prefix_embeds: Optional[jax.Array] = None,
        frames: Optional[jax.Array] = None,
    ) -> Tuple[DistTamunaState, Dict[str, jax.Array]]:
        batch = {"tokens": tokens, "labels": labels}
        if prefix_embeds is not None:
            batch["prefix_embeds"] = prefix_embeds
        if frames is not None:
            batch["frames"] = frames

        losses, grads = _client_grads(cfg, state.x, batch, tcfg.microbatches)

        if tcfg.local_opt == "adamw":
            eff = jax.tree.map(
                lambda g, h: g.astype(jnp.float32) - h.astype(jnp.float32),
                grads, state.h,
            )
            x_new, opt_new = opt.update(eff, state.opt, state.x)
        elif tcfg.use_kernel:
            from repro.kernels import ops as kops

            x_new = jax.tree.map(
                lambda x, g, h: kops.fused_local_step(x, g, h, gamma),
                state.x, grads, state.h,
            )
            opt_new = state.opt
        else:
            x_new = jax.tree.map(
                lambda x, g, h: (
                    x.astype(jnp.float32)
                    - gamma * (g.astype(jnp.float32) - h.astype(jnp.float32))
                ).astype(x.dtype),
                state.x, grads, state.h,
            )
            opt_new = state.opt

        metrics = {"loss": losses.mean().astype(jnp.float32)}
        return state._replace(x=x_new, opt=opt_new), metrics

    return fn


# --------------------------------------------------------------------------
# comm step
# --------------------------------------------------------------------------


def _as_key(key: jax.Array) -> jax.Array:
    """Accept typed PRNG keys or raw (2,) uint32 key data."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return key
    return jax.random.wrap_key_data(key)


# --------------------------------------------------------------------------
# cohort plan (elastic partial participation, DESIGN.md §11)
# --------------------------------------------------------------------------


def round_cohort(key: jax.Array, n: int, c: int) -> jax.Array:
    """The round's sorted ``(c,)`` cohort, derived from the round's COMM
    key (the same key ``make_comm_step`` consumes): uniform without
    replacement.  The single source of truth for who participates — the
    round engine gathers these rows for local compute, the data pipeline
    samples batches for them, and ``make_comm_step`` (given ``cohort=None``)
    re-derives the identical set, so every layer agrees by construction.
    Replayable from ``(comm_key_base, round)`` alone via
    ``rounds.comm_round_key``.  Non-uniform (availability-driven) plans
    come from the host instead: ``repro.dist.cohort.CohortPlan``."""
    k_cohort, _ = jax.random.split(_as_key(key))
    return jnp.sort(
        jax.random.choice(k_cohort, n, shape=(c,), replace=False)
    ).astype(jnp.int32)


def member_mask(cohort: jax.Array, n: int) -> jax.Array:
    """``(n,)`` bool membership of a ``(c,)`` cohort index array."""
    return jnp.zeros((n,), bool).at[cohort].set(True)


def gather_cohort(state: DistTamunaState,
                  cohort: jax.Array) -> DistTamunaState:
    """Gather the cohort's rows of x / h / opt moments into a compact
    ``(c, ...)``-stacked state (scalars shared).  Local compute on the
    result is O(c), not O(n) — idle clients do nothing, the paper's PP
    semantics."""
    take = lambda a: jnp.take(a, cohort, axis=0)
    opt: Any = state.opt
    if isinstance(opt, optimizers.AdamState):
        opt = optimizers.AdamState(
            mu=jax.tree.map(take, opt.mu),
            nu=jax.tree.map(take, opt.nu),
            count=opt.count,
        )
    return state._replace(
        x=jax.tree.map(take, state.x),
        h=jax.tree.map(take, state.h),
        opt=opt,
    )


def scatter_cohort(state: DistTamunaState, compact: DistTamunaState,
                   cohort: jax.Array) -> DistTamunaState:
    """Scatter a compact cohort state back into the full ``(n, ...)``
    rows (the inverse of ``gather_cohort``); idle rows pass through
    untouched.  Under donation the ``.at[].set`` updates write only the
    cohort rows in place."""
    put = lambda full, part: full.at[cohort].set(part)
    opt: Any = state.opt
    if isinstance(opt, optimizers.AdamState):
        opt = optimizers.AdamState(
            mu=jax.tree.map(put, opt.mu, compact.opt.mu),
            nu=jax.tree.map(put, opt.nu, compact.opt.nu),
            count=compact.opt.count,
        )
    return state._replace(
        x=jax.tree.map(put, state.x, compact.x),
        h=jax.tree.map(put, state.h, compact.h),
        opt=opt,
    )


def make_comm_step(
    cfg: ModelConfig,
    tcfg: DistTamunaConfig,
    mesh: Mesh,
    *,
    impl: Optional[str] = None,
    block: int = 4096,
    n: Optional[int] = None,
    with_stats: bool = False,
):
    """Build ``fn(state, key, cohort=None, down=None) -> state``: UpCom +
    DownCom of one round.

    masked_psum: sum the masked client vectors over the data axes (an
    all-reduce of the *sparse* contributions), reconstruct ``x_bar`` with
    the exact ``1/s`` factor, update the cohort's control variates on the
    masked coordinates only, and DownCom ``x_bar`` back down.

    block_rs: the contiguous-block template, now at any ``c <= n``
    (DESIGN.md §11): coordinates chunk into ``c`` blocks whose shifted
    ownership bands lie over the cohort's slots — still reduce-scatter
    shaped, still exactly ``s`` owners per coordinate, all of them
    participants.

    ``cohort`` is the round's ``(c,)`` client set; ``None`` derives it
    from ``key`` via ``round_cohort`` (the same derivation the elastic
    round engine uses, so engine and standalone callers agree).  ``down``
    is the DownCom row mask — the elastic engine passes the NEXT round's
    cohort (only joining clients download, the paper's DownCom); ``None``
    broadcasts ``x_bar`` to every row (full-participation behaviour).
    ``arrived``/``correct`` are the fault-tolerant aggregation inputs
    (DESIGN.md §12, ``comm_ws`` docstring): clients outside ``arrived``
    contribute nothing, the corrected rebuild divides by the arrived
    owner count, and the uplink float accounting scales to the arrived
    cohort fraction (the expected-survivor correction).

    The aggregation math runs over the flat comm workspace
    (``repro.dist.comm_ws``, DESIGN.md §9): ``impl`` (default
    ``tcfg.comm_impl``) picks fused-jnp (``"ws"``), Pallas kernels
    (``"pallas"``), or the per-leaf dense-mask reference (``"dense"``);
    ``"auto"`` resolves per backend.  All impls consume the same key and
    produce the same coordinates to float roundoff.

    Uplink/downlink float accounting is a builder-time constant (the leaf
    dims are static), not recomputed inside the traced step.

    ``with_stats=True`` makes ``fn`` return ``(state, stats)`` where
    ``stats["uncovered"]`` is the round's count of coordinates with no
    surviving owner (``comm_ws.uncovered_coords`` over the same slot
    assignment the aggregation used) — the coverage-loss observable the
    pipelined driver traces per round (DESIGN.md §14).
    """
    n = n or sharding.n_clients(mesh)
    c, s = tcfg.c, tcfg.s
    if c > n:
        raise ValueError(f"cohort c={c} exceeds population n={n}")
    eta = tcfg.eta_(n)
    scale = eta / tcfg.gamma
    impl = comm_ws.resolve_impl(impl or tcfg.comm_impl)

    # builder-time communication accounting: per-leaf dims are static, so
    # the traced fn only adds cached constants (the seed recomputed the
    # python sum over leaves inside every trace).  Both uplinks count the
    # COHORT's template: the blocked bands lie over the c cohort slots, so
    # a client uploads s chunks of ceil(D/c) — the seed's n-based constant
    # under-counted per-client floats whenever c < n.
    params_struct = jax.eval_shape(
        lambda: model_api.init(jax.random.key(0), cfg)
    )
    dims = [int(np.prod(a.shape)) for a in jax.tree.leaves(params_struct)]
    # the stacked state's PartitionSpecs: the shard-resident pallas engine
    # shard_maps with exactly these, so model-parallel leaves keep their
    # shards (no resharding at the shard_map boundary)
    stacked_struct = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((n,) + a.shape, a.dtype),
        params_struct,
    )
    stacked_specs = sharding.stacked_params_pspecs(stacked_struct, cfg, mesh)
    down_total = jnp.float32(sum(dims))
    if tcfg.uplink == "block_rs":
        nnzs = [masks.block_column_nnz(D, c, s) for D in dims]
    else:
        nnzs = [masks.column_nnz(D, c, s) for D in dims]
    up_total = jnp.float32(sum(nnzs))
    # dtype-aware wire-bytes accounting (§13), still builder-time: each
    # leaf's kind resolves from its static dim, so the per-round byte
    # constants fold at trace time.  Per CLIENT, like the float counters:
    # leaf_up_bytes at c=1 is one client's codes + (int kinds) its own
    # per-chunk scales; f32 resolves byte-identical to floats * 4.
    wire_active = wire.is_wire(tcfg.wire_precision)
    # the robust-combiner spec bakes into the built fn (a static python
    # tuple): mean/trimmed-k=0 normalize to None, so the default program
    # is the untouched PR 6/7 lowering, bitwise
    rspec = tcfg.robust_()
    kinds = tuple(
        wire.resolve_kind(D, tcfg.wire_precision) for D in dims
    )
    up_bytes_total = jnp.float32(sum(
        wire.leaf_up_bytes(nnz, D, 1, k)
        for nnz, D, k in zip(nnzs, dims, kinds)
    ))
    down_bytes_total = jnp.float32(sum(
        wire.leaf_down_bytes(D, k if tcfg.wire_down else "f32")
        for D, k in zip(dims, kinds)
    ))

    def bump(state, x_new, h_new, up=None, upb=None):
        upd = dict(
            x=x_new, h=h_new,
            round=state.round + 1,
            up_floats=state.up_floats + (up_total if up is None else up),
            down_floats=state.down_floats + down_total,
        )
        if state.up_bytes is not None:
            upd["up_bytes"] = state.up_bytes + (
                up_bytes_total if upb is None else upb
            )
        if state.down_bytes is not None:
            upd["down_bytes"] = state.down_bytes + down_bytes_total
        return state._replace(**upd)

    def slot_of_(cohort):
        return (
            jnp.full((n,), -1, jnp.int32)
            .at[cohort].set(jnp.arange(c, dtype=jnp.int32))
        )

    def up_arrived(slot_of, arrived):
        """Expected-survivor float accounting (DESIGN.md §12): only the
        arrived cohort members' uplinks consumed bandwidth.  The template
        splits the d coordinates' s-owner slots evenly over the c cohort
        slots, so the arrived fraction of ``up_total`` is the (exact in
        expectation, per-round approximate) survivor uplink volume.
        Returns ``(floats, bytes)``; the byte counter scales the same
        way (a dropped client ships neither codes nor scales)."""
        if arrived is None:
            return None, None
        surv = ((slot_of >= 0) & jnp.asarray(arrived).astype(bool)).sum()
        frac = surv.astype(jnp.float32) / c
        return up_total * frac, up_bytes_total * frac

    def wire_seed_(key):
        """The round's uint32 quantization seed, derived off the comm key
        on a folded-away stream: the ``jax.random.split`` draws for
        cohort/permutation/offset are untouched, so the f32 wire stays
        bitwise identical to the unquantized engine."""
        if not wire_active:
            return None
        return wire.round_seed(jax.random.fold_in(key, wire.WIRE_FOLD))

    if tcfg.uplink == "block_rs":
        from repro.dist.block_uplink import block_rs_aggregate

        def fn(state: DistTamunaState, key: jax.Array,
               cohort: Optional[jax.Array] = None,
               down: Optional[jax.Array] = None,
               arrived: Optional[jax.Array] = None,
               correct: bool = True) -> DistTamunaState:
            key = _as_key(key)
            _, k_off = jax.random.split(key)
            if cohort is None:
                cohort = round_cohort(key, n, c)
            off = jax.random.randint(k_off, (), 0, c, jnp.int32)
            slot_of = slot_of_(cohort)
            xb, hb = block_rs_aggregate(
                state.x, state.h, off, n, tcfg, eta, mesh, model_cfg=cfg,
                impl=impl, block=block, meshed=True, pspecs=stacked_specs,
                c=c, slot_of=slot_of, down=down, arrived=arrived,
                correct=correct, wire=tcfg.wire_precision,
                wire_seed=wire_seed_(key), wire_down=tcfg.wire_down,
                robust=rspec,
            )
            up, upb = up_arrived(slot_of, arrived)
            out = bump(state, xb, hb, up, upb)
            if not with_stats:
                return out
            bslot = jnp.where(
                slot_of >= 0, (-(slot_of + off)) % c, -1
            ).astype(jnp.int32)
            if arrived is not None:
                bslot = jnp.where(
                    jnp.asarray(arrived).astype(bool), bslot, -1
                )
            return out, {"uncovered": comm_ws.uncovered_coords(
                "blocked", tuple(dims), c, s, bslot
            )}

        fn.wire_kinds = kinds
        return fn

    def fn(state: DistTamunaState, key: jax.Array,
           cohort: Optional[jax.Array] = None,
           down: Optional[jax.Array] = None,
           arrived: Optional[jax.Array] = None,
           correct: bool = True) -> DistTamunaState:
        key = _as_key(key)
        _, k_perm = jax.random.split(key)
        if cohort is None:
            cohort = round_cohort(key, n, c)
        perm = jax.random.permutation(k_perm, c)
        slot_of = slot_of_(cohort)
        # the client's TEMPLATE column: perm[cohort slot], -1 when idle
        slot = jnp.where(
            slot_of >= 0, perm[jnp.clip(slot_of, 0)], -1
        ).astype(jnp.int32)
        # clients are sharded over the data axes here: comm_ws meshed mode
        # — the psum-shaped fused partial (ws/dense) or the shard-resident
        # engine (pallas: shard_map'd per-shard uplinks + one d-sized psum
        # of the partials; the mesh handle and state specs ride along)
        x_new, h_new = comm_ws.cyclic_comm(
            state.x, state.h, slot, c, s, scale, impl=impl, block=block,
            down=down, arrived=arrived, correct=correct,
            meshed=True, mesh=mesh, pspecs=stacked_specs,
            wire=tcfg.wire_precision, wire_seed=wire_seed_(key),
            wire_down=tcfg.wire_down, robust=rspec,
        )
        up, upb = up_arrived(slot_of, arrived)
        out = bump(state, x_new, h_new, up, upb)
        if not with_stats:
            return out
        sslot = slot
        if arrived is not None:
            sslot = jnp.where(
                jnp.asarray(arrived).astype(bool), sslot, -1
            )
        return out, {"uncovered": comm_ws.uncovered_coords(
            "cyclic", tuple(dims), c, s, sslot
        )}

    fn.wire_kinds = kinds
    return fn


def sample_round_length(rng: np.random.Generator, p: float,
                        max_L: int = 100_000) -> int:
    """Host-side ``L ~ Geometric(p)`` draw (each length compiles once)."""
    return int(min(rng.geometric(p), max_L))
