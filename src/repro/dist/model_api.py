"""Family-dispatching model API: one surface over ``repro.models``.

The dist engine, the serving drivers, and the dry-run all talk to the model
zoo through these five functions, so a new family only has to plug in here:

  init(key, cfg)                      -> params pytree
  loss(params, cfg, *, tokens, labels, ...) -> (scalar loss, metrics)
  prefill(params, cfg, *, tokens, ...)-> last-position logits (b, vocab)
  make_cache(cfg, batch, max_seq)     -> decode cache pytree
  decode(params, cfg, token, cache, pos, attend_fn=None)
                                      -> (logits (b, vocab), new cache)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, layers
from repro.models import transformer as tr
from repro.models.transformer import ModelConfig

Params = Dict[str, Any]

__all__ = ["init", "loss", "prefill", "make_cache", "decode"]


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    if cfg.family == "encdec":
        return encdec.init_encdec_params(key, cfg, cfg.n_encoder_layers)
    return tr.init_params(key, cfg)


def loss(
    params: Params,
    cfg: ModelConfig,
    *,
    tokens: jax.Array,
    labels: jax.Array,
    prefix_embeds: Optional[jax.Array] = None,
    frames: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    if cfg.family == "encdec":
        if frames is None:
            raise ValueError("encdec loss requires frames")
        if prefix_embeds is not None:
            raise ValueError("encdec does not consume prefix_embeds")
        return encdec.loss_fn(params, cfg, frames, tokens, labels)
    if frames is not None:
        raise ValueError(f"family {cfg.family!r} does not consume frames")
    return tr.loss_fn(params, cfg, tokens, labels, prefix_embeds)


def prefill(
    params: Params,
    cfg: ModelConfig,
    *,
    tokens: jax.Array,
    prefix_embeds: Optional[jax.Array] = None,
    frames: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence forward; returns the last position's logits — the
    tensor a serving runtime needs to start decoding (the KV cache for the
    decode loop is built by stepping ``decode``, exact for all families)."""
    if cfg.family == "encdec":
        enc = encdec.encode(params, cfg, frames)
        h = encdec.decode_train(params, cfg, tokens, enc)
        w = params["embed"].T
    else:
        h, _ = tr.forward(params, cfg, tokens, prefix_embeds=prefix_embeds)
        w = tr.lm_head_weight(params, cfg)
    last = h[:, -1]
    logits = jax.lax.dot_general(
        last.astype(jnp.float32), w.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )[:, : cfg.vocab]
    return layers.softcap(logits, cfg.final_softcap)


def make_cache(
    cfg: ModelConfig, batch: int, max_seq: int, kv_dtype=jnp.bfloat16
) -> Params:
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_seq, cfg.n_frames, kv_dtype)
    return tr.init_cache(cfg, batch, max_seq, kv_dtype)


def decode(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,
    cache: Params,
    pos: jax.Array,
    attend_fn=None,
) -> Tuple[jax.Array, Params]:
    if cfg.family == "encdec":
        # enc-dec decode has no pluggable attend path (cross-KV precomputed)
        return encdec.decode_step(params, cfg, token, cache, pos)
    return tr.decode_step(params, cfg, token, cache, pos, attend_fn=attend_fn)
