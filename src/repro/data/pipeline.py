"""Synthetic-data pipeline with per-client sharding.

Offline container: token streams are generated, not read from disk, but the
pipeline has the real structure — deterministic per-client shard keys
(clients see DISJOINT, heterogeneous data: the paper's no-similarity
regime), per-local-step batching, and device placement to the dp mesh axes.

The token generator is a small order-2 Markov chain per client (distinct
transition tables), which gives a learnable but heterogeneous distribution —
loss curves actually go down, unlike uniform noise.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.dist import sharding
from repro.models.transformer import ModelConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 256
    per_client_batch: int = 4
    vocab: int = 512
    seed: int = 0
    heterogeneity: float = 1.0  # 0 = iid clients, 1 = fully distinct chains
    n_clients: Optional[int] = None  # default: from the mesh (1 if no mesh)


class SyntheticTokenPipeline:
    """Yields batches with leaves shaped (n_clients, per_client_batch, seq)."""

    def __init__(self, dcfg: DataConfig, model_cfg: ModelConfig,
                 mesh: Optional[Mesh] = None):
        self.dcfg = dcfg
        self.cfg = model_cfg
        self.mesh = mesh
        self.n = dcfg.n_clients or (
            sharding.n_clients(mesh) if mesh is not None else 1
        )
        rng = np.random.default_rng(dcfg.seed)
        v = min(dcfg.vocab, model_cfg.vocab)
        self.v = v
        # per-client bigram transition logits, interpolated toward a shared
        # table by (1 - heterogeneity)
        shared = rng.normal(size=(v, v)) * 2.0
        per = rng.normal(size=(self.n, v, v)) * 2.0
        mix = dcfg.heterogeneity
        logits = mix * per + (1 - mix) * shared[None]
        z = np.exp(logits - logits.max(axis=-1, keepdims=True))
        self.trans = (z / z.sum(axis=-1, keepdims=True)).astype(np.float64)
        self.rng = rng
        self._sharding = (
            NamedSharding(mesh, sharding.train_batch_pspec(mesh))
            if mesh is not None else None
        )

    def _sample_chain(self, client: int, shape) -> np.ndarray:
        b, t = shape
        out = np.empty((b, t), np.int32)
        state = self.rng.integers(0, self.v, size=b)
        for j in range(t):
            out[:, j] = state
            probs = self.trans[client, state]
            cum = probs.cumsum(axis=-1)
            u = self.rng.random((b, 1))
            state = (u < cum).argmax(axis=-1)
        return out

    def next_batch(self) -> Dict[str, jax.Array]:
        d = self.dcfg
        toks = np.stack([
            self._sample_chain(i, (d.per_client_batch, d.seq_len + 1))
            for i in range(self.n)
        ])
        batch = {
            "tokens": jnp.asarray(toks[:, :, :-1]),
            "labels": jnp.asarray(toks[:, :, 1:]),
        }
        if self.cfg.prefix_len:
            pe = self.rng.normal(
                size=(self.n, d.per_client_batch, self.cfg.prefix_len,
                      self.cfg.d_model)
            ).astype(np.float32)
            batch["prefix_embeds"] = jnp.asarray(pe, self.cfg.dtype)
        if self.cfg.family == "encdec":
            fr = self.rng.normal(
                size=(self.n, d.per_client_batch, self.cfg.n_frames,
                      self.cfg.d_model)
            ).astype(np.float32)
            batch["frames"] = jnp.asarray(fr, self.cfg.dtype)
        if self._sharding is not None:
            sh = {
                k: NamedSharding(self.mesh,
                                 jax.sharding.PartitionSpec(
                                     sharding.dp_axes(self.mesh),
                                     *([None] * (v.ndim - 1))))
                for k, v in batch.items()
            }
            batch = {k: jax.device_put(v, sh[k]) for k, v in batch.items()}
        return batch

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        while True:
            yield self.next_batch()
