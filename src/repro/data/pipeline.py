"""Synthetic-data pipeline with per-client sharding.

Offline container: token streams are generated, not read from disk, but the
pipeline has the real structure — deterministic per-client shard keys
(clients see DISJOINT, heterogeneous data: the paper's no-similarity
regime), per-local-step batching, and device placement to the dp mesh axes.

The token generator is a small Markov chain per client (distinct transition
tables), which gives a learnable but heterogeneous distribution — loss
curves actually go down, unlike uniform noise.

Two sampling paths share the same per-client transition tables:

  host    ``next_batch()``: numpy chains advanced per client from
          *per-client* ``Generator``s (client ``i``'s stream depends only on
          ``(seed, i)`` — invariant to ``n_clients`` and generation order).
  device  ``device_sample_batch(data, key, ...)``: a pure jittable sampler
          over the device-resident cumulative tables — the chain advanced by
          a vectorized ``lax.scan`` + ``searchsorted``, per-client streams
          derived by ``fold_in(key, client)`` (again invariant to ``n``).
          This is what the fused round engine (``repro.dist.rounds``) calls
          inside its scan body, so steady-state training needs zero
          host->device transfers.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding
from repro.dist.tamuna_dp import _as_key
from repro.models.transformer import ModelConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 256
    per_client_batch: int = 4
    vocab: int = 512
    seed: int = 0
    heterogeneity: float = 1.0  # 0 = iid clients, 1 = fully distinct chains
    n_clients: Optional[int] = None  # default: from the mesh (1 if no mesh)


def _client_rng(seed: int, client: int) -> np.random.Generator:
    """Per-client host stream: depends only on (seed, client)."""
    return np.random.default_rng(np.random.SeedSequence([seed, 977, client]))


# --------------------------------------------------------------------------
# pure device sampler
# --------------------------------------------------------------------------


def device_sample_batch(
    data: Dict[str, jax.Array],
    key: jax.Array,
    *,
    dcfg: DataConfig,
    model_cfg: ModelConfig,
    mesh: Optional[Mesh] = None,
    clients: Optional[jax.Array] = None,
) -> Dict[str, jax.Array]:
    """Sample one ``(n, per_client_batch, ...)`` batch entirely on device.

    ``data`` holds the per-client *cumulative* transition tables
    (``{"cum": (n, v, v) f32}``, see ``SyntheticTokenPipeline.device_data``)
    so it can be threaded through a donated scan carry.  Client ``i``'s
    stream is derived via ``fold_in(key, i)``: invariant to ``n``.

    ``clients`` restricts the batch to a ``(c,)`` subset of client ids
    (the elastic engine's cohort, DESIGN.md §11): the result is
    ``(c, b, ...)``, row ``a`` holding client ``clients[a]``'s stream —
    the SAME tokens that client would see in a full batch (streams are
    keyed by actual client id), so cohort-gathered and all-rows compute
    consume identical per-client data.
    """
    cum = data["cum"]
    n, v = cum.shape[0], cum.shape[-1]
    b, T = dcfg.per_client_batch, dcfg.seq_len
    key = _as_key(key)
    k_tok, k_pre, k_fr = jax.random.split(key, 3)
    cohort = clients is not None
    clients = jnp.arange(n) if clients is None else clients
    n = clients.shape[0]
    cks = jax.vmap(lambda i: jax.random.fold_in(k_tok, i))(clients)

    state0 = jax.vmap(
        lambda k: jax.random.randint(
            jax.random.fold_in(k, 0), (b,), 0, v, jnp.int32
        )
    )(cks)
    rowix = clients[:, None]
    searchsorted = jax.vmap(jax.vmap(
        lambda row, u: jnp.searchsorted(row, u, side="right")
    ))

    def step(state, j):
        kj = jax.vmap(lambda k: jax.random.fold_in(k, j))(cks)
        u = jax.vmap(lambda k: jax.random.uniform(k, (b,)))(kj)
        rows = cum[rowix, state]  # (n, b, v) per-client cumulative rows
        nxt = jnp.clip(searchsorted(rows, u), 0, v - 1).astype(jnp.int32)
        return nxt, state

    # emit s_0 .. s_T (T+1 states): tokens = s_{:-1}, labels = s_{1:}
    _, seq = jax.lax.scan(step, state0, jnp.arange(1, T + 2))
    toks = jnp.moveaxis(seq, 0, -1)  # (n, b, T+1)
    if mesh is not None and not cohort:
        # cohort batches skip the dp constraint: c rarely divides the dp
        # extent, and the gathered compute GSPMD places decides anyway
        toks = jax.lax.with_sharding_constraint(
            toks, NamedSharding(mesh, P(sharding.dp_axes(mesh), None, None))
        )
    batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    if model_cfg.prefix_len:
        pks = jax.vmap(lambda i: jax.random.fold_in(k_pre, i))(clients)
        pe = jax.vmap(
            lambda k: jax.random.normal(
                k, (b, model_cfg.prefix_len, model_cfg.d_model), jnp.float32
            )
        )(pks)
        batch["prefix_embeds"] = pe.astype(model_cfg.dtype)
    if model_cfg.family == "encdec":
        fks = jax.vmap(lambda i: jax.random.fold_in(k_fr, i))(clients)
        fr = jax.vmap(
            lambda k: jax.random.normal(
                k, (b, model_cfg.n_frames, model_cfg.d_model), jnp.float32
            )
        )(fks)
        batch["frames"] = fr.astype(model_cfg.dtype)
    return batch


def device_sampler(dcfg: DataConfig, model_cfg: ModelConfig,
                   mesh: Optional[Mesh] = None):
    """The ``sample_batch(data, key)`` callable the round engine consumes."""
    return partial(device_sample_batch, dcfg=dcfg, model_cfg=model_cfg,
                   mesh=mesh)


class SyntheticTokenPipeline:
    """Yields batches with leaves shaped (n_clients, per_client_batch, seq)."""

    def __init__(self, dcfg: DataConfig, model_cfg: ModelConfig,
                 mesh: Optional[Mesh] = None):
        self.dcfg = dcfg
        self.cfg = model_cfg
        self.mesh = mesh
        self.n = dcfg.n_clients or (
            sharding.n_clients(mesh) if mesh is not None else 1
        )
        rng = np.random.default_rng(dcfg.seed)
        v = min(dcfg.vocab, model_cfg.vocab)
        self.v = v
        # per-client bigram transition logits, interpolated toward a shared
        # table by (1 - heterogeneity).  Both tables are drawn in single
        # sequential fills, so client i's table depends only on (seed, i),
        # never on n.
        shared = rng.normal(size=(v, v)) * 2.0
        per = rng.normal(size=(self.n, v, v)) * 2.0
        mix = dcfg.heterogeneity
        logits = mix * per + (1 - mix) * shared[None]
        z = np.exp(logits - logits.max(axis=-1, keepdims=True))
        self.trans = (z / z.sum(axis=-1, keepdims=True)).astype(np.float64)
        # per-client host streams: client i draws only from _rngs[i]
        self._rngs = [_client_rng(dcfg.seed, i) for i in range(self.n)]
        self._device_data: Optional[Dict[str, jax.Array]] = None
        self._sharding = (
            NamedSharding(mesh, sharding.train_batch_pspec(mesh))
            if mesh is not None else None
        )

    # ---------------------------------------------------------------- host

    def _sample_chain(self, client: int, shape) -> np.ndarray:
        b, t = shape
        rng = self._rngs[client]
        out = np.empty((b, t), np.int32)
        state = rng.integers(0, self.v, size=b)
        for j in range(t):
            out[:, j] = state
            probs = self.trans[client, state]
            cum = probs.cumsum(axis=-1)
            u = rng.random((b, 1))
            state = (u < cum).argmax(axis=-1)
        return out

    def next_batch(self, clients=None) -> Dict[str, jax.Array]:
        """One host-sampled batch.  ``clients`` restricts to a cohort (the
        per-step trainer's elastic path): only those clients' streams
        advance — idle clients consume nothing, matching the paper's
        idle-clients-do-nothing semantics on the host path too."""
        d = self.dcfg
        ids = (list(range(self.n)) if clients is None
               else [int(i) for i in np.asarray(clients)])
        toks = np.stack([
            self._sample_chain(i, (d.per_client_batch, d.seq_len + 1))
            for i in ids
        ])
        batch = {
            "tokens": jnp.asarray(toks[:, :, :-1]),
            "labels": jnp.asarray(toks[:, :, 1:]),
        }
        if self.cfg.prefix_len:
            pe = np.stack([
                self._rngs[i].normal(
                    size=(d.per_client_batch, self.cfg.prefix_len,
                          self.cfg.d_model)
                ) for i in ids
            ]).astype(np.float32)
            batch["prefix_embeds"] = jnp.asarray(pe, self.cfg.dtype)
        if self.cfg.family == "encdec":
            fr = np.stack([
                self._rngs[i].normal(
                    size=(d.per_client_batch, self.cfg.n_frames,
                          self.cfg.d_model)
                ) for i in ids
            ]).astype(np.float32)
            batch["frames"] = jnp.asarray(fr, self.cfg.dtype)
        if clients is not None:
            return batch  # cohort batches: GSPMD places the gathered rows
        if self._sharding is not None:
            sh = {
                k: NamedSharding(self.mesh,
                                 jax.sharding.PartitionSpec(
                                     sharding.dp_axes(self.mesh),
                                     *([None] * (v.ndim - 1))))
                for k, v in batch.items()
            }
            batch = {k: jax.device_put(v, sh[k]) for k, v in batch.items()}
        return batch

    # -------------------------------------------------------------- device

    def device_data(self) -> Dict[str, jax.Array]:
        """Device-resident per-client cumulative transition tables, sharded
        over the dp axes when a mesh is attached.  Threaded through the
        round engine's donated carry (aliased, uploaded once)."""
        if self._device_data is None:
            cum = np.cumsum(self.trans, axis=-1).astype(np.float32)
            arr = jnp.asarray(cum)
            if self.mesh is not None:
                arr = jax.device_put(
                    arr,
                    NamedSharding(
                        self.mesh,
                        P(sharding.dp_axes(self.mesh), None, None),
                    ),
                )
            self._device_data = {"cum": arr}
        return self._device_data

    def sample_batch(self, key: jax.Array) -> Dict[str, jax.Array]:
        """Stateless on-device sample (convenience wrapper around the pure
        ``device_sample_batch``)."""
        return device_sample_batch(
            self.device_data(), key, dcfg=self.dcfg, model_cfg=self.cfg,
            mesh=self.mesh,
        )

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        while True:
            yield self.next_batch()
