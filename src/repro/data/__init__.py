from repro.data.pipeline import (
    DataConfig,
    SyntheticTokenPipeline,
    device_sample_batch,
    device_sampler,
)

__all__ = [
    "DataConfig",
    "SyntheticTokenPipeline",
    "device_sample_batch",
    "device_sampler",
]
