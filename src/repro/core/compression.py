"""Compression operators used by TAMUNA and the baselines.

* ``permutation``-mask compressor (the paper's own; see masks.py),
* ``rand_k`` unbiased sparsifier (DIANA baseline),
* ``top_k`` biased sparsifier (EF21 baseline),
* aggregation helpers with the exact ``1/s`` reconstruction of Algorithm 1.

Everything operates on flat vectors; pytree plumbing lives in dist/.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import masks

# The convex reproduction (this module's only consumers: tamuna, baselines,
# and their tests) tracks aggregation error to ~1e-10; keep the f64 flag on
# here so importing the compression stack alone — without problems.py —
# still gives f64 numerics.  The LM/dist stack imports masks/theory only
# and stays out of x64 (see repro/core/__init__.py).
jax.config.update("jax_enable_x64", True)

__all__ = [
    "apply_mask",
    "aggregate_masked",
    "rand_k",
    "top_k",
    "uplink_floats_permutation",
    "uplink_floats_rand_k",
    "uplink_bytes_permutation",
    "uplink_bytes_rand_k",
]


def apply_mask(v: jax.Array, q_col: jax.Array) -> jax.Array:
    """``C_i(v)``: elementwise multiply by the client's binary mask column."""
    return v * q_col.astype(v.dtype)


def aggregate_masked(xs: jax.Array, q: jax.Array, s: int) -> jax.Array:
    """Server aggregation ``x_bar = (1/s) sum_i C_i(x_i)`` (Algorithm 1 l.12).

    xs: ``(c, d)`` stacked active-client vectors; q: ``(d, c)`` mask.
    Exact at consensus: if all rows of ``xs`` are equal, returns that vector
    exactly (each coordinate has exactly ``s`` owners).
    """
    masked = xs * q.T.astype(xs.dtype)  # (c, d)
    return masked.sum(axis=0) / s


def rand_k(key: jax.Array, v: jax.Array, k: int) -> jax.Array:
    """Unbiased rand-k compressor: keep ``k`` uniform coordinates scaled by
    ``d/k`` (zero elsewhere).  ``E[rand_k(v)] = v``."""
    d = v.shape[0]
    idx = jax.random.choice(key, d, shape=(k,), replace=False)
    out = jnp.zeros_like(v)
    return out.at[idx].set(v[idx] * (d / k))


def top_k(v: jax.Array, k: int) -> jax.Array:
    """Biased top-k compressor: keep the k largest-magnitude coordinates."""
    d = v.shape[0]
    _, idx = jax.lax.top_k(jnp.abs(v), k)
    out = jnp.zeros_like(v)
    return out.at[idx].set(v[idx])


def quantize_stochastic(
    key: jax.Array, v: jax.Array, bits: int, chunk: int = 256
) -> jax.Array:
    """Unbiased stochastic-rounding quantizer with PER-CHUNK scales.

    Beyond-paper experiment: the paper's conclusion leaves "quantization on
    top of the permutation sparsifier" as an open question; this composes an
    UNBIASED quantizer with the mask, so E[Q(C_i(x))] = C_i(x) and the
    aggregation remains exact in expectation.  See EXPERIMENTS.md §Beyond.

    Scales are per ``chunk`` coordinates rather than one per-tensor max, so
    a single outlier no longer collapses the resolution of every other
    coordinate (for ``d <= chunk`` this reduces exactly to the per-tensor
    scale).  Nonfinite coordinates are excluded from the chunk max and pass
    through untouched — a NaN is never quantized into a finite value, and
    (fault-path contract) quantization composes with the payload guards by
    running AFTER nonfinite-zeroing.
    """
    levels = 2 ** (bits - 1) - 1
    d = v.shape[-1]
    nc = -(-d // chunk)
    a = jnp.where(jnp.isfinite(v), jnp.abs(v), 0.0)
    pad = nc * chunk - d
    if pad:
        a = jnp.pad(a, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
    mx = a.reshape(v.shape[:-1] + (nc, chunk)).max(axis=-1)
    scale = jnp.maximum(mx / levels, 1e-12)
    sc = jnp.repeat(scale, chunk, axis=-1)[..., :d]
    z = v / sc
    low = jnp.floor(z)
    p = z - low
    rnd = jax.random.uniform(key, v.shape)
    q = low + (rnd < p).astype(v.dtype)
    return jnp.where(jnp.isfinite(v), q * sc, v)


def uplink_floats_permutation(d: int, c: int, s: int) -> int:
    """Floats uploaded per client per round under the permutation mask."""
    return masks.column_nnz(d, c, s)


def uplink_floats_rand_k(k: int) -> int:
    return k


# dtype-aware wire widths; kept in sync with repro.dist.wire.WIDTH_BYTES
# (dist must not import this module — it enables x64 — so the table is
# duplicated here rather than shared)
_WIRE_WIDTH_BYTES = {
    "f32": 4.0, "bf16": 2.0, "f16": 2.0, "int8": 1.0, "int4": 0.5,
}
_WIRE_CHUNK = 256


def uplink_bytes_permutation(
    d: int, c: int, s: int, kind: str = "f32"
) -> float:
    """Wire bytes uploaded per client per round under the permutation mask
    at wire kind ``kind``.  The f32 path is byte-identical to
    ``uplink_floats_permutation(d, c, s) * 4``; int kinds add the per-chunk
    f32 scales shipped alongside the codes."""
    b = uplink_floats_permutation(d, c, s) * _WIRE_WIDTH_BYTES[kind]
    if kind in ("int8", "int4"):
        b += (-(-d // _WIRE_CHUNK)) * 4.0
    return float(b)


def uplink_bytes_rand_k(k: int, kind: str = "f32") -> float:
    """rand-k value payload at wire width ``kind``; the f32 path is
    byte-identical to ``uplink_floats_rand_k(k) * 4``."""
    return float(k * _WIRE_WIDTH_BYTES[kind])


def split_cohort(
    key: jax.Array, n: int, c: int
) -> Tuple[jax.Array, jax.Array]:
    """Sample the active cohort ``Omega`` (c of n, uniform, no replacement).

    Returns ``(cohort_idx (c,), member_mask (n,))``.
    """
    idx = jax.random.choice(key, n, shape=(c,), replace=False)
    member = jnp.zeros((n,), dtype=bool).at[idx].set(True)
    return idx, member
