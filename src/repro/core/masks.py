"""Permutation-based compression masks (TAMUNA / CompressedScaffnew, Fig. 1).

The uplink compressor of TAMUNA multiplies each client's vector elementwise by
a binary mask ``q_i`` (column ``i`` of a mask matrix ``q in {0,1}^{d x c}``).
``q`` is a uniformly random column permutation of a fixed *template* with
exactly ``s`` ones in every row, so that

  * every coordinate ``k`` is uploaded by exactly ``s`` of the ``c`` active
    clients  (row property — makes the aggregation ``(1/s) sum_i C_i(x_i)``
    exact when all ``x_i`` are equal: the zero-error-at-consensus property),
  * every client uploads ``floor(s d / c)`` or ``ceil(s d / c)`` coordinates
    (column property — the UpCom saving of factor ``~ c/s``).

Two template regimes (paper Fig. 1):

  * ``d >= c/s``  : row ``k`` has ones at columns ``mod(s k + t, c)`` for
                    ``t = 0..s-1`` (cyclic band).
  * ``c/s >= d``  : column ``j`` has a single one at row ``mod(j, d)`` for
                    ``j < d s`` and is empty for ``j >= d s``.

Both are generated *on the fly* from the permutation without materializing
``q`` — the closed forms below are what the Pallas kernel uses.

A third, TPU-native *blocked* template (``block_template``) keeps the
exactly-``s``-owners row property but assigns each client **contiguous**
coordinate slices, turning the sparse uplink into reduce-scatter-shaped
blocks (see DESIGN.md §3).  It is a row reordering of the cyclic template.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "template_mask",
    "block_template_mask",
    "sample_mask",
    "sample_permutation",
    "mask_from_permutation",
    "block_shift_permutation",
    "column_nnz",
    "block_column_nnz",
    "owner_band_start",
]


def _validate(d: int, c: int, s: int) -> None:
    if not (2 <= s <= c):
        raise ValueError(f"need 2 <= s <= c, got s={s}, c={c}")
    if d < 1:
        raise ValueError(f"need d >= 1, got d={d}")


def template_mask(d: int, c: int, s: int) -> np.ndarray:
    """Dense ``{0,1}^{d x c}`` template pattern of paper Fig. 1 (numpy)."""
    _validate(d, c, s)
    q = np.zeros((d, c), dtype=np.int8)
    if d * s >= c:
        # cyclic band: row k owns columns mod(s k + t, c), t in [0, s)
        for k in range(d):
            for t in range(s):
                q[k, (s * k + t) % c] = 1
    else:
        # tall-and-thin regime: column j < d s has one at row mod(j, d)
        for j in range(d * s):
            q[j % d, j] = 1
    return q


def block_template_mask(d: int, c: int, s: int) -> np.ndarray:
    """Contiguous-block template: same row/column properties, but each
    client's owned coordinates form at most ``s`` contiguous slices.

    Coordinates are partitioned into ``c`` contiguous chunks of size
    ``ceil(d/c)`` (last chunk ragged); chunk ``j`` is owned by clients
    ``j, j+1, ..., j+s-1 (mod c)``.  Every coordinate has exactly ``s``
    owners; every client owns ``s`` chunks (~``s d / c`` coordinates).
    """
    _validate(d, c, s)
    q = np.zeros((d, c), dtype=np.int8)
    chunk = -(-d // c)  # ceil
    for k in range(d):
        j = min(k // chunk, c - 1)
        for t in range(s):
            q[k, (j + t) % c] = 1
    return q


def sample_permutation(key: jax.Array, c: int) -> jax.Array:
    """Uniformly random permutation of ``[c]`` (column permutation)."""
    return jax.random.permutation(key, c)


def mask_from_permutation(
    perm: jax.Array, d: int, c: int, s: int, *, blocked: bool = False
) -> jax.Array:
    """Dense mask ``q[:, i] = template[:, perm[i]]`` as a jnp int8 array.

    Closed-form (no template materialization), jit/vmap friendly.
    """
    _validate(d, c, s)
    cols = perm[None, :]  # (1, c) template column index of each actual column
    k = jnp.arange(d)[:, None]  # (d, 1)
    if blocked:
        chunk = -(-d // c)
        j = jnp.minimum(k // chunk, c - 1)
        # owned iff mod(col - j, c) < s
        q = ((cols - j) % c) < s
    elif d * s >= c:
        # owned iff mod(col - s k, c) < s
        q = ((cols - s * k) % c) < s
    else:
        q = (cols < d * s) & ((cols % d) == k)
    return q.astype(jnp.int8)


def block_shift_permutation(off, c: int, s: int) -> jax.Array:
    """The column permutation realizing the dist engine's *shifted*
    blocked ownership as a ``mask_from_permutation(..., blocked=True)``
    column permutation of the block template.

    The engine gives the client at cohort slot ``a`` the blocks
    ``a + off .. a + off + s - 1 (mod c)`` (``(j - a - off) mod c < s``),
    while the template's column ``p`` owns blocks ``p - s + 1 .. p``
    (``(p - j) mod c < s``); they coincide for
    ``p = (a + off + s - 1) mod c`` — a valid permutation of ``[c]``, so
    the elastic blocked uplink inherits the template's exactly-``s``-owners
    row property at every cohort size (property-tested in
    tests/test_dist_invariants.py)."""
    return (jnp.arange(c, dtype=jnp.int32) + off + s - 1) % c


def sample_mask(
    key: jax.Array, d: int, c: int, s: int, *, blocked: bool = False
) -> jax.Array:
    """Sample the round mask ``q in {0,1}^{d x c}`` (paper Fig. 1(c))."""
    perm = sample_permutation(key, c)
    return mask_from_permutation(perm, d, c, s, blocked=blocked)


def column_nnz(d: int, c: int, s: int) -> int:
    """Worst-case uploaded floats per client: ``ceil(s d / c)`` (or 1)."""
    return max(1, -(-s * d // c))


def block_column_nnz(d: int, c: int, s: int) -> int:
    """Worst-case uploaded floats per client under the *blocked* template:
    ``s`` chunks of ``ceil(d/c)`` coordinates (capped at ``d``) — slightly
    above the cyclic template's ``ceil(s d / c)`` when ``d % c != 0``."""
    return min(d, s * -(-d // c))


def owner_band_start(k: jax.Array, d: int, c: int, s: int) -> jax.Array:
    """Start of the cyclic owner band for coordinate ``k`` (``d s >= c``
    regime): coordinate ``k`` is owned by template columns
    ``mod(s k + t, c), t in [0, s)``.  Used by the Pallas kernel."""
    del d
    return (s * k) % c
