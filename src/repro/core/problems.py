"""Convex finite-sum problems for the paper's experiments (Section 5).

``FiniteSumProblem`` models ``f(x) = (1/n) sum_i f_i(x)`` with per-client
data shards.  The paper uses l2-regularized logistic regression (eq. 20) on
LIBSVM datasets (w8a: d=300, n~3d; real-sim: d=20958, d>>n).  This container
is offline, so we generate synthetic datasets with the same shape regimes and
condition number ``kappa = L/mu = 1e4`` (matching the paper's setup).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# The convex reproduction tracks suboptimality down to ~1e-12; float32 is not
# enough.  Model code elsewhere always passes explicit dtypes, so enabling
# x64 here is safe for the rest of the framework.
jax.config.update("jax_enable_x64", True)

__all__ = [
    "FiniteSumProblem",
    "make_logreg_problem",
    "make_quadratic_problem",
    "solve_exactly",
]


@dataclass
class FiniteSumProblem:
    """A finite-sum convex problem split across ``n`` clients.

    grad_all(x)       -> (n, d) per-client exact gradients at shared x
    grad_all_local(X) -> (n, d) per-client gradients at per-client models X(n,d)
    grad_cohort(X, cohort) -> (c, d) gradients of clients ``cohort`` at
                          their models X (c, d) — the O(c d) path a TAMUNA
                          round actually needs (only the cohort works).
    """

    n: int
    d: int
    mu: float
    L: float
    f: Callable[[jax.Array], jax.Array]
    grad_all_local: Callable[[jax.Array], jax.Array]
    x_star: Optional[jax.Array] = None
    f_star: Optional[float] = None
    name: str = "problem"
    meta: dict = field(default_factory=dict)
    grad_cohort: Optional[Callable[[jax.Array, jax.Array], jax.Array]] = None

    @property
    def kappa(self) -> float:
        return self.L / self.mu

    def grad_all(self, x: jax.Array) -> jax.Array:
        return self.grad_all_local(jnp.broadcast_to(x, (self.n, self.d)))

    def grad(self, x: jax.Array) -> jax.Array:
        return self.grad_all(x).mean(axis=0)

    def suboptimality(self, x: jax.Array) -> jax.Array:
        return self.f(x) - self.f_star

    def h_star(self) -> jax.Array:
        """Per-client optimal control variates ``h_i* = grad f_i(x*)``."""
        return self.grad_all(self.x_star)

    def cohort_grads(self, X: jax.Array, cohort: jax.Array) -> jax.Array:
        """(c, d) gradients for the cohort only; falls back to the O(n d)
        scatter-into-population path for problems without ``grad_cohort``."""
        if self.grad_cohort is not None:
            return self.grad_cohort(X, cohort)
        Xn = jnp.zeros((self.n, self.d), X.dtype).at[cohort].set(X)
        return self.grad_all_local(Xn)[cohort]


def _logistic_loss(x, A, b, mu):
    # mean_m log(1 + exp(-b_m a_m.x)) + mu/2 ||x||^2   (paper eq. 20)
    z = A @ x * b
    return jnp.mean(jax.nn.softplus(-z)) + 0.5 * mu * jnp.sum(x * x)


def make_logreg_problem(
    *,
    n: int = 64,
    d: int = 300,
    samples_per_client: int = 16,
    kappa: float = 1e4,
    seed: int = 0,
    heterogeneity: float = 1.0,
    name: str = "logreg",
) -> FiniteSumProblem:
    """Synthetic l2-regularized logistic regression, kappa = L/mu prescribed.

    Heterogeneous shards: each client's features are drawn around a distinct
    random center scaled by ``heterogeneity`` (no similarity assumption, as
    in the paper).
    """
    rng = np.random.default_rng(seed)
    m = samples_per_client
    centers = rng.normal(size=(n, 1, d)) * heterogeneity
    A = rng.normal(size=(n, m, d)) + centers
    w_true = rng.normal(size=(d,))
    logits = (A @ w_true) + 0.5 * rng.normal(size=(n, m))
    b = np.sign(logits).astype(np.float64)
    b[b == 0] = 1.0

    A_flat = A.reshape(n * m, d)
    # Smoothness of the unregularized part: ||A^T A|| / (4 M) globally; each
    # client's L_i = ||A_i^T A_i|| / (4 m).  Use the max over clients so that
    # every f_i is L-smooth (paper assumes uniform L).
    def spec_norm(M_):
        return np.linalg.eigvalsh(M_.T @ M_).max()

    L_data = max(spec_norm(A[i]) / (4.0 * m) for i in range(n))
    mu = L_data / (kappa - 1.0)
    L = L_data + mu

    A_j = jnp.asarray(A, dtype=jnp.float64)
    b_j = jnp.asarray(b, dtype=jnp.float64)
    A_flat_j = jnp.asarray(A_flat, dtype=jnp.float64)
    b_flat_j = jnp.asarray(b.reshape(-1), dtype=jnp.float64)

    def f(x):
        return _logistic_loss(x, A_flat_j, b_flat_j, mu)

    client_grad = jax.grad(lambda x, Ai, bi: _logistic_loss(x, Ai, bi, mu))

    @jax.jit
    def grad_all_local(X):
        return jax.vmap(client_grad)(X, A_j, b_j)

    @jax.jit
    def grad_cohort(X, cohort):
        return jax.vmap(client_grad)(X, A_j[cohort], b_j[cohort])

    prob = FiniteSumProblem(
        n=n, d=d, mu=float(mu), L=float(L), f=jax.jit(f),
        grad_all_local=grad_all_local, grad_cohort=grad_cohort, name=name,
        meta=dict(samples_per_client=m, kappa=kappa, seed=seed),
    )
    solve_exactly(prob, A_flat, b.reshape(-1), mu)
    return prob


def make_quadratic_problem(
    *, n: int = 32, d: int = 64, kappa: float = 100.0, seed: int = 0,
    name: str = "quadratic",
) -> FiniteSumProblem:
    """Heterogeneous strongly convex quadratics with known closed-form x*.

    f_i(x) = 1/2 x^T D x - t_i^T x  with shared diagonal D (spectrum in
    [mu, L]) and client-specific targets t_i -> arbitrary heterogeneity,
    exact x* = D^{-1} mean(t_i).
    """
    rng = np.random.default_rng(seed)
    mu, L = 1.0, float(kappa)
    diag = np.linspace(mu, L, d)
    t = rng.normal(size=(n, d)) * 5.0
    x_star = t.mean(axis=0) / diag

    diag_j = jnp.asarray(diag)
    t_j = jnp.asarray(t)

    def f(x):
        per = 0.5 * jnp.sum(diag_j * x * x) - t_j @ x  # (n,)
        return per.mean()

    @jax.jit
    def grad_all_local(X):
        return X * diag_j[None, :] - t_j

    @jax.jit
    def grad_cohort(X, cohort):
        return X * diag_j[None, :] - t_j[cohort]

    prob = FiniteSumProblem(
        n=n, d=d, mu=mu, L=L, f=jax.jit(f),
        grad_all_local=grad_all_local, grad_cohort=grad_cohort,
        x_star=jnp.asarray(x_star), name=name, meta=dict(kappa=kappa),
    )
    prob.f_star = float(prob.f(prob.x_star))
    return prob


def solve_exactly(
    prob: FiniteSumProblem, A: np.ndarray, b: np.ndarray, mu: float,
    tol: float = 1e-14, max_iter: int = 200,
) -> None:
    """Newton's method to machine precision — fills x_star / f_star."""
    x = np.zeros(prob.d)
    for _ in range(max_iter):
        z = (A @ x) * b
        sig = 1.0 / (1.0 + np.exp(z))  # sigmoid(-z)
        g = -(A * (b * sig)[:, None]).mean(axis=0) + mu * x
        w = sig * (1.0 - sig)
        H = (A.T * w) @ A / A.shape[0] + mu * np.eye(prob.d)
        step = np.linalg.solve(H, g)
        x = x - step
        if np.linalg.norm(g) < tol:
            break
    prob.x_star = jnp.asarray(x)
    prob.f_star = float(prob.f(prob.x_star))
