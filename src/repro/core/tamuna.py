"""TAMUNA (Algorithm 1) and its single-loop form (Algorithm 2).

This is the *paper-faithful* federated core: an exact implementation of the
algorithm over a ``FiniteSumProblem`` with

  * LT: ``L^(r) ~ Geometric(p)`` local steps per round (or fixed ``L``),
  * CC: permutation-mask compression with sparsity ``s`` (masks.py),
  * PP: uniform cohorts of size ``c``; idle clients do nothing,
  * optional stochastic gradients of variance ``sigma^2`` (eq. 3).

State layout is stacked for vectorization: ``h`` is ``(n, d)``; only the
cohort's ``x_i`` exist during a round (paper: idle clients store no model).
The distributed (mesh/shard_map) version for LM training lives in
``repro/dist``; this module is the reference semantics and is what the
convergence tests validate against Theorem 1.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression, masks, theory
from repro.core.problems import FiniteSumProblem

__all__ = ["TamunaConfig", "TamunaState", "init", "round_step", "run", "lyapunov"]


@dataclass(frozen=True)
class TamunaConfig:
    gamma: float  # local stepsize
    eta: float  # control-variate stepsize (Remark 2: eta = p * chi)
    p: float  # inverse expected number of local steps per round
    c: int  # cohort size (2 <= c <= n)
    s: int  # compression sparsity index (2 <= s <= c)
    geometric_L: bool = True  # L^(r) ~ Geom(p); else fixed L = round(1/p)
    sigma: float = 0.0  # stochastic gradient noise std-dev (per client)
    blocked_mask: bool = False  # TPU-native contiguous-block template
    max_L: int = 100_000  # safety cap on geometric draws
    quantize_bits: int = 0  # BEYOND-PAPER: stochastic-rounding quantization
    # of the uploaded (masked) values; 0 = off.  Unbiased, so the
    # aggregation stays exact in expectation (EXPERIMENTS.md §Beyond).

    @staticmethod
    def tuned(
        prob: FiniteSumProblem, c: int, alpha: float = 0.0, **over
    ) -> "TamunaConfig":
        """Theorem-3 tuned parameters for ``prob`` (eq. 12/14, Remark 2)."""
        tp = theory.TunedParams.for_problem(
            prob.mu, prob.L, prob.n, c, prob.d, alpha
        )
        cfg = TamunaConfig(gamma=tp.gamma, eta=tp.eta, p=tp.p, c=c, s=tp.s)
        return replace(cfg, **over) if over else cfg


class TamunaState(NamedTuple):
    x_bar: jax.Array  # (d,) server model estimate
    h: jax.Array  # (n, d) control variates, sum_i h_i = 0 invariant
    round: jax.Array  # scalar int
    total_local_steps: jax.Array  # scalar int (= paper's iteration count t)
    up_floats: jax.Array  # cumulative uplink floats per client
    down_floats: jax.Array  # cumulative downlink floats per client


def init(prob: FiniteSumProblem, x0: Optional[jax.Array] = None) -> TamunaState:
    d = prob.d
    # copy x0: run() donates state buffers into the scan driver and must not
    # invalidate a caller-owned array
    x_bar = jnp.zeros((d,)) if x0 is None else jnp.array(x0)
    zeros = jnp.zeros((prob.n, d))
    # int32 counters regardless of jax_enable_x64 (jnp.int64 silently
    # truncates to int32 without the flag); the float accounting
    # accumulators are overflow-safe at LM-scale d where int32 is not.
    # The core always runs with x64 active (problems.py enables it at
    # import), so these are true float64 — exact integer accounting to
    # 2^53.  Distinct buffers per field: run() donates the whole state.
    zi = lambda: jnp.zeros((), jnp.int32)
    zf = lambda: jnp.zeros(())  # default float: f64 under the x64 flag
    return TamunaState(x_bar, zeros, zi(), zi(), zf(), zf())


def _local_steps(
    prob: FiniteSumProblem,
    cfg: TamunaConfig,
    x0: jax.Array,  # (c, d) cohort-initial models (all = x_bar)
    h_cohort: jax.Array,  # (c, d)
    cohort: jax.Array,  # (c,) indices into [n]
    L: jax.Array,  # scalar int, number of local steps
    key: jax.Array,
) -> jax.Array:
    """Run ``L`` local steps x <- x - gamma g + gamma h for the cohort."""

    def grads(X, gkey):
        # Cohort-only gradients: O(c d) per local step.  (The previous
        # scatter-into-(n, d)-and-gather path made every local step O(n d),
        # defeating partial participation at large n.)
        G = prob.cohort_grads(X, cohort)
        if cfg.sigma > 0.0:
            G = G + cfg.sigma * jax.random.normal(gkey, G.shape, G.dtype)
        return G

    def body(carry, _):
        X, k = carry
        k, gk = jax.random.split(k)
        G = grads(X, gk)
        X = X - cfg.gamma * G + cfg.gamma * h_cohort
        return (X, k), None

    # Dynamic trip count via fori_loop (L is data-dependent under jit).
    def fbody(i, carry):
        del i
        (X, k), _ = body(carry, None)
        return (X, k)

    X, _ = jax.lax.fori_loop(0, L, fbody, (x0, key))
    return X


def round_step(
    prob: FiniteSumProblem, cfg: TamunaConfig, state: TamunaState, key: jax.Array
) -> TamunaState:
    """One TAMUNA round (Algorithm 1 lines 3-18), jit-compatible."""
    k_cohort, k_L, k_mask, k_grad = jax.random.split(key, 4)
    cohort, _member = compression.split_cohort(k_cohort, prob.n, cfg.c)

    if cfg.geometric_L:
        u = jax.random.uniform(k_L, (), minval=1e-12, maxval=1.0)
        L = jnp.minimum(
            1 + jnp.floor(jnp.log(u) / jnp.log1p(-cfg.p)).astype(jnp.int32),
            cfg.max_L,
        )
    else:
        L = jnp.asarray(max(1, round(1.0 / cfg.p)), jnp.int32)

    h_cohort = state.h[cohort]
    x0 = jnp.broadcast_to(state.x_bar, (cfg.c, prob.d))
    X = _local_steps(prob, cfg, x0, h_cohort, cohort, L, k_grad)

    # UpCom: permutation mask q (d, c); aggregation x_bar = (1/s) sum C_i(x_i)
    q = masks.sample_mask(
        k_mask, prob.d, cfg.c, cfg.s, blocked=cfg.blocked_mask
    )
    X_up = X
    if cfg.quantize_bits:
        qkeys = jax.random.split(jax.random.fold_in(k_mask, 7), cfg.c)
        X_up = jax.vmap(
            lambda kk, v: compression.quantize_stochastic(
                kk, v, cfg.quantize_bits
            )
        )(qkeys, X)
    x_bar_new = compression.aggregate_masked(X_up, q, cfg.s)

    # Control-variate update (line 14) for the cohort only, masked coords only
    delta = (cfg.eta / cfg.gamma) * q.T.astype(X.dtype) * (
        x_bar_new[None, :] - X
    )
    h = state.h.at[cohort].add(delta)

    up = (
        masks.block_column_nnz(prob.d, cfg.c, cfg.s)
        if cfg.blocked_mask
        else compression.uplink_floats_permutation(prob.d, cfg.c, cfg.s)
    )
    return TamunaState(
        x_bar=x_bar_new,
        h=h,
        round=state.round + 1,
        total_local_steps=state.total_local_steps + L,
        # weakly-typed python scalars: no downcast of the f64 accumulators
        up_floats=state.up_floats + float(up),
        down_floats=state.down_floats + float(prob.d),
    )


def lyapunov(
    prob: FiniteSumProblem, cfg: TamunaConfig, state: TamunaState
) -> jax.Array:
    """Paper eq. (6) Lyapunov function (with chi recovered from eta = p chi)."""
    chi = cfg.eta / cfg.p
    h_star = prob.h_star()
    term_x = prob.n / cfg.gamma * jnp.sum((state.x_bar - prob.x_star) ** 2)
    term_h = (
        cfg.gamma
        / (cfg.p**2 * chi)
        * (prob.n - 1)
        / (cfg.s - 1)
        * jnp.sum((state.h - h_star) ** 2)
    )
    return term_x + term_h


def run(
    prob: FiniteSumProblem,
    cfg: TamunaConfig,
    num_rounds: int,
    seed: int = 0,
    record_every: int = 1,
    x0: Optional[jax.Array] = None,
) -> dict:
    """Drive ``num_rounds`` rounds; return a trace dict for plotting/tests.

    Rounds between record points run as a single donated ``lax.scan`` — one
    dispatch per trace entry instead of one per round, and no host sync
    inside a chunk.  Record points (after round r for r % record_every == 0
    and the final round) and the key sequence are identical to the old
    per-round Python loop, so traces are reproducible across the rewrite.
    """
    state = init(prob, x0)
    key = jax.random.key(seed)

    @partial(jax.jit, static_argnames=("length",), donate_argnums=(0,))
    def run_chunk(state, key, length: int):
        def body(carry, _):
            st, k = carry
            k, rk = jax.random.split(k)
            return (round_step(prob, cfg, st, rk), k), None

        (state, key), _ = jax.lax.scan(
            body, (state, key), None, length=length
        )
        return state, key

    record_pts = (
        sorted(set(range(0, num_rounds, max(1, record_every)))
               | {num_rounds - 1})
        if num_rounds > 0 else []
    )
    rounds, subopt, up, down, steps, lyap = [], [], [], [], [], []
    prev = -1
    for r in record_pts:
        state, key = run_chunk(state, key, length=r - prev)
        prev = r
        rounds.append(r + 1)
        subopt.append(float(prob.suboptimality(state.x_bar)))
        up.append(int(state.up_floats))
        down.append(int(state.down_floats))
        steps.append(int(state.total_local_steps))
        if prob.x_star is not None:
            lyap.append(float(lyapunov(prob, cfg, state)))
    return dict(
        algo="tamuna",
        rounds=np.array(rounds),
        suboptimality=np.array(subopt),
        up_floats=np.array(up),
        down_floats=np.array(down),
        local_steps=np.array(steps),
        lyapunov=np.array(lyap),
        state=state,
    )
