"""Baselines the paper compares TAMUNA against (Tables 1-2, Figs. 2-3).

All algorithms share the trace-dict interface of ``tamuna.run`` so the
benchmark harness can overlay them on the same TotalCom axis:

  * GD                  — vanilla distributed gradient descent
  * FedAvg / LocalSGD   — LT heuristic, no variance reduction (client drift)
  * Scaffold            — LT + control variates (Karimireddy et al. 2020)
  * Scaffnew            — accelerated LT (ProxSkip; Mishchenko et al. 2022)
  * CompressedScaffnew  — LT + permutation CC (Condat et al. 2022a)
  * DIANA               — CC of gradient differences, rand-k
  * EF21                — biased CC with error feedback, top-k
  * 5GCS                — LT + PP via inexact prox / Point-SAGA
                          (Grudzień et al. 2023)

Uplink/downlink float accounting follows Section 1.2 of the paper: per-round
floats sent by *one* participating client (UpCom) and broadcast size
(DownCom); TotalCom = UpCom + alpha * DownCom.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression, masks
from repro.core.problems import FiniteSumProblem

__all__ = [
    "run_gd",
    "run_fedavg",
    "run_scaffold",
    "run_scaffnew",
    "run_compressed_scaffnew",
    "run_diana",
    "run_ef21",
    "run_5gcs",
]


def _trace_loop(prob, num_rounds, step, state, key, up_per_round,
                down_per_round, algo, record_every=1, x_of=None):
    """Shared driver: run rounds, record suboptimality vs communicated floats."""
    x_of = x_of or (lambda s: s["x"])
    step = jax.jit(step)
    rounds, subopt, up, down = [], [], [], []
    for r in range(num_rounds):
        key, rk = jax.random.split(key)
        state = step(state, rk)
        if r % record_every == 0 or r == num_rounds - 1:
            rounds.append(r + 1)
            subopt.append(float(prob.suboptimality(x_of(state))))
            up.append((r + 1) * up_per_round)
            down.append((r + 1) * down_per_round)
    return dict(
        algo=algo,
        rounds=np.array(rounds),
        suboptimality=np.array(subopt),
        up_floats=np.array(up),
        down_floats=np.array(down),
        state=state,
    )


# --------------------------------------------------------------------------
# GD
# --------------------------------------------------------------------------
def run_gd(prob: FiniteSumProblem, gamma: float, num_rounds: int,
           seed: int = 0, record_every: int = 1) -> dict:
    def step(state, key):
        del key
        x = state["x"]
        return {"x": x - gamma * prob.grad(x)}

    return _trace_loop(
        prob, num_rounds, step, {"x": jnp.zeros(prob.d)},
        jax.random.key(seed), up_per_round=prob.d, down_per_round=prob.d,
        algo="gd", record_every=record_every,
    )


# --------------------------------------------------------------------------
# FedAvg / LocalSGD (heuristic LT; biased fixed point -> client drift)
# --------------------------------------------------------------------------
def run_fedavg(prob: FiniteSumProblem, gamma: float, local_steps: int,
               c: Optional[int] = None, num_rounds: int = 100, seed: int = 0,
               record_every: int = 1) -> dict:
    c = c or prob.n

    def step(state, key):
        x = state["x"]
        cohort, _ = compression.split_cohort(key, prob.n, c)
        X = jnp.broadcast_to(x, (c, prob.d))

        def body(X, _):
            Xn = jnp.zeros((prob.n, prob.d), X.dtype).at[cohort].set(X)
            G = prob.grad_all_local(Xn)[cohort]
            return X - gamma * G, None

        X, _ = jax.lax.scan(body, X, None, length=local_steps)
        return {"x": X.mean(axis=0)}

    return _trace_loop(
        prob, num_rounds, step, {"x": jnp.zeros(prob.d)},
        jax.random.key(seed), up_per_round=prob.d, down_per_round=prob.d,
        algo="fedavg", record_every=record_every,
    )


# --------------------------------------------------------------------------
# Scaffold (option II control variates)
# --------------------------------------------------------------------------
def run_scaffold(prob: FiniteSumProblem, gamma: float, local_steps: int,
                 c: Optional[int] = None, global_lr: float = 1.0,
                 num_rounds: int = 100, seed: int = 0,
                 record_every: int = 1) -> dict:
    c = c or prob.n

    def step(state, key):
        x, ci, cg = state["x"], state["ci"], state["cg"]
        cohort, _ = compression.split_cohort(key, prob.n, c)
        X = jnp.broadcast_to(x, (c, prob.d))
        ci_cohort = ci[cohort]

        def body(X, _):
            Xn = jnp.zeros((prob.n, prob.d), X.dtype).at[cohort].set(X)
            G = prob.grad_all_local(Xn)[cohort]
            return X - gamma * (G - ci_cohort + cg), None

        X, _ = jax.lax.scan(body, X, None, length=local_steps)
        # option II: ci+ = ci - cg + (x - y_i) / (K gamma)
        ci_new = ci_cohort - cg + (x[None, :] - X) / (local_steps * gamma)
        dci = ci_new - ci_cohort
        ci = ci.at[cohort].set(ci_new)
        cg = cg + dci.sum(axis=0) / prob.n
        x = x + global_lr * (X.mean(axis=0) - x)
        return {"x": x, "ci": ci, "cg": cg}

    state = {
        "x": jnp.zeros(prob.d),
        "ci": jnp.zeros((prob.n, prob.d)),
        "cg": jnp.zeros(prob.d),
    }
    # Uplink: y_i and ci delta (2d per client, as in the Scaffold paper)
    return _trace_loop(
        prob, num_rounds, step, state, jax.random.key(seed),
        up_per_round=2 * prob.d, down_per_round=2 * prob.d,
        algo="scaffold", record_every=record_every,
    )


# --------------------------------------------------------------------------
# Scaffnew / ProxSkip (full participation; prob. p communication)
# --------------------------------------------------------------------------
def run_scaffnew(prob: FiniteSumProblem, gamma: float, p: float,
                 num_iters: int = 1000, seed: int = 0,
                 record_every: int = 1) -> dict:
    """Single-loop Scaffnew; a 'round' below is one iteration; float counters
    are accumulated only on communication iterations."""

    def step(state, key):
        X, h, up = state["X"], state["h"], state["up"]
        k1, _ = jax.random.split(key)
        G = prob.grad_all_local(X)
        Xhat = X - gamma * G + gamma * h
        theta = jax.random.bernoulli(k1, p)
        xbar = Xhat.mean(axis=0)
        Xnew = jnp.where(theta, jnp.broadcast_to(xbar, X.shape), Xhat)
        hnew = jnp.where(theta, h + (p / gamma) * (xbar[None, :] - Xhat), h)
        return {
            "X": Xnew, "h": hnew,
            "up": up + jnp.where(theta, prob.d, 0),
            "x": jnp.where(theta, xbar, state["x"]),
        }

    state = {
        "X": jnp.zeros((prob.n, prob.d)),
        "h": jnp.zeros((prob.n, prob.d)),
        "up": jnp.zeros((), jnp.int64),
        "x": jnp.zeros(prob.d),
    }
    step_j = jax.jit(step)
    key = jax.random.key(seed)
    rounds, subopt, up = [], [], []
    for t in range(num_iters):
        key, rk = jax.random.split(key)
        state = step_j(state, rk)
        if t % record_every == 0 or t == num_iters - 1:
            rounds.append(t + 1)
            subopt.append(float(prob.suboptimality(state["x"])))
            up.append(int(state["up"]))
    up = np.array(up)
    return dict(
        algo="scaffnew", rounds=np.array(rounds),
        suboptimality=np.array(subopt), up_floats=up, down_floats=up.copy(),
        state=state,
    )


# --------------------------------------------------------------------------
# CompressedScaffnew = Algorithm 2 with full participation (c = n)
# --------------------------------------------------------------------------
def run_compressed_scaffnew(prob: FiniteSumProblem, gamma: float, p: float,
                            s: int, chi: Optional[float] = None,
                            num_iters: int = 1000, seed: int = 0,
                            record_every: int = 1) -> dict:
    n = prob.n
    chi = chi if chi is not None else n * (s - 1) / (s * (n - 1))

    def step(state, key):
        X, h, up = state["X"], state["h"], state["up"]
        k1, k2 = jax.random.split(key)
        G = prob.grad_all_local(X)
        Xhat = X - gamma * G + gamma * h
        theta = jax.random.bernoulli(k1, p)
        q = masks.sample_mask(k2, prob.d, n, s)  # (d, n)
        xbar = compression.aggregate_masked(Xhat, q, s)
        Xnew = jnp.where(theta, jnp.broadcast_to(xbar, X.shape), Xhat)
        hdelta = (p * chi / gamma) * q.T * (xbar[None, :] - Xhat)
        hnew = jnp.where(theta, h + hdelta, h)
        upf = masks.column_nnz(prob.d, n, s)
        return {
            "X": Xnew, "h": hnew,
            "up": up + jnp.where(theta, upf, 0),
            "down": state["down"] + jnp.where(theta, prob.d, 0),
            "x": jnp.where(theta, xbar, state["x"]),
        }

    z = jnp.zeros((), jnp.int64)
    state = {
        "X": jnp.zeros((n, prob.d)), "h": jnp.zeros((n, prob.d)),
        "up": z, "down": z, "x": jnp.zeros(prob.d),
    }
    step_j = jax.jit(step)
    key = jax.random.key(seed)
    rounds, subopt, up, down = [], [], [], []
    for t in range(num_iters):
        key, rk = jax.random.split(key)
        state = step_j(state, rk)
        if t % record_every == 0 or t == num_iters - 1:
            rounds.append(t + 1)
            subopt.append(float(prob.suboptimality(state["x"])))
            up.append(int(state["up"]))
            down.append(int(state["down"]))
    return dict(
        algo="compressed_scaffnew", rounds=np.array(rounds),
        suboptimality=np.array(subopt), up_floats=np.array(up),
        down_floats=np.array(down), state=state,
    )


# --------------------------------------------------------------------------
# DIANA with rand-k compression of gradient differences
# --------------------------------------------------------------------------
def run_diana(prob: FiniteSumProblem, gamma: float, k: int,
              alpha_lr: Optional[float] = None, num_rounds: int = 500,
              seed: int = 0, record_every: int = 1) -> dict:
    n, d = prob.n, prob.d
    alpha_lr = alpha_lr if alpha_lr is not None else k / d  # 1/(1+omega)

    def step(state, key):
        x, h, hbar = state["x"], state["h"], state["hbar"]
        keys = jax.random.split(key, n)
        G = prob.grad_all(x)
        M = jax.vmap(lambda kk, v: compression.rand_k(kk, v, k))(keys, G - h)
        g_est = hbar + M.mean(axis=0)
        return {
            "x": x - gamma * g_est,
            "h": h + alpha_lr * M,
            "hbar": hbar + alpha_lr * M.mean(axis=0),
        }

    state = {
        "x": jnp.zeros(d), "h": jnp.zeros((n, d)), "hbar": jnp.zeros(d)
    }
    return _trace_loop(
        prob, num_rounds, step, state, jax.random.key(seed),
        up_per_round=k, down_per_round=prob.d, algo="diana",
        record_every=record_every,
    )


# --------------------------------------------------------------------------
# EF21 with top-k compression (biased, error feedback)
# --------------------------------------------------------------------------
def run_ef21(prob: FiniteSumProblem, gamma: float, k: int,
             num_rounds: int = 500, seed: int = 0,
             record_every: int = 1) -> dict:
    n, d = prob.n, prob.d

    def step(state, key):
        del key
        x, g = state["x"], state["g"]
        x_new = x - gamma * g.mean(axis=0)
        Gnew = prob.grad_all(x_new)
        C = jax.vmap(lambda v: compression.top_k(v, k))(Gnew - g)
        return {"x": x_new, "g": g + C}

    g0 = prob.grad_all(jnp.zeros(d))  # paper-standard warm start g_i^0
    state = {"x": jnp.zeros(d), "g": g0}
    return _trace_loop(
        prob, num_rounds, step, state, jax.random.key(seed),
        up_per_round=k, down_per_round=prob.d, algo="ef21",
        record_every=record_every,
    )


# --------------------------------------------------------------------------
# 5GCS (Grudzień et al. 2023): Point-SAGA with cohorts and inexact prox
# computed by an inner loop of local GD steps.
# --------------------------------------------------------------------------
def run_5gcs(prob: FiniteSumProblem, gamma: float, c: int,
             inner_steps: int = 20, inner_lr: Optional[float] = None,
             num_rounds: int = 200, seed: int = 0,
             record_every: int = 1) -> dict:
    """Each round: cohort clients compute prox_{gamma f_i}(z_i) inexactly via
    ``inner_steps`` GD steps on the strongly-convex prox subproblem, then the
    server and clients update the SAGA-style duals.  LT = the inner loop;
    PP = the cohort sampling (the paper's two-level combination)."""
    n, d = prob.n, prob.d
    inner_lr = inner_lr if inner_lr is not None else 1.0 / (prob.L + 1.0 / gamma)

    def step(state, key):
        x, U, ubar = state["x"], state["U"], state["ubar"]
        cohort, _ = compression.split_cohort(key, n, c)
        z = x[None, :] + gamma * (U[cohort] - ubar[None, :])  # (c, d)

        def body(Y, _):
            Yn = jnp.zeros((n, d), Y.dtype).at[cohort].set(Y)
            G = prob.grad_all_local(Yn)[cohort]
            return Y - inner_lr * (G + (Y - z) / gamma), None

        Y, _ = jax.lax.scan(body, z, None, length=inner_steps)
        u_new = (z - Y) / gamma  # ~ grad f_i(prox)
        du = u_new - U[cohort]
        U2 = U.at[cohort].set(u_new)
        ubar2 = ubar + du.sum(axis=0) / n
        x_new = Y.mean(axis=0)
        return {"x": x_new, "U": U2, "ubar": ubar2}

    state = {"x": jnp.zeros(d), "U": jnp.zeros((n, d)), "ubar": jnp.zeros(d)}
    return _trace_loop(
        prob, num_rounds, step, state, jax.random.key(seed),
        up_per_round=prob.d, down_per_round=prob.d, algo="5gcs",
        record_every=record_every,
    )
