"""Closed-form rates and communication complexities from the paper.

These formulas back the benchmark tables (Tables 1 & 2) and the parameter
tuning rules (Theorem 1, Remark 2, Theorem 3, Corollaries 4-5); the
convergence tests assert the empirical contraction matches ``theorem1_rate``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = [
    "theorem1_rate",
    "chi_max",
    "recommended_eta",
    "recommended_p",
    "recommended_s",
    "iteration_complexity",
    "upcom_complexity",
    "downcom_complexity",
    "totalcom_complexity",
    "gd_totalcom",
    "scaffnew_totalcom",
    "scaffold_totalcom",
]


def chi_max(n: int, s: int) -> float:
    """Upper bound (5): ``chi <= n(s-1)/(s(n-1)) in (1/2, 1]``."""
    return n * (s - 1) / (s * (n - 1))


def recommended_eta(p: float, n: int, s: int) -> float:
    """Remark 2, eq. (11): ``eta = p * n(s-1)/(s(n-1))`` (largest valid)."""
    return p * chi_max(n, s)


def theorem1_rate(
    gamma: float, mu: float, L: float, p: float, chi: float, n: int, s: int
) -> float:
    """Contraction factor ``tau`` of Theorem 1, eq. (10) (per local step)."""
    return max(
        (1.0 - gamma * mu) ** 2,
        (gamma * L - 1.0) ** 2,
        1.0 - p * p * chi * (s - 1) / (n - 1),
    )


def recommended_p(n: int, s: int, kappa: float) -> float:
    """Eq. (12): ``p = min(sqrt(n/(s kappa)), 1)``."""
    return min(math.sqrt(n / (s * kappa)), 1.0)


def recommended_s(c: int, d: int, alpha: float) -> int:
    """Eq. (14): ``s = max(2, floor(c/d), floor(alpha c))``, capped at c."""
    return min(c, max(2, c // d, int(alpha * c)))


def iteration_complexity(kappa: float, n: int, s: int, p: float) -> float:
    """O(kappa + n/(s p^2)) local steps to eps-accuracy (log factor dropped)."""
    return kappa + n / (s * p * p)


def upcom_complexity(
    kappa: float, n: int, c: int, s: int, d: int, p: float
) -> float:
    """UpCom floats per client: ``p (sd/c + 1)(kappa + n/(s p^2))``."""
    return p * (s * d / c + 1.0) * iteration_complexity(kappa, n, s, p)


def downcom_complexity(
    kappa: float, n: int, c: int, s: int, d: int, p: float
) -> float:
    return p * d * iteration_complexity(kappa, n, s, p)


def totalcom_complexity(
    kappa: float, n: int, c: int, s: int, d: int, p: float, alpha: float
) -> float:
    """Eq. (2): TotalCom = UpCom + alpha * DownCom."""
    return upcom_complexity(kappa, n, c, s, d, p) + alpha * downcom_complexity(
        kappa, n, c, s, d, p
    )


def gd_totalcom(kappa: float, d: int, alpha: float) -> float:
    return (1.0 + alpha) * d * kappa


def scaffnew_totalcom(kappa: float, d: int, alpha: float) -> float:
    return (1.0 + alpha) * d * math.sqrt(kappa)


def scaffold_totalcom(
    kappa: float, d: int, n: int, c: int, alpha: float
) -> float:
    return (1.0 + alpha) * d * (kappa + n / c)


@dataclass(frozen=True)
class TunedParams:
    """Theorem-3 tuned hyperparameters for a given problem."""

    gamma: float
    p: float
    s: int
    chi: float
    eta: float

    @staticmethod
    def for_problem(
        mu: float, L: float, n: int, c: int, d: int, alpha: float
    ) -> "TunedParams":
        kappa = L / mu
        s = recommended_s(c, d, alpha)
        p = recommended_p(n, s, kappa)
        gamma = 2.0 / (L + mu)
        chi = chi_max(n, s)
        eta = p * chi
        return TunedParams(gamma=gamma, p=p, s=s, chi=chi, eta=eta)
