"""Core TAMUNA library: the paper's contribution as composable JAX modules.

Layout:
  masks.py        permutation-template compression masks (paper Fig. 1)
  compression.py  compressors (permutation / rand-k / top-k) + aggregation
  problems.py     convex finite-sum problems (paper Section 5 experiments)
  tamuna.py       Algorithm 1 (round-based federated core)
  baselines.py    GD / FedAvg / Scaffold / Scaffnew / CompressedScaffnew /
                  DIANA / EF21 / 5GCS
  theory.py       Theorem 1/3 rates and Tables 1-2 complexity formulas

Submodules are loaded lazily (PEP 562): ``problems`` (and everything that
imports it) enables jax x64 at import — the convex reproduction tracks
suboptimality to ~1e-12 — and the LM/dist stack must NOT inherit that just
for importing ``masks`` or ``theory``.
"""

import importlib

_MODULES = ("baselines", "compression", "masks", "problems", "tamuna",
            "theory")

__all__ = list(_MODULES)


def __getattr__(name):
    if name in _MODULES:
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_MODULES))
