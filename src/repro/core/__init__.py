"""Core TAMUNA library: the paper's contribution as composable JAX modules.

Layout:
  masks.py        permutation-template compression masks (paper Fig. 1)
  compression.py  compressors (permutation / rand-k / top-k) + aggregation
  problems.py     convex finite-sum problems (paper Section 5 experiments)
  tamuna.py       Algorithm 1 (round-based federated core)
  baselines.py    GD / FedAvg / Scaffold / Scaffnew / CompressedScaffnew /
                  DIANA / EF21 / 5GCS
  theory.py       Theorem 1/3 rates and Tables 1-2 complexity formulas
"""

from repro.core import baselines, compression, masks, problems, tamuna, theory

__all__ = ["baselines", "compression", "masks", "problems", "tamuna", "theory"]
