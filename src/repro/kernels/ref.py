"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def compress_ref(
    x: jax.Array,  # (d,) flat vector (any leading shape flattened by caller)
    slot: jax.Array,  # scalar int32: this client's mask column, >= c if idle
    c: int,
    s: int,
) -> jax.Array:
    """TAMUNA permutation-mask compressor C_i(x): cyclic-band template.

    Coordinate k is owned by columns mod(s*k + t, c), t in [0, s).
    """
    d = x.shape[0]
    k = jnp.arange(d, dtype=jnp.int32)
    owned = (((slot - s * (k % c)) % c) < s) & (slot < c)
    return jnp.where(owned, x, jnp.zeros((), x.dtype))


def _owned_ref(slot, band, m: int, s: int):
    sl = slot[:, None]
    return (sl >= 0) & (sl < m) & (((sl + band[None, :]) % m) < s)


def uplink_masked_sum_ref(
    x: jax.Array,  # (n, d) f32 workspace
    slot: jax.Array,  # (n,) int32
    band: jax.Array,  # (d,) int32
    m: int,
    s: int,
) -> jax.Array:
    """Owner-masked client-axis sum with the exact 1/s rebuild."""
    owned = _owned_ref(slot, band, m, s)
    return jnp.where(owned, x, 0.0).sum(axis=0) / s


def uplink_h_update_ref(
    x: jax.Array,
    h: jax.Array,
    x_bar: jax.Array,
    slot: jax.Array,
    band: jax.Array,
    m: int,
    s: int,
    scale: float,
    down: Optional[jax.Array] = None,  # (n,) DownCom rows; None = all
):
    """Control-variate update on owned coordinates + DownCom (``down``
    rows get ``x_bar``; all rows when None)."""
    owned = _owned_ref(slot, band, m, s)
    h_new = h + scale * jnp.where(owned, x_bar[None, :] - x, 0.0)
    x_new = jnp.broadcast_to(x_bar[None, :], x.shape)
    if down is not None:
        x_new = jnp.where(down.astype(bool)[:, None], x_new, x)
    return h_new, x_new


def fused_local_step_ref(
    x: jax.Array, g: jax.Array, h: jax.Array, gamma: float
) -> jax.Array:
    """TAMUNA local step x <- x - gamma*g + gamma*h (f32 accumulate)."""
    xf = x.astype(jnp.float32)
    out = xf - gamma * g.astype(jnp.float32) + gamma * h.astype(jnp.float32)
    return out.astype(x.dtype)


def decode_attention_ref(
    q: jax.Array,  # (b, h, hd) single-position queries
    k: jax.Array,  # (b, S, kvh, hd) cache keys
    v: jax.Array,  # (b, S, kvh, hd) cache values
    pos: jax.Array,  # scalar int32: index of the newest token (inclusive)
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Single-token GQA decode attention over a KV cache (f32 softmax)."""
    b, h, hd = q.shape
    S, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    qg = q.reshape(b, kvh, group, hd).astype(jnp.float32)
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k.astype(jnp.float32)
    ) / math.sqrt(hd)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(S)
    mask = kpos <= pos
    if window is not None:
        mask &= kpos > pos - window
    logits = jnp.where(mask[None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)
