"""Pallas TPU kernel: fused TAMUNA local step  x <- x - gamma*g + gamma*h.

A 3-operand AXPY executed tile-by-tile in VMEM with f32 accumulation and a
single write-back in the storage dtype.  Unfused, XLA emits two intermediate
HBM round-trips for mixed-dtype (bf16 params, f32 grads) updates; fused it
is exactly 3 reads + 1 write — the HBM floor for this op.

``interpret=None`` auto-detects the backend (Mosaic compile on TPU,
interpreter elsewhere) — same policy as every other kernel in this package
(``compress.resolve_interpret``); the seed hard-coded ``interpret=True``,
which silently ran the interpreter on real TPUs.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compress import resolve_interpret


def _local_step_kernel(x_ref, g_ref, h_ref, o_ref, *, gamma: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    o_ref[...] = (x - gamma * (g - h)).astype(o_ref.dtype)


def fused_local_step(
    x: jax.Array,
    g: jax.Array,
    h: jax.Array,
    gamma: float,
    *,
    block: int = 65536,
    interpret: Optional[bool] = None,
) -> jax.Array:
    shape, dtype = x.shape, x.dtype
    xf, gf, hf = (a.reshape(-1) for a in (x, g, h))
    d = xf.shape[0]
    blk = min(block, d)
    pad = (-d) % blk
    if pad:
        xf = jnp.pad(xf, (0, pad))
        gf = jnp.pad(gf, (0, pad))
        hf = jnp.pad(hf, (0, pad))
    n_blocks = xf.shape[0] // blk
    spec = pl.BlockSpec((blk,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_local_step_kernel, gamma=gamma),
        grid=(n_blocks,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(xf.shape, dtype),
        interpret=resolve_interpret(interpret),
    )(xf, gf, hf)
    return (out[:d] if pad else out).reshape(shape)
