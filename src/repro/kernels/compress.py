"""Pallas TPU kernel: fused TAMUNA mask-generate-and-apply (C_i).

The permutation mask is never materialized in HBM: each VMEM tile computes
its coordinates' ownership from the cyclic-band closed form (masks.py /
paper Fig. 1) and multiplies in place.  VPU-only (no MXU): the kernel is
bandwidth-bound by design — 1 read + 1 write per element instead of the
3 reads + 1 write a materialized-mask path costs.

``owned_from_band`` is the shared ownership predicate of the whole comm
path: the uplink kernels (``kernels/uplink.py``) and the flat-workspace
comm step (``dist/comm_ws.py``) evaluate the same closed form, so this
module's mask generation IS the production comm step's mask generation.

Operands may be flat ``(d,)`` vectors (1-D grid over coordinate blocks,
``slot`` shaped ``(1,)``) or client-stacked ``(n, d)`` matrices (2-D grid
with clients as the leading grid axis, ``slot`` shaped ``(n,)``).
``interpret=None`` auto-detects the backend: compiled via Mosaic on TPU,
interpreter elsewhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> interpret only off-TPU (Mosaic compile on real TPUs)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def owned_from_band(slot, band, m: int, s: int):
    """Closed-form ownership: active slots in ``[0, m)`` own coordinate
    ``k`` iff ``(slot + band[k]) mod m < s``.  With the cyclic band
    ``band = (-s k) mod c`` this is exactly ``masks.mask_from_permutation``
    row ownership; with the blocked band (chunk ids) it is the block_rs
    closed form.  Shapes broadcast; never materialized outside a tile."""
    return (slot >= 0) & (slot < m) & (((slot + band) % m) < s)


def cyclic_band(k, c: int, s: int):
    """The cyclic template's per-coordinate band: ``(-s k) mod c``."""
    return (-(s * (k % c))) % c


def wire_dequant(codes, scales, chunk_ids):
    """Dequantize int-wire payload lanes: ``codes`` (rows, d) int8 times
    the per-chunk f32 scale each column's ``chunk_ids`` entry selects
    from ``scales`` (rows, nchunk).  Shared by the uplink kernels (in-
    tile, f32 accumulation downstream) and the jnp comm paths — the one
    definition of the wire's dequantization, so the kernel and jnp
    impls cannot drift (a NaN-poisoned chunk scale propagates the NaN
    here in both)."""
    return codes.astype(jnp.float32) * jnp.take(scales, chunk_ids, axis=1)


def _compress_kernel(slot_ref, x_ref, o_ref, *, c: int, s: int, block: int):
    i = pl.program_id(0)
    k = jax.lax.broadcasted_iota(jnp.int32, (block,), 0) + i * block
    owned = owned_from_band(slot_ref[0], cyclic_band(k, c, s), c, s)
    x = x_ref[...]
    o_ref[...] = jnp.where(owned, x, jnp.zeros((), x.dtype))


def _compress2d_kernel(slot_ref, x_ref, o_ref, *, c: int, s: int,
                       block: int):
    j = pl.program_id(1)
    k = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1) + j * block
    owned = owned_from_band(slot_ref[0], cyclic_band(k, c, s), c, s)
    x = x_ref[...]
    o_ref[...] = jnp.where(owned, x, jnp.zeros((), x.dtype))


def compress(
    x: jax.Array,  # (d,) flat or (n, d) client-stacked
    slot: jax.Array,  # (1,)/(n,) int32 mask column(s); outside [0, c) -> 0s
    c: int,
    s: int,
    *,
    block: int = 4096,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    if x.ndim == 2:
        n, d = x.shape
        blk = min(block, d)
        pad = (-d) % blk
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad)))
        n_blocks = x.shape[1] // blk
        out = pl.pallas_call(
            functools.partial(_compress2d_kernel, c=c, s=s, block=blk),
            grid=(n, n_blocks),
            in_specs=[
                pl.BlockSpec((1,), lambda i, j: (i,)),  # this client's slot
                pl.BlockSpec((1, blk), lambda i, j: (i, j)),
            ],
            out_specs=pl.BlockSpec((1, blk), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interpret,
        )(slot, x)
        return out[:, :d] if pad else out

    d = x.shape[0]
    pad = (-d) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    n_blocks = x.shape[0] // block
    out = pl.pallas_call(
        functools.partial(_compress_kernel, c=c, s=s, block=block),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # slot, broadcast to all tiles
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(slot, x)
    return out[:d] if pad else out
