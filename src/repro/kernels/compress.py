"""Pallas TPU kernel: fused TAMUNA mask-generate-and-apply (C_i).

The permutation mask is never materialized in HBM: each VMEM tile computes
its coordinates' ownership from the cyclic-band closed form (masks.py /
paper Fig. 1) and multiplies in place.  VPU-only (no MXU): the kernel is
bandwidth-bound by design — 1 read + 1 write per element instead of the
3 reads + 1 write a materialized-mask path costs.

Grid: 1-D over coordinate blocks; the client's mask column (``slot``) and
the cohort/sparsity constants arrive via scalar prefetch (SMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compress_kernel(slot_ref, x_ref, o_ref, *, c: int, s: int, block: int):
    i = pl.program_id(0)
    slot = slot_ref[0]
    k = jax.lax.broadcasted_iota(jnp.int32, (block,), 0) + i * block
    owned = (((slot - s * (k % c)) % c) < s) & (slot < c)
    x = x_ref[...]
    o_ref[...] = jnp.where(owned, x, jnp.zeros((), x.dtype))


def compress(
    x: jax.Array,  # (d,) flat
    slot: jax.Array,  # (1,) int32 mask column (>= c -> inactive, zeros)
    c: int,
    s: int,
    *,
    block: int = 4096,
    interpret: bool = True,
) -> jax.Array:
    d = x.shape[0]
    pad = (-d) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    n_blocks = x.shape[0] // block
    out = pl.pallas_call(
        functools.partial(_compress_kernel, c=c, s=s, block=block),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # slot, broadcast to all tiles
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(slot, x)
    return out[:d] if pad else out
