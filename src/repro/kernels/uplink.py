"""Pallas TPU kernels: the mask-free fused TAMUNA comm step.

Both kernels run over the flat comm workspace (``dist/comm_ws.py``): the
client-stacked state packed to an ``(n, d)`` f32 buffer, ownership encoded
by a static per-coordinate ``band`` table and a per-client ``slot`` vector,
evaluated per VMEM tile via ``compress.owned_from_band`` — no ``(n, d)``
or ``(d, c)`` mask is ever materialized in HBM.

  masked_sum  UpCom: per-tile ownership, masked client-axis sum, and the
              exact ``1/s`` rebuild fused into one pass — 1 read of x and
              a ``d``-sized write, vs the dense reference's mask write +
              mask read + masked-product materialization.  The payload
              lanes may be the narrow float wire dtype (bf16/f16,
              ``dist/wire.py``); accumulation is always f32.
  masked_sum_dequant
              the int-wire variant: (n, d) int8 codes + (n, nchunk) f32
              per-chunk scales, dequantized per VMEM tile
              (``compress.wire_dequant``) with f32 accumulation — the
              client-axis HBM read shrinks to 1 byte per coordinate.
  h_update    the round's state update: reads x, h and the server model
              x_bar once and writes BOTH h_new (control variates, owned
              coordinates only) and the DownCom'd x_new in the same pass —
              2 reads + 2 writes, the HBM floor for this update.  The
              per-client ``down`` vector selects which rows receive the
              ``x_bar`` broadcast: under elastic partial participation
              (DESIGN.md §11) only the NEXT round's cohort downloads, so
              idle clients' rows pass through bit-exactly.

Grid: 1-D over coordinate blocks; tiles are ``(n, block)`` — pick ``block``
so ``n * block * 4B`` tiles fit VMEM (n=512 at the default block=4096 is
8 MB).  ``interpret=None`` auto-detects the backend (Mosaic on TPU,
interpreter elsewhere); CPU CI exercises exactly these bodies in interpret
mode (tests/test_kernels.py), while the CPU production path uses the
equivalent fused-jnp workspace math.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compress import (
    owned_from_band,
    resolve_interpret,
    wire_dequant,
)

__all__ = ["masked_sum", "masked_sum_dequant", "robust_sum", "h_update"]


def _masked_sum_kernel(slot_ref, band_ref, x_ref, o_ref, *, m: int, s: int):
    owned = owned_from_band(
        slot_ref[...][:, None], band_ref[...][None, :], m, s
    )
    # workspace lanes may be the narrow float wire dtype (bf16/f16);
    # accumulation is always f32 (a no-op cast on the f32 path)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.where(owned, x, 0.0).sum(axis=0) / s


def _masked_sum_counts_kernel(
    slot_ref, band_ref, x_ref, num_ref, cnt_ref, *, m: int, s: int
):
    # survivor-aware variant: raw masked sum + per-coordinate arrived
    # owner count (no /s — the caller divides after any psum so the
    # count stays exact across shards).  Dropped clients arrive here
    # with slot = -1, which owns nothing.
    owned = owned_from_band(
        slot_ref[...][:, None], band_ref[...][None, :], m, s
    )
    x = x_ref[...].astype(jnp.float32)
    num_ref[...] = jnp.where(owned, x, 0.0).sum(axis=0)
    cnt_ref[...] = owned.astype(jnp.float32).sum(axis=0)


def _masked_sum_dequant_kernel(
    slot_ref, band_ref, chunk_ref, codes_ref, scales_ref, o_ref,
    *, m: int, s: int,
):
    # int-wire lanes: int8 codes dequantized in-tile against the per-
    # chunk scales (full (n, nchunk) block, tiny next to the codes tile),
    # then the same masked f32 accumulation as the float-lane kernel
    owned = owned_from_band(
        slot_ref[...][:, None], band_ref[...][None, :], m, s
    )
    v = wire_dequant(codes_ref[...], scales_ref[...], chunk_ref[...])
    o_ref[...] = jnp.where(owned, v, 0.0).sum(axis=0) / s


def _masked_sum_dequant_counts_kernel(
    slot_ref, band_ref, chunk_ref, codes_ref, scales_ref, num_ref, cnt_ref,
    *, m: int, s: int,
):
    owned = owned_from_band(
        slot_ref[...][:, None], band_ref[...][None, :], m, s
    )
    v = wire_dequant(codes_ref[...], scales_ref[...], chunk_ref[...])
    num_ref[...] = jnp.where(owned, v, 0.0).sum(axis=0)
    cnt_ref[...] = owned.astype(jnp.float32).sum(axis=0)


def _robust_sum_kernel(
    slot_ref, band_ref, x_ref, bar_ref, cnt_ref,
    *, m: int, s: int, kind: str, k: int,
):
    # Byzantine-robust UpCom (DESIGN.md §15): per-coordinate trimmed
    # mean / median over the arrived owner values, fused in-tile.  The
    # owner stack is sorted by s passes of masked-min extraction
    # (argmin-free: ties break by first row, one occurrence removed per
    # pass) — s is small and static, so the per-tile cost is s
    # client-axis reductions instead of a full sort network, and the
    # loop unrolls into pure VPU selects.  Values past the arrived
    # count never enter the combine.
    owned = owned_from_band(
        slot_ref[...][:, None], band_ref[...][None, :], m, s
    )
    x = x_ref[...].astype(jnp.float32)
    cnt = owned.astype(jnp.int32).sum(axis=0)
    big = jnp.asarray(jnp.inf, jnp.float32)
    active = owned
    order = []  # order[t] = t-th smallest arrived owner value (+inf past cnt)
    for _ in range(s):
        v = jnp.where(active, x, big)
        mn = v.min(axis=0)
        hit = (v == mn[None, :]) & active
        first = (jnp.cumsum(hit.astype(jnp.int32), axis=0) == 1) & hit
        active = active & ~first
        order.append(mn)
    zero = jnp.zeros((), jnp.float32)
    if kind == "median":
        loi = jnp.maximum((cnt - 1) // 2, 0)
        hii = cnt // 2
        lo = hi = zero
        for t, mn in enumerate(order):
            lo = jnp.where(loi == t, mn, lo)
            hi = jnp.where(hii == t, mn, hi)
        bar = 0.5 * (lo + hi)  # lo == hi at odd counts: exact
    else:  # trimmed
        k_eff = jnp.clip(jnp.minimum(k, (cnt - 1) // 2), 0)
        num = zero
        for t, mn in enumerate(order):
            use = (t >= k_eff) & (t < cnt - k_eff)
            num = num + jnp.where(use, mn, zero)
        bar = num / jnp.maximum(cnt - 2 * k_eff, 1).astype(jnp.float32)
    bar_ref[...] = jnp.where(cnt > 0, bar, zero)
    cnt_ref[...] = cnt.astype(jnp.float32)


def _h_update_kernel(
    slot_ref, down_ref, band_ref, xbar_ref, x_ref, h_ref, h_out, x_out,
    *, m: int, s: int, scale: float,
):
    owned = owned_from_band(
        slot_ref[...][:, None], band_ref[...][None, :], m, s
    )
    x = x_ref[...]
    x_bar = xbar_ref[...][None, :]
    h_out[...] = h_ref[...] + scale * jnp.where(owned, x_bar - x, 0.0)
    down = down_ref[...][:, None] != 0
    x_out[...] = jnp.where(down, jnp.broadcast_to(x_bar, x.shape), x)


def _h_update_covered_kernel(
    slot_ref, down_ref, band_ref, cov_ref, xbar_ref, x_ref, h_ref,
    h_out, x_out, *, m: int, s: int, scale: float,
):
    # survivor-aware variant: uncovered coordinates (no arrived owner)
    # have an x_bar rebuilt from nothing — gate both the control-variate
    # update and the DownCom so those coordinates pass through
    # bit-exactly (PR 5's idle-client semantics, per-coordinate).
    owned = owned_from_band(
        slot_ref[...][:, None], band_ref[...][None, :], m, s
    )
    cov = cov_ref[...][None, :] != 0
    x = x_ref[...]
    x_bar = xbar_ref[...][None, :]
    h_out[...] = h_ref[...] + scale * jnp.where(
        owned & cov, x_bar - x, 0.0
    )
    down = (down_ref[...][:, None] != 0) & cov
    x_out[...] = jnp.where(down, jnp.broadcast_to(x_bar, x.shape), x)


def _pad_cols(a: jax.Array, pad: int) -> jax.Array:
    return jnp.pad(a, ((0, 0), (0, pad))) if pad else a


def masked_sum(
    x: jax.Array,  # (n, d) f32 workspace
    slot: jax.Array,  # (n,) int32; outside [0, m) -> contributes nothing
    band: jax.Array,  # (d,) int32 per-coordinate owner band
    m: int,
    s: int,
    *,
    counts: bool = False,
    block: int = 4096,
    interpret: Optional[bool] = None,
):
    """UpCom fused with the 1/s rebuild: ``sum_owned(x, axis=0) / s``.

    With ``counts=True`` (the survivor-aware path) returns the raw
    ``(num, cnt)`` pair instead — the undivided masked sum and the
    per-coordinate arrived-owner count — so the caller can psum both
    and rebuild ``x_bar = num / max(cnt, 1)`` globally."""
    n, d = x.shape
    blk = min(block, d)
    pad = (-d) % blk
    x = _pad_cols(x, pad)
    band = jnp.pad(band, (0, pad)) if pad else band
    in_specs = [
        pl.BlockSpec((n,), lambda i: (0,)),
        pl.BlockSpec((blk,), lambda i: (i,)),
        pl.BlockSpec((n, blk), lambda i: (0, i)),
    ]
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    if counts:
        num, cnt = pl.pallas_call(
            functools.partial(_masked_sum_counts_kernel, m=m, s=s),
            grid=(x.shape[1] // blk,),
            in_specs=in_specs,
            out_specs=(vec, vec),
            out_shape=(
                jax.ShapeDtypeStruct((x.shape[1],), jnp.float32),
                jax.ShapeDtypeStruct((x.shape[1],), jnp.float32),
            ),
            interpret=resolve_interpret(interpret),
        )(slot, band, x)
        return (num[:d], cnt[:d]) if pad else (num, cnt)
    out = pl.pallas_call(
        functools.partial(_masked_sum_kernel, m=m, s=s),
        grid=(x.shape[1] // blk,),
        in_specs=in_specs,
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((x.shape[1],), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(slot, band, x)
    return out[:d] if pad else out


def masked_sum_dequant(
    codes: jax.Array,  # (n, d) int8 wire codes (int4 codes fit in int8)
    scales: jax.Array,  # (n, nchunk) f32 per-chunk scales
    chunk_ids: jax.Array,  # (d,) int32 scale column per coordinate
    slot: jax.Array,  # (n,) int32; outside [0, m) -> contributes nothing
    band: jax.Array,  # (d,) int32 per-coordinate owner band
    m: int,
    s: int,
    *,
    counts: bool = False,
    block: int = 4096,
    interpret: Optional[bool] = None,
):
    """``masked_sum`` over int-wire workspace lanes: the (n, d) payload is
    int8 codes plus per-chunk f32 scales; each tile dequantizes in VMEM
    (``compress.wire_dequant``) and accumulates in f32, so HBM traffic on
    the client-stacked axis is 1 byte per coordinate instead of 4.  The
    ``counts=True`` survivor-aware contract matches ``masked_sum``."""
    n, d = codes.shape
    blk = min(block, d)
    pad = (-d) % blk
    codes = _pad_cols(codes, pad)
    if pad:
        band = jnp.pad(band, (0, pad))
        chunk_ids = jnp.pad(chunk_ids, (0, pad))
    nc = scales.shape[1]
    in_specs = [
        pl.BlockSpec((n,), lambda i: (0,)),
        pl.BlockSpec((blk,), lambda i: (i,)),
        pl.BlockSpec((blk,), lambda i: (i,)),
        pl.BlockSpec((n, blk), lambda i: (0, i)),
        pl.BlockSpec((n, nc), lambda i: (0, 0)),
    ]
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    if counts:
        num, cnt = pl.pallas_call(
            functools.partial(_masked_sum_dequant_counts_kernel, m=m, s=s),
            grid=(codes.shape[1] // blk,),
            in_specs=in_specs,
            out_specs=(vec, vec),
            out_shape=(
                jax.ShapeDtypeStruct((codes.shape[1],), jnp.float32),
                jax.ShapeDtypeStruct((codes.shape[1],), jnp.float32),
            ),
            interpret=resolve_interpret(interpret),
        )(slot, band, chunk_ids, codes, scales)
        return (num[:d], cnt[:d]) if pad else (num, cnt)
    out = pl.pallas_call(
        functools.partial(_masked_sum_dequant_kernel, m=m, s=s),
        grid=(codes.shape[1] // blk,),
        in_specs=in_specs,
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((codes.shape[1],), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(slot, band, chunk_ids, codes, scales)
    return out[:d] if pad else out


def robust_sum(
    x: jax.Array,  # (n, d) f32 (or float-wire) workspace
    slot: jax.Array,  # (n,) int32; outside [0, m) -> contributes nothing
    band: jax.Array,  # (d,) int32 per-coordinate owner band
    m: int,
    s: int,
    *,
    kind: str,  # "trimmed" | "median"
    k: int = 0,  # values trimmed per side (trimmed only)
    block: int = 4096,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Byzantine-robust UpCom: per-coordinate trimmed mean / median over
    the arrived owner values, in-tile (the ``masked_sum(counts=True)``
    robust sibling).  Returns ``(x_bar, cnt)`` — the already-combined
    value (0 where no owner arrived; callers gate on ``cnt > 0`` exactly
    like the survivor path, and do NOT divide) and the f32 arrived-owner
    count.  Int-wire lanes must be dequantized before the call: robust
    order statistics are defined on dequantized values (DESIGN.md §15).
    """
    if kind not in ("trimmed", "median"):
        raise ValueError(f"robust_sum kind {kind!r}")
    if not (0 <= 2 * int(k) < s):
        if kind == "trimmed":
            raise ValueError(f"robust_sum needs 0 <= 2k < s (k={k}, s={s})")
    n, d = x.shape
    blk = min(block, d)
    pad = (-d) % blk
    x = _pad_cols(x, pad)
    band = jnp.pad(band, (0, pad)) if pad else band
    in_specs = [
        pl.BlockSpec((n,), lambda i: (0,)),
        pl.BlockSpec((blk,), lambda i: (i,)),
        pl.BlockSpec((n, blk), lambda i: (0, i)),
    ]
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    bar, cnt = pl.pallas_call(
        functools.partial(_robust_sum_kernel, m=m, s=s, kind=kind,
                          k=int(k)),
        grid=(x.shape[1] // blk,),
        in_specs=in_specs,
        out_specs=(vec, vec),
        out_shape=(
            jax.ShapeDtypeStruct((x.shape[1],), jnp.float32),
            jax.ShapeDtypeStruct((x.shape[1],), jnp.float32),
        ),
        interpret=resolve_interpret(interpret),
    )(slot, band, x)
    return (bar[:d], cnt[:d]) if pad else (bar, cnt)


def h_update(
    x: jax.Array,  # (n, d) f32 workspace
    h: jax.Array,  # (n, d) f32 control variates
    x_bar: jax.Array,  # (d,) f32 rebuilt server model
    slot: jax.Array,  # (n,) int32
    band: jax.Array,  # (d,) int32
    m: int,
    s: int,
    scale: float,  # eta / gamma
    *,
    down: Optional[jax.Array] = None,  # (n,) int32/bool DownCom targets
    covered: Optional[jax.Array] = None,  # (d,) bool: coord has a survivor
    block: int = 4096,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """One fused pass: ``h += scale * owned * (x_bar - x)`` and the DownCom
    ``x_new = x_bar`` on the ``down`` rows (every row when ``down=None``);
    rows outside ``down`` keep their ``x`` bit-exactly.  ``covered``
    (survivor-aware path) additionally masks per-coordinate: coordinates
    with no arrived owner keep both h and x bit-exactly."""
    n, d = x.shape
    blk = min(block, d)
    pad = (-d) % blk
    x, h = _pad_cols(x, pad), _pad_cols(h, pad)
    band = jnp.pad(band, (0, pad)) if pad else band
    x_bar = jnp.pad(x_bar, (0, pad)) if pad else x_bar
    down = (jnp.ones((n,), jnp.int32) if down is None
            else down.astype(jnp.int32))
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    mat = pl.BlockSpec((n, blk), lambda i: (0, i))
    row = pl.BlockSpec((n,), lambda i: (0,))
    if covered is not None:
        cov = jnp.pad(covered.astype(jnp.int32), (0, pad)) if pad \
            else covered.astype(jnp.int32)
        h_new, x_new = pl.pallas_call(
            functools.partial(
                _h_update_covered_kernel, m=m, s=s, scale=scale
            ),
            grid=(x.shape[1] // blk,),
            in_specs=[row, row, vec, vec, vec, mat, mat],
            out_specs=(mat, mat),
            out_shape=(
                jax.ShapeDtypeStruct(x.shape, jnp.float32),
                jax.ShapeDtypeStruct(x.shape, jnp.float32),
            ),
            interpret=resolve_interpret(interpret),
        )(slot, down, band, cov, x_bar, x, h)
    else:
        h_new, x_new = pl.pallas_call(
            functools.partial(_h_update_kernel, m=m, s=s, scale=scale),
            grid=(x.shape[1] // blk,),
            in_specs=[
                row,  # slot
                row,  # down
                vec,  # band
                vec,  # x_bar
                mat,  # x
                mat,  # h
            ],
            out_specs=(mat, mat),
            out_shape=(
                jax.ShapeDtypeStruct(x.shape, jnp.float32),
                jax.ShapeDtypeStruct(x.shape, jnp.float32),
            ),
            interpret=resolve_interpret(interpret),
        )(slot, down, band, x_bar, x, h)
    if pad:
        h_new, x_new = h_new[:, :d], x_new[:, :d]
    return h_new, x_new
