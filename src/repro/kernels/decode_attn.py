"""Pallas TPU kernel: single-query GQA decode attention (flash-decode).

The serving hot-spot for decode_32k / long_500k: one new query per sequence
against a KV cache of up to 524k positions.  KV blocks are streamed
HBM->VMEM; an online softmax (running max / denominator in VMEM scratch)
keeps the working set at ``(block_s, head_dim)`` regardless of context
length.  GQA is exploited by loading each KV head once for its whole query
group (``group = n_heads // n_kv_heads`` rows share the tile).

Grid: ``(batch, kv_heads, S // block_s)`` — the S axis iterates fastest so
scratch accumulators carry across KV blocks of one (b, kv-head) pair.
Causal/window masking is applied from the scalar-prefetched ``pos``.

MXU alignment: the q-block is (group, head_dim); head_dim is 64-256 in the
zoo and block_s defaults to 512, so both matmuls hit 128-multiple shapes
for every assigned config (group is padded to 8 lanes by Mosaic if small).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(
    pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, block_s: int, window: Optional[int], softcap: Optional[float],
    scale: float,
):
    i_s = pl.program_id(2)
    n_s = pl.num_programs(2)
    pos = pos_ref[0]

    @pl.when(i_s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (group, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (block_s, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)  # (block_s, hd)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (group, block_s)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    kpos = jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1) + i_s * block_s
    mask = kpos <= pos
    if window is not None:
        mask &= kpos > pos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]  # (group, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)  # (group, block_s)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(i_s == n_s - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # (b, h, hd)
    k: jax.Array,  # (b, S, kvh, hd)
    v: jax.Array,  # (b, S, kvh, hd)
    pos: jax.Array,  # scalar int32
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, h, hd = q.shape
    S, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    assert S % block_s == 0, (S, block_s)
    qg = q.reshape(b, kvh, group, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(
            _decode_attn_kernel, block_s=block_s, window=window,
            softcap=softcap, scale=1.0 / math.sqrt(hd),
        ),
        grid=(b, kvh, S // block_s),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ik, i_s: (0,)),  # pos
            pl.BlockSpec(
                (1, 1, group, hd), lambda ib, ik, i_s: (ib, ik, 0, 0)
            ),
            pl.BlockSpec(
                (1, block_s, 1, hd), lambda ib, ik, i_s: (ib, i_s, ik, 0)
            ),
            pl.BlockSpec(
                (1, block_s, 1, hd), lambda ib, ik, i_s: (ib, i_s, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, group, hd), lambda ib, ik, i_s: (ib, ik, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),   # running max m
            pltpu.VMEM((group, 1), jnp.float32),   # running denominator l
            pltpu.VMEM((group, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(pos_arr, qg, k, v)
    return out.reshape(b, h, hd)
