"""Jit'd public wrappers around the Pallas kernels.

``interpret`` mode is selected automatically: on the CPU container the
kernels execute their bodies in the Pallas interpreter (bit-accurate
validation); on a real TPU backend they compile via Mosaic.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import compress as _compress
from repro.kernels import decode_attn as _decode_attn
from repro.kernels import local_step as _local_step
from repro.kernels import uplink as _uplink


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("c", "s", "block"))
def compress(x, slot, c: int, s: int, block: int = 4096):
    """C_i(x): (d,) with slot (1,), or client-stacked (n, d) with slot
    (n,) — the 2-D form runs a grid over clients."""
    return _compress.compress(
        x, slot, c, s, block=block, interpret=_interpret()
    )


@partial(jax.jit, static_argnames=("m", "s", "block"))
def uplink_masked_sum(x, slot, band, m: int, s: int, block: int = 4096):
    """Mask-free UpCom over the (n, d) comm workspace, 1/s rebuild fused."""
    return _uplink.masked_sum(
        x, slot, band, m, s, block=block, interpret=_interpret()
    )


@partial(jax.jit, static_argnames=("m", "s", "scale", "block"))
def uplink_h_update(x, h, x_bar, slot, band, m: int, s: int, scale: float,
                    down=None, block: int = 4096):
    """Fused control-variate update + DownCom, one pass.  ``down`` selects
    the rows that receive ``x_bar`` (all rows when None)."""
    return _uplink.h_update(
        x, h, x_bar, slot, band, m, s, scale, down=down, block=block,
        interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("gamma", "block"))
def fused_local_step(x, g, h, gamma: float, block: int = 65536):
    """x <- x - gamma*(g - h), any shape, storage-dtype preserving."""
    return _local_step.fused_local_step(
        x, g, h, gamma, block=block, interpret=_interpret()
    )


@partial(jax.jit, static_argnames=("window", "softcap", "block_s"))
def decode_attention(
    q, k, v, pos,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_s: int = 512,
):
    """Flash-decode GQA attention: q (b,h,hd) vs cache k/v (b,S,kvh,hd)."""
    return _decode_attn.decode_attention(
        q, k, v, pos, window=window, softcap=softcap, block_s=block_s,
        interpret=_interpret(),
    )


def make_attend_fn(
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_s: int = 512,
):
    """Adapter plugging the Pallas decode kernel into the model decode path
    (``transformer.decode_step(..., attend_fn=...)`` /
    ``layers.attention_decode``).  ``window`` must be static here; archs
    with per-layer dynamic windows use the jnp reference instead.
    """

    def attend(q, cache_k, cache_v, pos, dyn_window=None):
        del dyn_window  # static-window kernel variant
        b, t, h, hd = q.shape
        assert t == 1, "decode kernel is single-query"
        S = cache_k.shape[1]
        bs = block_s if S % block_s == 0 else S
        out = decode_attention(
            q[:, 0], cache_k.astype(q.dtype), cache_v.astype(q.dtype),
            pos, window=window, softcap=softcap, block_s=bs,
        )
        return out[:, None]

    return attend
