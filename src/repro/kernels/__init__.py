"""Pallas TPU kernels for the framework's compute/bandwidth hot-spots.

  compress.py     fused TAMUNA mask-generate-and-apply (C_i), VPU/bandwidth;
                  owns the closed-form ownership predicate the whole comm
                  path shares (``owned_from_band``)
  uplink.py       the mask-free fused comm step over the flat workspace:
                  masked_sum (UpCom + 1/s rebuild) and h_update (control
                  variates + DownCom broadcast in one pass), DESIGN.md §9
  local_step.py   fused local step x - gamma*(g - h), 3 reads + 1 write
  decode_attn.py  flash-decode GQA attention over KV-cache blocks (MXU)

``ops.py`` holds the jit'd wrappers (auto interpret-mode off-TPU);
``ref.py`` the pure-jnp oracles the tests sweep against.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
