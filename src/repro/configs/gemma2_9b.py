"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000
— local+global alternating, logit softcap.  [arXiv:2408.00118]"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    sliding_window=4096,
    local_global_pattern=2,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    scale_embed=True,
    tie_embeddings=True,
    act="gelu",
    dtype=jnp.bfloat16,
    source="arXiv:2408.00118",
)

REDUCED = ModelConfig(
    name="gemma2-9b-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab=512,
    sliding_window=64,
    local_global_pattern=2,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    scale_embed=True,
    tie_embeddings=True,
    act="gelu",
    dtype=jnp.float32,
    source=CONFIG.source,
)
