"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) per-expert
d_ff=768 vocab=151936, MoE 128 experts top-8 (no shared experts,
renormalized top-k).  [hf:Qwen/Qwen3-30B-A3B]"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    moe_d_ff=768,
    num_experts=128,
    top_k=8,
    shared_d_ff=0,
    renormalize=True,
    vocab=151936,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    source="hf:Qwen/Qwen3-30B-A3B",
)

REDUCED = ModelConfig(
    name="qwen3-moe-30b-a3b-reduced",
    family="moe",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=128,
    moe_d_ff=128,
    num_experts=4,
    top_k=2,
    shared_d_ff=0,
    renormalize=True,
    vocab=512,
    tie_embeddings=False,
    dtype=jnp.float32,
    source=CONFIG.source,
)
