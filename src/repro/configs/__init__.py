"""Architecture configs (one module per assigned architecture) + registry."""

from repro.configs.registry import (
    ARCHS,
    SHAPES,
    InputShape,
    get_config,
    get_reduced_config,
    input_specs,
    list_archs,
)

__all__ = [
    "ARCHS", "SHAPES", "InputShape", "get_config", "get_reduced_config",
    "input_specs", "list_archs",
]
