"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 + weight-shared attention blocks
[arXiv:2411.15242]"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="mamba_hybrid",
    n_layers=54,  # Mamba2 blocks
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,  # shared block MLP width
    vocab=32000,
    d_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_every=6,  # one weight-shared attn+MLP block per 6 Mamba blocks
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    source="arXiv:2411.15242",
)

REDUCED = ModelConfig(
    name="zamba2-2.7b-reduced",
    family="mamba_hybrid",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab=512,
    d_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_every=1,
    dtype=jnp.float32,
    source=CONFIG.source,
)
