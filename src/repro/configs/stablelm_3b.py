"""stablelm-3b [dense]: 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b]"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    rope_theta=10000.0,
    qkv_bias=True,  # stablelm-2 uses qkv biases
    tie_embeddings=False,
    act="silu",
    dtype=jnp.bfloat16,
    source="hf:stabilityai/stablelm-2-1_6b",
)

REDUCED = ModelConfig(
    name="stablelm-3b-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=688,
    vocab=512,
    qkv_bias=True,
    tie_embeddings=False,
    dtype=jnp.float32,
    source=CONFIG.source,
)
