"""Architecture registry + the 4 assigned input shapes + input_specs().

``input_specs(arch, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every input of the lowered step function — no device
allocation — exactly what ``jax.jit(...).lower(**specs)`` needs.

Shape semantics (per the assignment):
  train_4k     seq_len=4096    global_batch=256   -> train_step
  prefill_32k  seq_len=32768   global_batch=32    -> prefill_step
  decode_32k   seq_len=32768   global_batch=128   -> serve_step (1 new token
                                                     vs seq_len KV cache)
  long_500k    seq_len=524288  global_batch=1     -> serve_step, requires
                                                     sub-quadratic attention

long_500k policy (DESIGN.md §4): native for rwkv6 / zamba2 / gemma2 (SWA);
full-attention archs run a documented sliding-window-override variant
(window 8192); whisper-tiny is skipped.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig

ARCHS = {
    "stablelm-3b": "repro.configs.stablelm_3b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# archs whose unmodified attention is already sub-quadratic (or windowed)
NATIVE_SUBQUADRATIC = {"rwkv6-7b", "zamba2-2.7b", "gemma2-2b", "gemma2-9b"}
# archs for which long_500k is skipped entirely (documented in DESIGN.md)
LONG_SKIP = {"whisper-tiny"}
# window applied to full-attention archs for the long_500k variant
LONG_OVERRIDE_WINDOW = 8192


def list_archs():
    return sorted(ARCHS)


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    return importlib.import_module(ARCHS[arch])


def get_config(arch: str, shape: Optional[str] = None) -> ModelConfig:
    """Full-size config, with documented long_500k adjustments applied."""
    cfg: ModelConfig = _module(arch).CONFIG
    if shape == "long_500k":
        if arch in LONG_SKIP:
            raise ValueError(
                f"{arch}: long_500k is skipped (full-attention enc-dec; "
                "see DESIGN.md §4)"
            )
        if arch not in NATIVE_SUBQUADRATIC:
            cfg = dataclasses.replace(
                cfg, sliding_window_override=LONG_OVERRIDE_WINDOW
            )
        if arch == "zamba2-2.7b":
            # window the weight-shared attention block at 500k context
            cfg = dataclasses.replace(
                cfg, sliding_window=LONG_OVERRIDE_WINDOW
            )
    return cfg


def get_reduced_config(arch: str) -> ModelConfig:
    return _module(arch).REDUCED


def supported(arch: str, shape: str) -> bool:
    if shape == "long_500k" and arch in LONG_SKIP:
        return False
    return True


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(
    arch: str,
    shape_name: str,
    cfg: Optional[ModelConfig] = None,
    batch_override: Optional[int] = None,
    kv_dtype=jnp.bfloat16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the step fn."""
    cfg = cfg or get_config(arch, shape_name)
    sh = SHAPES[shape_name]
    B = batch_override or sh.global_batch
    T = sh.seq_len
    i32 = jnp.int32

    if sh.kind == "train":
        if cfg.family == "encdec":
            return {
                "frames": _sds((B, cfg.n_frames, cfg.d_model), cfg.dtype),
                "tokens": _sds((B, T), i32),
                "labels": _sds((B, T), i32),
            }
        specs = {
            "tokens": _sds((B, T - cfg.prefix_len), i32),
            "labels": _sds((B, T - cfg.prefix_len), i32),
        }
        if cfg.prefix_len:
            specs["prefix_embeds"] = _sds(
                (B, cfg.prefix_len, cfg.d_model), cfg.dtype
            )
        return specs

    if sh.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "frames": _sds((B, cfg.n_frames, cfg.d_model), cfg.dtype),
                "tokens": _sds((B, T), i32),
            }
        specs = {"tokens": _sds((B, T - cfg.prefix_len), i32)}
        if cfg.prefix_len:
            specs["prefix_embeds"] = _sds(
                (B, cfg.prefix_len, cfg.d_model), cfg.dtype
            )
        return specs

    # decode: one token against a cache of length T
    cache = cache_specs(cfg, B, T, kv_dtype)
    return {
        "token": _sds((B, 1), i32),
        "cache": cache,
        "pos": _sds((), i32),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int, kv_dtype):
    L = cfg.n_layers
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    f32 = jnp.float32
    if cfg.family in ("dense", "moe"):
        return {
            "k": _sds((L, batch, max_seq, kvh, hd), kv_dtype),
            "v": _sds((L, batch, max_seq, kvh, hd), kv_dtype),
        }
    if cfg.family == "rwkv":
        hd_r = cfg.d_model // cfg.n_heads
        return {
            "shift_tm": _sds((L, batch, 1, cfg.d_model), f32),
            "shift_cm": _sds((L, batch, 1, cfg.d_model), f32),
            "wkv": _sds((L, batch, cfg.n_heads, hd_r, hd_r), f32),
        }
    if cfg.family == "mamba_hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        n_heads = d_inner // cfg.ssm_head_dim
        n_groups = cfg.n_layers // cfg.shared_attn_every
        return {
            "conv": _sds((L, batch, 3, d_inner), f32),
            "ssm": _sds(
                (L, batch, n_heads, cfg.d_state, cfg.ssm_head_dim), f32
            ),
            "k": _sds((n_groups, batch, max_seq, kvh, hd), kv_dtype),
            "v": _sds((n_groups, batch, max_seq, kvh, hd), kv_dtype),
        }
    if cfg.family == "encdec":
        return {
            "k": _sds((L, batch, max_seq, kvh, hd), kv_dtype),
            "v": _sds((L, batch, max_seq, kvh, hd), kv_dtype),
            "xk": _sds((L, batch, cfg.n_frames, kvh, hd), kv_dtype),
            "xv": _sds((L, batch, cfg.n_frames, kvh, hd), kv_dtype),
        }
    raise ValueError(cfg.family)
