"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536
— "Finch", data-dependent decay.  [arXiv:2404.05892]"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="rwkv",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # head_dim 64 WKV heads
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    rope_theta=None,  # attention-free
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    source="arXiv:2404.05892",
)

REDUCED = ModelConfig(
    name="rwkv6-7b-reduced",
    family="rwkv",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=896,
    vocab=512,
    rope_theta=None,
    tie_embeddings=False,
    dtype=jnp.float32,
    source=CONFIG.source,
)
