"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama-architecture.  [arXiv:2401.14196]"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab=32256,
    rope_theta=100000.0,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    source="arXiv:2401.14196",
)

REDUCED = ModelConfig(
    name="deepseek-coder-33b-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    head_dim=32,
    d_ff=640,
    vocab=512,
    rope_theta=100000.0,
    tie_embeddings=False,
    dtype=jnp.float32,
    source=CONFIG.source,
)
