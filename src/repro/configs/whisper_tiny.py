"""whisper-tiny [audio]: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865
— encoder-decoder; mel-spectrogram + conv frontend STUBBED (precomputed
frame embeddings, 1500 frames).  [arXiv:2212.04356]

Note: the real model's decoder context is 448; the assigned decode_32k shape
is exercised mechanically (cache of 32768).  long_500k is SKIPPED for this
arch (full-attention enc-dec audio decoder; see DESIGN.md §4)."""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,  # decoder layers
    n_encoder_layers=4,
    n_frames=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    rope_theta=None,  # sinusoidal positions
    tie_embeddings=True,
    act="gelu",
    dtype=jnp.bfloat16,
    source="arXiv:2212.04356",
)

REDUCED = ModelConfig(
    name="whisper-tiny-reduced",
    family="encdec",
    n_layers=2,
    n_encoder_layers=2,
    n_frames=64,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab=512,
    rope_theta=None,
    tie_embeddings=True,
    act="gelu",
    dtype=jnp.float32,
    source=CONFIG.source,
)
