"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT vision encoder STUBBED (precomputed patch
embeddings, 256 patches); this is the InternLM2-20B language backbone.
[arXiv:2404.16821]"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    prefix_len=256,  # stub ViT patch embeddings prepended to text
    rope_theta=1000000.0,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    source="arXiv:2404.16821",
)

REDUCED = ModelConfig(
    name="internvl2-26b-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab=512,
    prefix_len=16,
    rope_theta=1000000.0,
    tie_embeddings=False,
    dtype=jnp.float32,
    source=CONFIG.source,
)
