"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 experts top-4 + 4 shared experts (fused 4*1408 shared
MLP with sigmoid gate).  [hf:Qwen/Qwen1.5-MoE-A2.7B]"""

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,  # informational: per-expert width
    moe_d_ff=1408,
    num_experts=60,
    top_k=4,
    shared_d_ff=5632,  # 4 shared experts fused: 4 * 1408
    renormalize=False,  # Qwen1.5-MoE: norm_topk_prob = false
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

REDUCED = ModelConfig(
    name="qwen2-moe-a2.7b-reduced",
    family="moe",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    moe_d_ff=128,
    num_experts=4,
    top_k=2,
    shared_d_ff=256,
    renormalize=False,
    vocab=512,
    qkv_bias=True,
    tie_embeddings=False,
    dtype=jnp.float32,
    source=CONFIG.source,
)
