"""Generation and evaluation utilities on top of the decode runtime.

  sample_token    temperature / top-k / top-p sampling from logits
  generate        batched autoregressive generation over any model family
  perplexity      teacher-forced eval over a token stream
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import model_api
from repro.models.transformer import ModelConfig


def sample_token(
    key: jax.Array,
    logits: jax.Array,  # (b, vocab) f32
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 0.0,
) -> jax.Array:
    """Returns sampled token ids (b,). temperature<=0 -> greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -1e30, logits)
    if top_p and 0.0 < top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.argmax(cum >= top_p, axis=-1)
        cutoff = jnp.take_along_axis(
            sorted_logits, cutoff_idx[:, None], axis=-1
        )
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1)


def generate(
    params: Any,
    cfg: ModelConfig,
    prompts: jax.Array,  # (b, prompt_len) int32
    gen_len: int,
    key: jax.Array,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 0.0,
    cache: Optional[Any] = None,
    attend_fn=None,
) -> Tuple[jax.Array, Any]:
    """Prefill via stepped decode (exact across families), then sample."""
    b, plen = prompts.shape
    max_seq = plen + gen_len
    if cache is None:
        cache = model_api.make_cache(cfg, b, max_seq, kv_dtype=jnp.float32)

    step = jax.jit(
        lambda p, t, c, pos: model_api.decode(
            p, cfg, t, c, pos, attend_fn=attend_fn
        )
    )
    logits = None
    for i in range(plen):
        logits, cache = step(
            params, prompts[:, i: i + 1], cache, jnp.asarray(i, jnp.int32)
        )
    out = []
    for i in range(plen, max_seq):
        key, sk = jax.random.split(key)
        tok = sample_token(sk, logits, temperature, top_k, top_p)
        out.append(tok)
        logits, cache = step(
            params, tok[:, None].astype(jnp.int32), cache,
            jnp.asarray(i, jnp.int32),
        )
    return jnp.stack(out, axis=1), cache


def perplexity(
    params: Any, cfg: ModelConfig, tokens: jax.Array, labels: jax.Array,
    **extra,
) -> float:
    """exp(mean token NLL) under teacher forcing."""
    loss, _ = model_api.loss(
        params, cfg, tokens=tokens, labels=labels, **extra
    )
    return float(jnp.exp(loss))
