from repro.optim.optimizers import (
    Optimizer, adamw, clip_by_global_norm, global_norm, momentum, sgd,
)

__all__ = [
    "Optimizer", "adamw", "clip_by_global_norm", "global_norm", "momentum",
    "sgd",
]
