"""Optimizers built from scratch (no optax in this container).

``sgd`` / ``momentum`` / ``adamw`` share a tiny (init, update) interface over
arbitrary pytrees.  The TAMUNA trainer uses the plain local step from the
paper by default; ``local_opt="adamw"`` swaps the inner update for AdamW —
a beyond-theory option (documented in DESIGN.md §7) used by the LM example.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params], Tuple[Params, Any]]  # (g, state, p)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        new = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype),
            params, grads,
        )
        return new, state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params):
        m = jax.tree.map(
            lambda mu, g: beta * mu + g.astype(jnp.float32), state, grads
        )
        new = jax.tree.map(
            lambda p, mu: (p - lr * mu.astype(p.dtype)).astype(p.dtype),
            params, m,
        )
        return new, m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Params
    nu: Params
    count: jax.Array


def adamw(
    lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            mu=jax.tree.map(z, params),
            nu=jax.tree.map(z, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params):
        count = state.count + 1
        c = count.astype(jnp.float32)
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads,
        )
        nu = jax.tree.map(
            lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads,
        )
        mu_hat_scale = 1.0 / (1 - b1**c)
        nu_hat_scale = 1.0 / (1 - b2**c)

        def step(p, m, n):
            upd = (m * mu_hat_scale) / (jnp.sqrt(n * nu_hat_scale) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new = jax.tree.map(step, params, mu, nu)
        return new, AdamState(mu=mu, nu=nu, count=count)

    return Optimizer(init, update)


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree: Params, max_norm: float) -> Params:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree)
