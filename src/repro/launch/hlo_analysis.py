"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which silently
undercounts everything inside ``lax.scan`` (layer stacks, xent chunks,
linear-attention chunk scans) by the trip count — useless for a roofline of
scanned models.  This module re-derives the three roofline inputs from the
post-SPMD HLO text with while-loop multipliers applied:

  flops             2 * prod(result) * K for every dot (incl. dots inside
                    fusions), K = product of the lhs contracting dims
  bytes_accessed    per top-level (post-fusion) instruction:
                    result bytes + sum(operand bytes) — an HBM-traffic proxy
  collective_bytes  result bytes of all-gather / all-reduce / reduce-scatter
                    / all-to-all / collective-permute (tuple shapes summed)

Trip counts are read from each while's condition computation (the constant
compared against the induction variable — exact for lax.scan/fori_loop).
Validated against known matmul/scan programs in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([a-z][\w\-]*)\("
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_ATTR_RE = re.compile(r"(\w+)=%?([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "while", "conditional", "call", "custom-call",
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        b = float(_DTYPE_BYTES[dt])
        if dims:
            for d in dims.split(","):
                b *= int(d)
        total += b
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    raw: str

    @property
    def result_bytes(self) -> float:
        return _type_bytes(self.type_str)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_marked: Optional[str] = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry_marked = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        paren = line[m.end():]
        # operands live before the closing paren of the op call; attrs after
        depth = 1
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = paren[:end]
        operands = _OPERAND_RE.findall(operand_str)
        inst = Instr(name, type_str, opcode, operands, line)
        cur.instrs.append(inst)
        cur.by_name[name] = inst
    if entry_marked:
        comps["__entry__"] = comps[entry_marked]
    return comps


def _attr(raw: str, key: str) -> Optional[str]:
    m = re.search(rf"{key}=%?([\w\.\-]+)", raw)
    return m.group(1) if m else None


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the while condition (exact for scans)."""
    best = 1
    for inst in cond.instrs:
        for m in _CONST_RE.finditer(inst.raw):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(inst: Instr, comp: Computation,
               comps: Dict[str, Computation]) -> float:
    out_dims = _shape_dims(inst.type_str)
    out_elems = math.prod(out_dims) if out_dims else 1
    # K = product of lhs contracting dims
    mc = _LHS_CONTRACT_RE.search(inst.raw)
    k = 1
    if mc and inst.operands:
        lhs = comp.by_name.get(inst.operands[0])
        if lhs is not None:
            dims = _shape_dims(lhs.type_str)
            idxs = [int(i) for i in mc.group(1).split(",")] if mc.group(1) else []
            for i in idxs:
                if i < len(dims):
                    k *= dims[i]
    return 2.0 * out_elems * k


@dataclass
class Costs:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)

    def add_coll(self, kind: str, b: float) -> None:
        self.collective_bytes[kind] = self.collective_bytes.get(kind, 0.0) + b

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def _fusion_bytes(inst: Instr, comp: Computation,
                  called: Optional[Computation]) -> float:
    """HBM traffic of a fusion: result + operands, EXCEPT operands whose
    only use inside the fused computation is a (dynamic-)slice/gather — a
    fused windowed read touches only the window, not the whole buffer
    (dominant for scan-carried KV caches / stacked params)."""
    b = inst.result_bytes
    if called is not None and called.instrs:
        # in-place DUS-rooted fusions (scan output stacking): traffic is the
        # update window, not the whole aliased buffer
        root = called.instrs[-1]
        roots = [root]
        if root.opcode == "tuple":
            roots = [called.by_name[o] for o in root.operands
                     if o in called.by_name]
        if roots and all(r.opcode == "dynamic-update-slice" for r in roots):
            b = 0.0
            for r in roots:
                upd = called.by_name.get(r.operands[1]) if len(r.operands) > 1 else None
                b += 2.0 * (upd.result_bytes if upd is not None
                            else r.result_bytes)
    sliced_param_windows: Dict[int, float] = {}
    if called is not None:
        params = {}
        for ci in called.instrs:
            if ci.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", ci.raw)
                if m:
                    params[ci.name] = int(m.group(1))
        uses: Dict[str, List[Instr]] = {}
        for ci in called.instrs:
            for o in ci.operands:
                if o in params:
                    uses.setdefault(o, []).append(ci)
        for pname, idx in params.items():
            consumers = uses.get(pname, [])
            if consumers and all(
                c.opcode in ("dynamic-slice", "slice", "gather")
                for c in consumers
            ):
                sliced_param_windows[idx] = sum(
                    c.result_bytes for c in consumers
                )
    for i, o in enumerate(inst.operands):
        src = comp.by_name.get(o)
        if src is None or src.opcode == "constant":
            continue
        if i in sliced_param_windows:
            b += sliced_param_windows[i]
        else:
            b += src.result_bytes
    return b


def _walk(comp: Computation, comps: Dict[str, Computation], mult: float,
          costs: Costs, top_level: bool) -> None:
    for inst in comp.instrs:
        op = inst.opcode
        raw = inst.raw
        # collectives (sync or async -start; -done repeats no transfer)
        base = op.replace("-start", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            costs.add_coll(base, mult * inst.result_bytes)

        if op == "dot":
            costs.flops += mult * _dot_flops(inst, comp, comps)

        if op == "fusion":
            called = _attr(raw, "calls")
            if called and called in comps:
                # flops inside fusions count; bytes do not (fused in VMEM)
                _walk(comps[called], comps, mult, costs, top_level=False)
            if top_level:
                costs.bytes_accessed += mult * _fusion_bytes(
                    inst, comp, comps.get(called)
                )
            continue
        elif op == "while":
            body = _attr(raw, "body")
            cond = _attr(raw, "condition")
            trips = _trip_count(comps[cond]) if cond in comps else 1
            if body in comps:
                _walk(comps[body], comps, mult * trips, costs, top_level=True)
        elif op == "conditional":
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%?([\w\.\-]+))",
                                 raw):
                names = (m.group(1) or m.group(2) or "").replace("%", "")
                for nm in filter(None, (s.strip() for s in names.split(","))):
                    if nm in comps:
                        _walk(comps[nm], comps, mult, costs, top_level=True)
        elif op in ("call", "async-start"):
            called = _attr(raw, "to_apply") or _attr(raw, "calls")
            if called and called in comps:
                _walk(comps[called], comps, mult, costs, top_level=top_level)

        # HBM-traffic proxy: top-level instructions only (fusions already
        # aggregate their internals)
        if top_level and op not in _SKIP_BYTES_OPS:
            if op in ("dynamic-slice", "gather", "slice"):
                # reads only the sliced window, not the whole operand
                b = 2.0 * inst.result_bytes
            elif op in ("dynamic-update-slice", "scatter"):
                # in-place update: traffic ~ 2x the update operand
                upd = None
                if len(inst.operands) >= 2:
                    upd = comp.by_name.get(inst.operands[1])
                b = 2.0 * (upd.result_bytes if upd is not None
                           else inst.result_bytes)
            else:
                b = inst.result_bytes
                for o in inst.operands:
                    src = comp.by_name.get(o)
                    if src is not None and src.opcode != "constant":
                        b += src.result_bytes
            costs.bytes_accessed += mult * b


def analyze(hlo_text: str) -> Costs:
    comps = parse_module(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:  # fall back: last computation
        entry = list(comps.values())[-1]
    costs = Costs()
    _walk(entry, comps, 1.0, costs, top_level=True)
    return costs
