"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import; import it only in a
dedicated process (python -m repro.launch.dryrun).  This package init
deliberately does NOT import it.
"""

from repro.launch import mesh, steps

__all__ = ["mesh", "steps"]
