"""End-to-end TAMUNA-DP training driver.

Runs real training (CPU host mesh by default — the same step functions the
dry-run lowers for the production mesh).  Round structure follows
Algorithm 1: ``L^(r) ~ Geometric(p)`` local steps then a compressed
communication step.

By default the round is ONE compiled unit: the fused round engine
(``repro.dist.rounds``) scans the local steps with donated state, samples
batches on device from scan-carried PRNG keys, runs the comm step in the
same program, and accumulates metrics on device (drained every
``--flush-every`` rounds).  ``--no-fuse`` keeps the legacy per-step path
(one jit dispatch per local step, host-sampled batches) as an escape hatch
— still with donated state buffers.

Example (the (b) deliverable end-to-end driver):
  PYTHONPATH=src python -m repro.launch.train \
      --arch gemma2-2b --reduced --rounds 30 --seq-len 128 \
      --per-client-batch 2 --data-parallel 4 --model-parallel 2
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--per-client-batch", type=int, default=2)
    ap.add_argument("--data-parallel", type=int, default=4)
    ap.add_argument("--model-parallel", type=int, default=2)
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--p", type=float, default=0.34)
    ap.add_argument("--cohort", type=int, default=0, help="0 = 3n/4")
    ap.add_argument("--clients", type=int, default=0,
                    help="population n (0 = one client per data shard); "
                         "n > dp stacks n/dp client rows per shard")
    ap.add_argument("--sparsity", type=int, default=2)
    ap.add_argument("--uplink", default="masked_psum",
                    choices=["masked_psum", "block_rs"])
    # literal list (= comm_ws.COMM_IMPLS): this module must not import
    # repro/jax before main() sets XLA_FLAGS; DistTamunaConfig re-validates
    ap.add_argument("--comm-impl", default="auto",
                    choices=["auto", "dense", "ws", "pallas"],
                    help="comm-step aggregation path (DESIGN.md §9/§10): "
                         "psum-shaped fused partials (ws), the "
                         "shard-resident shard_map'd kernel engine "
                         "(pallas; per-shard uplinks + one d-sized psum), "
                         "or the per-leaf dense-mask reference (dense)")
    # literal list (= wire.WIRE_POLICIES): same no-early-jax rule as above
    ap.add_argument("--wire-precision", default="f32",
                    choices=["auto", "f32", "bf16", "f16", "int8", "int4"],
                    help="UpCom payload width (DESIGN.md §13): f32 is the "
                         "unquantized wire, auto resolves per leaf size "
                         "(small leaves f16, large 8-bit stochastic)")
    ap.add_argument("--wire-down", action="store_true",
                    help="also quantize the DownCom broadcast (needs a "
                         "non-f32 --wire-precision)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log", default="")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--no-fuse", action="store_true",
                    help="legacy per-step driver: one jit dispatch per "
                         "local step, host-sampled batches")
    ap.add_argument("--max-L", type=int, default=16,
                    help="cap on the geometric round length")
    ap.add_argument("--flush-every", type=int, default=10,
                    help="fused path: drain device metric traces every "
                         "this many rounds")
    ap.add_argument("--pipeline", action="store_true",
                    help="pipelined round engine (DESIGN.md §14): overlap "
                         "round t+1's local compute with round t's "
                         "commit under bounded staleness")
    ap.add_argument("--staleness", type=int, default=1,
                    help="pipeline depth tau: a round's commit may lag "
                         "its dispatch by this many rounds (0 = the "
                         "synchronous schedule, run through the split-"
                         "phase engine)")
    ap.add_argument("--latency-dist", default="",
                    help="path to an availability_sim --dist export; "
                         "drives the pipelined driver's simulated clock "
                         "(per-step straggler latencies)")
    ap.add_argument("--round-policy", default="wait_all",
                    choices=["wait_all", "quorum", "deadline"],
                    help="pipelined admission policy at the deferred "
                         "commit (late uplinks past the cutoff are "
                         "dropped, their coordinates untouched)")
    ap.add_argument("--quorum", type=int, default=0,
                    help="quorum size for --round-policy quorum "
                         "(0 = c//2 + 1)")
    # literal list (= robust.ROBUST_AGGS): same no-early-jax rule as above
    ap.add_argument("--robust-agg", default="mean",
                    choices=["mean", "trimmed", "median"],
                    help="per-coordinate combiner over the s arrived "
                         "owner values (DESIGN.md §15): trimmed drops "
                         "--trim-k per side, median takes the middle; "
                         "mean (or trimmed with k=0) is the bitwise "
                         "legacy path")
    ap.add_argument("--trim-k", type=int, default=0,
                    help="values trimmed per side for --robust-agg "
                         "trimmed (needs 2k < sparsity)")
    ap.add_argument("--adversary", default="none",
                    choices=["none", "sign_flip", "scale", "inlier"],
                    help="simulate a Byzantine fraction of clients "
                         "(deterministic in --seed): sign-flipped, "
                         "scaled, or collusive-inlier uplinks")
    ap.add_argument("--f-byz", type=float, default=0.0,
                    help="Byzantine client fraction for --adversary")
    ap.add_argument("--reputation", action="store_true",
                    help="EWMA anomaly reputation driving escalating "
                         "quarantine windows (needs --adversary; fused "
                         "synchronous driver only)")
    args = ap.parse_args(argv)

    n_dev = args.data_parallel * args.model_parallel
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n_dev}"
    )

    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import checkpoint, metrics
    from repro.configs import registry
    from repro.data import DataConfig, SyntheticTokenPipeline, device_sampler
    from repro.dist import rounds, sharding, tamuna_dp
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(args.data_parallel, args.model_parallel)
    cfg = (
        registry.get_reduced_config(args.arch)
        if args.reduced else registry.get_config(args.arch)
    )
    n = args.clients or sharding.n_clients(mesh)
    # partial participation works on BOTH uplinks now (the blocked bands
    # lie over the cohort slots, DESIGN.md §11) — no c = n forcing
    c = args.cohort or max(2, (3 * n) // 4)
    tcfg = tamuna_dp.DistTamunaConfig(
        gamma=args.gamma, c=c, s=min(args.sparsity, c), p=args.p,
        uplink=args.uplink, comm_impl=args.comm_impl,
        wire_precision=args.wire_precision, wire_down=args.wire_down,
        robust_agg=args.robust_agg, trim_k=args.trim_k,
    )
    adversarial = args.adversary != "none" and args.f_byz > 0.0
    if args.reputation and not adversarial:
        ap.error("--reputation needs --adversary and --f-byz > 0")
    if adversarial and (args.no_fuse or args.pipeline):
        ap.error("--adversary runs on the fused synchronous driver "
                 "(drop --no-fuse/--pipeline)")

    state = tamuna_dp.init_state(jax.random.key(args.seed), cfg, mesh,
                                 tcfg, n=n)
    specs = tamuna_dp.state_pspecs(state, cfg, mesh)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    state = jax.device_put(state, shardings)

    pipe = SyntheticTokenPipeline(
        DataConfig(
            seq_len=args.seq_len, per_client_batch=args.per_client_batch,
            vocab=min(cfg.vocab, 512), seed=args.seed, n_clients=n,
        ),
        cfg, mesh,
    )

    logger = metrics.MetricLogger(args.log or None)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()

    if args.no_fuse:
        # legacy per-step path: one dispatch per local step, host batches —
        # but with the state buffers donated (the seed copied the full
        # (n, *param) state in HBM every step).  Cohort-aware too: at
        # c < n only the cohort's rows are gathered, trained, and
        # scattered back (idle clients do nothing; the DownCom broadcasts
        # here — the per-step escape hatch keeps the simpler eager form).
        local_step = jax.jit(
            tamuna_dp.make_local_step(cfg, tcfg), donate_argnums=(0,)
        )
        comm_step = jax.jit(
            tamuna_dp.make_comm_step(cfg, tcfg, mesh, n=n),
            donate_argnums=(0,),
        )
        key = jax.random.key(args.seed + 1)
        total_steps = 0
        final_loss = float("nan")
        # same elasticity gate as the fused engine: gather only where
        # cohort rows can vacate hardware
        elastic = rounds.default_elastic(
            n, tcfg.c, sharding.n_clients(mesh)
        )
        for r in range(args.rounds):
            L = tamuna_dp.sample_round_length(rng, tcfg.p, max_L=args.max_L)
            key, ck = jax.random.split(key)
            cohort = (tamuna_dp.round_cohort(ck, n, tcfg.c)
                      if elastic else None)
            work = (tamuna_dp.gather_cohort(state, cohort)
                    if elastic else state)
            for _ in range(L):
                batch = pipe.next_batch(
                    clients=np.asarray(cohort) if elastic else None
                )
                work, m = local_step(work, **batch)
                total_steps += 1
            if elastic:
                # the gather SHARED the scalar leaves (round / float
                # accumulators / opt.count) with `state`, and the first
                # donated local_step deleted those buffers — rebuild them
                # from `work`, whose leaves are live donated-jit outputs
                # (local steps never change their values)
                state = tamuna_dp.scatter_cohort(
                    state, work, cohort
                )._replace(
                    round=work.round, up_floats=work.up_floats,
                    down_floats=work.down_floats,
                    up_bytes=work.up_bytes, down_bytes=work.down_bytes,
                )
            else:
                state = work
            state = comm_step(state, jax.random.key_data(ck),
                              cohort=cohort)
            final_loss = float(m["loss"])
            logger.log(r, {
                "round": r, "L": L, "loss": final_loss,
                "local_steps": total_steps,
            })
            if (args.checkpoint_dir and args.checkpoint_every
                    and (r + 1) % args.checkpoint_every == 0):
                checkpoint.save(
                    os.path.join(args.checkpoint_dir, f"step_{r+1}"),
                    state, r + 1,
                )
    elif args.pipeline:
        from repro.dist import faults as faults_mod

        latency = (faults_mod.EmpiricalDelays.from_json(
            args.latency_dist, n=n, seed=args.seed,
        ) if args.latency_dist else None)
        engine = rounds.make_pipelined_round_fn(
            cfg, tcfg, mesh,
            sample_batch=device_sampler(pipe.dcfg, cfg, mesh),
            max_L=args.max_L, n=n,
        )
        state, last = rounds.run_rounds_pipelined(
            state,
            round_fn=engine,
            data=pipe.device_data(),
            key=jax.random.key(args.seed + 1),
            rounds=args.rounds,
            rng=rng,
            p=tcfg.p,
            staleness=args.staleness,
            flush_every=args.flush_every,
            logger=logger,
            checkpoint_dir=args.checkpoint_dir or None,
            checkpoint_every=args.checkpoint_every,
            latency=latency,
            policy=args.round_policy,
            quorum=args.quorum or None,
        )
        total_steps = last.get("local_steps", 0)
        final_loss = last.get("loss", float("nan"))
        if "commit_s" in last:
            print(f"[train] simulated clock: {last['commit_s']:.2f}s "
                  f"at staleness {args.staleness}")
    else:
        round_fn = rounds.make_round_fn(
            cfg, tcfg, mesh,
            sample_batch=device_sampler(pipe.dcfg, cfg, mesh),
            max_L=args.max_L, n=n,
        )
        fkw = {}
        if adversarial:
            from repro.dist import cohort as cohort_mod
            from repro.dist import faults as faults_mod

            fkw["faults"] = faults_mod.FaultPlan(
                seed=args.seed, n=n,
                model=faults_mod.FaultModel(
                    adversary=args.adversary, f_byz=args.f_byz,
                ),
            )
            if args.reputation:
                fkw["plan"] = cohort_mod.CohortPlan(args.seed, n, tcfg.c)
                fkw["reputation"] = True
        state, last = rounds.run_rounds(
            state,
            round_fn=round_fn,
            data=pipe.device_data(),
            key=jax.random.key(args.seed + 1),
            rounds=args.rounds,
            rng=rng,
            p=tcfg.p,
            flush_every=args.flush_every,
            logger=logger,
            checkpoint_dir=args.checkpoint_dir or None,
            checkpoint_every=args.checkpoint_every,
            **fkw,
        )
        total_steps = last.get("local_steps", 0)
        final_loss = last.get("loss", float("nan"))

    dt = time.time() - t0
    print(f"[train] {args.rounds} rounds / {total_steps} local steps "
          f"in {dt:.1f}s; final loss {final_loss:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
