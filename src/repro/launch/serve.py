"""Batched serving driver: prefill + decode loop over a request batch.

CPU-host demonstration of the inference runtime the decode dry-run shapes
lower for the production mesh.  Requests are prompt token arrays; the loop
prefills each batch (teacher-forced forward writing the KV cache via decode
steps for exactness across families), then decodes greedily.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
      --batch 4 --prompt-len 32 --gen-len 16
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--data-parallel", type=int, default=2)
    ap.add_argument("--model-parallel", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n_dev = args.data_parallel * args.model_parallel
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n_dev}"
    )

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import registry
    from repro.dist import model_api, sharding
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(args.data_parallel, args.model_parallel)
    cfg = registry.get_reduced_config(args.arch)
    max_seq = args.prompt_len + args.gen_len

    params = model_api.init(jax.random.key(args.seed), cfg)
    params = jax.device_put(
        params, sharding.params_shardings(params, cfg, mesh)
    )
    cache = model_api.make_cache(cfg, args.batch, max_seq)
    cache_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        sharding.cache_pspecs(cfg, mesh, batch=args.batch),
        is_leaf=lambda x: isinstance(x, P),
    )
    cache = jax.device_put(cache, cache_sh)

    if cfg.family == "encdec":
        from repro.models import encdec

        frames = jax.random.normal(
            jax.random.key(7),
            (args.batch, cfg.n_frames, cfg.d_model), jnp.float32,
        ).astype(cfg.dtype)
        enc = encdec.encode(params, cfg, frames)
        cache = encdec.precompute_cross_kv(params, cfg, enc, cache)

    step = jax.jit(
        lambda p, t, c, pos: model_api.decode(p, cfg, t, c, pos)
    )

    prompts = jax.random.randint(
        jax.random.key(args.seed + 1),
        (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32,
    )
    t0 = time.time()
    # prefill by stepping the decode path (exact across all families)
    for i in range(args.prompt_len):
        logits, cache = step(
            params, prompts[:, i: i + 1], cache, jnp.asarray(i, jnp.int32)
        )
    generated = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for i in range(args.prompt_len, max_seq):
        generated.append(tok)
        logits, cache = step(params, tok, cache, jnp.asarray(i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    tput = args.batch * (max_seq) / dt
    print(f"[serve] {args.arch}: batch {args.batch}, "
          f"{args.prompt_len}+{len(generated)} tokens/seq, "
          f"{dt:.1f}s ({tput:.1f} tok/s incl. compile)")
    print("[serve] sample continuations:", out[:2].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
