"""Production mesh construction.

Called as a FUNCTION so importing this module never touches jax device
state; the dry-run sets --xla_force_host_platform_device_count=512 before
any jax import and then calls these.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (one v5e pod's 256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 4, model: int = 2) -> Mesh:
    """Small mesh over forced-host devices for tests/examples."""
    return jax.make_mesh(
        (data, model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )
