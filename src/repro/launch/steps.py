"""Step builders: assemble (step_fn, in_shardings, input ShapeDtypeStructs)
for every (architecture x input-shape x mesh) combination.

Used by the multi-pod dry-run, the trainers, and the integration tests, so
the thing we dry-run is EXACTLY the thing we train/serve.

Train shapes lower THREE functions (Algorithm 2's two iteration types plus
their fusion):
  local   one TAMUNA local step over the global batch — the common case,
          zero cross-client collectives,
  comm    the compressed-aggregation + control-variate round end — all of
          the paper's communication lives here.  The aggregation impl the
          artifact records is the one that ACTUALLY executes on the mesh:
          `comm_ws.effective_impl(tcfg.comm_impl, meshed=True, mesh=mesh)`
          (with the mesh handle, `pallas` means the shard-resident
          shard_map engine of DESIGN.md §10, not the pre-shard_map ws
          fallback),
  round   the fused round engine program (`repro.dist.rounds`): E[L] local
          steps under `lax.scan` with on-device data sampling, then the
          comm step — what the production trainer actually dispatches, so
          the roofline artifacts see the scanned round, not a lone step.
Roofline amortizes: round = E[L] * local + comm (and reports the fused
round separately).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.data import DataConfig, device_sampler
from repro.dist import model_api, rounds, sharding, tamuna_dp
from repro.models.transformer import ModelConfig


class Built(NamedTuple):
    name: str
    fn: Callable
    in_specs: Tuple  # ShapeDtypeStructs (positional)
    in_shardings: Tuple
    out_shardings: Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _ns(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def dryrun_model_cfg(arch: str, shape: str) -> ModelConfig:
    """Full-size config with bf16 master params (fits v5e HBM; DESIGN.md §5)
    and flash-attention internals time-sharded over `model` (§Perf iter 2 —
    kv_heads < 16 otherwise makes GSPMD shard head_dim and all-reduce the
    attention blocks)."""
    cfg = registry.get_config(arch, shape)
    return dataclasses.replace(
        cfg, param_dtype=jnp.bfloat16, flash_t_shard_axis="model"
    )


import os


def default_tamuna_cfg(mesh: Mesh, uplink: str = "masked_psum",
                       s: int = 4,
                       comm_impl: str = "auto",
                       wire_precision: str = "f32",
                       robust_agg: str = "mean",
                       trim_k: int = 0,
                       ) -> tamuna_dp.DistTamunaConfig:
    n = sharding.n_clients(mesh)
    # both uplinks run partial participation (the blocked bands lie over
    # the cohort slots, DESIGN.md §11), so the dry-run lowers the elastic
    # round for block_rs too
    c = max(2, (3 * n) // 4)
    return tamuna_dp.DistTamunaConfig(
        gamma=0.02, c=c, s=min(s, c), p=0.25, uplink=uplink,
        microbatches=int(os.environ.get("REPRO_MICROBATCHES", "1")),
        comm_impl=comm_impl, wire_precision=wire_precision,
        robust_agg=robust_agg, trim_k=trim_k,
    )


# --------------------------------------------------------------------------
# train steps
# --------------------------------------------------------------------------


def build_train_steps(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    tcfg: Optional[tamuna_dp.DistTamunaConfig] = None,
    cfg: Optional[ModelConfig] = None,
) -> Dict[str, Built]:
    cfg = cfg or dryrun_model_cfg(arch, shape_name)
    tcfg = tcfg or default_tamuna_cfg(mesh)
    sh = registry.SHAPES[shape_name]
    n = sharding.n_clients(mesh)
    assert sh.global_batch % n == 0, (sh.global_batch, n)
    bs = sh.global_batch // n
    T = sh.seq_len

    # state specs via eval_shape: no device allocation
    state_struct = jax.eval_shape(
        lambda: tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    )
    state_pspec = tamuna_dp.state_pspecs(state_struct, cfg, mesh)
    state_shard = _ns(mesh, state_pspec)

    # per-client batch structs
    batch_struct: Dict[str, Any] = {}
    if cfg.family == "encdec":
        batch_struct["frames"] = _sds(
            (n, bs, cfg.n_frames, cfg.d_model), cfg.dtype
        )
        batch_struct["tokens"] = _sds((n, bs, T), jnp.int32)
        batch_struct["labels"] = _sds((n, bs, T), jnp.int32)
    else:
        Tt = T - cfg.prefix_len
        batch_struct["tokens"] = _sds((n, bs, Tt), jnp.int32)
        batch_struct["labels"] = _sds((n, bs, Tt), jnp.int32)
        if cfg.prefix_len:
            batch_struct["prefix_embeds"] = _sds(
                (n, bs, cfg.prefix_len, cfg.d_model), cfg.dtype
            )
    da = sharding.dp_axes(mesh)
    batch_pspec = {
        k: P(da, *([None] * (v.ndim - 1))) for k, v in batch_struct.items()
    }
    batch_shard = _ns(mesh, batch_pspec)

    local_raw = tamuna_dp.make_local_step(cfg, tcfg)

    def local_fn(state, batch):
        return local_raw(state, **batch)

    comm_raw = tamuna_dp.make_comm_step(cfg, tcfg, mesh)

    local = Built(
        name=f"{arch}:{shape_name}:local",
        fn=local_fn,
        in_specs=(state_struct, batch_struct),
        in_shardings=(state_shard, batch_shard),
        out_shardings=(state_shard, None),
    )
    comm = Built(
        name=f"{arch}:{shape_name}:comm",
        fn=comm_raw,
        in_specs=(state_struct, _sds((2,), jnp.uint32)),
        in_shardings=(state_shard, NamedSharding(mesh, P())),
        out_shardings=state_shard,
    )

    # fused round: E[L] = 1/p scanned local steps (data sampled on device
    # from the per-client transition tables) + the comm step, one program
    v = min(cfg.vocab, 512)
    tok_len = T if cfg.family == "encdec" else T - cfg.prefix_len
    dcfg = DataConfig(seq_len=tok_len, per_client_batch=bs, vocab=v,
                      n_clients=n)
    round_raw = rounds.make_fused_round(
        cfg, tcfg, mesh,
        sample_batch=device_sampler(dcfg, cfg, mesh),
        L=max(1, int(round(1.0 / tcfg.p))),
    )
    round_ = Built(
        name=f"{arch}:{shape_name}:round",
        fn=round_raw,
        in_specs=(state_struct, _sds((2,), jnp.uint32),
                  {"cum": _sds((n, v, v), jnp.float32)}),
        in_shardings=(state_shard, NamedSharding(mesh, P()),
                      {"cum": NamedSharding(mesh, P(da, None, None))}),
        out_shardings=(state_shard, None),
    )
    return {"local": local, "comm": comm, "round": round_}


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------


def build_prefill_step(
    arch: str, shape_name: str, mesh: Mesh,
    cfg: Optional[ModelConfig] = None,
) -> Built:
    cfg = cfg or dryrun_model_cfg(arch, shape_name)
    sh = registry.SHAPES[shape_name]
    B, T = sh.global_batch, sh.seq_len

    params_struct = jax.eval_shape(
        lambda: model_api.init(jax.random.key(0), cfg)
    )
    params_shard = _ns(mesh, sharding.params_pspecs(params_struct, cfg, mesh))

    inputs: Dict[str, Any] = {}
    if cfg.family == "encdec":
        inputs["frames"] = _sds((B, cfg.n_frames, cfg.d_model), cfg.dtype)
        inputs["tokens"] = _sds((B, T), jnp.int32)
    else:
        inputs["tokens"] = _sds((B, T - cfg.prefix_len), jnp.int32)
        if cfg.prefix_len:
            inputs["prefix_embeds"] = _sds(
                (B, cfg.prefix_len, cfg.d_model), cfg.dtype
            )
    in_pspec = sharding.prefill_input_pspecs(cfg, mesh)
    in_pspec = {k: in_pspec[k] for k in inputs}
    in_shard = _ns(mesh, in_pspec)

    def prefill_fn(params, inputs):
        return model_api.prefill(params, cfg, **inputs)

    return Built(
        name=f"{arch}:{shape_name}:prefill",
        fn=prefill_fn,
        in_specs=(params_struct, inputs),
        in_shardings=(params_shard, in_shard),
        out_shardings=None,
    )


def build_decode_step(
    arch: str, shape_name: str, mesh: Mesh,
    cfg: Optional[ModelConfig] = None,
) -> Built:
    cfg = cfg or dryrun_model_cfg(arch, shape_name)
    sh = registry.SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len

    params_struct = jax.eval_shape(
        lambda: model_api.init(jax.random.key(0), cfg)
    )
    # serving params: F-shard MoE experts so gather-dispatch indexes locally
    params_shard = _ns(mesh, sharding.params_pspecs(
        params_struct, cfg, mesh, moe_expert_parallel=False
    ))
    cache_struct = jax.eval_shape(
        lambda: model_api.make_cache(cfg, B, S)
    )
    serve_pspecs = sharding.serve_input_pspecs(cfg, mesh, B)
    cache_shard = _ns(mesh, serve_pspecs["cache"])
    token_shard = NamedSharding(mesh, serve_pspecs["token"])
    pos_shard = NamedSharding(mesh, P())

    def serve_fn(params, token, cache, pos):
        return model_api.decode(params, cfg, token, cache, pos)

    return Built(
        name=f"{arch}:{shape_name}:decode",
        fn=serve_fn,
        in_specs=(
            params_struct,
            _sds((B, 1), jnp.int32),
            cache_struct,
            _sds((), jnp.int32),
        ),
        in_shardings=(params_shard, token_shard, cache_shard, pos_shard),
        out_shardings=(None, cache_shard),
    )


def build(arch: str, shape_name: str, mesh: Mesh, **kw) -> Dict[str, Built]:
    kind = registry.SHAPES[shape_name].kind
    if kind == "train":
        return build_train_steps(arch, shape_name, mesh, **kw)
    if kind == "prefill":
        return {"prefill": build_prefill_step(arch, shape_name, mesh, **kw)}
    return {"decode": build_decode_step(arch, shape_name, mesh, **kw)}
