import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers + compiles on the production mesh, and extract the
roofline terms from the compiled artifact.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices for the 2x16x16
mesh.  (Smoke tests and benches run in separate processes and see 1 device.)

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--uplink block_rs]
  python -m repro.launch.dryrun --all --both-meshes

Artifacts: benchmarks/artifacts/dryrun/<mesh>/<arch>/<shape>/<step>.json
holding memory_analysis, cost_analysis, per-collective byte counts, and the
roofline terms (see benchmarks/roofline.py and EXPERIMENTS.md §Roofline).
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import registry
from repro.dist import comm_ws, robust as robust_lib, wire as wire_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh

# hardware constants (TPU v5e target; see the assignment)
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_LINE_RE = re.compile(
    r"=\s*(?P<result>.*?)\s"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<async>-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes of collective results in the post-SPMD module.

    Handles tuple-result collectives (XLA combines many leaves into one op).
    Async pairs are counted once (-start counted, -done skipped).
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if not m or m.group("async") == "-done":
            continue
        kind = m.group("kind")
        size = 0.0
        for dt, dims in _SHAPE_RE.findall(m.group("result")):
            if dt not in _DTYPE_BYTES:
                continue
            b = _DTYPE_BYTES[dt]
            if dims:
                for d in dims.split(","):
                    b *= int(d)
            size += b
        out[kind] = out.get(kind, 0.0) + size
    out["total"] = sum(out.values())
    return out


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE), D = tokens."""
    from repro.dist import model_api

    cfg = steps_lib.dryrun_model_cfg(arch, shape_name)
    sh = registry.SHAPES[shape_name]
    params_struct = jax.eval_shape(
        lambda: model_api.init(jax.random.key(0), cfg)
    )
    if cfg.family == "moe":
        n_params = cfg.active_param_count(params_struct)
    else:
        n_params = sum(x.size for x in jax.tree.leaves(params_struct))
    tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
    mult = 6.0 if sh.kind == "train" else 2.0  # fwd+bwd vs fwd-only
    return mult * n_params * tokens


def wire_summary(arch: str, shape_name: str, tcfg) -> dict:
    """The comm step's resolved wire format: per-leaf kinds (builder-time
    size-adaptive policy) and the per-client wire bytes one round costs —
    the artifact records what actually travels, not just the policy."""
    from repro.core import masks
    from repro.dist import model_api

    cfg = steps_lib.dryrun_model_cfg(arch, shape_name)
    params_struct = jax.eval_shape(
        lambda: model_api.init(jax.random.key(0), cfg)
    )
    dims = [int(np.prod(a.shape)) for a in jax.tree.leaves(params_struct)]
    kinds = [wire_lib.resolve_kind(D, tcfg.wire_precision) for D in dims]
    nnz = masks.block_column_nnz if tcfg.uplink == "block_rs" \
        else masks.column_nnz
    counts: Dict[str, int] = {}
    for k in kinds:
        counts[k] = counts.get(k, 0) + 1
    return {
        "policy": tcfg.wire_precision,
        "leaf_kind_counts": counts,
        "leaf_kinds": kinds,
        "up_bytes_per_round": sum(
            wire_lib.leaf_up_bytes(nnz(D, tcfg.c, tcfg.s), D, 1, k)
            for D, k in zip(dims, kinds)
        ),
        "down_bytes_per_round": sum(
            wire_lib.leaf_down_bytes(D, k if tcfg.wire_down else "f32")
            for D, k in zip(dims, kinds)
        ),
    }


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    uplink: str = "masked_psum",
    comm_impl: str = "auto",
    wire_precision: str = "f32",
    robust_agg: str = "mean",
    trim_k: int = 0,
    out_dir: Optional[str] = None,
    verbose: bool = True,
) -> Dict[str, dict]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    tcfg = steps_lib.default_tamuna_cfg(mesh, uplink=uplink,
                                        comm_impl=comm_impl,
                                        wire_precision=wire_precision,
                                        robust_agg=robust_agg,
                                        trim_k=trim_k)
    built = steps_lib.build(arch, shape_name, mesh, **(
        {"tcfg": tcfg} if registry.SHAPES[shape_name].kind == "train" else {}
    ))

    results = {}
    for step_name, b in built.items():
        t0 = time.time()
        with mesh:
            jitted = jax.jit(
                b.fn,
                in_shardings=b.in_shardings,
                out_shardings=b.out_shardings,
            )
            lowered = jitted.lower(*b.in_specs)
            compiled = lowered.compile()
        t1 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # older jax returns [dict] (one entry per program), newer a dict
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        # XLA's cost_analysis counts while bodies ONCE (useless for scanned
        # layer stacks); hlo_analysis re-derives flops / bytes / collective
        # bytes with while-loop trip counts applied (see hlo_analysis.py).
        from repro.launch import hlo_analysis

        ha = hlo_analysis.analyze(hlo)
        coll = dict(ha.collective_bytes)
        coll["total"] = ha.collective_total

        flops_total = float(ha.flops)
        bytes_total = float(ha.bytes_accessed)
        # post-SPMD HLO shapes are per-partition, so all terms are per-chip.
        compute_s = flops_total / PEAK_FLOPS
        memory_s = bytes_total / HBM_BW
        coll_s = coll["total"] / LINK_BW
        mflops = model_flops(arch, shape_name)

        rec = {
            "arch": arch,
            "shape": shape_name,
            "step": step_name,
            "mesh": mesh_name,
            "chips": n_chips,
            "uplink": uplink if step_name in ("comm", "round") else None,
            # the impl that actually executes: make_comm_step runs meshed
            # (clients are device-sharded) WITH the mesh handle, so
            # "pallas" resolves to the shard-resident engine (§10), not
            # the pre-shard_map ws fallback — see comm_ws.effective_impl
            "comm_impl": (
                comm_ws.effective_impl(tcfg.comm_impl, meshed=True,
                                       mesh=mesh)
                if step_name in ("comm", "round") else None
            ),
            # resolved per-leaf wire precision (§13): what each leaf
            # actually ships, not just the policy name
            "wire": (
                wire_summary(arch, shape_name, tcfg)
                if step_name in ("comm", "round") else None
            ),
            # robust combiner over the s owner values (DESIGN.md §15):
            # mean / trimmed-k / median; mean (and trimmed k=0) lowers
            # the bitwise legacy aggregation
            "robust": (
                {"agg": tcfg.robust_agg, "trim_k": tcfg.trim_k}
                if step_name in ("comm", "round") else None
            ),
            "compile_s": round(t1 - t0, 2),
            "memory_analysis": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None
                ),
            },
            "cost_analysis": {
                "flops": flops_total,
                "bytes_accessed": bytes_total,
                "xla_raw_flops": float(cost.get("flops", 0.0)),
                "xla_raw_bytes": float(cost.get("bytes accessed", 0.0)),
            },
            "collective_bytes": coll,
            "roofline": {
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": coll_s,
                "dominant": max(
                    [("compute", compute_s), ("memory", memory_s),
                     ("collective", coll_s)],
                    key=lambda kv: kv[1],
                )[0],
                "model_flops_global": mflops,
                "model_flops_per_chip": mflops / n_chips,
                "useful_flops_ratio": (
                    (mflops / n_chips) / flops_total
                    if flops_total else None
                ),
            },
        }
        results[step_name] = rec
        if verbose:
            r = rec["roofline"]
            print(
                f"[dryrun] {arch} {shape_name} {step_name} {mesh_name}: "
                f"compile {rec['compile_s']}s  "
                f"compute {r['compute_s']:.3e}s  mem {r['memory_s']:.3e}s  "
                f"coll {r['collective_s']:.3e}s  -> {r['dominant']}",
                flush=True,
            )
        if out_dir:
            d = os.path.join(out_dir, mesh_name, arch, shape_name)
            os.makedirs(d, exist_ok=True)
            with open(os.path.join(d, f"{step_name}.json"), "w") as f:
                json.dump(rec, f, indent=1)
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.list_archs())
    ap.add_argument("--shape", choices=list(registry.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--uplink", default="masked_psum",
                    choices=["masked_psum", "block_rs"])
    ap.add_argument("--comm-impl", default="auto",
                    choices=list(comm_ws.COMM_IMPLS),
                    help="comm-step aggregation path (DESIGN.md §9); auto "
                         "= fused workspace off-TPU, Pallas kernels on TPU")
    ap.add_argument("--wire-precision", default="f32",
                    choices=list(wire_lib.WIRE_POLICIES),
                    help="UpCom payload width (DESIGN.md §13); the "
                         "artifact records the resolved per-leaf kinds")
    ap.add_argument("--robust-agg", default="mean",
                    choices=list(robust_lib.ROBUST_AGGS),
                    help="per-coordinate combiner over the s owner "
                         "values (DESIGN.md §15); the artifact records "
                         "the lowered aggregation")
    ap.add_argument("--trim-k", type=int, default=0,
                    help="values trimmed per side for --robust-agg "
                         "trimmed (needs 2k < s)")
    ap.add_argument("--out-dir", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    pairs = []
    if args.all:
        for a in registry.list_archs():
            for s in registry.SHAPES:
                if registry.supported(a, s):
                    pairs.append((a, s))
                else:
                    print(f"[dryrun] SKIP {a} {s} (documented in DESIGN.md)")
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        pairs = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for mp in meshes:
        for a, s in pairs:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            if args.skip_existing and args.out_dir:
                kind = registry.SHAPES[s].kind
                probe = {"train": "local", "prefill": "prefill",
                         "decode": "decode"}[kind]
                p = os.path.join(args.out_dir, mesh_name, a, s,
                                 f"{probe}.json")
                if os.path.exists(p):
                    print(f"[dryrun] skip existing {a} {s} {mesh_name}")
                    continue
            try:
                run_one(a, s, mp, uplink=args.uplink,
                        comm_impl=args.comm_impl,
                        wire_precision=args.wire_precision,
                        robust_agg=args.robust_agg, trim_k=args.trim_k,
                        out_dir=args.out_dir)
            except Exception:
                traceback.print_exc()
                failures.append((a, s, mesh_name))
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        return 1
    print("[dryrun] all combinations lowered + compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
