"""Forward-compatibility shims for older jax versions.

The test-suite and the launch layer target the newer sharding surface
(``jax.sharding.AxisType`` and ``jax.make_mesh(..., axis_types=...)``).
On containers pinned to an older jax (0.4.3x) those names do not exist;
this module installs no-op equivalents so the same code runs on both.
Installed from ``repro/__init__.py`` so any ``repro.*`` import (which all
entry points and subprocess tests perform before building a mesh) is
sufficient.
"""

from __future__ import annotations

import enum
import functools
import inspect


def install() -> None:
    import jax

    if not hasattr(jax.sharding, "AxisType"):

        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if not hasattr(jax, "make_mesh"):
        from jax.experimental import mesh_utils

        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            del axis_types
            return jax.sharding.Mesh(
                mesh_utils.create_device_mesh(tuple(axis_shapes),
                                              devices=devices),
                tuple(axis_names),
            )

        jax.make_mesh = make_mesh
    elif "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _orig = jax.make_mesh

        @functools.wraps(_orig)
        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            # Auto is the only type this codebase uses and it is the old
            # default behaviour, so dropping the argument is faithful.
            del axis_types
            return _orig(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh


install()
