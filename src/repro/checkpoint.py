"""Sharded checkpointing: npz payloads + msgpack metadata.

Saves arbitrary pytrees (TAMUNA TrainState included) with the tree structure
and per-leaf dtype/shape recorded so restore works without reconstructing
the pytree first.  Device arrays are fetched shard-by-shard
(``jax.device_get``); restore re-places onto the provided shardings.

Saves are **atomic**: the payload is written into a staging directory next
to the target and ``os.replace``'d into place, so a crash mid-save (the
fault modes DESIGN.md §12 injects are exactly the kind that interrupt a
run) never leaves a half-written checkpoint where ``latest_step`` would
find it — a directory either holds a complete ``arrays.npz`` + ``meta.json``
pair or does not exist.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(
            "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                     for e in path)
        )
        leaves.append(leaf)
    return names, leaves, treedef


def save(path: str, tree: Params, step: Optional[int] = None) -> None:
    path = os.path.normpath(path)
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    # stage under a dot-prefixed sibling: same filesystem (so the final
    # os.replace is atomic) and invisible to latest_step's step_* scan
    stage = os.path.join(parent, f".tmp_{os.path.basename(path)}")
    if os.path.isdir(stage):
        shutil.rmtree(stage)
    os.makedirs(stage)
    try:
        names, leaves, treedef = _flatten_with_names(tree)
        arrays = {}
        for i, x in enumerate(leaves):
            a = np.asarray(jax.device_get(x))
            if a.dtype == jnp.bfloat16:  # npz has no bf16 cast: store raw bits
                a = a.view(np.uint16)
            arrays[f"leaf_{i}"] = a
        np.savez(os.path.join(stage, "arrays.npz"), **arrays)
        meta = {
            "names": names,
            "treedef": str(treedef),
            "step": step,
            "dtypes": [str(x.dtype) for x in leaves],
            "shapes": [list(x.shape) for x in leaves],
        }
        with open(os.path.join(stage, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.isdir(path):
            # os.replace cannot clobber a non-empty dir: drop the old
            # checkpoint only now that the replacement is fully staged
            shutil.rmtree(path)
        os.replace(stage, path)
    except BaseException:
        shutil.rmtree(stage, ignore_errors=True)
        raise


def _load_meta(path: str) -> Optional[dict]:
    try:
        with open(os.path.join(path, "meta.json")) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def restore(path: str, like: Params, shardings: Optional[Params] = None
            ) -> Params:
    """Restore into the structure of ``like`` (leaf order must match save).

    A leaf-count mismatch names the offending leaf *paths* (saved names
    vs the names of ``like``), not just the counts — the error you get
    when restoring into a state whose structure drifted across versions.
    """
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = [z[f"leaf_{i}"] for i in range(len(z.files))]
    names, leaves, treedef = _flatten_with_names(like)
    if len(arrays) != len(leaves):
        meta = _load_meta(path)
        saved = list(meta["names"]) if meta and "names" in meta else None
        msg = (f"checkpoint has {len(arrays)} leaves, expected "
               f"{len(leaves)}")
        if saved is not None:
            missing = sorted(set(saved) - set(names))
            extra = sorted(set(names) - set(saved))
            if missing:
                msg += f"; in checkpoint but not in target: {missing}"
            if extra:
                msg += f"; in target but not in checkpoint: {extra}"
        raise ValueError(msg)
    out = []
    for i, (arr, ref) in enumerate(zip(arrays, leaves)):
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"shape mismatch at leaf {names[i]!r}: "
                f"{tuple(arr.shape)} vs {tuple(ref.shape)}"
            )
        if ref.dtype == jnp.bfloat16 and arr.dtype == np.uint16:
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)  # bit-exact restore
        out.append(jnp.asarray(arr, dtype=ref.dtype))
    tree = jax.tree.unflatten(jax.tree.structure(like), out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [
        int(d.split("_")[-1]) for d in os.listdir(root)
        if d.startswith("step_")
    ]
    return max(steps) if steps else None
