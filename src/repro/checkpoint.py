"""Sharded checkpointing: npz payloads + msgpack metadata.

Saves arbitrary pytrees (TAMUNA TrainState included) with the tree structure
and per-leaf dtype/shape recorded so restore works without reconstructing
the pytree first.  Device arrays are fetched shard-by-shard
(``jax.device_get``); restore re-places onto the provided shardings.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(
            "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                     for e in path)
        )
        leaves.append(leaf)
    return names, leaves, treedef


def save(path: str, tree: Params, step: Optional[int] = None) -> None:
    os.makedirs(path, exist_ok=True)
    names, leaves, treedef = _flatten_with_names(tree)
    arrays = {}
    for i, x in enumerate(leaves):
        a = np.asarray(jax.device_get(x))
        if a.dtype == jnp.bfloat16:  # npz has no bf16 cast: store raw bits
            a = a.view(np.uint16)
        arrays[f"leaf_{i}"] = a
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    meta = {
        "names": names,
        "treedef": str(treedef),
        "step": step,
        "dtypes": [str(x.dtype) for x in leaves],
        "shapes": [list(x.shape) for x in leaves],
    }
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def restore(path: str, like: Params, shardings: Optional[Params] = None
            ) -> Params:
    """Restore into the structure of ``like`` (leaf order must match save)."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = [z[f"leaf_{i}"] for i in range(len(z.files))]
    _, leaves, treedef = _flatten_with_names(like)
    if len(arrays) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, expected {len(leaves)}"
        )
    out = []
    for arr, ref in zip(arrays, leaves):
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch {arr.shape} vs {ref.shape}")
        if ref.dtype == jnp.bfloat16 and arr.dtype == np.uint16:
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)  # bit-exact restore
        out.append(jnp.asarray(arr, dtype=ref.dtype))
    tree = jax.tree.unflatten(jax.tree.structure(like), out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [
        int(d.split("_")[-1]) for d in os.listdir(root)
        if d.startswith("step_")
    ]
    return max(steps) if steps else None
