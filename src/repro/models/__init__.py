"""Functional model zoo: layers, MoE, SSM (Mamba2), RWKV-6, unified
transformer, and the Whisper-style encoder-decoder."""

from repro.models import encdec, layers, moe, rwkv, ssm, transformer
from repro.models.transformer import ModelConfig

__all__ = [
    "encdec", "layers", "moe", "rwkv", "ssm", "transformer", "ModelConfig",
]
