"""Unified decoder-only transformer covering the assigned architecture zoo.

One config → one of four homogeneous *families*, each a single
``lax.scan`` over stacked per-layer params (compile-time O(1) in depth):

  dense        pre/post-norm GQA attention (+RoPE, per-layer sliding window,
               logit softcap) + gated MLP           [stablelm, gemma2-2b/9b,
                                                     deepseek, internvl-LM]
  moe          attention + top-k MoE FF (+ optional shared experts)
                                                    [qwen2-moe, qwen3-moe]
  rwkv         RWKV-6 time-mix + channel-mix        [rwkv6-7b]
  mamba_hybrid Mamba2 stacks with a single weight-SHARED attention+MLP block
               applied every ``shared_attn_every`` layers   [zamba2-2.7b]

Heterogeneity that survives inside a scan (e.g. gemma2's local/global
alternation) is expressed as *per-layer scalar arrays* threaded through the
scan (``window``), not as distinct param structures.

Both entry points are pure functions of (params, inputs):
  forward(params, cfg, tokens, ...)            -> final hidden states
  loss_fn(params, cfg, tokens, labels, ...)    -> (scalar loss, metrics)
  decode_step(params, cfg, token, cache, pos)  -> (logits, new cache)
  init_cache(cfg, batch, max_seq)              -> cache pytree
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, moe, rwkv, ssm

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | rwkv | mamba_hybrid
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: Optional[int] = None  # default d_model // n_heads
    # attention details
    rope_theta: Optional[float] = 10000.0
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None  # window for "local" layers
    local_global_pattern: Optional[int] = None  # e.g. 2 -> every 2nd local
    sliding_window_override: Optional[int] = None  # force SWA on ALL layers
    post_norm: bool = False  # gemma2 sandwich norms
    act: str = "silu"
    # moe
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0
    renormalize: bool = True
    # ssm / hybrid
    d_state: int = 64
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    shared_attn_every: int = 6
    # embeddings / frontends
    tie_embeddings: bool = True
    prefix_len: int = 0  # VLM/audio stub frontend: # of prepended embeddings
    scale_embed: bool = False  # gemma multiplies embed by sqrt(d_model)
    # encoder-decoder extras (family == "encdec")
    n_encoder_layers: int = 0
    n_frames: int = 0  # encoder input length (stub frontend frames)
    # numerics
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    xent_chunk: int = 512
    scan_chunk: int = 64  # linear-attention chunk size
    # §Perf: shard flash-attention internals' query-time axis over this mesh
    # axis (None = let GSPMD choose; see layers._constrain_t)
    flash_t_shard_axis: Optional[str] = None
    # bookkeeping (filled by configs/)
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a multiple of 128 so the vocab dim shards
        over the `model` mesh axis (standard practice; logits are sliced back
        to the logical vocab in the loss/decode paths)."""
        return -(-self.vocab // 128) * 128

    def layer_windows(self, seq_hint: int = 0) -> jax.Array:
        """Per-layer sliding windows as an int32 array; 0 means global."""
        if self.sliding_window_override is not None:
            w = [self.sliding_window_override] * self.n_layers
        elif self.sliding_window and self.local_global_pattern:
            w = [
                self.sliding_window if (i % self.local_global_pattern == 0) else 0
                for i in range(self.n_layers)
            ]
        elif self.sliding_window:
            w = [self.sliding_window] * self.n_layers
        else:
            w = [0] * self.n_layers
        return jnp.asarray(w, jnp.int32)

    def param_count(self, params=None) -> int:
        if params is None:
            params = init_params(jax.random.key(0), self)  # pragma: no cover
        return sum(x.size for x in jax.tree.leaves(params))

    def active_param_count(self, params: Params) -> int:
        """Params touched per token (MoE: top_k of num_experts experts)."""
        total = 0
        for path, x in jax.tree_util.tree_flatten_with_path(params)[0]:
            keys = "/".join(str(p) for p in path)
            if self.family == "moe" and any(
                f"'{n}'" in keys for n in ("w_gate", "w_up", "w_down")
            ) and "shared" not in keys:
                total += x.size * self.top_k // max(self.num_experts, 1)
            else:
                total += x.size
        return total


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig) -> Params:
    """One layer's params (unstacked)."""
    pd = cfg.param_dtype
    ks = jax.random.split(key, 8)
    if cfg.family in ("dense", "moe"):
        p: Params = {
            "ln_attn": layers.init_rmsnorm(cfg.d_model, pd),
            "attn": layers.init_attention(
                ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.head_dim_, pd, qkv_bias=cfg.qkv_bias,
            ),
            "ln_ff": layers.init_rmsnorm(cfg.d_model, pd),
        }
        if cfg.post_norm:
            p["ln_attn_post"] = layers.init_rmsnorm(cfg.d_model, pd)
            p["ln_ff_post"] = layers.init_rmsnorm(cfg.d_model, pd)
        if cfg.family == "moe":
            p["moe"] = moe.init_moe(
                ks[1], cfg.d_model, cfg.moe_d_ff, cfg.num_experts, pd,
                shared_d_ff=cfg.shared_d_ff,
            )
        else:
            p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, pd)
        return p
    if cfg.family == "rwkv":
        return {
            "ln_tm": layers.init_rmsnorm(cfg.d_model, pd),
            "tm": rwkv.init_rwkv6_timemix(ks[0], cfg.d_model, cfg.n_heads, pd),
            "ln_cm": layers.init_rmsnorm(cfg.d_model, pd),
            "cm": rwkv.init_rwkv6_channelmix(ks[1], cfg.d_model, cfg.d_ff, pd),
        }
    if cfg.family == "mamba_hybrid":
        return {
            "ln": layers.init_rmsnorm(cfg.d_model, pd),
            "mamba": ssm.init_mamba2(
                ks[0], cfg.d_model, cfg.d_state, pd,
                expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            ),
        }
    raise ValueError(f"unknown family {cfg.family}")


def init_params(key, cfg: ModelConfig) -> Params:
    pd = cfg.param_dtype
    k_embed, k_blocks, k_shared, k_head = jax.random.split(key, 4)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    # stacked per-layer params: tree-of-(L, ...) arrays -> scannable
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(block_keys)
    params: Params = {
        "embed": layers.embed_init(k_embed, cfg.padded_vocab, cfg.d_model, pd),
        "blocks": blocks,
        "final_norm": layers.init_rmsnorm(cfg.d_model, pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            k_head, cfg.d_model, cfg.padded_vocab, pd
        )
    if cfg.family == "mamba_hybrid":
        params["shared_attn"] = {
            "ln_attn": layers.init_rmsnorm(cfg.d_model, pd),
            "attn": layers.init_attention(
                k_shared, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.head_dim_, pd,
            ),
            "ln_ff": layers.init_rmsnorm(cfg.d_model, pd),
            "mlp": layers.init_mlp(
                jax.random.fold_in(k_shared, 1), cfg.d_model, cfg.d_ff, pd
            ),
        }
    if cfg.prefix_len:
        params["prefix_proj"] = layers.dense_init(
            jax.random.fold_in(k_embed, 7), cfg.d_model, cfg.d_model, pd
        )
    return params


# --------------------------------------------------------------------------
# train-mode forward
# --------------------------------------------------------------------------


def _attn_ff_block(
    bp: Params, x: jax.Array, cfg: ModelConfig, window: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Shared dense/moe block body; returns (x, moe aux loss)."""
    t = x.shape[1]
    # dynamic per-layer window: 0 -> global. Implemented by making the
    # window larger than the sequence when global, so one fused mask works.
    eff_window = jnp.where(window > 0, window, t + 1)
    h = layers.rmsnorm(bp["ln_attn"], x)
    h = _attention_with_dyn_window(bp["attn"], h, cfg, eff_window)
    if cfg.post_norm:
        h = layers.rmsnorm(bp["ln_attn_post"], h)
    x = x + h
    h = layers.rmsnorm(bp["ln_ff"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        h, auxd = moe.moe_fwd(
            bp["moe"], h, cfg.top_k, cfg.act, cfg.renormalize
        )
        aux = auxd["load_balance"]
    else:
        h = layers.mlp_fwd(bp["mlp"], h, cfg.act)
    if cfg.post_norm:
        h = layers.rmsnorm(bp["ln_ff_post"], h)
    return x + h, aux


def _attention_with_dyn_window(
    ap: Params, x: jax.Array, cfg: ModelConfig, window: jax.Array
) -> jax.Array:
    """Full-seq attention with a traced (per-layer) window size."""
    b, t, _ = x.shape
    q, k, v = layers._qkv(ap, x, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
    if cfg.rope_theta is not None:
        pos = jnp.arange(t)[None, :]
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k = layers.apply_rope(k, pos, cfg.rope_theta)
    if t >= layers.FLASH_THRESHOLD:
        out = layers.flash_attention(
            q, k, v, causal=True, window=window,
            attn_softcap=cfg.attn_softcap,
            t_shard_axis=cfg.flash_t_shard_axis,
        )
    else:
        import math

        group = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(b, t, cfg.n_kv_heads, group, cfg.head_dim_)
        logits = jnp.einsum(
            "btkgd,bskd->bkgts", qg, k, preferred_element_type=jnp.float32
        ) / math.sqrt(cfg.head_dim_)
        logits = layers.softcap(logits, cfg.attn_softcap)
        qp = jnp.arange(t)[:, None]
        kp = jnp.arange(t)[None, :]
        mask = (kp <= qp) & (kp > qp - window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    out = out.reshape(b, t, cfg.n_heads * cfg.head_dim_)
    return layers.matmul(out, ap["wo"])


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (b, t)
    prefix_embeds: Optional[jax.Array] = None,  # (b, P, d_model) stub frontend
) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden (b, t_total, d_model), total moe aux loss)."""
    x = params["embed"].astype(cfg.dtype)[tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model).astype(jnp.float32), cfg.dtype)
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(cfg.dtype)
        if "prefix_proj" in params:
            pe = layers.matmul(pe, params["prefix_proj"])
        x = jnp.concatenate([pe, x], axis=1)

    windows = cfg.layer_windows()

    if cfg.family in ("dense", "moe"):

        def body(x, xs):
            bp, w = xs
            x, aux = _attn_ff_block(bp, x, cfg, w)
            return x, aux

        if cfg.remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, (params["blocks"], windows))
        aux = auxs.sum()

    elif cfg.family == "rwkv":

        def body(x, bp):
            x = x + rwkv.rwkv6_timemix_fwd(
                bp["tm"], layers.rmsnorm(bp["ln_tm"], x), cfg.n_heads,
                chunk=cfg.scan_chunk,
                head_shard_axis=cfg.flash_t_shard_axis,
            )
            x = x + rwkv.rwkv6_channelmix_fwd(
                bp["cm"], layers.rmsnorm(bp["ln_cm"], x)
            )
            return x, jnp.zeros((), jnp.float32)

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])
        aux = jnp.zeros((), jnp.float32)

    elif cfg.family == "mamba_hybrid":
        every = cfg.shared_attn_every
        assert cfg.n_layers % every == 0
        n_groups = cfg.n_layers // every
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]),
            params["blocks"],
        )
        sap = params["shared_attn"]

        def mamba_body(x, bp):
            x = x + ssm.mamba2_fwd(
                bp["mamba"], layers.rmsnorm(bp["ln"], x), cfg.d_state,
                chunk=cfg.scan_chunk,
            )
            return x, None

        if cfg.remat:
            mamba_body = jax.checkpoint(mamba_body)

        def group_body(x, gbp):
            # weight-shared attention block, then `every` mamba layers
            h = layers.rmsnorm(sap["ln_attn"], x)
            h = layers.attention_fwd(
                sap["attn"], h, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                rope_theta=cfg.rope_theta,
                sliding_window=cfg.sliding_window,
                t_shard_axis=cfg.flash_t_shard_axis,
            )
            x = x + h
            h = layers.rmsnorm(sap["ln_ff"], x)
            x = x + layers.mlp_fwd(sap["mlp"], h, cfg.act)
            x, _ = jax.lax.scan(mamba_body, x, gbp)
            return x, jnp.zeros((), jnp.float32)

        x, _ = jax.lax.scan(group_body, x, grouped)
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(cfg.family)

    return layers.rmsnorm(params["final_norm"], x), aux


def lm_head_weight(params: Params, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def loss_fn(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    labels: jax.Array,
    prefix_embeds: Optional[jax.Array] = None,
    aux_weight: float = 0.01,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h, aux = forward(params, cfg, tokens, prefix_embeds)
    if prefix_embeds is not None:
        h = h[:, prefix_embeds.shape[1]:]  # loss on text positions only
    xent = layers.chunked_softmax_xent(
        h, lm_head_weight(params, cfg), labels,
        chunk=cfg.xent_chunk, logit_softcap=cfg.final_softcap,
        valid_vocab=cfg.vocab,
    )
    loss = xent + aux_weight * aux
    return loss, {"xent": xent, "moe_aux": aux}


# --------------------------------------------------------------------------
# decode (single new token against a cache)
# --------------------------------------------------------------------------


def init_cache(
    cfg: ModelConfig, batch: int, max_seq: int, kv_dtype=jnp.bfloat16
) -> Params:
    L = cfg.n_layers
    if cfg.family in ("dense", "moe"):
        return {
            "k": jnp.zeros(
                (L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim_), kv_dtype
            ),
            "v": jnp.zeros(
                (L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim_), kv_dtype
            ),
        }
    if cfg.family == "rwkv":
        hd = cfg.d_model // cfg.n_heads
        return {
            "shift_tm": jnp.zeros((L, batch, 1, cfg.d_model), jnp.float32),
            "shift_cm": jnp.zeros((L, batch, 1, cfg.d_model), jnp.float32),
            "wkv": jnp.zeros((L, batch, cfg.n_heads, hd, hd), jnp.float32),
        }
    if cfg.family == "mamba_hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        n_heads = d_inner // cfg.ssm_head_dim
        n_groups = cfg.n_layers // cfg.shared_attn_every
        return {
            "conv": jnp.zeros((L, batch, 3, d_inner), jnp.float32),
            "ssm": jnp.zeros(
                (L, batch, n_heads, cfg.d_state, cfg.ssm_head_dim), jnp.float32
            ),
            # shared attention block: one cache per application
            "k": jnp.zeros(
                (n_groups, batch, max_seq, cfg.n_kv_heads, cfg.head_dim_),
                kv_dtype,
            ),
            "v": jnp.zeros(
                (n_groups, batch, max_seq, cfg.n_kv_heads, cfg.head_dim_),
                kv_dtype,
            ),
        }
    raise ValueError(cfg.family)


def decode_step(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,  # (b, 1) int32
    cache: Params,
    pos: jax.Array,  # scalar int32: current length (new token index)
    attend_fn=None,
) -> Tuple[jax.Array, Params]:
    """One decode step; returns (logits (b, vocab), updated cache)."""
    x = params["embed"].astype(cfg.dtype)[token]
    if cfg.scale_embed:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model).astype(jnp.float32), cfg.dtype)
    windows = cfg.layer_windows()

    if cfg.family in ("dense", "moe"):

        def body(x, xs):
            bp, w, ck, cv = xs
            h = layers.rmsnorm(bp["ln_attn"], x)
            sw = jnp.where(w > 0, w, jnp.iinfo(jnp.int32).max // 2)
            h, ck, cv = _attention_decode_dyn(
                bp["attn"], h, ck, cv, pos, cfg, sw, attend_fn
            )
            if cfg.post_norm:
                h = layers.rmsnorm(bp["ln_attn_post"], h)
            x = x + h
            h = layers.rmsnorm(bp["ln_ff"], x)
            if cfg.family == "moe":
                h, _ = moe.moe_fwd(
                    bp["moe"], h, cfg.top_k, cfg.act, cfg.renormalize
                )
            else:
                h = layers.mlp_fwd(bp["mlp"], h, cfg.act)
            if cfg.post_norm:
                h = layers.rmsnorm(bp["ln_ff_post"], h)
            return x + h, (ck, cv)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["blocks"], windows, cache["k"], cache["v"])
        )
        new_cache = {"k": ks, "v": vs}

    elif cfg.family == "rwkv":

        def body(x, xs):
            bp, stm, scm, wkv = xs
            h, tm_cache = rwkv.rwkv6_timemix_decode(
                bp["tm"], layers.rmsnorm(bp["ln_tm"], x),
                {"shift": stm, "wkv": wkv}, cfg.n_heads,
            )
            x = x + h
            h, new_scm = rwkv.rwkv6_channelmix_decode(
                bp["cm"], layers.rmsnorm(bp["ln_cm"], x), scm
            )
            x = x + h
            return x, (tm_cache["shift"], new_scm, tm_cache["wkv"])

        x, (stm, scm, wkv) = jax.lax.scan(
            body, x,
            (params["blocks"], cache["shift_tm"], cache["shift_cm"],
             cache["wkv"]),
        )
        new_cache = {"shift_tm": stm, "shift_cm": scm, "wkv": wkv}

    elif cfg.family == "mamba_hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]),
            params["blocks"],
        )
        conv_g = cache["conv"].reshape((n_groups, every) + cache["conv"].shape[1:])
        ssm_g = cache["ssm"].reshape((n_groups, every) + cache["ssm"].shape[1:])
        sap = params["shared_attn"]

        def mamba_body(x, xs):
            bp, conv, ssm_state = xs
            h, new_c = ssm.mamba2_decode(
                bp["mamba"], layers.rmsnorm(bp["ln"], x),
                {"conv": conv, "ssm": ssm_state}, cfg.d_state,
            )
            return x + h, (new_c["conv"], new_c["ssm"])

        def group_body(x, xs):
            gbp, conv, ssm_state, ck, cv = xs
            h = layers.rmsnorm(sap["ln_attn"], x)
            h, ck, cv = layers.attention_decode(
                sap["attn"], h, ck, cv, pos,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta,
                sliding_window=cfg.sliding_window, attend_fn=attend_fn,
            )
            x = x + h
            h = layers.rmsnorm(sap["ln_ff"], x)
            x = x + layers.mlp_fwd(sap["mlp"], h, cfg.act)
            x, (conv, ssm_state) = jax.lax.scan(
                mamba_body, x, (gbp, conv, ssm_state)
            )
            return x, (conv, ssm_state, ck, cv)

        x, (conv, ssm_state, ks, vs) = jax.lax.scan(
            group_body, x, (grouped, conv_g, ssm_g, cache["k"], cache["v"])
        )
        new_cache = {
            "conv": conv.reshape(cache["conv"].shape),
            "ssm": ssm_state.reshape(cache["ssm"].shape),
            "k": ks, "v": vs,
        }
    else:
        raise ValueError(cfg.family)

    h = layers.rmsnorm(params["final_norm"], x)[:, 0]
    logits = jax.lax.dot_general(
        h, lm_head_weight(params, cfg).astype(h.dtype),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )[:, : cfg.vocab]
    logits = layers.softcap(logits, cfg.final_softcap)
    return logits, new_cache


def _attention_decode_dyn(
    ap, x, cache_k, cache_v, pos, cfg: ModelConfig, window: jax.Array,
    attend_fn=None,
):
    """Decode attention with traced per-layer window (scan-friendly)."""
    b = x.shape[0]
    q, k, v = layers._qkv(ap, x, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
    if cfg.rope_theta is not None:
        pk = jnp.full((b, 1), pos)
        q = layers.apply_rope(q, pk, cfg.rope_theta)
        k = layers.apply_rope(k, pk, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos, axis=1
    )
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos, axis=1
    )
    if attend_fn is not None:
        out = attend_fn(q, cache_k, cache_v, pos, window)
    else:
        out = _decode_scores_dyn(q, cache_k, cache_v, pos, window, cfg)
    y = layers.matmul(
        out.reshape(b, 1, cfg.n_heads * cfg.head_dim_), ap["wo"]
    )
    return y, cache_k, cache_v


def _decode_scores_dyn(q, cache_k, cache_v, pos, window, cfg: ModelConfig):
    import math

    b, _, h, hd = q.shape
    kvh = cache_k.shape[2]
    group = h // kvh
    qg = q.reshape(b, 1, kvh, group, hd)
    logits = jnp.einsum(
        "btkgd,bskd->bkgts", qg, cache_k.astype(q.dtype),
        preferred_element_type=jnp.float32,
    ) / math.sqrt(hd)
    logits = layers.softcap(logits, cfg.attn_softcap)
    kpos = jnp.arange(cache_k.shape[1])
    mask = (kpos <= pos) & (kpos > pos - window)
    logits = jnp.where(mask[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, cache_v.astype(q.dtype))
    return out.reshape(b, 1, h, hd)
