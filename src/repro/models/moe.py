"""Mixture-of-Experts feed-forward with top-k routing.

Covers both assigned MoE architectures:
  * qwen2-moe-a2.7b : 60 routed experts, top-4, + 4 "shared" experts that see
    every token (implemented as one fused shared MLP of width 4*d_ff) with a
    learned sigmoid gate, per the Qwen1.5-MoE model card.
  * qwen3-moe-30b-a3b : 128 routed experts, top-8, no shared experts,
    renormalized top-k probs.

Dispatch is *dense einsum* over the expert axis (one-hot combine weights):
no gather/scatter, MXU-friendly, and shards cleanly over the ``model`` mesh
axis (expert parallelism) — tokens meet experts through an all-to-all-free
contraction; see DESIGN.md §5 and the §Perf iteration on sparse dispatch.

Router aux losses: load-balance (Switch-style) + router z-loss, both
returned so the trainer can add them to the LM loss.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Dict[str, Any]


def init_moe(
    key,
    d_model: int,
    d_ff_expert: int,
    num_experts: int,
    dtype,
    shared_d_ff: int = 0,
) -> Params:
    ks = jax.random.split(key, 5)
    import math

    sc_in = 1.0 / math.sqrt(d_model)
    sc_ff = 1.0 / math.sqrt(d_ff_expert)
    p: Params = {
        "router": layers.dense_init(ks[0], d_model, num_experts, dtype),
        # experts stacked on a leading axis -> shardable over `model`
        "w_gate": jax.random.normal(
            ks[1], (num_experts, d_model, d_ff_expert), dtype
        ) * jnp.asarray(sc_in, dtype),
        "w_up": jax.random.normal(
            ks[2], (num_experts, d_model, d_ff_expert), dtype
        ) * jnp.asarray(sc_in, dtype),
        "w_down": jax.random.normal(
            ks[3], (num_experts, d_ff_expert, d_model), dtype
        ) * jnp.asarray(sc_ff, dtype),
    }
    if shared_d_ff:
        p["shared"] = layers.init_mlp(ks[4], d_model, shared_d_ff, dtype)
        p["shared_gate"] = jnp.zeros((d_model, 1), dtype)
    return p


def route(
    params: Params, x: jax.Array, top_k: int, renormalize: bool = True
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Returns (top_idx (..., k), top_p (..., k), aux losses)."""
    num_experts = params["router"].shape[-1]
    logits = layers.matmul(x, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, top_k)
    if renormalize:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Switch load-balance loss: E * sum_e f_e * p_e
    hot = jax.nn.one_hot(top_idx, num_experts, dtype=jnp.float32).sum(-2)
    frac_tokens = jnp.mean(
        (hot > 0).astype(jnp.float32), axis=tuple(range(hot.ndim - 1))
    )
    mean_probs = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    lb = num_experts * jnp.sum(frac_tokens * mean_probs)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return top_idx, top_p, {"load_balance": lb, "router_z": z}


def combine_weights(
    top_idx: jax.Array, top_p: jax.Array, num_experts: int
) -> jax.Array:
    """Dense (..., E) combine weights from top-k routing."""
    return jnp.sum(
        jax.nn.one_hot(top_idx, num_experts, dtype=jnp.float32)
        * top_p[..., None],
        axis=-2,
    )


def moe_fwd(
    params: Params,
    x: jax.Array,  # (b, t, d_model)
    top_k: int,
    act: str = "silu",
    renormalize: bool = True,
    dispatch: str = "auto",  # auto | dense | gather
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    top_idx, top_p, aux = route(params, x, top_k, renormalize)
    num_experts = params["router"].shape[-1]
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    b, t, _ = x.shape

    if dispatch == "auto":
        import os

        forced = os.environ.get("REPRO_MOE_DISPATCH")  # measurement knob
        if forced in ("dense", "gather"):
            dispatch = forced
        else:
            # gather wins when few tokens touch few experts (decode): weight
            # traffic drops from ALL experts to the top_k selected (§Perf i5)
            dispatch = "gather" if b * t * top_k <= num_experts else "dense"

    if dispatch == "gather":
        wg = params["w_gate"][top_idx].astype(x.dtype)  # (b,t,k,D,F)
        wu = params["w_up"][top_idx].astype(x.dtype)
        wd = params["w_down"][top_idx].astype(x.dtype)  # (b,t,k,F,D)
        g = jnp.einsum("btd,btkdf->btkf", x, wg,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("btd,btkdf->btkf", x, wu,
                       preferred_element_type=jnp.float32)
        h = (actf(g) * u).astype(x.dtype)
        y = jnp.einsum(
            "btkf,btkfd,btk->btd", h, wd, top_p.astype(x.dtype),
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)
    else:
        cw = combine_weights(top_idx, top_p, num_experts).astype(x.dtype)
        # dense-dispatch: every expert sees every token, weighted combine.
        g = jnp.einsum(
            "btd,edf->btef", x, params["w_gate"].astype(x.dtype),
            preferred_element_type=jnp.float32,
        )
        u = jnp.einsum(
            "btd,edf->btef", x, params["w_up"].astype(x.dtype),
            preferred_element_type=jnp.float32,
        )
        h = (actf(g) * u).astype(x.dtype)
        h = h * cw[..., None]  # weight before down-proj: skipped experts -> 0
        y = jnp.einsum(
            "btef,efd->btd", h, params["w_down"].astype(x.dtype),
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)

    if "shared" in params:
        gate = jax.nn.sigmoid(
            layers.matmul(x, params["shared_gate"]).astype(jnp.float32)
        ).astype(x.dtype)
        y = y + gate * layers.mlp_fwd(params["shared"], x, act)
    return y, aux
