"""RWKV-6 "Finch" time-mix and channel-mix blocks (arXiv:2404.05892).

Attention-free: the WKV recurrence with *data-dependent* per-channel decay
``w_t = exp(-exp(w_base + lora(x_t)))`` maps directly onto the chunked
linear-attention machinery in ``ssm.py`` (per-key-dim decay + u bonus).
Token-shift mixes each token with its predecessor; decode keeps a 1-token
shift buffer plus the (K x V) WKV state per head — O(1) in context length,
which is why rwkv6 runs the long_500k shape natively.

Simplifications vs the reference implementation (documented in DESIGN.md):
the five data-dependent token-shift interpolation LoRAs are collapsed into
per-projection learned mix coefficients + a single shared LoRA on the decay,
preserving the data-dependent-decay mechanism the paper is about.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, ssm

Params = Dict[str, Any]


def init_rwkv6_timemix(
    key, d_model: int, n_heads: int, dtype, decay_lora: int = 64
) -> Params:
    head_dim = d_model // n_heads
    ks = jax.random.split(key, 8)
    return {
        "mix": jnp.full((4, d_model), 0.5, dtype),  # r, k, v, w shift mixes
        "wr": layers.dense_init(ks[0], d_model, d_model, dtype),
        "wk": layers.dense_init(ks[1], d_model, d_model, dtype),
        "wv": layers.dense_init(ks[2], d_model, d_model, dtype),
        "wg": layers.dense_init(ks[3], d_model, d_model, dtype),
        "wo": layers.dense_init(ks[4], d_model, d_model, dtype),
        # data-dependent decay: w_t = exp(-exp(w_base + B(A x_t)))
        "w_base": jnp.full((d_model,), -1.0, dtype),
        "w_lora_a": layers.dense_init(ks[5], d_model, decay_lora, dtype),
        "w_lora_b": layers.dense_init(ks[6], decay_lora, d_model, dtype)
        * jnp.asarray(0.1, dtype),
        "u": jnp.full((n_heads, head_dim), 0.5, dtype),  # current-token bonus
        "ln_x": layers.init_rmsnorm(d_model, dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """x_{t-1} with a zero (or supplied) first token; (b, t, d)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _rkvw(params, x, xs, n_heads):
    b, t, d = x.shape
    head_dim = d // n_heads
    mix = params["mix"].astype(x.dtype)
    xr = x * mix[0] + xs * (1 - mix[0])
    xk = x * mix[1] + xs * (1 - mix[1])
    xv = x * mix[2] + xs * (1 - mix[2])
    xw = x * mix[3] + xs * (1 - mix[3])
    r = layers.matmul(xr, params["wr"]).reshape(b, t, n_heads, head_dim)
    k = layers.matmul(xk, params["wk"]).reshape(b, t, n_heads, head_dim)
    v = layers.matmul(xv, params["wv"]).reshape(b, t, n_heads, head_dim)
    g = jax.nn.silu(layers.matmul(xr, params["wg"]))
    lora = layers.matmul(
        jnp.tanh(layers.matmul(xw, params["w_lora_a"])), params["w_lora_b"]
    )
    log_w = -jnp.exp(
        jnp.clip(
            params["w_base"].astype(jnp.float32) + lora.astype(jnp.float32),
            -8.0, 4.0,
        )
    ).reshape(b, t, n_heads, head_dim)
    return r, k, v, g, log_w


def _head_groupnorm(scale: jax.Array, y: jax.Array, eps=1e-5) -> jax.Array:
    """RWKV's ln_x is a per-head GroupNorm (official impl): statistics over
    each head's channels only.  Besides faithfulness, this keeps the norm
    LOCAL under head-sharded tensor parallelism — a full-width norm forces
    an all-gather of the (b, t, d) activations every layer (measured
    584 GB/step f32 on rwkv6-7b train — §Perf iteration 7)."""
    b, t, h, hd = y.shape
    yf = y.astype(jnp.float32)
    mean = yf.mean(axis=-1, keepdims=True)
    var = ((yf - mean) ** 2).mean(axis=-1, keepdims=True)
    yn = (yf - mean) * jax.lax.rsqrt(var + eps)
    sc = (1.0 + scale.astype(jnp.float32)).reshape(h, hd)
    return (yn * sc).reshape(b, t, h * hd)


def rwkv6_timemix_fwd(
    params: Params, x: jax.Array, n_heads: int, chunk: int = 64,
    head_shard_axis=None,
) -> jax.Array:
    b, t, d = x.shape
    xs = _token_shift(x)
    r, k, v, g, log_w = _rkvw(params, x, xs, n_heads)
    if head_shard_axis is not None:
        # §Perf iteration 8: keep the WKV recurrence head-sharded (heads
        # divide the model axis for rwkv6) so GSPMD does not all-gather the
        # f32 projection outputs before the chunk scan.
        from repro.models.layers import _constrain_t

        r, k, v, log_w = (
            _constrain_t(a, 2, head_shard_axis) for a in (r, k, v, log_w)
        )
    y, _ = ssm.chunked_linear_attention(
        r, k, v, log_w, u=params["u"], chunk=chunk
    )
    y = y.astype(x.dtype)  # cast per-shard, before any resharding
    y = _head_groupnorm(params["ln_x"]["scale"], y).astype(x.dtype) * g
    return layers.matmul(y, params["wo"])


def rwkv6_init_cache(
    params: Params, batch: int, n_heads: int, dtype=jnp.float32
) -> Dict[str, jax.Array]:
    d_model = params["w_base"].shape[0]
    head_dim = d_model // n_heads
    return {
        "shift": jnp.zeros((batch, 1, d_model), dtype),
        "wkv": jnp.zeros((batch, n_heads, head_dim, head_dim), dtype),
    }


def rwkv6_timemix_decode(
    params: Params, x: jax.Array, cache: Dict[str, jax.Array], n_heads: int
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, _, d = x.shape
    xs = cache["shift"].astype(x.dtype)
    r, k, v, g, log_w = _rkvw(params, x, xs, n_heads)
    y, new_wkv = ssm.linear_attention_decode(
        r[:, 0], k[:, 0], v[:, 0], log_w[:, 0], cache["wkv"], u=params["u"]
    )
    y = y[:, None].astype(x.dtype)  # (b, 1, h, hd)
    y = _head_groupnorm(params["ln_x"]["scale"], y).astype(x.dtype) * g
    out = layers.matmul(y, params["wo"])
    return out, {"shift": x, "wkv": new_wkv}


# --------------------------------------------------------------------------
# channel-mix (RWKV's MLP with token shift)
# --------------------------------------------------------------------------


def init_rwkv6_channelmix(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mix": jnp.full((2, d_model), 0.5, dtype),
        "wk": layers.dense_init(k1, d_model, d_ff, dtype),
        "wv": layers.dense_init(k2, d_ff, d_model, dtype),
        "wr": layers.dense_init(k3, d_model, d_model, dtype),
    }


def rwkv6_channelmix_fwd(
    params: Params, x: jax.Array, prev: jax.Array | None = None
) -> jax.Array:
    xs = _token_shift(x, prev)
    mix = params["mix"].astype(x.dtype)
    xk = x * mix[0] + xs * (1 - mix[0])
    xr = x * mix[1] + xs * (1 - mix[1])
    k = jnp.square(jax.nn.relu(layers.matmul(xk, params["wk"])))
    return jax.nn.sigmoid(layers.matmul(xr, params["wr"])) * layers.matmul(
        k, params["wv"]
    )


def rwkv6_channelmix_decode(
    params: Params, x: jax.Array, shift: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    out = rwkv6_channelmix_fwd(params, x, prev=shift.astype(x.dtype))
    return out, x
