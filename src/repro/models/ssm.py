"""State-space / linear-attention machinery: chunked scan + Mamba2 block.

The common recurrence (covers Mamba2/SSD and RWKV-6) is

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          S in R^{K x V}
    y_t = r_t^T S_{t-1} + (r_t . (u . k_t)) v_t  (u-bonus form, RWKV)
or  y_t = r_t^T S_t                              (in-state form, Mamba)

with data-dependent decay ``w_t in (0,1)^K`` (per-key-dim for RWKV, scalar
per head broadcast for Mamba2).  ``chunked_linear_attention`` evaluates it in
O(T/C) sequential steps with intra-chunk matmuls (MXU-friendly; this is the
TPU adaptation of the CUDA selective-scan — see DESIGN.md).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Dict[str, Any]


def chunked_linear_attention(
    r: jax.Array,  # (b, t, h, K) receptance / C
    k: jax.Array,  # (b, t, h, K) key / B
    v: jax.Array,  # (b, t, h, V) value / dt*x
    log_w: jax.Array,  # (b, t, h, K) log decay, <= 0
    u: Optional[jax.Array] = None,  # (h, K) current-token bonus (RWKV)
    state: Optional[jax.Array] = None,  # (b, h, K, V) initial state
    chunk: int = 64,
    include_current: bool = False,  # Mamba-style y_t = r_t^T S_t
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (b,t,h,V), final_state (b,h,K,V)).  float32 internally."""
    b, t, h, K = r.shape
    V = v.shape[-1]
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        zr = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zr)
        k = jnp.pad(k, zr)
        v = jnp.pad(v, zr)
        log_w = jnp.pad(log_w, zr)  # log w = 0 -> w = 1 on padding is fine

    f32 = jnp.float32
    rc = r.astype(f32).reshape(b, n_chunks, chunk, h, K).transpose(1, 0, 3, 2, 4)
    kc = k.astype(f32).reshape(b, n_chunks, chunk, h, K).transpose(1, 0, 3, 2, 4)
    vc = v.astype(f32).reshape(b, n_chunks, chunk, h, V).transpose(1, 0, 3, 2, 4)
    lw = log_w.astype(f32).reshape(b, n_chunks, chunk, h, K).transpose(1, 0, 3, 2, 4)
    # shapes now (n_chunks, b, h, chunk, K/V)

    if state is None:
        state = jnp.zeros((b, h, K, V), f32)
    else:
        state = state.astype(f32)

    def per_chunk(S, xs):
        R, Kk, Vv, LW = xs  # (b, h, C, K/V)
        L = jnp.cumsum(LW, axis=2)  # L_t = sum_{j<=t} log w_j (incl. t)
        # readout exponent: Mamba form reads S_t (decay through w_t, use L);
        # RWKV/u form reads S_{t-1} (use L_{t-1} = L - LW).
        P = L if include_current else L - LW
        Ltot = L[:, :, -1:, :]  # (b,h,1,K)
        # inter-chunk: y1_t = (r_t . exp(P_t)) @ S
        r_in = R * jnp.exp(P)
        y1 = jnp.einsum("bhck,bhkv->bhcv", r_in, S)
        # intra-chunk: A[t,s] = sum_k r_tk k_sk exp(P_t - L_s), s < t
        k_ = Kk * jnp.exp(-L)
        A = jnp.einsum("bhck,bhdk->bhcd", r_in, k_)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        y2 = jnp.einsum("bhcd,bhdv->bhcv", A, Vv)
        y = y1 + y2
        # state update: S' = exp(Ltot) . S + sum_s (k_s exp(Ltot - L_s)) v_s^T
        k_out = Kk * jnp.exp(Ltot - L)
        S_new = jnp.exp(Ltot).transpose(0, 1, 3, 2) * S + jnp.einsum(
            "bhck,bhcv->bhkv", k_out, Vv
        )
        return S_new, y

    S_final, ys = jax.lax.scan(per_chunk, state, (rc, kc, vc, lw))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, n_chunks * chunk, h, V)
    y = y[:, :t]

    if include_current:
        y = y + jnp.einsum(
            "bthk,bthk,bthv->bthv",
            r.astype(f32)[:, :t],
            k.astype(f32)[:, :t],
            v.astype(f32)[:, :t],
        )
    elif u is not None:
        bonus = jnp.einsum(
            "bthk,hk,bthk->bth",
            r.astype(f32)[:, :t],
            u.astype(f32),
            k.astype(f32)[:, :t],
        )
        y = y + bonus[..., None] * v.astype(f32)[:, :t]
    return y, S_final


def linear_attention_decode(
    r: jax.Array,  # (b, h, K)
    k: jax.Array,
    v: jax.Array,  # (b, h, V)
    log_w: jax.Array,  # (b, h, K)
    state: jax.Array,  # (b, h, K, V)
    u: Optional[jax.Array] = None,
    include_current: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """One recurrence step; O(1) in sequence length."""
    f32 = jnp.float32
    r, k, v, log_w = (a.astype(f32) for a in (r, k, v, log_w))
    state = state.astype(f32)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    new_state = jnp.exp(log_w)[..., None] * state + kv
    if include_current:
        y = jnp.einsum("bhk,bhkv->bhv", r, new_state)
    elif u is not None:
        y = jnp.einsum("bhk,bhkv->bhv", r, state)
        y = y + jnp.einsum("bhk,hk,bhk->bh", r, u.astype(f32), k)[..., None] * v
    else:
        y = jnp.einsum("bhk,bhkv->bhv", r, state)  # strictly-past readout
    return y, new_state


# --------------------------------------------------------------------------
# Mamba2 block (SSD): scalar per-head decay a_t = exp(-softplus(dt) * A)
# --------------------------------------------------------------------------


def init_mamba2(
    key, d_model: int, d_state: int, dtype,
    expand: int = 2, head_dim: int = 64, conv_width: int = 4,
) -> Params:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 6)
    return {
        # fused input projection -> [x (d_inner), z (d_inner), B, C (d_state
        # each, shared across heads as in Mamba2), dt (n_heads)]
        "w_in": layers.dense_init(
            ks[0], d_model, 2 * d_inner + 2 * d_state + n_heads, dtype
        ),
        "conv_w": jax.random.normal(ks[1], (conv_width, d_inner), dtype)
        * jnp.asarray(0.1, dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "A_log": jnp.zeros((n_heads,), dtype),  # A = -exp(A_log)
        "D": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "norm": layers.init_rmsnorm(d_inner, dtype),
        "w_out": layers.dense_init(ks[2], d_inner, d_model, dtype),
    }


def _mamba_dims(params):
    conv_width, d_inner = params["conv_w"].shape
    n_heads = params["A_log"].shape[0]
    head_dim = d_inner // n_heads
    return conv_width, d_inner, n_heads, head_dim


def _mamba_split(params, proj, d_inner, d_state, n_heads):
    x, z, Bm, Cm, dt = jnp.split(
        proj,
        [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state],
        axis=-1,
    )
    return x, z, Bm, Cm, dt


def mamba2_fwd(
    params: Params, x_in: jax.Array, d_state: int, chunk: int = 64
) -> jax.Array:
    """Training-mode forward, (b, t, d_model) -> (b, t, d_model)."""
    b, t, _ = x_in.shape
    conv_width, d_inner, n_heads, head_dim = _mamba_dims(params)
    proj = layers.matmul(x_in, params["w_in"])
    x, z, Bm, Cm, dt = _mamba_split(params, proj, d_inner, d_state, n_heads)

    # depthwise causal conv over time
    xp = jnp.pad(x, ((0, 0), (conv_width - 1, 0), (0, 0)))
    xc = sum(
        xp[:, i : i + t] * params["conv_w"][i][None, None].astype(x.dtype)
        for i in range(conv_width)
    ) + params["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (b, t, h)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (h,)
    log_w = (dt * A[None, None, :])[..., None]  # (b, t, h, 1) broadcast over K
    log_w = jnp.broadcast_to(log_w, (b, t, n_heads, d_state))

    xh = xc.reshape(b, t, n_heads, head_dim).astype(jnp.float32)
    v = xh * dt[..., None]  # dt-scaled input
    r = jnp.broadcast_to(Cm[:, :, None, :], (b, t, n_heads, d_state))
    k = jnp.broadcast_to(Bm[:, :, None, :], (b, t, n_heads, d_state))

    y, _ = chunked_linear_attention(
        r, k, v, log_w, chunk=chunk, include_current=True
    )
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b, t, d_inner).astype(x_in.dtype)
    y = layers.rmsnorm(params["norm"], y) * jax.nn.silu(z)
    return layers.matmul(y, params["w_out"])


def mamba2_init_cache(
    params: Params, batch: int, d_state: int, dtype=jnp.float32
) -> Dict[str, jax.Array]:
    conv_width, d_inner, n_heads, head_dim = _mamba_dims(params)
    return {
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, n_heads, d_state, head_dim), dtype),
    }


def mamba2_decode(
    params: Params, x_in: jax.Array, cache: Dict[str, jax.Array], d_state: int
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode, (b, 1, d_model) -> (b, 1, d_model); O(1) state."""
    b = x_in.shape[0]
    conv_width, d_inner, n_heads, head_dim = _mamba_dims(params)
    proj = layers.matmul(x_in[:, 0], params["w_in"])
    x, z, Bm, Cm, dt = _mamba_split(params, proj, d_inner, d_state, n_heads)

    conv_buf = jnp.concatenate([cache["conv"], x[:, None]], axis=1)
    xc = jnp.einsum(
        "bcd,cd->bd", conv_buf.astype(jnp.float32),
        params["conv_w"].astype(jnp.float32),
    ) + params["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(xc).astype(x.dtype)
    new_conv = conv_buf[:, 1:]

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (b, h)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    log_w = jnp.broadcast_to(
        (dt * A[None, :])[..., None], (b, n_heads, d_state)
    )
    xh = xc.reshape(b, n_heads, head_dim).astype(jnp.float32)
    v = xh * dt[..., None]
    r = jnp.broadcast_to(Cm[:, None, :], (b, n_heads, d_state))
    k = jnp.broadcast_to(Bm[:, None, :], (b, n_heads, d_state))
    y, new_ssm = linear_attention_decode(
        r, k, v, log_w, cache["ssm"], include_current=True
    )
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, d_inner).astype(x_in.dtype)
    y = layers.rmsnorm(params["norm"], y) * jax.nn.silu(z)
    out = layers.matmul(y, params["w_out"])
    return out[:, None], {"conv": new_conv, "ssm": new_ssm}
