"""Functional building blocks shared by every architecture in the zoo.

Params are plain nested dicts of ``jnp`` arrays (no flax).  Every module is a
pair ``init_*(key, ...) -> params`` / ``*_fwd(params, x, ...) -> y`` so the
whole model is a pytree the distribution layer (and TAMUNA itself, which
masks/aggregates arbitrary pytrees) can shard leaf-by-leaf.

Conventions:
  * activations computed in ``cfg.dtype`` (bf16 by default), params stored in
    ``cfg.param_dtype`` (f32), matmuls accumulate in f32
    (``preferred_element_type``),
  * attention is GQA with optional RoPE, sliding window and logit softcap
    (covers stablelm / gemma2 / deepseek / qwen / internlm variants),
  * decode path takes a single token + KV cache slice-update.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return jax.random.normal(key, (in_dim, out_dim), dtype) * jnp.asarray(
        scale, dtype
    )


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return jax.random.normal(key, (vocab, dim), dtype) * jnp.asarray(
        0.02, dtype
    )


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """bf16-safe matmul with f32 accumulation, result cast back to x.dtype.

    (§Perf iteration 3 tried preferred_element_type=x.dtype to avoid f32
    activations in HBM; the byte proxy showed a net REGRESSION — the casts
    became separate fusion outputs — so f32 accumulation stays.  See
    EXPERIMENTS.md §Perf.)"""
    return jax.lax.dot_general(
        x,
        w.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layernorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32
    )
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


# --------------------------------------------------------------------------
# GQA attention
# --------------------------------------------------------------------------


def init_attention(
    key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int, dtype,
    qkv_bias: bool = False,
) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d_model, n_heads * head_dim, dtype),
        "wk": dense_init(k2, d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(k3, d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(k4, n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def _qkv(params, x, n_heads, n_kv_heads, head_dim):
    b, t, _ = x.shape
    q = matmul(x, params["wq"])
    k = matmul(x, params["wk"])
    v = matmul(x, params["wv"])
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, t, n_heads, head_dim)
    k = k.reshape(b, t, n_kv_heads, head_dim)
    v = v.reshape(b, t, n_kv_heads, head_dim)
    return q, k, v


def attention_scores(
    q: jax.Array,  # (b, tq, h, hd)
    k: jax.Array,  # (b, tk, kvh, hd)
    v: jax.Array,  # (b, tk, kvh, hd)
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    sliding_window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference dense attention (the Pallas decode kernel mirrors this)."""
    b, tq, h, hd = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    qg = q.reshape(b, tq, kvh, group, hd)
    logits = jnp.einsum(
        "btkgd,bskd->bkgts", qg, k, preferred_element_type=jnp.float32
    ) / math.sqrt(hd)
    logits = softcap(logits, attn_softcap)

    q_pos = jnp.arange(tq) + q_offset  # (tq,)
    k_pos = jnp.arange(tk)  # (tk,)
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if sliding_window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - sliding_window
    if kv_valid_len is not None:
        mask &= k_pos[None, :] < kv_valid_len
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(b, tq, h, hd)


def attention_fwd(
    params: Params,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float] = 10000.0,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    t_shard_axis: Optional[str] = None,
) -> jax.Array:
    b, t, _ = x.shape
    q, k, v = _qkv(params, x, n_heads, n_kv_heads, head_dim)
    if rope_theta is not None:
        pos = positions if positions is not None else jnp.arange(t)[None, :]
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    if t >= FLASH_THRESHOLD:
        out = flash_attention(
            q, k, v, causal=causal,
            window=(jnp.asarray(sliding_window)
                    if sliding_window is not None else None),
            attn_softcap=attn_softcap,
            t_shard_axis=t_shard_axis,
        )
    else:
        out = attention_scores(
            q, k, v, causal=causal, sliding_window=sliding_window,
            attn_softcap=attn_softcap,
        )
    return matmul(out.reshape(b, t, n_heads * head_dim), params["wo"])


def attention_decode(
    params: Params,
    x: jax.Array,  # (b, 1, d_model)
    cache_k: jax.Array,  # (b, S, kvh, hd)
    cache_v: jax.Array,
    pos: jax.Array,  # scalar: index where the new token goes (= cur length)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float] = 10000.0,
    sliding_window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    attend_fn=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode with in-place cache update.

    ``attend_fn(q, k, v, pos)`` may be supplied by the distribution layer to
    run the sequence-parallel (LSE-combined) or Pallas attention instead of
    the dense reference.
    """
    b = x.shape[0]
    q, k, v = _qkv(params, x, n_heads, n_kv_heads, head_dim)
    if rope_theta is not None:
        pk = jnp.full((b, 1), pos)
        q = apply_rope(q, pk, rope_theta)
        k = apply_rope(k, pk, rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    if attend_fn is not None:
        out = attend_fn(q, cache_k, cache_v, pos)
    else:
        out = attention_scores(
            q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
            causal=True, q_offset=pos, sliding_window=sliding_window,
            attn_softcap=attn_softcap, kv_valid_len=pos + 1,
        )
    y = matmul(out.reshape(b, 1, n_heads * head_dim), params["wo"])
    return y, cache_k, cache_v


# --------------------------------------------------------------------------
# chunked (flash-style) attention — §Perf iteration 1
#
# The dense reference materializes a (b, kvh, g, t, s) f32 logits tensor;
# at 32k context that is hundreds of GB and forces the SPMD partitioner
# into TB-scale all-reduces (measured: 3.96 TB/device for deepseek-33b
# prefill).  This pure-jnp flash attention scans key blocks with an online
# softmax so the working set is (t, k_chunk) per block and XLA shards it
# cleanly.  Numerics match attention_scores to ~1e-6 (tests).
# --------------------------------------------------------------------------


def _constrain_t(x: jax.Array, t_dim: int, axis: Optional[str]):
    """Shard dim ``t_dim`` over mesh axis ``axis``, everything else
    unconstrained (so the partitioner keeps batch/dp shardings).  §Perf
    iteration 2: when kv_heads < the model-axis size, GSPMD otherwise
    shards head_dim and partial-sum all-reduces the flash logits every
    key block (measured 3.7 TB/device for deepseek prefill)."""
    if axis is None:
        return x
    from jax.sharding import PartitionSpec as P

    U = P.UNCONSTRAINED
    spec = [U] * x.ndim
    spec[t_dim] = axis
    return jax.lax.with_sharding_constraint(x, P(*spec))


def flash_attention(
    q: jax.Array,  # (b, t, h, hd)
    k: jax.Array,  # (b, s, kvh, hd)
    v: jax.Array,  # (b, s, kvh, hd)
    *,
    causal: bool = True,
    window: Optional[jax.Array] = None,  # traced scalar; <=0 or None: global
    attn_softcap: Optional[float] = None,
    k_chunk: int = 1024,
    q_offset: int | jax.Array = 0,
    t_shard_axis: Optional[str] = None,
) -> jax.Array:
    b, t, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    scale = 1.0 / math.sqrt(hd)
    n_blocks = -(-s // k_chunk)
    pad = n_blocks * k_chunk - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, n_blocks, k_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blocks, k_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)

    # inputs stay in the compute dtype (bf16): MXU accumulates f32
    # internally; softmax statistics and the output accumulator are f32.
    qg = q.reshape(b, t, kvh, group, hd) * jnp.asarray(scale, q.dtype)
    qg = _constrain_t(qg, 1, t_shard_axis)
    q_pos = jnp.arange(t) + q_offset  # (t,)

    def body(carry, xs):
        m, l, acc = carry  # (b,kvh,g,t,1), (b,kvh,g,t,1), (b,kvh,g,t,hd)
        kc, vc, i = xs
        logits = jnp.einsum(
            "btkgd,bskd->bkgts", qg, kc,
            preferred_element_type=jnp.float32,
        )  # (b,kvh,g,t,k_chunk) f32
        if attn_softcap is not None:
            logits = softcap(logits, attn_softcap)
        k_pos = jnp.arange(k_chunk) + i * k_chunk
        mask = jnp.ones((t, k_chunk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < s)[None, :]  # padding
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_cur = logits.max(axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new)
        l_new = alpha * l + p.sum(axis=-1, keepdims=True)
        # p stays f32 into the PV contraction: storing a bf16 copy of p was
        # measured to ADD ~1.3 TB traffic (§Perf iteration 3, refuted).
        acc_new = alpha * acc + jnp.einsum(
            "bkgts,bskd->bkgtd", p, vc.astype(jnp.float32),
        )
        return (m_new, l_new, acc_new), None

    m0 = _constrain_t(
        jnp.full((b, kvh, group, t, 1), -1e30, jnp.float32), 3, t_shard_axis
    )
    l0 = _constrain_t(
        jnp.zeros((b, kvh, group, t, 1), jnp.float32), 3, t_shard_axis
    )
    a0 = _constrain_t(
        jnp.zeros((b, kvh, group, t, hd), jnp.float32), 3, t_shard_axis
    )
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(n_blocks))
    )
    out = acc / jnp.maximum(l, 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, t, h, hd)
    return out.astype(q.dtype)


# seq length at/above which the scanned flash path replaces the dense one
# (REPRO_DISABLE_FLASH=1 forces the dense reference — baseline measurement)
import os as _os

FLASH_THRESHOLD = (
    10**12 if _os.environ.get("REPRO_DISABLE_FLASH") == "1" else 2048
)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True) -> Params:
    if gated:
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype),
        }
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
    }


def mlp_fwd(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    if "w_gate" in params:
        return matmul(
            actf(matmul(x, params["w_gate"])) * matmul(x, params["w_up"]),
            params["w_down"],
        )
    return matmul(actf(matmul(x, params["w_up"])), params["w_down"])


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------


def chunked_softmax_xent(
    h: jax.Array,  # (b, t, d_model) final hidden states
    w_vocab: jax.Array,  # (d_model, vocab)
    labels: jax.Array,  # (b, t) int32
    *,
    chunk: int = 512,
    logit_softcap: Optional[float] = None,
    ignore_id: int = -1,
    valid_vocab: Optional[int] = None,
) -> jax.Array:
    """Mean token cross-entropy without materializing (b, t, vocab).

    Scans over sequence chunks: peak logits memory is (b, chunk, vocab).
    ``valid_vocab``: mask out padded embedding rows (> logical vocab).
    """
    b, t, d = h.shape
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=ignore_id)
    hc = h.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)  # (n, b, chunk, d)
    lc = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        tot, cnt = carry
        hx, lx = xs
        logits = jax.lax.dot_general(
            hx, w_vocab.astype(hx.dtype),
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        logits = softcap(logits, logit_softcap)
        if valid_vocab is not None and valid_vocab < logits.shape[-1]:
            vmask = jnp.arange(logits.shape[-1]) < valid_vocab
            logits = jnp.where(vmask, logits, -1e30)
        valid = lx != ignore_id
        lsafe = jnp.where(valid, lx, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, lsafe[..., None], axis=-1
        ).squeeze(-1)
        nll = jnp.where(valid, logz - gold, 0.0)
        return (
            tot + nll.sum().astype(jnp.float32),
            cnt + valid.sum().astype(jnp.int32),
        ), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (hc, lc)
    )
    return tot / jnp.maximum(cnt, 1)
