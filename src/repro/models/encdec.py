"""Whisper-style encoder-decoder transformer (arXiv:2212.04356).

Per the assignment carve-out, the audio frontend (log-mel spectrogram +
2-layer conv downsampler) is a STUB: ``input_specs`` provides precomputed
frame embeddings ``(batch, n_frames, d_model)`` and this module implements
the transformer backbone that consumes them:

  encoder : bidirectional self-attention stack over frames (sinusoidal pos)
  decoder : causal self-attention + cross-attention to encoder output

Decode supports a KV cache for the self-attention; cross-attention K/V are
precomputed once from the encoder output and kept in the cache.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.transformer import ModelConfig

Params = Dict[str, Any]


def sinusoidal_positions(t: int, d: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(
        dtype
    )


def _init_xattn_block(key, cfg: ModelConfig, cross: bool) -> Params:
    pd = cfg.param_dtype
    ks = jax.random.split(key, 4)
    p: Params = {
        "ln_attn": layers.init_layernorm(cfg.d_model, pd),
        "attn": layers.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_,
            pd, qkv_bias=True,
        ),
        "ln_ff": layers.init_layernorm(cfg.d_model, pd),
        "mlp": layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, pd, gated=False),
    }
    if cross:
        p["ln_x"] = layers.init_layernorm(cfg.d_model, pd)
        p["xattn"] = layers.init_attention(
            ks[2], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_,
            pd, qkv_bias=True,
        )
    return p


def init_encdec_params(key, cfg: ModelConfig, n_encoder_layers: int) -> Params:
    ke, kd, kt = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, n_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": layers.embed_init(
            kt, cfg.padded_vocab, cfg.d_model, cfg.param_dtype
        ),
        "enc_blocks": jax.vmap(
            lambda k: _init_xattn_block(k, cfg, cross=False)
        )(enc_keys),
        "enc_norm": layers.init_layernorm(cfg.d_model, cfg.param_dtype),
        "dec_blocks": jax.vmap(
            lambda k: _init_xattn_block(k, cfg, cross=True)
        )(dec_keys),
        "final_norm": layers.init_layernorm(cfg.d_model, cfg.param_dtype),
    }


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (b, T_frames, d_model) stub frontend output."""
    x = frames.astype(cfg.dtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model, cfg.dtype)[None]

    def body(x, bp):
        h = layers.layernorm(bp["ln_attn"], x)
        h = layers.attention_fwd(
            bp["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim_, rope_theta=None, causal=False,
            t_shard_axis=cfg.flash_t_shard_axis,
        )
        x = x + h
        h = layers.layernorm(bp["ln_ff"], x)
        x = x + layers.mlp_fwd(bp["mlp"], h, act="gelu")
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.layernorm(params["enc_norm"], x)


def _cross_attend(bp, x, enc_kv_or_out, cfg, precomputed: bool):
    b, t, _ = x.shape
    h = layers.layernorm(bp["ln_x"], x)
    q = layers.matmul(h, bp["xattn"]["wq"]) + bp["xattn"]["bq"].astype(h.dtype)
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim_)
    if precomputed:
        k, v = enc_kv_or_out
    else:
        enc = enc_kv_or_out
        te = enc.shape[1]
        k = (layers.matmul(enc, bp["xattn"]["wk"])
             + bp["xattn"]["bk"].astype(enc.dtype)).reshape(
            b, te, cfg.n_kv_heads, cfg.head_dim_
        )
        v = (layers.matmul(enc, bp["xattn"]["wv"])
             + bp["xattn"]["bv"].astype(enc.dtype)).reshape(
            b, te, cfg.n_kv_heads, cfg.head_dim_
        )
    out = layers.attention_scores(q, k, v, causal=False)
    out = out.reshape(b, t, cfg.n_heads * cfg.head_dim_)
    return layers.matmul(out, bp["xattn"]["wo"])


def decode_train(
    params: Params, cfg: ModelConfig, tokens: jax.Array, enc_out: jax.Array
) -> jax.Array:
    """Teacher-forced decoder forward; returns hidden states (b, t, d)."""
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model, cfg.dtype)[None]

    def body(x, bp):
        h = layers.layernorm(bp["ln_attn"], x)
        h = layers.attention_fwd(
            bp["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim_, rope_theta=None, causal=True,
            t_shard_axis=cfg.flash_t_shard_axis,
        )
        x = x + h
        x = x + _cross_attend(bp, x, enc_out, cfg, precomputed=False)
        h = layers.layernorm(bp["ln_ff"], x)
        x = x + layers.mlp_fwd(bp["mlp"], h, act="gelu")
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return layers.layernorm(params["final_norm"], x)


def loss_fn(
    params: Params, cfg: ModelConfig, frames: jax.Array, tokens: jax.Array,
    labels: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    enc = encode(params, cfg, frames)
    h = decode_train(params, cfg, tokens, enc)
    xent = layers.chunked_softmax_xent(
        h, params["embed"].T, labels, chunk=cfg.xent_chunk,
        valid_vocab=cfg.vocab,
    )
    return xent, {"xent": xent}


def init_cache(
    cfg: ModelConfig, batch: int, max_seq: int, n_frames: int,
    kv_dtype=jnp.bfloat16,
) -> Params:
    L = cfg.n_layers
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((L, batch, max_seq, kvh, hd), kv_dtype),
        "v": jnp.zeros((L, batch, max_seq, kvh, hd), kv_dtype),
        "xk": jnp.zeros((L, batch, n_frames, kvh, hd), kv_dtype),
        "xv": jnp.zeros((L, batch, n_frames, kvh, hd), kv_dtype),
    }


def precompute_cross_kv(
    params: Params, cfg: ModelConfig, enc_out: jax.Array, cache: Params
) -> Params:
    """Fill the cross-attention K/V entries of the cache from encoder output."""
    b, te, _ = enc_out.shape

    def per_layer(bp):
        k = (layers.matmul(enc_out, bp["xattn"]["wk"])
             + bp["xattn"]["bk"].astype(enc_out.dtype)).reshape(
            b, te, cfg.n_kv_heads, cfg.head_dim_
        )
        v = (layers.matmul(enc_out, bp["xattn"]["wv"])
             + bp["xattn"]["bv"].astype(enc_out.dtype)).reshape(
            b, te, cfg.n_kv_heads, cfg.head_dim_
        )
        return k, v

    xk, xv = jax.vmap(per_layer)(params["dec_blocks"])
    return {**cache, "xk": xk.astype(cache["xk"].dtype),
            "xv": xv.astype(cache["xv"].dtype)}


def decode_step(
    params: Params, cfg: ModelConfig, token: jax.Array, cache: Params,
    pos: jax.Array,
) -> Tuple[jax.Array, Params]:
    b = token.shape[0]
    x = params["embed"].astype(cfg.dtype)[token]
    # single-position sinusoid computed directly from the scalar pos
    dim = jnp.arange(cfg.d_model // 2, dtype=jnp.float32)
    angle = pos.astype(jnp.float32) / jnp.power(
        10000.0, 2 * dim / cfg.d_model
    )
    pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)]).astype(cfg.dtype)
    x = x + pe[None, None, :]

    def body(x, xs):
        bp, ck, cv, xk, xv = xs
        h = layers.layernorm(bp["ln_attn"], x)
        h, ck, cv = layers.attention_decode(
            bp["attn"], h, ck, cv, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim_, rope_theta=None,
        )
        x = x + h
        x = x + _cross_attend(
            bp, x, (xk.astype(x.dtype), xv.astype(x.dtype)), cfg,
            precomputed=True,
        )
        h = layers.layernorm(bp["ln_ff"], x)
        x = x + layers.mlp_fwd(bp["mlp"], h, act="gelu")
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec_blocks"], cache["k"], cache["v"], cache["xk"],
         cache["xv"]),
    )
    h = layers.layernorm(params["final_norm"], x)[:, 0]
    logits = jax.lax.dot_general(
        h, params["embed"].T.astype(h.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, : cfg.vocab]
    return logits, {**cache, "k": ks, "v": vs}
