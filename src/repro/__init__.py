"""repro: TAMUNA (Condat et al., 2023) as a production-grade multi-pod JAX
training/serving framework.

Subpackages:
  core      the paper's algorithm + baselines + theory (convex reproduction)
  models    functional model zoo (dense/GQA, MoE, Mamba2, RWKV-6, enc-dec)
  configs   the 10 assigned architectures + input shapes + input_specs
  dist      sharding rules, TAMUNA-DP trainer, blocked uplink, model API
  kernels   Pallas TPU kernels (compress, local step, flash-decode attention)
  data      synthetic per-client pipeline
  optim     SGD / momentum / AdamW
  launch    mesh, multi-pod dry-run, train and serve drivers
"""

__version__ = "1.0.0"

from repro import _compat as _compat  # installs jax forward-compat shims
