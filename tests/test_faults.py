"""Fault-tolerant rounds (DESIGN.md §12):

* ``FaultPlan`` draws are deterministic, replayable, and query-order
  independent; attempt-0 streams key exactly as the pre-fault code,
* survivor-aware aggregation matches a numpy reference for every impl
  and both templates; a NaN payload on a dropped row never leaks into
  arrived rows; an all-dropped round leaves x and h bitwise untouched,
* zero-fault ``arrived=None`` is the identical program (bitwise) and an
  all-True arrived mask matches to float roundoff,
* ``MarkovAvailability.states`` is the unique trajectory of its seed —
  any query order returns identical states (property test),
* atomic checkpointing: a crashed save leaves no partial checkpoint
  where ``latest_step`` would find it; leaf-mismatch errors name paths,
* e2e through ``run_rounds``: NaN corruption mid-run ends with a finite
  model and a quarantine window; the zero-fault plan under ``wait_all``
  is bitwise identical to the legacy driver on BOTH uplinks.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import checkpoint
from repro.dist import comm_ws
from repro.dist.cohort import CohortPlan, MarkovAvailability
from repro.dist.faults import FaultModel, FaultPlan, corrupt_rows, \
    nonfinite_clients


# --------------------------------------------------------------------------
# FaultPlan: determinism, replay, zero plan
# --------------------------------------------------------------------------


def test_fault_plan_deterministic_and_order_independent():
    m = FaultModel(p_drop=0.3, p_corrupt=0.2, delay_sigma=0.4,
                   straggler_frac=0.25)
    a = FaultPlan(seed=5, n=32, model=m)
    b = FaultPlan(seed=5, n=32, model=m)
    # query b backwards, a forwards: same draws
    for rnd in range(8):
        rb = 7 - rnd
        np.testing.assert_array_equal(a.drops(rnd), b.drops(rnd))
        np.testing.assert_array_equal(a.corrupts(rb), b.corrupts(rb))
        np.testing.assert_array_equal(a.delays(rnd), b.delays(rnd))
    # attempts draw fresh, deterministic streams
    assert not np.array_equal(a.drops(3), a.drops(3, attempt=1))
    np.testing.assert_array_equal(a.drops(3, attempt=1),
                                  b.drops(3, attempt=1))


def test_fault_plan_zero_and_rates():
    z = FaultPlan.zero(16)
    assert z.is_zero
    assert not z.drops(0).any() and not z.corrupts(5).any()
    p = FaultPlan(seed=1, n=2000, model=FaultModel(p_drop=0.2))
    assert not p.is_zero
    frac = np.mean([p.drops(r).mean() for r in range(20)])
    assert abs(frac - 0.2) < 0.03
    # stragglers: persistent per-client base latency
    ps = FaultPlan(seed=2, n=64,
                   model=FaultModel(straggler_frac=0.25,
                                    straggler_scale=10.0))
    base = ps.base_delays
    assert (base > 5.0).sum() >= 8  # ~16 stragglers at 10x
    np.testing.assert_array_equal(base, FaultPlan(
        seed=2, n=64, model=ps.model).base_delays)


def test_nonfinite_clients_and_corrupt_rows():
    tree = {"a": jnp.ones((6, 4)), "b": jnp.ones((6, 2, 3))}
    mask = jnp.asarray([True, False, False, True, False, False])
    for mode in ("nan", "inf", "blowup"):
        bad_tree = corrupt_rows(tree, mask, mode=mode, blowup=1e8)
        bad = nonfinite_clients(bad_tree, max_abs=1e6)
        np.testing.assert_array_equal(np.asarray(bad), np.asarray(mask))
        # untouched rows bit-exact
        for k in tree:
            np.testing.assert_array_equal(
                np.asarray(bad_tree[k])[~np.asarray(mask)],
                np.asarray(tree[k])[~np.asarray(mask)])
    clean = nonfinite_clients(tree)
    assert not np.asarray(clean).any()


# --------------------------------------------------------------------------
# survivor-aware aggregation: numpy reference, all impls, both templates
# --------------------------------------------------------------------------


def _mk_state(n, d, seed):
    k = jax.random.key(seed)
    x = {"p": jax.random.normal(k, (n, d), jnp.float32)}
    h = {"p": 0.1 * jax.random.normal(jax.random.fold_in(k, 1), (n, d),
                                      jnp.float32)}
    return x, h


def _np_survivor(x, h, slot, c, s, scale, arrived, template, off=0):
    """Per-coordinate arrived-owner mean; uncovered coords untouched."""
    n, d = x.shape
    owned = np.zeros((n, d), bool)
    for i in range(n):
        if slot[i] < 0 or not arrived[i]:
            continue
        j = slot[i]
        if template == "cyclic":
            if d * s < c:  # tall: column j covers coord j % d once
                if j < d * s:
                    owned[i, j % d] = True
            else:
                band = (np.arange(d) * s) // max(d, 1) if False else None
                # band table: coordinate k owned by slots
                # [k*s//d... ] — use the wrapped-interval rule
                kk = np.arange(d)
                start = (kk.astype(np.int64) * s) // d if False else None
                # replicate comm_ws table: cols[t, k] = (k*s + t) ... the
                # simplest equivalent: slot j owns coord k iff
                # (j - band_k) mod c < s with band_k = floor(k*c/d)? Use
                # brute force via comm_ws dense reference instead.
                raise RuntimeError("use dense reference")
        else:
            m = c  # blocked over c slots
            chunk = -(-d // m)
            for t in range(s):
                blk = (j + off + t) % m
                owned[i, blk * chunk:min((blk + 1) * chunk, d)] = True
    num = (np.where(owned, x, 0.0)).sum(axis=0)
    cnt = owned.sum(axis=0)
    covered = cnt > 0
    x_bar = np.where(covered, num / np.maximum(cnt, 1), 0.0)
    x_new = np.where(covered[None, :], x_bar[None, :], x)
    h_new = h + scale * np.where(owned, x_bar[None, :] - x, 0.0)
    return x_new, h_new, covered


@given(st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_blocked_survivor_matches_numpy_reference(seed):
    rng = np.random.default_rng(seed)
    n, d = 8, 20
    c = int(rng.integers(2, n + 1))
    s = int(rng.integers(2, c + 1))
    off = int(rng.integers(0, c))
    x, h = _mk_state(n, d, seed)
    ids = np.sort(rng.choice(n, c, replace=False))
    slot = np.full(n, -1, np.int64)
    slot[ids] = np.arange(c)
    arrived = rng.random(n) < 0.6
    xr, hr, cov = _np_survivor(np.asarray(x["p"]), np.asarray(h["p"]),
                               slot, c, s, 0.5, arrived, "blocked", off)
    # dense DownCom target: every row (down=None broadcasts)
    for impl in ("dense", "ws", "pallas"):
        xn, hn = comm_ws.blocked_comm(
            x, h, jnp.asarray(off), n, s, 0.5, impl=impl, c=c,
            slot_of=jnp.asarray(slot, jnp.int32),
            arrived=jnp.asarray(arrived),
        )
        np.testing.assert_allclose(np.asarray(xn["p"]), xr, atol=2e-6,
                                   err_msg=impl)
        np.testing.assert_allclose(np.asarray(hn["p"]), hr, atol=2e-6,
                                   err_msg=impl)


@given(st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_cyclic_survivor_impls_agree_and_isolate_nan(seed):
    rng = np.random.default_rng(seed)
    n, d = 8, 12
    c = int(rng.integers(2, n + 1))
    s = int(rng.integers(2, c + 1))
    x, h = _mk_state(n, d, seed)
    ids = np.sort(rng.choice(n, c, replace=False))
    slot = np.full(n, -1, np.int64)
    slot[ids] = rng.permutation(c)
    arrived = rng.random(n) < 0.6
    # poison one non-arrived cohort row: must never leak
    dropped = [i for i in ids if not arrived[i]]
    if dropped:
        x["p"] = x["p"].at[dropped[0]].set(jnp.nan)
    outs = {}
    for impl in ("dense", "ws", "pallas"):
        outs[impl] = comm_ws.cyclic_comm(
            x, h, jnp.asarray(slot, jnp.int32), c, s, 0.5, impl=impl,
            arrived=jnp.asarray(arrived),
        )
        for t in outs[impl]:
            a = np.asarray(t["p"])
            assert np.isfinite(a[np.asarray(arrived)]).all(), impl
    for impl in ("ws", "pallas"):
        for k in range(2):
            a = np.asarray(outs["dense"][k]["p"])
            b = np.asarray(outs[impl][k]["p"])
            fin = np.isfinite(a)
            np.testing.assert_array_equal(fin, np.isfinite(b))
            np.testing.assert_allclose(a[fin], b[fin], atol=2e-6,
                                       err_msg=impl)


def test_all_dropped_round_is_a_no_op():
    n, d, c, s = 6, 10, 4, 2
    x, h = _mk_state(n, d, 3)
    slot = np.full(n, -1, np.int64)
    slot[:c] = np.arange(c)
    none = jnp.zeros((n,), bool)
    for impl in ("dense", "ws", "pallas"):
        xn, hn = comm_ws.cyclic_comm(
            x, h, jnp.asarray(slot, jnp.int32), c, s, 0.5, impl=impl,
            arrived=none)
        np.testing.assert_array_equal(np.asarray(xn["p"]),
                                      np.asarray(x["p"]), err_msg=impl)
        np.testing.assert_array_equal(np.asarray(hn["p"]),
                                      np.asarray(h["p"]), err_msg=impl)


def test_zero_fault_arrival_mask_matches_baseline():
    n, d, c, s = 8, 12, 5, 3
    x, h = _mk_state(n, d, 9)
    slot = np.full(n, -1, np.int64)
    slot[np.sort(np.random.default_rng(0).choice(n, c, False))] = \
        np.arange(c)
    slot_j = jnp.asarray(slot, jnp.int32)
    allt = jnp.ones((n,), bool)
    for impl, tol in (("dense", 0.0), ("ws", 0.0), ("pallas", 1e-6)):
        base = comm_ws.cyclic_comm(x, h, slot_j, c, s, 0.5, impl=impl)
        filt = comm_ws.cyclic_comm(x, h, slot_j, c, s, 0.5, impl=impl,
                                   arrived=allt)
        for k in range(2):
            a, b = np.asarray(base[k]["p"]), np.asarray(filt[k]["p"])
            if tol == 0.0:
                # bitwise: the survivor mean over ALL owners is num/cnt
                # with cnt == s exactly
                np.testing.assert_array_equal(a, b, err_msg=impl)
            else:
                # the pallas counts kernel reassociates the reduction
                # (<= 1 ulp) — which is why the driver passes
                # arrived=None outright for a zero-fault plan
                np.testing.assert_allclose(a, b, atol=tol, err_msg=impl)


# --------------------------------------------------------------------------
# MarkovAvailability: replay determinism (property)
# --------------------------------------------------------------------------


@given(st.integers(0, 2**16), st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_markov_states_query_order_independent(seed, qseed):
    qrng = np.random.default_rng(qseed)
    queries = qrng.integers(0, 41, size=int(qrng.integers(1, 13))).tolist()
    mk = lambda: MarkovAvailability(p_fail=0.3, p_recover=0.5, n=16,
                                    seed=seed)
    a, b = mk(), mk()
    fwd = {r: np.asarray(a.states(r)) for r in sorted(set(queries))}
    for r in queries:  # arbitrary (repeated, unsorted) order
        np.testing.assert_array_equal(np.asarray(b.states(r)), fwd[r])
    # a third instance queried at only the max round agrees too
    mx = max(queries)
    np.testing.assert_array_equal(np.asarray(mk().states(mx)), fwd[mx])


def test_cohort_plan_attempts_and_quarantine():
    plan = CohortPlan(seed=3, n=16, c=4)
    c0 = plan.cohort(5)
    np.testing.assert_array_equal(c0, CohortPlan(seed=3, n=16,
                                                 c=4).cohort(5))
    c1 = plan.cohort(5, attempt=1)
    assert not np.array_equal(c0, c1)
    # quarantined clients are excluded while healthy clients suffice
    victim = int(plan.cohort(7)[0])
    plan.quarantine([victim], 7, 9)
    for r in (7, 8, 9):
        assert victim not in plan.cohort(r)
    assert victim in CohortPlan(seed=3, n=16, c=4).cohort(7)


# --------------------------------------------------------------------------
# atomic checkpointing
# --------------------------------------------------------------------------


def test_checkpoint_atomic_crash_leaves_nothing(tmp_path, monkeypatch):
    tree = {"w": jnp.arange(6.0), "b": jnp.ones((2, 3))}
    root = tmp_path / "ckpt"
    path = str(root / "step_4")
    # crash mid-save: meta write explodes after the npz landed in staging
    real_dump = json.dump

    def boom(*a, **k):
        raise OSError("disk gone")

    monkeypatch.setattr(json, "dump", boom)
    with pytest.raises(OSError):
        checkpoint.save(path, tree, 4)
    monkeypatch.setattr(json, "dump", real_dump)
    assert not os.path.exists(path)
    assert checkpoint.latest_step(str(root)) is None
    leftovers = [d for d in os.listdir(root)] if root.is_dir() else []
    assert leftovers == []  # staging dir cleaned up
    # a real save then works and round-trips
    checkpoint.save(path, tree, 4)
    like = jax.tree.map(jnp.zeros_like, tree)
    got = checkpoint.restore(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint.latest_step(str(root)) == 4


def test_checkpoint_save_replaces_existing(tmp_path):
    path = str(tmp_path / "step_1")
    checkpoint.save(path, {"w": jnp.zeros(3)}, 1)
    checkpoint.save(path, {"w": jnp.ones(3)}, 1)  # overwrite, atomically
    got = checkpoint.restore(path, {"w": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.ones(3))


def test_checkpoint_leaf_mismatch_names_paths(tmp_path):
    path = str(tmp_path / "step_2")
    checkpoint.save(path, {"w": jnp.zeros(3), "extra": jnp.zeros(2)}, 2)
    with pytest.raises(ValueError) as ei:
        checkpoint.restore(path, {"w": jnp.zeros(3),
                                  "missing": jnp.zeros(4),
                                  "also": jnp.zeros(1)})
    msg = str(ei.value)
    assert "'extra'" in msg and "'missing'" in msg and "'also'" in msg
    # shape mismatch names the leaf too
    with pytest.raises(ValueError, match="leaf"):
        checkpoint.restore(path, {"w": jnp.zeros(5),
                                  "extra": jnp.zeros(2)})


# --------------------------------------------------------------------------
# e2e: run_rounds under faults
# --------------------------------------------------------------------------

_E2E_SETUP = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import cohort as cm
from repro.dist import rounds, sharding, tamuna_dp
from repro.dist.faults import FaultPlan, FaultModel

mesh = jax.make_mesh((4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
dcfg = DataConfig(seq_len=8, per_client_batch=1, vocab=64, seed=0,
                  n_clients=n)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
sampler = device_sampler(dcfg, cfg, mesh)


def build(uplink, elastic=True):
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=2, s=2, p=0.5,
                                      uplink=uplink)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      tamuna_dp.state_pspecs(state, cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, sh)
    round_fn = rounds.make_round_fn(cfg, tcfg, mesh, sample_batch=sampler,
                                    max_L=4, elastic=elastic)
    return tcfg, state, round_fn


def drive(uplink, elastic=True, **kw):
    tcfg, state, round_fn = build(uplink, elastic)
    return rounds.run_rounds(
        state, round_fn=round_fn, data=pipe.device_data(),
        key=jax.random.key(3), rounds=4, rng=np.random.default_rng(0),
        p=tcfg.p, flush_every=2, **kw)
"""


def test_zero_fault_plan_bitwise_identical_both_uplinks(subproc):
    subproc(_E2E_SETUP + r"""
for uplink in ("masked_psum", "block_rs"):
    for elastic in (True, False):  # cohort-gathered AND all-rows bodies
        plan = cm.CohortPlan(seed=17, n=n, c=2)
        legacy, _ = drive(uplink, elastic, plan=plan)
        plan = cm.CohortPlan(seed=17, n=n, c=2)
        faulted, last = drive(uplink, elastic, plan=plan,
                              faults=FaultPlan.zero(n), policy="wait_all")
        for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(faulted)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert "arrivals" not in last  # legacy path: identical program
print("OK")
""", devices=4, timeout=1500)


def test_nan_corruption_guard_and_quarantine_e2e(subproc):
    subproc(_E2E_SETUP + r"""
fp = FaultPlan(seed=9, n=n,
               model=FaultModel(p_drop=0.0, p_corrupt=0.5,
                                corrupt_mode="nan"))
assert any(fp.corrupts(g).any() for g in range(4))
plan = cm.CohortPlan(seed=17, n=n, c=2)

class Rows:
    def __init__(self):
        self.rows = []
    def log(self, step, m):
        self.rows.append(dict(m))

logger = Rows()
tcfg, state, round_fn = build("masked_psum")
final, last = rounds.run_rounds(
    state, round_fn=round_fn, data=pipe.device_data(),
    key=jax.random.key(3), rounds=4, rng=np.random.default_rng(0),
    p=tcfg.p, flush_every=2, logger=logger, plan=plan, faults=fp,
    policy="quorum", quorum=1, quarantine_rounds=2)
# the guard caught corrupted payloads and the model stayed finite
assert sum(r["corrupted"] for r in logger.rows) > 0
for leaf in jax.tree.leaves(final.x):
    assert np.isfinite(np.asarray(leaf)).all()
for leaf in jax.tree.leaves(final.h):
    assert np.isfinite(np.asarray(leaf)).all()
# quarantine windows recorded against the plan
assert len(plan._quarantine) > 0
ids, first, lastr = plan._quarantine[0]
for r in range(first, lastr + 1):
    assert not set(ids.tolist()) & set(plan.cohort(r).tolist())
print("OK")
""", devices=4, timeout=1500)


def test_dropout_quorum_e2e_metrics(subproc):
    subproc(_E2E_SETUP + r"""
fp = FaultPlan(seed=5, n=n, model=FaultModel(p_drop=0.4))

class Rows:
    def __init__(self):
        self.rows = []
    def log(self, step, m):
        self.rows.append(dict(m))

logger = Rows()
plan = cm.CohortPlan(seed=17, n=n, c=2)
final, last = drive("masked_psum", plan=plan, faults=fp, policy="quorum",
                    quorum=2, max_retries=3, logger=logger)
assert len(logger.rows) == 4
for r in logger.rows:
    assert 0 <= r["arrivals"] <= 2
    assert r["retries"] >= 0 and r["round_latency_s"] >= 0.0
# quorum held wherever retries sufficed
held = [r for r in logger.rows if r["quorum_miss"] < 3]
assert any(r["arrivals"] >= 2 for r in held)
for leaf in jax.tree.leaves(final):
    assert np.isfinite(np.asarray(leaf)).all()
print("OK")
""", devices=4, timeout=1500)
