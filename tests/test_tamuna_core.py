"""Convergence tests for the paper-faithful federated core (Theorem 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, problems, tamuna, theory


@pytest.fixture(scope="module")
def quad():
    return problems.make_quadratic_problem(n=16, d=32, kappa=50)


def test_linear_convergence_to_exact_solution(quad):
    cfg = tamuna.TamunaConfig.tuned(quad, c=8)
    tr = tamuna.run(quad, cfg, num_rounds=2000, record_every=200)
    assert tr["suboptimality"][-1] < 1e-9


def test_empirical_rate_respects_theorem1(quad):
    cfg = tamuna.TamunaConfig.tuned(quad, c=8)
    chi = cfg.eta / cfg.p
    tau = theory.theorem1_rate(
        cfg.gamma, quad.mu, quad.L, cfg.p, chi, quad.n, cfg.s
    )
    tr = tamuna.run(quad, cfg, num_rounds=1500, record_every=100)
    ly, st = tr["lyapunov"], tr["local_steps"]
    emp = (ly[-1] / ly[2]) ** (1.0 / (st[-1] - st[2]))
    assert emp <= tau * 1.03, (emp, tau)


def test_control_variate_sum_invariant(quad):
    cfg = tamuna.TamunaConfig.tuned(quad, c=6)
    tr = tamuna.run(quad, cfg, num_rounds=50)
    h = tr["state"].h
    assert float(jnp.abs(h.sum(axis=0)).max()) < 1e-8


def test_control_variates_converge_to_grad_at_optimum(quad):
    cfg = tamuna.TamunaConfig.tuned(quad, c=quad.n)
    tr = tamuna.run(quad, cfg, num_rounds=2500, record_every=500)
    h_err = float(jnp.abs(tr["state"].h - quad.h_star()).max())
    assert h_err < 1e-4, h_err


def test_partial_participation_levels(quad):
    # converges with as few as 2 active clients (paper: any c >= 2)
    for c in (2, 4, 16):
        cfg = tamuna.TamunaConfig.tuned(quad, c=c)
        tr = tamuna.run(quad, cfg, num_rounds=600, record_every=600)
        assert tr["suboptimality"][-1] < 1.0, (c, tr["suboptimality"][-1])


def test_sigma_noise_converges_to_neighborhood(quad):
    cfg = tamuna.TamunaConfig.tuned(quad, c=8, sigma=0.05)
    tr = tamuna.run(quad, cfg, num_rounds=800, record_every=100)
    tail = tr["suboptimality"][-4:]
    assert tail.max() < 1e-2  # noise floor, not divergence
    cfg0 = tamuna.TamunaConfig.tuned(quad, c=8)
    tr0 = tamuna.run(quad, cfg0, num_rounds=800, record_every=100)
    assert tr0["suboptimality"][-1] < tail.min()  # exact < noisy floor


def test_no_compression_mode_is_valid(quad):
    # s = c disables compression (paper Table 3); still converges
    cfg = tamuna.TamunaConfig.tuned(quad, c=8, s=8)
    tr = tamuna.run(quad, cfg, num_rounds=500, record_every=500)
    assert tr["suboptimality"][-1] < 1e-3


def test_blocked_mask_variant(quad):
    cfg = tamuna.TamunaConfig.tuned(quad, c=8, blocked_mask=True)
    tr = tamuna.run(quad, cfg, num_rounds=1200, record_every=400)
    assert tr["suboptimality"][-1] < 1e-6


def test_fixed_L_rule_of_thumb(quad):
    # Remark 2: replace p by 1/L with fixed round lengths.  Periodic
    # communication converges more slowly than geometric (the theory's
    # randomness matters), but still linearly.
    cfg = tamuna.TamunaConfig.tuned(quad, c=8, geometric_L=False)
    tr = tamuna.run(quad, cfg, num_rounds=3000, record_every=1000)
    assert tr["suboptimality"][-1] < 1e-4


def test_logreg_problem_converges():
    prob = problems.make_logreg_problem(
        n=16, d=40, samples_per_client=8, kappa=100.0, seed=1
    )
    assert prob.f_star is not None and prob.x_star is not None
    # Newton solution is a stationary point
    g = prob.grad(prob.x_star)
    assert float(jnp.abs(g).max()) < 1e-8
    cfg = tamuna.TamunaConfig.tuned(prob, c=8)
    tr = tamuna.run(prob, cfg, num_rounds=1500, record_every=500)
    assert tr["suboptimality"][-1] < 1e-8


def test_communication_accounting(quad):
    cfg = tamuna.TamunaConfig.tuned(quad, c=8)
    tr = tamuna.run(quad, cfg, num_rounds=10)
    per_round_up = tr["up_floats"][-1] / 10
    assert per_round_up == max(1, -(-cfg.s * quad.d // cfg.c))
    assert tr["down_floats"][-1] == 10 * quad.d
