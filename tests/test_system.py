"""End-to-end system tests: the real drivers, run as a user would run them."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _run(args, devices=8, timeout=1200, xla_flags=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if xla_flags is not None:
        env["XLA_FLAGS"] = xla_flags
    else:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    proc = subprocess.run(
        [sys.executable] + args, capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, (
        f"cmd {args} failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    )
    return proc.stdout + proc.stderr


def test_train_driver_end_to_end(tmp_path):
    out = _run([
        "-m", "repro.launch.train", "--arch", "gemma2-2b", "--reduced",
        "--rounds", "6", "--seq-len", "48", "--per-client-batch", "2",
        "--data-parallel", "4", "--model-parallel", "2",
        "--log", str(tmp_path / "m.csv"),
        "--checkpoint-dir", str(tmp_path / "ckpt"), "--checkpoint-every", "3",
    ])
    assert "final loss" in out
    assert (tmp_path / "m.csv").exists()
    assert (tmp_path / "ckpt" / "step_6").exists()


def test_train_driver_block_rs_partial_participation(tmp_path):
    """The blocked uplink at c < n end to end (ISSUE 5 acceptance), with
    the client population decoupled from the mesh (--clients 8 on 4 data
    shards: 2 stacked client rows per shard) — the elastic engine trains
    only the cohort and the blocked bands lie over its slots."""
    out = _run([
        "-m", "repro.launch.train", "--arch", "gemma2-2b", "--reduced",
        "--rounds", "4", "--seq-len", "32", "--per-client-batch", "1",
        "--data-parallel", "4", "--model-parallel", "1",
        "--clients", "8", "--cohort", "4", "--uplink", "block_rs",
        "--log", str(tmp_path / "m.csv"),
    ], devices=4)
    assert "final loss" in out
    assert (tmp_path / "m.csv").exists()


def test_train_driver_no_fuse_elastic(tmp_path):
    """The per-step escape hatch under the elastic gate: the gathered
    compact state shares no buffers with the donated step in a way that
    deletes the full state's scalars (regression — the first cut crashed
    comm_step with 'Array has been deleted')."""
    out = _run([
        "-m", "repro.launch.train", "--arch", "gemma2-2b", "--reduced",
        "--rounds", "2", "--seq-len", "32", "--per-client-batch", "1",
        "--data-parallel", "1", "--model-parallel", "1",
        "--clients", "4", "--cohort", "2", "--no-fuse",
    ], devices=1)
    assert "final loss" in out


def test_serve_driver_end_to_end():
    out = _run([
        "-m", "repro.launch.serve", "--arch", "rwkv6-7b", "--reduced",
        "--batch", "2", "--prompt-len", "8", "--gen-len", "4",
        "--data-parallel", "2", "--model-parallel", "2",
    ], devices=4)
    assert "sample continuations" in out


@pytest.mark.slow
def test_dryrun_single_pair_production_mesh(tmp_path):
    """One real production-mesh dry-run (512 host devices) as a gate; the
    full 40x2 sweep runs via `python -m repro.launch.dryrun --all`."""
    out = _run(
        ["-m", "repro.launch.dryrun", "--arch", "whisper-tiny",
         "--shape", "decode_32k", "--out-dir", str(tmp_path)],
        xla_flags="",  # dryrun sets its own device count
        timeout=1800,
    )
    assert "all combinations lowered + compiled OK" in out
    rec = json.load(open(
        tmp_path / "pod16x16" / "whisper-tiny" / "decode_32k" / "decode.json"
    ))
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["cost_analysis"]["flops"] > 0
