"""Benchmark tooling cannot rot: ``benchmarks/run.py --smoke`` executes
the comm-step bench end to end at tiny shapes (both subprocesses: the
single-device sweep and the 2-device meshed sweep with the shard-resident
engine) and the elastic cohort-gather bench, without touching the
measured BENCH_*.json artifacts, and ``benchmarks/report.py`` renders the
perf-trajectory table over every artifact in the repo root."""

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_run_smoke_comm_step_emits_rows_and_preserves_artifact(subproc):
    guarded = [
        os.path.join(REPO, "BENCH_comm_step.json"),
        os.path.join(REPO, "benchmarks", "artifacts", "results.json"),
    ]
    before = {
        p: os.path.getmtime(p) for p in guarded if os.path.exists(p)
    }
    out = subproc("""
import sys
sys.path.insert(0, ".")
from benchmarks import run
rc = run.main(["--smoke", "--only", "comm_step"])
assert rc == 0
""", devices=1, timeout=1500)
    # CSV rows from both placements, including the shard-engine column
    assert "comm_step/n2/masked_psum/ws," in out, out[-2000:]
    assert "comm_step_meshed/n2/masked_psum/shard," in out, out[-2000:]
    assert "speedup_shard_vs_ws" in out
    for p, mtime in before.items():
        assert os.path.getmtime(p) == mtime, \
            f"--smoke must not overwrite the measured artifact {p}"


def test_run_smoke_elastic_emits_rows_and_preserves_artifact(subproc):
    guarded = [
        os.path.join(REPO, "BENCH_elastic.json"),
        os.path.join(REPO, "benchmarks", "artifacts", "results.json"),
    ]
    before = {
        p: os.path.getmtime(p) for p in guarded if os.path.exists(p)
    }
    out = subproc("""
import sys
sys.path.insert(0, ".")
from benchmarks import run
rc = run.main(["--smoke", "--only", "elastic"])
assert rc == 0
""", devices=1, timeout=1500)
    # both variants and the acceptance column, for both uplinks, at a
    # partial cohort (n=4, c=2 in smoke mode)
    assert "elastic/n4/c2/masked_psum/gather," in out, out[-2000:]
    assert "elastic/n4/c2/block_rs/allrows," in out, out[-2000:]
    assert "speedup_gather_vs_allrows" in out
    for p, mtime in before.items():
        assert os.path.getmtime(p) == mtime, \
            f"--smoke must not overwrite the measured artifact {p}"


def test_run_smoke_faults_emits_rows_and_preserves_artifact(subproc):
    guarded = [
        os.path.join(REPO, "BENCH_faults.json"),
        os.path.join(REPO, "benchmarks", "artifacts", "results.json"),
    ]
    before = {
        p: os.path.getmtime(p) for p in guarded if os.path.exists(p)
    }
    out = subproc("""
import sys
sys.path.insert(0, ".")
from benchmarks import run
rc = run.main(["--smoke", "--only", "faults"])
assert rc == 0
""", devices=1, timeout=1500)
    # the fault-free reference, both drivers at the dropout rate, and the
    # acceptance summary row
    assert "faults/p0.0/fault_free," in out, out[-2000:]
    assert "faults/p0.2/quorum," in out, out[-2000:]
    assert "faults/p0.2/wait_all," in out, out[-2000:]
    assert "faults/quorum_ratio_at_p02," in out
    assert "replay_ok=True" in out
    for p, mtime in before.items():
        assert os.path.getmtime(p) == mtime, \
            f"--smoke must not overwrite the measured artifact {p}"


def test_run_smoke_quant_comm_emits_rows_and_preserves_artifact(subproc):
    guarded = [
        os.path.join(REPO, "BENCH_quant_comm.json"),
        os.path.join(REPO, "benchmarks", "artifacts", "results.json"),
    ]
    before = {
        p: os.path.getmtime(p) for p in guarded if os.path.exists(p)
    }
    out = subproc("""
import sys
sys.path.insert(0, ".")
from benchmarks import run
rc = run.main(["--smoke", "--only", "quant_comm"])
assert rc == 0
""", devices=1, timeout=1500)
    # byte accounting per policy, the headline reduction ratio, fused-
    # round timing for both widths, and the convergence floor rows
    assert "quant_comm/bytes/f32," in out, out[-2000:]
    assert "quant_comm/bytes/int8," in out, out[-2000:]
    assert "quant_comm/bytes/auto," in out, out[-2000:]
    assert "quant_comm/up_bytes_ratio_int8_vs_f32," in out
    assert "quant_comm/round/f32," in out
    assert "quant_comm/round/int8," in out
    assert "quant_comm/floor/int8," in out
    assert "quant_comm/floor_ratio_int8_vs_f32," in out
    for p, mtime in before.items():
        assert os.path.getmtime(p) == mtime, \
            f"--smoke must not overwrite the measured artifact {p}"


def test_trajectory_table_aggregates_artifacts():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks import report

    table = report.trajectory_table()
    # artifacts shipped in the repo root all appear with their acceptance
    assert "dist_round" in table
    assert "round_engine" in table
    assert "comm_step" in table
    assert "faults" in table
    assert "| acceptance |" in table.splitlines()[0].replace(
        " ok |", " ok |")  # header shape
    rows = report.trajectory_rows()
    assert all(len(r) == 5 for r in rows)
    # the table is what EXPERIMENTS links; a failing acceptance shows NO
    assert all(isinstance(r[4], bool) for r in rows)


def test_run_smoke_pipeline_emits_rows_and_preserves_artifacts(subproc):
    guarded = [
        os.path.join(REPO, "BENCH_pipeline.json"),
        os.path.join(REPO, "benchmarks", "artifacts", "latency_dist.json"),
        os.path.join(REPO, "benchmarks", "artifacts", "results.json"),
    ]
    before = {
        p: os.path.getmtime(p) for p in guarded if os.path.exists(p)
    }
    out = subproc("""
import sys
sys.path.insert(0, ".")
from benchmarks import run
rc = run.main(["--smoke", "--only", "pipeline"])
assert rc == 0
""", devices=1, timeout=1500)
    # the sync baseline clock, the overlapped tau=1 clock, and the
    # headline acceptance row
    assert "pipeline/n8/c2/tau0/wait_all/clock_s," in out, out[-2000:]
    assert "pipeline/n8/c2/tau1/wait_all/clock_s," in out, out[-2000:]
    assert "pipeline/speedup_at_tail," in out
    for p, mtime in before.items():
        assert os.path.getmtime(p) == mtime, \
            f"--smoke must not overwrite the measured artifact {p}"


def test_run_smoke_robust_emits_rows_and_preserves_artifacts(subproc):
    guarded = [
        os.path.join(REPO, "BENCH_robust.json"),
        os.path.join(REPO, "benchmarks", "artifacts", "results.json"),
    ]
    before = {
        p: os.path.getmtime(p) for p in guarded if os.path.exists(p)
    }
    out = subproc("""
import sys
sys.path.insert(0, ".")
from benchmarks import run
rc = run.main(["--smoke", "--only", "robust"])
assert rc == 0
""", devices=1, timeout=1500)
    # the fault-free baseline, each attack under plain mean (the stall
    # control) and under both robust combiners, plus the overhead and
    # acceptance summary rows
    assert "robust/none/mean," in out, out[-2000:]
    assert "robust/sign_flip/mean," in out, out[-2000:]
    assert "robust/sign_flip/trimmed," in out, out[-2000:]
    assert "robust/sign_flip/median," in out, out[-2000:]
    assert "robust/blowup/trimmed," in out, out[-2000:]
    assert "robust/comm_overhead_ratio," in out
    assert "robust/acceptance," in out
    assert "identity=True" in out
    assert "replay=True" in out
    for p, mtime in before.items():
        assert os.path.getmtime(p) == mtime, \
            f"--smoke must not overwrite the measured artifact {p}"


def test_trajectory_emits_machine_readable_json(tmp_path):
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    import json

    from benchmarks import report

    path = str(tmp_path / "traj" / "trajectory.json")
    report.trajectory_json(path)
    with open(path) as f:
        got = json.load(f)
    rows = got["rows"]
    assert rows and all(
        set(r) == {"artifact", "metric", "value", "acceptance", "ok"}
        for r in rows
    )
    # same rows as the markdown table, same order
    assert [(r["artifact"], r["metric"]) for r in rows] == \
        [(a, m) for a, m, _, _, _ in report.trajectory_rows()]
    assert got["all_ok"] == all(r["ok"] for r in rows)
    # the pipeline artifact ships in the repo root -> its acceptance
    # rows must be aggregated
    assert any(r["artifact"] == "pipeline" for r in rows)
    # --trajectory wires the write through main()
    import contextlib
    import io

    path2 = str(tmp_path / "traj2.json")
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        report.main(["--trajectory", "--trajectory-json", path2])
    assert os.path.exists(path2)
    assert "Perf trajectory" in buf.getvalue()
