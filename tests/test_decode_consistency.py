"""Strong correctness: teacher-forced (train-mode) logits must match
step-by-step decode-with-cache logits for every model family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import model_api
from repro.models import encdec, transformer as tr
from repro.models.transformer import ModelConfig

T = 12


def _train_logits(params, cfg, toks, prefix=None):
    h, _ = tr.forward(params, cfg, toks, prefix_embeds=prefix)
    if prefix is not None:
        h = h[:, prefix.shape[1]:]
    w = tr.lm_head_weight(params, cfg)
    logits = jnp.einsum("btd,dv->btv", h.astype(jnp.float32),
                        w.astype(jnp.float32))[..., : cfg.vocab]
    from repro.models import layers

    return layers.softcap(logits, cfg.final_softcap)


def _decode_logits(params, cfg, toks, prefix=None):
    b = toks.shape[0]
    cache = model_api.make_cache(cfg, b, T + 4, kv_dtype=jnp.float32)
    outs = []
    # note: prefix-embed decode would need prefix positions in the cache;
    # covered separately for the VLM config below.
    for i in range(toks.shape[1]):
        logits, cache = model_api.decode(
            params, cfg, toks[:, i: i + 1], cache, jnp.asarray(i, jnp.int32)
        )
        outs.append(logits)
    return jnp.stack(outs, axis=1)


CONFIGS = {
    "dense-rope-gqa": ModelConfig(
        family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=101, dtype=jnp.float32, remat=False,
    ),
    "gemma-style": ModelConfig(
        family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=101, sliding_window=6, local_global_pattern=2,
        attn_softcap=30.0, final_softcap=20.0, post_norm=True,
        scale_embed=True, dtype=jnp.float32, remat=False,
    ),
    "moe": ModelConfig(
        family="moe", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=101, num_experts=4, top_k=2, moe_d_ff=48,
        shared_d_ff=64, dtype=jnp.float32, remat=False,
    ),
    "rwkv": ModelConfig(
        family="rwkv", n_layers=2, d_model=64, n_heads=2, d_ff=96,
        vocab=101, rope_theta=None, dtype=jnp.float32, remat=False,
    ),
    "mamba-hybrid": ModelConfig(
        family="mamba_hybrid", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab=101, d_state=16, ssm_head_dim=32,
        shared_attn_every=1, dtype=jnp.float32, remat=False,
    ),
}


@pytest.mark.parametrize("name", list(CONFIGS))
def test_decode_matches_teacher_forcing(name):
    cfg = CONFIGS[name]
    params = tr.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, T), 0, cfg.vocab)
    lt = _train_logits(params, cfg, toks)
    ld = _decode_logits(params, cfg, toks)
    # compare normalized distributions at every position
    pt = jax.nn.log_softmax(lt, axis=-1)
    pd = jax.nn.log_softmax(ld, axis=-1)
    err = float(jnp.abs(pt - pd).max())
    assert err < 5e-3, (name, err)


def test_encdec_decode_matches_teacher_forcing():
    cfg = ModelConfig(
        family="encdec", n_layers=2, n_encoder_layers=2, n_frames=8,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=96, vocab=101,
        rope_theta=None, dtype=jnp.float32, remat=False, act="gelu",
    )
    params = encdec.init_encdec_params(jax.random.key(0), cfg, 2)
    frames = jax.random.normal(
        jax.random.key(2), (2, cfg.n_frames, cfg.d_model), jnp.float32
    )
    toks = jax.random.randint(jax.random.key(1), (2, T), 0, cfg.vocab)
    enc = encdec.encode(params, cfg, frames)
    h = encdec.decode_train(params, cfg, toks, enc)
    lt = jnp.einsum(
        "btd,dv->btv", h.astype(jnp.float32),
        params["embed"].T.astype(jnp.float32),
    )[..., : cfg.vocab]

    cache = encdec.init_cache(cfg, 2, T + 2, cfg.n_frames, jnp.float32)
    cache = encdec.precompute_cross_kv(params, cfg, enc, cache)
    outs = []
    for i in range(T):
        logits, cache = encdec.decode_step(
            params, cfg, toks[:, i: i + 1], cache, jnp.asarray(i, jnp.int32)
        )
        outs.append(logits)
    ld = jnp.stack(outs, axis=1)
    err = float(jnp.abs(
        jax.nn.log_softmax(lt, -1) - jax.nn.log_softmax(ld, -1)
    ).max())
    assert err < 5e-3, err


def test_sliding_window_actually_masks():
    """A token beyond the window must not influence the output."""
    cfg = ModelConfig(
        family="dense", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, vocab=50, sliding_window=4, dtype=jnp.float32, remat=False,
    )
    params = tr.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 10), 0, 50)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % 50)  # perturb far-past token
    l1 = _train_logits(params, cfg, toks)
    l2 = _train_logits(params, cfg, toks2)
    # last position is > window away from position 0: identical logits
    np.testing.assert_allclose(
        np.asarray(l1[0, -1]), np.asarray(l2[0, -1]), atol=1e-5
    )
    # but an in-window perturbation does change the last position
    toks3 = toks.at[0, 8].set((toks[0, 8] + 7) % 50)
    l3 = _train_logits(params, cfg, toks3)
    assert float(jnp.abs(l1[0, -1] - l3[0, -1]).max()) > 1e-6
