"""Tests for the §Perf features: flash attention, MoE dispatch paths,
microbatched local steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers, moe
from repro.models.transformer import ModelConfig


def test_flash_matches_dense_reference():
    b, t, h, kvh, hd = 2, 53, 8, 4, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, t, h, hd))
    k = jax.random.normal(ks[1], (b, t, kvh, hd))
    v = jax.random.normal(ks[2], (b, t, kvh, hd))
    for causal in (True, False):
        for win, cap in [(None, None), (7, None), (None, 30.0), (11, 50.0)]:
            ref = layers.attention_scores(
                q, k, v, causal=causal, sliding_window=win, attn_softcap=cap
            )
            out = layers.flash_attention(
                q, k, v, causal=causal,
                window=None if win is None else jnp.asarray(win),
                attn_softcap=cap, k_chunk=16,
            )
            assert float(jnp.abs(ref - out).max()) < 2e-5, (causal, win, cap)


def test_flash_gradients_match():
    b, t, h, kvh, hd = 1, 40, 4, 2, 8
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, t, h, hd))
    k = jax.random.normal(ks[1], (b, t, kvh, hd))
    v = jax.random.normal(ks[2], (b, t, kvh, hd))
    g1 = jax.grad(lambda q_: layers.flash_attention(
        q_, k, v, causal=True, k_chunk=8).sum())(q)
    g2 = jax.grad(lambda q_: layers.attention_scores(
        q_, k, v, causal=True).sum())(q)
    assert float(jnp.abs(g1 - g2).max()) < 5e-4


def test_moe_gather_matches_dense_dispatch():
    cfg_key = jax.random.key(0)
    d, f, E, k = 32, 16, 8, 2
    params = moe.init_moe(cfg_key, d, f, E, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 1, d))  # 1 token -> gather
    y_dense, aux_d = moe.moe_fwd(params, x, k, dispatch="dense")
    y_gather, aux_g = moe.moe_fwd(params, x, k, dispatch="gather")
    np.testing.assert_allclose(
        np.asarray(y_dense), np.asarray(y_gather), rtol=2e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        float(aux_d["load_balance"]), float(aux_g["load_balance"]), rtol=1e-5
    )


def test_moe_auto_dispatch_selection():
    d, f, E, k = 16, 8, 8, 2
    params = moe.init_moe(jax.random.key(0), d, f, E, jnp.float32)
    # 1 token * top2 <= 8 experts -> gather; 16 tokens -> dense; both must
    # agree numerically with the explicit paths
    x1 = jax.random.normal(jax.random.key(1), (1, 1, d))
    x16 = jax.random.normal(jax.random.key(2), (2, 8, d))
    for x in (x1, x16):
        y_auto, _ = moe.moe_fwd(params, x, k, dispatch="auto")
        y_dense, _ = moe.moe_fwd(params, x, k, dispatch="dense")
        np.testing.assert_allclose(
            np.asarray(y_auto), np.asarray(y_dense), rtol=2e-4, atol=1e-5
        )


def test_microbatched_local_step_matches_single(subproc):
    subproc("""
import jax, jax.numpy as jnp
from repro.models.transformer import ModelConfig
from repro.dist import tamuna_dp

cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
mesh = jax.make_mesh((2, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
toks = jax.random.randint(jax.random.key(1), (2, 4, 16), 0, 64)
labs = jax.random.randint(jax.random.key(2), (2, 4, 16), 0, 64)
outs = {}
for M in (1, 2, 4):
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=2, s=2, p=0.5,
                                      microbatches=M)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    state, m = tamuna_dp.make_local_step(cfg, tcfg)(
        state, tokens=toks, labels=labs)
    outs[M] = (state.x, float(m["loss"]))
for M in (2, 4):
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), outs[1][0], outs[M][0])))
    assert err < 1e-5, (M, err)
    assert abs(outs[1][1] - outs[M][1]) < 1e-5
print("OK")
""", devices=2)


def test_local_adamw_trains(subproc):
    subproc("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import ModelConfig
from repro.dist import tamuna_dp
mesh = jax.make_mesh((2,2),("data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32,
                  remat=False)
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.003, c=2, s=2, p=0.5,
                                  local_opt="adamw")
state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                  tamuna_dp.state_pspecs(state, cfg, mesh),
                  is_leaf=lambda x: isinstance(x, P))
state = jax.device_put(state, sh)
toks = jax.random.randint(jax.random.key(1),(2,4,32),0,128)
labs = jax.random.randint(jax.random.key(2),(2,4,32),0,128)
local = jax.jit(tamuna_dp.make_local_step(cfg, tcfg))
comm = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh))
l0 = None
for r in range(8):
    for _ in range(2):
        state, m = local(state, tokens=toks, labels=labs)
    state = comm(state, jax.random.key(r))
    l0 = l0 or float(m["loss"])
assert float(m["loss"]) < l0
hs = max(jax.tree.leaves(jax.tree.map(
    lambda a: float(jnp.abs(a.sum(axis=0)).max()), state.h)))
assert hs < 1e-3
print("OK")
""", devices=4)


def test_quantized_uplink_floor_scales_with_bits():
    """Beyond-paper: unbiased stochastic quantization on top of the mask —
    converges linearly to a bits-controlled neighbourhood."""
    from repro.core import problems, tamuna

    prob = problems.make_quadratic_problem(n=16, d=32, kappa=50)
    floors = {}
    for bits in (0, 8):
        cfg = tamuna.TamunaConfig.tuned(prob, c=8, quantize_bits=bits)
        tr = tamuna.run(prob, cfg, num_rounds=1500, record_every=300)
        floors[bits] = max(abs(tr["suboptimality"][-1]), 1e-16)
    assert floors[0] < 1e-10  # exact without quantization
    assert 1e-7 < floors[8] < 1e-1  # bits=8: finite noise floor


def test_pallas_decode_kernel_plugs_into_model():
    """End-to-end: decode_step with the Pallas attend_fn must match the jnp
    reference decode path."""
    from repro.dist import model_api
    from repro.kernels import ops as kops
    from repro.models import transformer as tr

    cfg = ModelConfig(
        family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=101, dtype=jnp.float32, remat=False,
    )
    params = tr.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 6), 0, cfg.vocab)
    attend = kops.make_attend_fn(block_s=8)
    c_ref = model_api.make_cache(cfg, 2, 16, kv_dtype=jnp.float32)
    c_ker = model_api.make_cache(cfg, 2, 16, kv_dtype=jnp.float32)
    for i in range(6):
        l_ref, c_ref = model_api.decode(
            params, cfg, toks[:, i:i+1], c_ref, jnp.asarray(i, jnp.int32)
        )
        l_ker, c_ker = model_api.decode(
            params, cfg, toks[:, i:i+1], c_ker, jnp.asarray(i, jnp.int32),
            attend_fn=attend,
        )
        err = float(jnp.abs(l_ref - l_ker).max())
        assert err < 1e-4, (i, err)


def test_flash_used_in_model_forward_long_seq():
    """A long-seq forward must go through the flash path and stay finite."""
    cfg = ModelConfig(
        family="dense", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, vocab=64, dtype=jnp.float32, remat=False,
        sliding_window=64, local_global_pattern=2,
    )
    from repro.models import transformer as tr

    params = tr.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(
        jax.random.key(1), (1, layers.FLASH_THRESHOLD), 0, 64
    )
    h, _ = tr.forward(params, cfg, toks)
    assert jnp.isfinite(h).all()
