"""Property tests for the permutation compression masks (paper Fig. 1).

These are the paper's load-bearing combinatorial facts: exactly s owners per
coordinate (-> zero error at consensus), balanced columns (-> ceil(sd/c)
uplink floats per client), unbiased aggregation over the permutation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compression, masks

dcs = st.tuples(
    st.integers(1, 64),   # d
    st.integers(2, 24),   # c
    st.integers(2, 24),   # s
).filter(lambda t: t[2] <= t[1])


@given(dcs)
@settings(max_examples=60, deadline=None)
def test_template_row_and_column_properties(t):
    d, c, s = t
    q = masks.template_mask(d, c, s)
    assert q.shape == (d, c)
    # every coordinate has exactly s owners
    assert (q.sum(axis=1) == s).all()
    if d * s >= c:
        nnz = q.sum(axis=0)
        assert nnz.max() <= -(-s * d // c)
        assert nnz.min() >= (s * d) // c
    else:
        assert q.sum() == d * s


@given(dcs, st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_closed_form_matches_template_permutation(t, seed):
    d, c, s = t
    key = jax.random.key(seed)
    perm = masks.sample_permutation(key, c)
    q = np.asarray(masks.mask_from_permutation(perm, d, c, s))
    templ = masks.template_mask(d, c, s)
    expected = templ[:, np.asarray(perm)]
    np.testing.assert_array_equal(q, expected)


@given(dcs)
@settings(max_examples=30, deadline=None)
def test_blocked_template_row_property(t):
    d, c, s = t
    q = masks.block_template_mask(d, c, s)
    assert (q.sum(axis=1) == s).all()


@given(st.integers(2, 16), st.integers(2, 8), st.integers(0, 10**6))
@settings(max_examples=30, deadline=None)
def test_exact_at_consensus(c, s, seed):
    if s > c:
        s = c
    d = 23
    v = jax.random.normal(jax.random.key(seed), (d,))
    xs = jnp.broadcast_to(v, (c, d))
    q = masks.sample_mask(jax.random.key(seed + 1), d, c, s)
    xbar = compression.aggregate_masked(xs, q, s)
    np.testing.assert_allclose(np.asarray(xbar), np.asarray(v), rtol=1e-6)


def test_aggregation_unbiased_over_permutations():
    """E_perm[(1/s) sum_i C_i(x_i)] == mean_i(x_i) (paper Section A.1)."""
    d, c, s = 6, 4, 2
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(c, d)))
    import itertools

    acc = np.zeros(d)
    perms = list(itertools.permutations(range(c)))
    for p in perms:
        q = masks.mask_from_permutation(jnp.asarray(p), d, c, s)
        acc += np.asarray(compression.aggregate_masked(xs, q, s))
    acc /= len(perms)
    np.testing.assert_allclose(acc, np.asarray(xs).mean(axis=0), atol=1e-10)


def test_column_nnz_formula():
    assert masks.column_nnz(300, 16, 4) == 75
    assert masks.column_nnz(5, 7, 2) == 2
    assert masks.column_nnz(3, 10, 2) == 1


def test_small_d_regime():
    # c/s >= d regime of Fig. 1(d)
    q = masks.template_mask(3, 10, 2)
    assert (q.sum(axis=1) == 2).all()
    assert q[:, 6:].sum() == 0  # columns >= d*s are empty


@given(st.integers(2, 12), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_rand_k_unbiased(k, seed):
    d = 24
    if k > d:
        k = d
    x = jax.random.normal(jax.random.key(seed), (d,))
    keys = jax.random.split(jax.random.key(seed + 1), 600)
    outs = jax.vmap(lambda kk: compression.rand_k(kk, x, k))(keys)
    est = outs.mean(axis=0)
    err = float(jnp.abs(est - x).max())
    assert err < 1.0, err  # stochastic; loose bound


def test_top_k():
    x = jnp.asarray([1.0, -5.0, 2.0, 0.1])
    out = compression.top_k(x, 2)
    np.testing.assert_allclose(np.asarray(out), [0.0, -5.0, 2.0, 0.0])
