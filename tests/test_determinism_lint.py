"""Static determinism lint over the simulated-engine sources (ISSUE 9
satellite): every random draw in ``repro.dist`` / ``repro.core`` must be
SeedSequence-keyed and every clock simulated — checkpoint-resume replays
(fault schedules, cohort plans, reputation windows) depend on it.

Flags, per source line:
  * legacy global-state numpy RNG (``np.random.random`` etc. — anything
    under ``np.random.`` other than ``default_rng`` / ``SeedSequence`` /
    the ``Generator`` type),
  * OS-entropy seeding (``default_rng()`` / ``SeedSequence()`` with no
    arguments),
  * the stdlib ``random`` module,
  * wall clocks (``time.time`` / ``monotonic`` / ``perf_counter``,
    ``datetime.now`` / ``utcnow``) — simulated time must come from the
    delay models, never the host.

An ``_ALLOW`` table exists for future deliberate exceptions (none today);
additions need a justification comment here.
"""

import os
import re

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(os.path.dirname(HERE), "src", "repro")
_SCOPES = ("dist", "core")

_RULES = (
    ("unseeded-numpy-rng",
     re.compile(r"np\.random\.(?!default_rng\b|SeedSequence\b|Generator\b)"
                r"[A-Za-z_]+")),
    ("os-entropy-default_rng", re.compile(r"default_rng\(\s*\)")),
    ("os-entropy-seedsequence", re.compile(r"SeedSequence\(\s*\)")),
    ("stdlib-random",
     re.compile(r"^\s*(?:import random\b|from random import\b)")),
    ("wall-clock",
     re.compile(r"\btime\.(?:time|monotonic|perf_counter)\s*\(|"
                r"\bdatetime\.(?:now|utcnow)\s*\(")),
)

# (relative path, rule name) pairs deliberately exempted — keep empty
# unless a line is genuinely outside the simulated/replayed paths
_ALLOW = frozenset()


def test_dist_and_core_have_no_unseeded_randomness_or_wall_clock():
    hits = []
    for scope in _SCOPES:
        root = os.path.join(SRC, scope)
        assert os.path.isdir(root), root
        for dirpath, _, names in os.walk(root):
            for name in sorted(names):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, SRC)
                with open(path, encoding="utf-8") as f:
                    for lineno, line in enumerate(f, 1):
                        code = line.split("#", 1)[0]
                        for rule, rx in _RULES:
                            if rx.search(code) and (rel, rule) not in _ALLOW:
                                hits.append(
                                    f"{rel}:{lineno} [{rule}] "
                                    f"{line.strip()}"
                                )
    assert not hits, (
        "non-replayable randomness / wall-clock in simulated paths:\n"
        + "\n".join(hits)
    )
