"""Minimal, deterministic fallback for the tiny slice of the `hypothesis`
API this repo's property tests use (``given``, ``settings``,
``strategies.integers/floats/tuples`` with ``.filter``/``.map``).

Activated by ``tests/conftest.py`` ONLY when the real hypothesis is not
installed (this container is offline).  Examples are drawn from a seeded
PRNG keyed on the test name, with min/max boundary examples injected first,
so runs are reproducible.  Shrinking, the database, and health checks are
intentionally not implemented — on a machine with hypothesis installed the
real library is used and this package is never imported.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

from hypothesis import strategies  # noqa: F401  (submodule re-export)

__version__ = "0.0-repro-fallback"

__all__ = ["given", "settings", "strategies", "HealthCheck"]


class HealthCheck:
    """Placeholder namespace (tests only reference attributes, if at all)."""

    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"

    @staticmethod
    def all():
        return []


def settings(max_examples: int = 100, deadline=None, **_kw):
    """Records max_examples on the wrapped function (deadline ignored)."""

    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = (
                getattr(wrapper, "_hyp_max_examples", None)
                or getattr(fn, "_hyp_max_examples", None)
                or 50
            )
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                boundary = i if i < 4 else None
                vals = [s.example(rng, boundary) for s in arg_strategies]
                kvals = {
                    k: s.example(rng, boundary)
                    for k, s in kw_strategies.items()
                }
                fn(*args, *vals, **kvals, **kwargs)

        # strategy-filled parameters must not look like pytest fixtures:
        # hide the original signature from introspection
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(parameters=[])
        return wrapper

    return deco
