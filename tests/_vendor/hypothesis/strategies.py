"""Strategy objects for the fallback hypothesis shim (see __init__.py).

Each strategy implements ``example(rng, boundary=None)``; ``boundary``
cycles 0..3 for the first few draws so min/max corners are always hit.
"""

from __future__ import annotations

import random
from typing import Any, Callable, Optional, Sequence


class SearchStrategy:
    def example(self, rng: random.Random, boundary: Optional[int] = None):
        raise NotImplementedError

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        return _Filtered(self, pred)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return _Mapped(self, fn)


class _Filtered(SearchStrategy):
    def __init__(self, base: SearchStrategy, pred):
        self.base, self.pred = base, pred

    def example(self, rng, boundary=None):
        for attempt in range(1000):
            # only honor the boundary request on the first attempt; corner
            # values often fail the predicate
            v = self.base.example(rng, boundary if attempt == 0 else None)
            if self.pred(v):
                return v
        raise ValueError("filter predicate rejected 1000 examples")


class _Mapped(SearchStrategy):
    def __init__(self, base: SearchStrategy, fn):
        self.base, self.fn = base, fn

    def example(self, rng, boundary=None):
        return self.fn(self.base.example(rng, boundary))


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int):
        self.lo, self.hi = int(min_value), int(max_value)

    def example(self, rng, boundary=None):
        if boundary == 0:
            return self.lo
        if boundary == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float):
        self.lo, self.hi = float(min_value), float(max_value)

    def example(self, rng, boundary=None):
        if boundary == 0:
            return self.lo
        if boundary == 1:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Tuples(SearchStrategy):
    def __init__(self, strats: Sequence[SearchStrategy]):
        self.strats = tuple(strats)

    def example(self, rng, boundary=None):
        return tuple(s.example(rng, boundary) for s in self.strats)


class _SampledFrom(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, rng, boundary=None):
        if boundary == 0:
            return self.elements[0]
        if boundary == 1:
            return self.elements[-1]
        return rng.choice(self.elements)


class _Booleans(_SampledFrom):
    def __init__(self):
        super().__init__([False, True])


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return _Integers(min_value, max_value)


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return _Floats(min_value, max_value)


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return _Tuples(strats)


def sampled_from(elements) -> SearchStrategy:
    return _SampledFrom(elements)


def booleans() -> SearchStrategy:
    return _Booleans()
