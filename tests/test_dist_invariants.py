"""Invariants the dist engine relies on but the seed suite never pinned:

* blocked-template mask semantics (exactly-s-owners at ragged d, under
  arbitrary column permutations and under the cyclic shifts the block_rs
  uplink actually uses),
* exact-at-consensus aggregation for the blocked template with d % c != 0,
* ``block_rs_aggregate`` numerics on a single device (pytree generality,
  sum_i h_i == 0, owner-mean against numpy),
* int32/float counter dtypes of the reference core (no silent int64
  truncation dependence).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import compression, masks, problems, tamuna

ragged_dcs = st.tuples(
    st.integers(3, 97),   # d
    st.integers(2, 16),   # c
    st.integers(2, 16),   # s
).filter(lambda t: t[2] <= t[1] and t[0] % t[1] != 0)


@given(ragged_dcs)
@settings(max_examples=40, deadline=None)
def test_block_template_exactly_s_owners_ragged(t):
    d, c, s = t
    q = masks.block_template_mask(d, c, s)
    assert q.shape == (d, c)
    assert (q.sum(axis=1) == s).all()
    # the accounting helper is the exact worst-case column load
    assert q.sum(axis=0).max() == masks.block_column_nnz(d, c, s)


@given(ragged_dcs, st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_blocked_closed_form_matches_template_and_keeps_row_property(t, seed):
    d, c, s = t
    perm = masks.sample_permutation(jax.random.key(seed), c)
    q = np.asarray(masks.mask_from_permutation(perm, d, c, s, blocked=True))
    templ = masks.block_template_mask(d, c, s)
    np.testing.assert_array_equal(q, templ[:, np.asarray(perm)])
    assert (q.sum(axis=1) == s).all()  # permutation preserves owners-per-row


elastic_blocked = st.tuples(
    st.integers(3, 97),   # D
    st.integers(4, 16),   # n
    st.integers(2, 12),   # c
    st.integers(2, 12),   # s
    st.integers(0, 2**16),  # seed
).filter(lambda t: t[3] <= t[2] <= t[1])


@given(elastic_blocked)
@settings(max_examples=40, deadline=None)
def test_elastic_blocked_bands_keep_row_property(t):
    """The blocked bands laid over c < n cohort slots (DESIGN.md §11):
    every coordinate still has exactly s owners, all of them cohort
    members, idle clients own nothing, the per-client load stays within
    ``block_column_nnz(D, c, s)`` — and the whole thing IS a column
    permutation (``block_shift_permutation``) of the property-tested core
    block template, so Appendix A.1's unbiasedness argument applies."""
    D, n, c, s, seed = t
    rng = np.random.default_rng(seed)
    cohort = np.sort(rng.choice(n, size=c, replace=False))
    off = int(rng.integers(0, c))
    slot_of = np.full(n, -1)
    slot_of[cohort] = np.arange(c)
    # the engine's closed form: (block(k) - slot_of[i] - off) mod c < s
    chunk = -(-D // c)
    blk = np.arange(D) // chunk
    own = (slot_of[:, None] >= 0) & (
        ((blk[None, :] - slot_of[:, None] - off) % c) < s
    )
    assert (own.sum(axis=0) == s).all()  # exactly s owners per coordinate
    assert not own[slot_of < 0].any()  # idle clients own nothing
    assert own.sum(axis=1).max() <= masks.block_column_nnz(D, c, s)
    perm = masks.block_shift_permutation(jnp.asarray(off), c, s)
    q = np.asarray(
        masks.mask_from_permutation(perm, D, c, s, blocked=True)
    )
    np.testing.assert_array_equal(own[cohort].astype(np.int8), q.T)


@given(st.integers(2, 12), st.integers(2, 12), st.integers(0, 10**6))
@settings(max_examples=40, deadline=None)
def test_blocked_aggregation_exact_at_consensus_ragged(c, s, seed):
    if s > c:
        s = c
    d = 5 * c + (c - 1)  # always ragged: d % c == c - 1 != 0
    v = jax.random.normal(jax.random.key(seed), (d,))
    xs = jnp.broadcast_to(v, (c, d))
    q = masks.sample_mask(jax.random.key(seed + 1), d, c, s, blocked=True)
    xbar = compression.aggregate_masked(xs, q, s)
    np.testing.assert_allclose(np.asarray(xbar), np.asarray(v), rtol=1e-6)


def test_block_rs_aggregate_pytree_single_device():
    """Owner-mean + h-sum-zero for the dist blocked uplink, checked without
    a mesh: block_rs_aggregate is pure jnp over the stacked client axis."""
    from repro.dist import tamuna_dp
    from repro.dist.block_uplink import block_rs_aggregate

    n, s = 8, 3
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.1, c=n, s=s, p=0.5,
                                      uplink="block_rs")
    eta = tcfg.eta_(n)
    ks = jax.random.split(jax.random.key(0), 4)
    x = {
        "w": jax.random.normal(ks[0], (n, 13, 5), jnp.float32),  # ragged 65
        "b": jax.random.normal(ks[1], (n, 3), jnp.float32),  # D < n
    }
    h = {
        "w": jax.random.normal(ks[2], (n, 13, 5), jnp.float32),
        "b": jax.random.normal(ks[3], (n, 3), jnp.float32),
    }
    # center h so sum_i h_i == 0 going in (the invariant to preserve)
    h = jax.tree.map(lambda a: a - a.mean(axis=0, keepdims=True), h)
    off = jnp.asarray(5, jnp.int32)

    xb, hb = jax.jit(
        lambda x, h: block_rs_aggregate(x, h, off, n, tcfg, eta, None)
    )(x, h)

    for name in ("w", "b"):
        xl = np.asarray(x[name], np.float64).reshape(n, -1)
        D = xl.shape[1]
        chunk = -(-D // n)
        blk = np.minimum(np.arange(D) // chunk, n - 1)
        expect = np.zeros(D)
        for j in range(n):
            owners = [i for i in range(n) if ((j - i - 5) % n) < s]
            sel = blk == j
            expect[sel] = sum(xl[i, sel] for i in owners) / s
        got = np.asarray(xb[name], np.float64).reshape(n, -1)
        # every client row equals the aggregated server model
        for i in range(n):
            np.testing.assert_allclose(got[i], expect, rtol=1e-5, atol=1e-6)
        hs = np.abs(np.asarray(hb[name], np.float64).sum(axis=0)).max()
        assert hs < 1e-4, (name, hs)


def test_reference_counters_int32_and_float_accumulators():
    """init/round_step must not depend on jax_enable_x64 for counters: ints
    are explicit int32, communication accounting is float (overflow-safe at
    LM-scale d where int32 is not)."""
    prob = problems.make_quadratic_problem(n=8, d=16, kappa=10)
    cfg = tamuna.TamunaConfig.tuned(prob, c=4)
    state = tamuna.init(prob)
    assert state.round.dtype == jnp.int32
    assert state.total_local_steps.dtype == jnp.int32
    assert jnp.issubdtype(state.up_floats.dtype, jnp.floating)
    assert jnp.issubdtype(state.down_floats.dtype, jnp.floating)

    step = jax.jit(lambda st, k: tamuna.round_step(prob, cfg, st, k))
    state = step(state, jax.random.key(0))
    assert state.round.dtype == jnp.int32
    assert state.total_local_steps.dtype == jnp.int32
    assert jnp.issubdtype(state.up_floats.dtype, jnp.floating)
    assert int(state.round) == 1
    # accounting stays exactly integral in the float accumulator
    assert float(state.up_floats) == masks.column_nnz(prob.d, cfg.c, cfg.s)
    assert float(state.down_floats) == prob.d


def test_run_trace_matches_per_round_reference():
    """The chunked lax.scan driver must reproduce the old per-round Python
    loop: same record points, same key sequence, same trajectory."""
    prob = problems.make_quadratic_problem(n=8, d=12, kappa=20)
    cfg = tamuna.TamunaConfig.tuned(prob, c=4)

    tr = tamuna.run(prob, cfg, num_rounds=23, record_every=5, seed=3)
    np.testing.assert_array_equal(tr["rounds"], [1, 6, 11, 16, 21, 23])

    # hand-rolled reference loop (the pre-scan driver semantics)
    state = tamuna.init(prob)
    key = jax.random.key(3)
    step = jax.jit(lambda st, k: tamuna.round_step(prob, cfg, st, k))
    ref_sub = []
    for r in range(23):
        key, rk = jax.random.split(key)
        state = step(state, rk)
        if r % 5 == 0 or r == 22:
            ref_sub.append(float(prob.suboptimality(state.x_bar)))
    np.testing.assert_allclose(tr["suboptimality"], ref_sub, rtol=1e-12)
