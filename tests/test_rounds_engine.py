"""Fused round engine tests: equivalence with the per-step path, compile
cache bound, on-device data determinism, checkpoint round-trip mid-run."""

import pytest


def test_fused_round_equals_per_step(subproc):
    """One engine round matches (<=1e-6) L per-step local_step calls +
    comm_step replayed on the same key schedule — for both uplinks
    (block_rs now at c < n too) and local_opt='adamw', at L spanning
    single- and multi-chunk buckets — and the compile cache stays within
    log2(max_L)+1.  At c < n the replay runs the ELASTIC semantics: gather
    the device-derived cohort, train the compact state on cohort-only
    batches, scatter, comm with the cohort and next-cohort DownCom."""
    subproc("""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import rounds, sharding, tamuna_dp

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
dcfg = DataConfig(seq_len=16, per_client_batch=2, vocab=64, seed=0,
                  n_clients=n)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
data = pipe.device_data()
sampler = device_sampler(dcfg, cfg, mesh)

for uplink, opt in [("masked_psum", "sgd"), ("block_rs", "sgd"),
                    ("masked_psum", "adamw")]:
    c = 3
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=2, p=0.5,
                                      uplink=uplink, local_opt=opt)
    def mk_state():
        st = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          tamuna_dp.state_pspecs(st, cfg, mesh),
                          is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(st, sh)

    # elastic forced: this 4x2 host mesh has one client per data shard,
    # where the default keeps the all-rows body (the gather cannot vacate
    # hardware there) — the replay below tests the elastic semantics
    round_fn = rounds.make_round_fn(cfg, tcfg, mesh, sample_batch=sampler,
                                    max_L=8, elastic=True)
    assert round_fn.elastic and round_fn.c == c and round_fn.n == n
    local = jax.jit(tamuna_dp.make_local_step(cfg, tcfg))
    comm = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh))

    # L=1: single bucket; L=3: two chunks (2+1); L=5: two chunks (4+1)
    for L in (1, 3, 5):
        carry = rounds.init_carry(mk_state(), jax.random.key(7),
                                  flush_every=1)
        # snapshot the base keys BEFORE the engine donates the carry
        dk = np.asarray(carry.data_key).copy()
        ck = np.asarray(carry.comm_key).copy()

        # per-step reference on the SAME key schedule and cohort plan
        ref = mk_state()
        cohort = tamuna_dp.round_cohort(
            rounds.comm_round_key(ck, ref.round), n, c)
        down = tamuna_dp.member_mask(
            tamuna_dp.round_cohort(
                rounds.comm_round_key(ck, ref.round + 1), n, c), n)
        work = tamuna_dp.gather_cohort(ref, cohort)
        acc = 0.0
        for t in range(L):
            batch = sampler(data, rounds.data_step_key(dk, t),
                            clients=cohort)
            work, m = local(work, **batch)
            acc += float(m["loss"])
        ref = tamuna_dp.scatter_cohort(ref, work, cohort)
        ckey = rounds.comm_round_key(ck, ref.round)
        ref = comm(ref, jax.random.key_data(ckey), cohort=cohort,
                   down=down)

        carry = round_fn(carry, data, L, 0)

        # states match to <= 1e-6 on every leaf (x, h, opt)
        for name, a, b in [("x", carry.state.x, ref.x),
                           ("h", carry.state.h, ref.h),
                           ("opt", carry.state.opt, ref.opt)]:
            errs = jax.tree.map(
                lambda u, v: float(jnp.max(jnp.abs(
                    u.astype(jnp.float32) - v.astype(jnp.float32)))), a, b)
            err = max(jax.tree.leaves(errs), default=0.0)
            assert err <= 1e-6, (uplink, opt, L, name, err)
        assert int(carry.state.round) == int(ref.round) == 1
        assert int(carry.t) == L
        # device traces match the per-step loss sum and counters
        tr = jax.device_get(carry.traces)
        np.testing.assert_allclose(tr["loss_sum"][0], acc, rtol=1e-5)
        assert int(tr["steps"][0]) == L
        assert float(tr["up_floats"][0]) == float(ref.up_floats)
    # compile cache bound: chunks of {1,3,5} are {1,2,4} -> <= log2(8)+1
    assert len(round_fn.cache) <= 4, sorted(round_fn.cache)
print("OK")
""", timeout=1500)


def test_compile_cache_bounded_over_geometric_rounds(subproc):
    """30 geometric rounds compile at most log2(max_L)+1 distinct programs."""
    subproc("""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import rounds, sharding, tamuna_dp

mesh = jax.make_mesh((4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
dcfg = DataConfig(seq_len=8, per_client_batch=1, vocab=64, seed=0,
                  n_clients=n)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=3, s=2, p=0.34)
state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                  tamuna_dp.state_pspecs(state, cfg, mesh),
                  is_leaf=lambda x: isinstance(x, P))
state = jax.device_put(state, sh)
MAX_L = 16
round_fn = rounds.make_round_fn(
    cfg, tcfg, mesh, sample_batch=device_sampler(dcfg, cfg, mesh),
    max_L=MAX_L)
rng = np.random.default_rng(0)
seen = set()
data = pipe.device_data()
carry = rounds.init_carry(state, jax.random.key(1), 8)
for r in range(30):
    L = tamuna_dp.sample_round_length(rng, tcfg.p, max_L=MAX_L)
    seen.add(L)
    carry = round_fn(carry, data, L, r % 8)
assert len(seen) > 4, seen  # geometric draws actually varied
assert len(round_fn.cache) <= 5, sorted(round_fn.cache)  # log2(16)+1
# chunk decomposition is exact for every length
for L in range(1, MAX_L + 1):
    assert sum(rounds.round_chunks(L, MAX_L)) == L
assert sum(rounds.round_chunks(100, MAX_L)) == MAX_L  # cap
print("OK")
""", devices=4, timeout=1500)


def test_run_rounds_checkpoint_roundtrip_bf16_adamw(subproc):
    """DistTamunaState (bf16 params + AdamW moments) survives
    checkpoint.save/restore mid-run from run_rounds, bit-exactly, and the
    restored state continues training."""
    subproc("""
import os, tempfile
import numpy as np
import jax, jax.numpy as jnp
import ml_dtypes
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import checkpoint
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import rounds, sharding, tamuna_dp

mesh = jax.make_mesh((4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  param_dtype=jnp.bfloat16, remat=False)
n = sharding.n_clients(mesh)
dcfg = DataConfig(seq_len=8, per_client_batch=1, vocab=64, seed=0,
                  n_clients=n)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.01, c=3, s=2, p=0.5,
                                  local_opt="adamw")
state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                  tamuna_dp.state_pspecs(state, cfg, mesh),
                  is_leaf=lambda x: isinstance(x, P))
state = jax.device_put(state, sh)
assert any(a.dtype == jnp.bfloat16 for a in jax.tree.leaves(state.x))
round_fn = rounds.make_round_fn(
    cfg, tcfg, mesh, sample_batch=device_sampler(dcfg, cfg, mesh), max_L=4)
d = tempfile.mkdtemp()
final, last = rounds.run_rounds(
    state, round_fn=round_fn, data=pipe.device_data(),
    key=jax.random.key(3), rounds=2, rng=np.random.default_rng(0),
    p=tcfg.p, flush_every=2, checkpoint_dir=d, checkpoint_every=2)
assert os.path.isdir(os.path.join(d, "step_2"))
assert last["round"] == 1 and last["local_steps"] >= 2

like = jax.tree.map(jnp.zeros_like, final)
restored = checkpoint.restore(os.path.join(d, "step_2"), like)
for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(restored)):
    assert a.dtype == b.dtype, (a.dtype, b.dtype)
    an, bn = np.asarray(a), np.asarray(b)
    if a.dtype == jnp.bfloat16:  # bit-exact bf16 round-trip
        np.testing.assert_array_equal(an.view(np.uint16),
                                      bn.view(np.uint16))
    else:
        np.testing.assert_array_equal(an, bn)

# the restored state continues training through the engine
restored = jax.device_put(restored, sh)
cont, last2 = rounds.run_rounds(
    restored, round_fn=round_fn, data=pipe.device_data(),
    key=jax.random.key(4), rounds=1, rng=np.random.default_rng(1),
    p=tcfg.p, flush_every=1)
assert int(cont.round) == 3  # 2 checkpointed rounds + 1 continued
assert np.isfinite(last2["loss"])
print("OK")
""", devices=4, timeout=1500)


def test_device_sampler_matches_engine_schedule(subproc):
    """The on-device sampler is pure: same key -> same batch, eager or
    jitted, and tokens land in [0, vocab)."""
    subproc("""
import numpy as np
import jax, jax.numpy as jnp
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sample_batch
from repro.data.pipeline import SyntheticTokenPipeline

cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
dcfg = DataConfig(seq_len=12, per_client_batch=3, vocab=64, seed=5,
                  n_clients=4)
pipe = SyntheticTokenPipeline(dcfg, cfg)
data = pipe.device_data()
key = jax.random.key(9)
b1 = device_sample_batch(data, key, dcfg=dcfg, model_cfg=cfg)
b2 = jax.jit(lambda d, k: device_sample_batch(d, k, dcfg=dcfg,
                                              model_cfg=cfg))(data, key)
for k in b1:
    np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))
assert b1["tokens"].shape == (4, 3, 12)
assert int(b1["tokens"].min()) >= 0 and int(b1["tokens"].max()) < 64
# labels are the next-token shift of the same chain
np.testing.assert_array_equal(np.asarray(b1["tokens"][..., 1:]),
                              np.asarray(b1["labels"][..., :-1]))
print("OK")
""", devices=1, timeout=900)
