"""Data pipeline, optimizers, checkpointing, metrics, theory formulas."""

import csv
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.core import theory
from repro.data import DataConfig, SyntheticTokenPipeline, device_sample_batch
from repro.metrics import MetricLogger
from repro.models.transformer import ModelConfig
from repro.optim import adamw, clip_by_global_norm, global_norm, momentum, sgd


def test_pipeline_shapes_and_determinism():
    cfg = ModelConfig(vocab=64, d_model=32)
    p1 = SyntheticTokenPipeline(DataConfig(seq_len=16, per_client_batch=3,
                                           vocab=64, seed=7), cfg)
    p2 = SyntheticTokenPipeline(DataConfig(seq_len=16, per_client_batch=3,
                                           vocab=64, seed=7), cfg)
    b1, b2 = p1.next_batch(), p2.next_batch()
    assert b1["tokens"].shape == (1, 3, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(b1["tokens"][..., 1:]), np.asarray(b1["labels"][..., :-1])
    )


def test_pipeline_client_stream_invariant_to_population():
    """Client i's data stream (host and device paths) depends only on
    (seed, i) — never on n_clients or generation order."""
    cfg = ModelConfig(vocab=64, d_model=32)
    mk = lambda n: SyntheticTokenPipeline(
        DataConfig(seq_len=12, per_client_batch=2, vocab=64, seed=3,
                   n_clients=n), cfg)
    p4, p8 = mk(4), mk(8)
    # transition tables: client i's chain is the same in both populations
    np.testing.assert_allclose(p4.trans, p8.trans[:4])
    # host path, two consecutive batches (streams advance per client)
    for _ in range(2):
        b4, b8 = p4.next_batch(), p8.next_batch()
        np.testing.assert_array_equal(
            np.asarray(b4["tokens"]), np.asarray(b8["tokens"][:4])
        )
    # device path: per-client fold-in keys are population-invariant too
    key = jax.random.key(11)
    d4 = device_sample_batch(p4.device_data(), key, dcfg=p4.dcfg,
                             model_cfg=cfg)
    d8 = device_sample_batch(p8.device_data(), key, dcfg=p8.dcfg,
                             model_cfg=cfg)
    np.testing.assert_array_equal(np.asarray(d4["tokens"]),
                                  np.asarray(d8["tokens"][:4]))


def test_metric_logger_tolerates_evolving_keys(tmp_path):
    """Later rows may introduce keys the first row did not have (the fused
    engine logs up/down floats per round); the CSV widens its header."""
    path = tmp_path / "m.csv"
    lg = MetricLogger(str(path), print_every=10**9)
    lg.log(0, {"loss": 1.25})
    lg.log(1, {"loss": 0.5, "up_floats": 3.0})  # new key mid-stream
    lg.log(2, {"up_floats": 4.0})  # missing key mid-stream
    lg.close()
    with open(path) as f:
        rows = list(csv.DictReader(f))
    assert [r["step"] for r in rows] == ["0", "1", "2"]
    assert rows[0]["up_floats"] == ""  # widened header backfills empty
    assert float(rows[1]["up_floats"]) == 3.0
    assert float(rows[1]["loss"]) == 0.5
    assert rows[2]["loss"] == ""


def test_pipeline_heterogeneity_knob():
    cfg = ModelConfig(vocab=32, d_model=16)
    iid = SyntheticTokenPipeline(
        DataConfig(seq_len=8, vocab=32, heterogeneity=0.0, seed=1,
                   n_clients=4), cfg)
    het = SyntheticTokenPipeline(
        DataConfig(seq_len=8, vocab=32, heterogeneity=1.0, seed=1,
                   n_clients=4), cfg)
    # iid: all client transition tables identical by construction
    assert np.allclose(iid.trans.std(axis=0), 0.0)
    assert het.trans.std(axis=0).max() > 0.0


def _rosenbrockish(params):
    return jnp.sum((params["a"] - 1.5) ** 2) + jnp.sum(params["b"] ** 2) * 4.0


@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adamw"])
def test_optimizers_minimize(opt_name):
    opt = {"sgd": sgd(0.1), "momentum": momentum(0.05),
           "adamw": adamw(0.1)}[opt_name]
    params = {"a": jnp.zeros((4,)), "b": jnp.ones((3,))}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(_rosenbrockish)(params)
        params, state = opt.update(g, state, params)
    assert float(_rosenbrockish(params)) < 1e-3, opt_name


def test_clip_by_global_norm():
    tree = {"x": jnp.full((4,), 10.0)}
    assert abs(float(global_norm(tree)) - 20.0) < 1e-5
    clipped = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
    }
    path = os.path.join(tmp_path, "step_3")
    checkpoint.save(path, tree, step=3)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored = checkpoint.restore(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )
    assert checkpoint.latest_step(tmp_path) == 3


def test_theory_formulas():
    # chi bound in (1/2, 1]
    assert 0.5 < theory.chi_max(1000, 2) <= 1.0
    assert theory.chi_max(10, 10) == pytest.approx(10 * 9 / (10 * 9))
    # tau < 1 for valid params
    tau = theory.theorem1_rate(1e-4, 1.0, 1e4, 0.1, 0.5, 100, 4)
    assert 0 < tau < 1
    # recommended s (eq. 14)
    assert theory.recommended_s(c=100, d=300, alpha=0.0) == 2
    assert theory.recommended_s(c=1000, d=3, alpha=0.0) == 333
    assert theory.recommended_s(c=100, d=300, alpha=0.5) == 50
    # TAMUNA TotalCom beats GD by a wide margin in the paper's regime
    kappa, d, n, c = 1e4, 300, 1000, 1000
    s = theory.recommended_s(c, d, 0.0)
    p = theory.recommended_p(n, s, kappa)
    t_tamuna = theory.totalcom_complexity(kappa, n, c, s, d, p, 0.0)
    t_gd = theory.gd_totalcom(kappa, d, 0.0)
    assert t_tamuna < t_gd / 50
    # and beats Scaffnew (CC acceleration on top of LT)
    t_scaffnew = theory.scaffnew_totalcom(kappa, d, 0.0)
    assert t_tamuna < t_scaffnew


def test_tuned_params_satisfy_theorem1_conditions():
    tp = theory.TunedParams.for_problem(
        mu=1.0, L=1e4, n=1000, c=100, d=300, alpha=0.0
    )
    assert 0 < tp.gamma < 2.0 / 1e4 * (1 + 1e-4) * 2  # gamma < 2/L region
    assert 0 < tp.p <= 1
    assert 2 <= tp.s <= 100
    assert 0 < tp.chi <= theory.chi_max(1000, tp.s) + 1e-12
