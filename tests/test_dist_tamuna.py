"""Distributed TAMUNA-DP integration tests (multi-device via subprocess)."""

import pytest


def test_masked_psum_training_and_invariants(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models.transformer import ModelConfig
from repro.dist import tamuna_dp, sharding

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32,
                  remat=False)
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=3, s=2, p=0.5)
state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                  tamuna_dp.state_pspecs(state, cfg, mesh),
                  is_leaf=lambda x: isinstance(x, P))
state = jax.device_put(state, sh)
n = sharding.n_clients(mesh)
tokens = jax.random.randint(jax.random.key(1), (n, 2, 32), 0, cfg.vocab)
labels = jax.random.randint(jax.random.key(2), (n, 2, 32), 0, cfg.vocab)
local = jax.jit(tamuna_dp.make_local_step(cfg, tcfg))
comm = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh))
losses = []
for r in range(8):
    for _ in range(2):
        state, m = local(state, tokens=tokens, labels=labels)
    state = comm(state, jax.random.key(100 + r))
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
hs = max(jax.tree.leaves(jax.tree.map(
    lambda a: float(jnp.abs(a.sum(axis=0)).max()), state.h)))
assert hs < 1e-3, hs
xd = max(jax.tree.leaves(jax.tree.map(
    lambda a: float(jnp.abs(a - a[0:1]).max()), state.x)))
assert xd == 0.0, xd
print("OK")
""")


def test_block_rs_equals_masked_psum_aggregation(subproc):
    """With the blocked template and full participation, block_rs matches a
    direct owner-mean computed in numpy.  model=1 mesh so the python ref's
    global-flat chunking equals the implementation's per-TP-shard chunking
    (with TP > 1 the template is a per-shard row reordering — still a valid
    exactly-s-owners template, but a different coordinate order)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models.transformer import ModelConfig
from repro.dist import tamuna_dp, sharding
from repro.dist.block_uplink import block_rs_aggregate

mesh = jax.make_mesh((4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32,
                  remat=False)
n = 4
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=n, s=2, p=0.5,
                                  uplink="block_rs")
state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
# give clients DIFFERENT params so aggregation is non-trivial
xs = jax.tree.map(
    lambda a: a + 0.1 * jax.random.normal(jax.random.key(hash(a.shape) % 100),
                                          a.shape, jnp.float32),
    state.x)
state = state._replace(x=xs)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                  tamuna_dp.state_pspecs(state, cfg, mesh),
                  is_leaf=lambda x: isinstance(x, P))
state = jax.device_put(state, sh)
eta = tcfg.eta_(n)
off = jnp.asarray(1, jnp.int32)

xb, hb = jax.jit(lambda x, h: block_rs_aggregate(
    x, h, off, n, tcfg, eta, mesh, model_cfg=cfg))(state.x, state.h)

# reference: per-leaf blocked-ownership masked mean over owners
def ref_leaf(xl):
    D = int(np.prod(xl.shape[1:]))
    chunk = -(-D // n)
    k = (np.arange(n * chunk) // chunk)[:D]
    x = np.asarray(xl, np.float64).reshape(n, -1)
    out = np.zeros(D)
    for j in range(n):
        owners = [i for i in range(n)
                  if ((j - ((i + 1) % n)) % n) < tcfg.s]
        sel = k == j
        out[sel] = sum(x[i, sel] for i in owners) / tcfg.s
    return out.reshape(xl.shape[1:])

for (path, xl), xbl in zip(
        jax.tree_util.tree_flatten_with_path(state.x)[0],
        jax.tree.leaves(xb)):
    expect = ref_leaf(xl)
    got = np.asarray(xbl[0], np.float64)
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6)
# invariant: sum_i h_i == 0 preserved
hs = max(jax.tree.leaves(jax.tree.map(
    lambda a: float(jnp.abs(np.asarray(a, np.float64).sum(axis=0)).max()), hb)))
assert hs < 1e-4, hs
print("OK")
""")


def test_moe_and_hybrid_families_train_distributed(subproc):
    subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models.transformer import ModelConfig
from repro.dist import tamuna_dp, sharding

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
for cfg in [
    ModelConfig(family="moe", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=64, vocab=128, num_experts=4, top_k=2,
                moe_d_ff=32, dtype=jnp.float32, remat=False),
    ModelConfig(family="mamba_hybrid", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab=128, d_state=16,
                ssm_head_dim=32, shared_attn_every=1, dtype=jnp.float32,
                remat=False),
]:
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.01, c=4, s=2, p=0.5)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      tamuna_dp.state_pspecs(state, cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, sh)
    n = sharding.n_clients(mesh)
    toks = jax.random.randint(jax.random.key(1), (n, 2, 16), 0, cfg.vocab)
    labs = jax.random.randint(jax.random.key(2), (n, 2, 16), 0, cfg.vocab)
    local = jax.jit(tamuna_dp.make_local_step(cfg, tcfg))
    comm = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh))
    l0 = None
    for r in range(6):
        state, m = local(state, tokens=toks, labels=labs)
        state = comm(state, jax.random.key(r))
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0, (cfg.family, l0, float(m["loss"]))
print("OK")
""")


def test_kernelized_local_step_matches_plain(subproc):
    subproc("""
import jax, jax.numpy as jnp
from repro.models.transformer import ModelConfig
from repro.dist import tamuna_dp

cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
mesh = jax.make_mesh((2, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
toks = jax.random.randint(jax.random.key(1), (2, 2, 16), 0, 64)
labs = jax.random.randint(jax.random.key(2), (2, 2, 16), 0, 64)
outs = {}
for use_k in (False, True):
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=2, s=2, p=0.5,
                                      use_kernel=use_k)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    state, m = tamuna_dp.make_local_step(cfg, tcfg)(
        state, tokens=toks, labels=labs)
    outs[use_k] = state.x
err = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.abs(a - b).max()), outs[False], outs[True])))
assert err < 1e-5, err
print("OK")
""", devices=2)
