"""Quantized wire (DESIGN.md §13):

* the counter-hash stochastic quantizer is unbiased (E[Q(v)] = v over a
  seed batch) at int8 and int4, and so is the core's per-chunk
  ``quantize_stochastic`` — whose chunked scales keep resolution on
  outlier-heavy leaves where a per-tensor scale collapses it,
* all four comm impls (dense / ws / pallas / shard engine) produce the
  same coordinates at matching wire seeds, for every wire kind, both
  templates, elastic cohorts c < n, and the arrived mask,
* ``wire_precision="f32"`` is BITWISE identical to the unquantized
  engine — the wire machinery must be dead code on the f32 path,
* nonfinite payloads are never quantized into finite wire values (float
  kinds pass through, int kinds NaN-poison the chunk scale) and finite
  f16 payloads never overflow to inf,
* the dtype-aware byte accounting: f32 byte-identical to floats * 4,
  int8 roughly 4x smaller, threaded through ``make_comm_step``.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.dist import comm_ws, wire


def _mesh_1x1():
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def _maxerr(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.abs(
            x.astype(jnp.float32) - y.astype(jnp.float32)).max()),
        a, b)))


def _slot(rng, n, c):
    """Template column per client (perm of the cohort's slots, -1 idle)."""
    cohort = rng.choice(n, size=c, replace=False)
    out = np.full((n,), -1, np.int32)
    out[cohort] = rng.permutation(c)
    return jnp.asarray(out)


def _tree(rng, n):
    x = {
        "w": jnp.asarray(rng.normal(size=(n, 13, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 1)), jnp.bfloat16),
        "v": jnp.asarray(rng.normal(size=(n, 29)), jnp.float32),
    }
    h = {
        k: jnp.asarray(rng.normal(size=a.shape), jnp.float32)
        for k, a in x.items()
    }
    h = jax.tree.map(lambda a: a - a.mean(axis=0, keepdims=True), h)
    return x, h


# --------------------------------------------------------------------------
# unbiasedness
# --------------------------------------------------------------------------


@given(st.sampled_from(["int8", "int4"]), st.integers(0, 2**16))
@settings(max_examples=8, deadline=None)
def test_wire_quantizer_unbiased(kind, seed):
    """Mean of Q(v) over many independent wire seeds converges to v at
    the Monte-Carlo rate: the rounding is unbiased, so the masked-sum
    aggregation stays exact in expectation."""
    rng = np.random.default_rng(seed)
    d = 70
    v = jnp.asarray(rng.normal(size=(1, d)).astype(np.float32)) * 3.0
    reps = 4096
    rid = jnp.zeros((1, 1), jnp.uint32)
    kk = jnp.arange(d, dtype=jnp.int32)

    def one(s):
        return wire.quantize(v, kind, s, rid, kk)

    seeds = jnp.arange(reps, dtype=jnp.uint32) * jnp.uint32(2654435761)
    mean = jax.vmap(one)(seeds).mean(axis=0)
    # per-draw std <= scale (one quantization step); mean-of-reps std is
    # scale/sqrt(reps) — allow 6 sigma
    scale = float(jnp.abs(v).max()) / wire.LEVELS[kind]
    tol = 6.0 * scale / np.sqrt(reps)
    assert float(jnp.abs(mean - v).max()) <= tol


@given(st.integers(0, 2**16))
@settings(max_examples=6, deadline=None)
def test_core_quantize_stochastic_unbiased(seed):
    from repro.core.compression import quantize_stochastic

    rng = np.random.default_rng(seed)
    d = 40
    v = jnp.asarray(rng.normal(size=(d,)))
    reps = 4096
    keys = jax.random.split(jax.random.key(seed), reps)
    mean = jax.vmap(lambda k: quantize_stochastic(k, v, 8))(keys).mean(
        axis=0)
    scale = float(jnp.abs(v).max()) / 127.0
    tol = 6.0 * scale / np.sqrt(reps)
    assert float(jnp.abs(mean - v).max()) <= tol


def test_per_chunk_scales_survive_outliers():
    """One huge coordinate in chunk 0 must not collapse the resolution of
    chunk 1 (the satellite fix): with per-tensor scaling the small chunk's
    values all quantize to 0/±1 steps of a giant scale; per-chunk, their
    error is bounded by their OWN chunk max."""
    from repro.core.compression import quantize_stochastic

    d = 512  # two chunks of 256
    v = np.full((d,), 1e-3, np.float32)
    v[0] = 1e4  # outlier lives in chunk 0
    vj = jnp.asarray(v)
    q = quantize_stochastic(jax.random.key(0), vj, 8)
    small = np.asarray(q)[256:]
    # per-chunk scale of chunk 1 is 1e-3/127; per-tensor would be 1e4/127
    # (so small values would round to 0 or jump by ~79)
    assert np.abs(small - 1e-3).max() <= 1e-3 / 127 * 1.01
    # the wire quantizer obeys the same bound
    qw = wire.quantize(
        vj[None, :], "int8", jnp.uint32(7), jnp.zeros((1, 1), jnp.uint32),
        jnp.arange(d, dtype=jnp.int32),
    )
    assert np.abs(np.asarray(qw)[0, 256:] - 1e-3).max() <= 1e-3 / 127 * 1.01


def test_quantize_stochastic_matches_per_tensor_below_chunk():
    """For d <= chunk the per-chunk rewrite IS the per-tensor quantizer
    bitwise (one chunk, same scale, same uniform draw) — pins the floor
    assertions of test_perf_features to the same trajectory."""
    from repro.core.compression import quantize_stochastic

    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.normal(size=(32,)))
    key = jax.random.key(5)
    levels = 127.0
    scale = jnp.maximum(jnp.abs(v).max() / levels, 1e-12)
    z = v / scale
    low = jnp.floor(z)
    q_ref = (low + (jax.random.uniform(key, v.shape) < z - low)) * scale
    np.testing.assert_array_equal(
        np.asarray(quantize_stochastic(key, v, 8)), np.asarray(q_ref)
    )


# --------------------------------------------------------------------------
# nonfinite / overflow guards
# --------------------------------------------------------------------------


def test_nonfinite_never_quantized_finite():
    v = np.ones((2, 300), np.float32)
    v[0, 3] = np.nan
    v[1, 290] = np.inf
    vj = jnp.asarray(v)
    rid = jnp.arange(2, dtype=jnp.uint32)[:, None]
    kk = jnp.arange(300, dtype=jnp.int32)
    for kind in ("bf16", "f16", "int8", "int4"):
        q = np.asarray(wire.quantize(vj, kind, jnp.uint32(1), rid, kk))
        assert not np.isfinite(q[0, 3]), kind
        assert not np.isfinite(q[1, 290]), kind
    # int wire-lane form: codes stay int, the chunk SCALE carries the NaN
    scales = wire.leaf_scales(vj, "int8")
    codes, sc = wire.quantize_to_int(
        vj, "int8", jnp.uint32(1), rid, kk, scales, kk // wire.CHUNK
    )
    assert codes.dtype == jnp.int8
    assert np.isnan(np.asarray(sc)[0, 0])  # row 0 chunk 0 poisoned
    assert np.isnan(np.asarray(sc)[1, 1])  # row 1 chunk 1 poisoned
    assert np.isfinite(np.asarray(sc)[0, 1]) and np.isfinite(
        np.asarray(sc)[1, 0])
    from repro.kernels.compress import wire_dequant

    dq = np.asarray(wire_dequant(codes, sc, kk // wire.CHUNK))
    assert np.isnan(dq[0, :256]).all() and np.isfinite(dq[0, 256:]).all()


def test_f16_wire_never_overflows_finite_payload():
    v = jnp.asarray([[1e38, -3e38, 65504.0, 1.5]], jnp.float32)
    q = np.asarray(wire.quantize(
        v, "f16", jnp.uint32(0), jnp.zeros((1, 1), jnp.uint32),
        jnp.arange(4, dtype=jnp.int32),
    ))
    assert np.isfinite(q).all()
    assert q[0, 3] == 1.5


def test_core_quantizer_passes_nonfinite_through():
    from repro.core.compression import quantize_stochastic

    v = jnp.asarray([np.nan, np.inf, 1.0, -2.0])
    q = np.asarray(quantize_stochastic(jax.random.key(0), v, 8))
    assert np.isnan(q[0]) and np.isinf(q[1]) and np.isfinite(q[2:]).all()


# --------------------------------------------------------------------------
# cross-impl agreement at matching wire seeds
# --------------------------------------------------------------------------

ncs = st.tuples(
    st.integers(2, 9),  # n
    st.integers(2, 9),  # c
    st.integers(2, 9),  # s
    st.integers(0, 2**16),  # seed
).filter(lambda t: t[1] <= t[0] and t[2] <= t[1])


@given(ncs, st.sampled_from(["f16", "int8", "auto"]))
@settings(max_examples=10, deadline=None)
def test_quantized_cyclic_impls_agree(t, policy):
    n, c, s, seed = t
    rng = np.random.default_rng(seed)
    x, h = _tree(rng, n)
    slot = _slot(rng, n, c)
    wseed = wire.round_seed(
        jax.random.fold_in(jax.random.key(seed), wire.WIRE_FOLD))
    kw = dict(wire=policy, wire_seed=wseed)
    xd, hd = jax.jit(
        lambda x, h: comm_ws.cyclic_comm(x, h, slot, c, s, 0.37,
                                         impl="dense", **kw)
    )(x, h)
    mesh = _mesh_1x1()
    for impl, meshed, extra in (
        ("ws", False, {}),
        ("ws", True, {}),
        ("pallas", False, {}),
        ("pallas", True, {"mesh": mesh, "shard_kernels": False}),
        ("pallas", True, {"mesh": mesh, "shard_kernels": True}),
    ):
        xn, hn = jax.jit(
            lambda x, h, impl=impl, meshed=meshed, extra=extra:
                comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl=impl,
                                    block=32, meshed=meshed, **extra, **kw)
        )(x, h)
        assert _maxerr(xd, xn) <= 1e-6, (impl, meshed, policy, n, c, s)
        assert _maxerr(hd, hn) <= 1e-6, (impl, meshed, policy, n, c, s)


@given(ncs, st.sampled_from(["f16", "int8"]))
@settings(max_examples=8, deadline=None)
def test_quantized_blocked_impls_agree(t, policy):
    n, _, s, seed = t
    rng = np.random.default_rng(seed)
    x, h = _tree(rng, n)
    off = jnp.asarray(int(rng.integers(0, n)), jnp.int32)
    wseed = wire.round_seed(
        jax.random.fold_in(jax.random.key(seed), wire.WIRE_FOLD))
    kw = dict(wire=policy, wire_seed=wseed)
    xd, hd = jax.jit(
        lambda x, h: comm_ws.blocked_comm(x, h, off, n, s, 0.37,
                                          impl="dense", **kw)
    )(x, h)
    mesh = _mesh_1x1()
    for impl, meshed, extra in (
        ("ws", False, {}),
        ("pallas", False, {}),
        ("pallas", True, {"mesh": mesh, "shard_kernels": True}),
    ):
        xn, hn = jax.jit(
            lambda x, h, impl=impl, meshed=meshed, extra=extra:
                comm_ws.blocked_comm(x, h, off, n, s, 0.37, impl=impl,
                                     block=32, meshed=meshed, **extra, **kw)
        )(x, h)
        assert _maxerr(xd, xn) <= 1e-6, (impl, meshed, policy, n, s)
        assert _maxerr(hd, hn) <= 1e-6, (impl, meshed, policy, n, s)


@given(ncs)
@settings(max_examples=8, deadline=None)
def test_quantized_elastic_and_arrived_compose(t):
    """c < n cohorts + a dropped arrival under int8: all impls agree, and
    a coordinate with no arrived owner passes through x and h bitwise
    untouched (the §12 contract survives quantization — the survivor
    rebuild runs AFTER dequantization)."""
    n, c, s, seed = t
    if c == n:
        c = max(2, n - 1)
        if s > c:
            s = c
    rng = np.random.default_rng(seed)
    x, h = _tree(rng, n)
    # cohort of c rows; one cohort member drops
    cohort = rng.permutation(n)[:c]
    slot_np = np.full((n,), -1, np.int64)
    slot_np[cohort] = rng.permutation(c)
    slot = jnp.asarray(slot_np, jnp.int32)
    arrived_np = np.ones((n,), bool)
    arrived_np[cohort[0]] = False
    arrived = jnp.asarray(arrived_np)
    wseed = wire.round_seed(
        jax.random.fold_in(jax.random.key(seed), wire.WIRE_FOLD))
    kw = dict(wire="int8", wire_seed=wseed, arrived=arrived)
    xd, hd = jax.jit(
        lambda x, h: comm_ws.cyclic_comm(x, h, slot, c, s, 0.37,
                                         impl="dense", **kw)
    )(x, h)
    mesh = _mesh_1x1()
    for impl, meshed, extra in (
        ("ws", False, {}),
        ("pallas", False, {}),
        ("pallas", True, {"mesh": mesh, "shard_kernels": True}),
    ):
        xn, hn = jax.jit(
            lambda x, h, impl=impl, meshed=meshed, extra=extra:
                comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl=impl,
                                    block=32, meshed=meshed, **extra, **kw)
        )(x, h)
        assert _maxerr(xd, xn) <= 1e-6, (impl, meshed)
        assert _maxerr(hd, hn) <= 1e-6, (impl, meshed)
        # uncovered coordinates: no arrived owner -> x, h bitwise kept
        sl = slot_np[:, None]
        arr = arrived_np[:, None]
        for k, a in x.items():
            D = int(np.prod(a.shape[1:]))
            from repro.core import masks

            q = np.asarray(masks.mask_from_permutation(
                jnp.arange(c, dtype=jnp.int32), D, c, s)).astype(bool)
            owned = (np.where(sl >= 0, q.T[np.clip(slot_np, 0, c - 1)],
                              False) & (sl >= 0) & arr)
            uncov = ~owned.any(axis=0)
            if uncov.any():
                xa = np.asarray(a).reshape(n, D)
                xb = np.asarray(xn[k]).reshape(n, D)
                ha = np.asarray(h[k]).reshape(n, D)
                hb = np.asarray(hn[k]).reshape(n, D)
                np.testing.assert_array_equal(xa[:, uncov], xb[:, uncov])
                np.testing.assert_array_equal(ha[:, uncov], hb[:, uncov])


# --------------------------------------------------------------------------
# f32 wire == unquantized engine, bitwise
# --------------------------------------------------------------------------


def test_f32_wire_bitwise_identity_all_impls():
    rng = np.random.default_rng(11)
    n, c, s = 6, 5, 3
    x, h = _tree(rng, n)
    slot_np = np.full((n,), -1, np.int64)
    cohort = rng.permutation(n)[:c]
    slot_np[cohort] = rng.permutation(c)
    slot = jnp.asarray(slot_np, jnp.int32)
    wseed = wire.round_seed(
        jax.random.fold_in(jax.random.key(0), wire.WIRE_FOLD))
    mesh = _mesh_1x1()
    cases = [
        ("dense", False, {}),
        ("ws", False, {}),
        ("pallas", False, {}),
        ("pallas", True, {"mesh": mesh, "shard_kernels": True}),
        ("pallas", True, {"mesh": mesh, "shard_kernels": False}),
    ]
    for impl, meshed, extra in cases:
        base = jax.jit(
            lambda x, h, impl=impl, meshed=meshed, extra=extra:
                comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl=impl,
                                    block=32, meshed=meshed, **extra)
        )(x, h)
        wired = jax.jit(
            lambda x, h, impl=impl, meshed=meshed, extra=extra:
                comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl=impl,
                                    block=32, meshed=meshed, wire="f32",
                                    wire_seed=wseed, **extra)
        )(x, h)
        for k in x:
            np.testing.assert_array_equal(
                np.asarray(base[0][k]), np.asarray(wired[0][k]),
                err_msg=f"{impl} meshed={meshed} x[{k}]")
            np.testing.assert_array_equal(
                np.asarray(base[1][k]), np.asarray(wired[1][k]),
                err_msg=f"{impl} meshed={meshed} h[{k}]")
    # blocked template too
    off = jnp.int32(2)
    for impl in ("dense", "ws", "pallas"):
        base = comm_ws.blocked_comm(x, h, off, n, s, 0.37, impl=impl)
        wired = comm_ws.blocked_comm(x, h, off, n, s, 0.37, impl=impl,
                                     wire="f32", wire_seed=wseed)
        for k in x:
            np.testing.assert_array_equal(
                np.asarray(base[0][k]), np.asarray(wired[0][k]))
            np.testing.assert_array_equal(
                np.asarray(base[1][k]), np.asarray(wired[1][k]))


def test_wire_determinism_same_seed_same_wire():
    """Same wire seed -> bitwise-identical quantized comm (replay); a
    different seed changes the draw (the stream is live)."""
    rng = np.random.default_rng(7)
    n, c, s = 5, 5, 3
    x, h = _tree(rng, n)
    slot = jnp.asarray(np.arange(n) % c, jnp.int32)
    s1 = wire.round_seed(
        jax.random.fold_in(jax.random.key(1), wire.WIRE_FOLD))
    s2 = wire.round_seed(
        jax.random.fold_in(jax.random.key(2), wire.WIRE_FOLD))
    a = comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl="ws",
                            wire="int8", wire_seed=s1)
    b = comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl="ws",
                            wire="int8", wire_seed=s1)
    d = comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl="ws",
                            wire="int8", wire_seed=s2)
    for k in x:
        np.testing.assert_array_equal(np.asarray(a[0][k]),
                                      np.asarray(b[0][k]))
    assert _maxerr(a[0], d[0]) > 0.0


# --------------------------------------------------------------------------
# byte accounting
# --------------------------------------------------------------------------


def test_uplink_bytes_f32_identical_to_floats():
    from repro.core import compression

    for d, c, s in ((1000, 8, 4), (37, 5, 2), (65537, 10, 3)):
        assert compression.uplink_bytes_permutation(d, c, s) == \
            compression.uplink_floats_permutation(d, c, s) * 4.0
    assert compression.uplink_bytes_rand_k(17) == 17 * 4.0
    assert wire.leaf_up_bytes(100, 1000, 1, "f32") == 400.0
    assert wire.leaf_down_bytes(1000, "f32") == 4000.0


def test_leaf_bytes_int8_near_4x_reduction():
    d, c, s = 2**17, 8, 4
    from repro.core import masks

    nnz = masks.column_nnz(d, c, s)
    f32 = wire.leaf_up_bytes(nnz, d, 1, "f32")
    i8 = wire.leaf_up_bytes(nnz, d, 1, "int8")
    assert f32 / i8 >= 3.5


def test_resolve_kind_auto_threshold():
    assert wire.resolve_kind(10, "auto") == "f16"
    assert wire.resolve_kind(2**16, "auto") == "f16"
    assert wire.resolve_kind(2**16 + 1, "auto") == "int8"
    assert wire.resolve_kind(123, None) == "f32"
    assert wire.resolve_kind(123, "int4") == "int4"


def test_comm_step_bytes_accounting(subproc):
    subproc("""
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.core import masks
from repro.models.transformer import ModelConfig
from repro.dist import sharding, tamuna_dp, wire
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
c = 3
for policy in ("f32", "int8", "auto"):
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=2, p=0.5,
                                      wire_precision=policy)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      tamuna_dp.state_pspecs(state, cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, sh)
    fn = tamuna_dp.make_comm_step(cfg, tcfg, mesh)
    out = jax.jit(fn)(state, jax.random.key(11))
    dims = [int(np.prod(a.shape[1:])) for a in jax.tree.leaves(state.x)]
    kinds = [wire.resolve_kind(D, policy) for D in dims]
    exp_up = sum(
        wire.leaf_up_bytes(masks.column_nnz(D, c, 2), D, 1, k)
        for D, k in zip(dims, kinds))
    assert float(out.up_bytes) == float(jnp.float32(exp_up)), policy
    assert float(out.down_bytes) == float(sum(dims)) * 4.0, policy
    if policy == "f32":
        assert float(out.up_bytes) == float(out.up_floats) * 4.0
    else:
        # the quantized wire really is smaller on this model
        assert float(out.up_bytes) < float(out.up_floats) * 4.0
print("OK")
""")
