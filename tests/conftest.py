"""Shared fixtures.  NOTE: no XLA device-count forcing in THIS process —
smoke tests and benches must see the real single CPU device; multi-device
tests run through the ``subproc`` fixture, which is where the
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` default lives.

Also installs the offline `hypothesis` fallback (tests/_vendor) when the
real package is not installed, so the property-test modules collect and run
on the container without pip access.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

try:  # pragma: no cover - environment dependent
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(REPO, "tests", "_vendor"))

DEFAULT_DEVICES = int(os.environ.get("REPRO_TEST_DEVICES", "8"))


def run_in_subprocess(code: str, devices: int = DEFAULT_DEVICES,
                      timeout: int = 900):
    """Run python code in a fresh process with a forced host device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_in_subprocess
