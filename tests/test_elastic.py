"""Elastic partial participation (DESIGN.md §11): the dist engine against
the paper-faithful Algorithm 1 semantics at c < n.

* One elastic engine round == a line-for-line transliteration of
  ``repro.core.tamuna.round_step`` (cohort gather, local steps from the
  shared model, mask from ``repro.core.masks.mask_from_permutation`` —
  cyclic perm for masked_psum, ``block_shift_permutation`` for block_rs —
  1/s aggregation, cohort-only h-update, next-cohort DownCom), at
  n=16, c=4 on a single device (the n-override placement), <= 1e-6.
* Clients sitting out a round are bitwise untouched (x, h, AdamW moments).
* Idle clients provably do no gradient work: compiled-HLO FLOPs of the
  elastic round scale with c, not n.
* ``run_rounds`` with an availability-driven ``CohortPlan``: mid-run
  checkpoint round-trip, global-round plan indexing on the continuation.
* ``CohortPlan`` / availability model unit behaviour (host-side).
"""

import numpy as np
import pytest


def test_cohort_plan_deterministic_and_availability_gated():
    from repro.dist import cohort as cm

    n, c = 12, 4
    plan = cm.CohortPlan(seed=5, n=n, c=c)
    a, b = plan.cohort(7), cm.CohortPlan(seed=5, n=n, c=c).cohort(7)
    np.testing.assert_array_equal(a, b)  # pure in (seed, round)
    assert len(set(a.tolist())) == c and (np.diff(a) > 0).all()
    assert plan.cohort(8).tolist() != a.tolist()  # rounds differ

    # hard-down clients are never drafted while >= c clients are up
    p_up = np.ones(n)
    p_up[:3] = 0.0
    gated = cm.CohortPlan(
        seed=1, n=n, c=c,
        availability=cm.BernoulliAvailability(p_up=p_up, seed=2),
    )
    for r in range(30):
        assert (gated.cohort(r) >= 3).all(), r
    # ...but the plan still fills the cohort when the fleet is short
    mostly_down = cm.CohortPlan(
        seed=1, n=n, c=c,
        availability=cm.BernoulliAvailability(p_up=np.zeros(n), seed=2),
    )
    assert len(mostly_down.cohort(0)) == c

    # Markov streams are lazily advanced and replayable
    mk = cm.MarkovAvailability(p_fail=0.4, p_recover=0.5, n=n, seed=3)
    s10 = mk.states(10).copy()
    mk2 = cm.MarkovAvailability(p_fail=0.4, p_recover=0.5, n=n, seed=3)
    np.testing.assert_array_equal(s10, mk2.states(10))
    assert mk.states(0).all()  # everyone starts up

    # weights bias selection: a heavily weighted client appears in nearly
    # every cohort
    w = np.ones(n)
    w[5] = 1e6
    weighted = cm.CohortPlan(seed=9, n=n, c=c, weights=w)
    hits = sum(5 in weighted.cohort(r) for r in range(50))
    assert hits >= 45, hits


def test_elastic_round_matches_algorithm1_reference(subproc):
    """n=16 clients on ONE device (the n-override placement): one elastic
    engine round at L=3 equals the Algorithm-1 reference — mirroring
    ``repro.core.tamuna.round_step`` with the mask built by
    ``repro.core.masks`` — for both uplinks, <= 1e-6; idle clients bitwise
    untouched; sum_i h_i == 0 preserved; cohort-based float accounting."""
    subproc("""
import numpy as np
import jax, jax.numpy as jnp
from repro.core import masks
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import rounds, tamuna_dp

mesh = jax.make_mesh((1, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
N, C, S, L = 16, 4, 2, 3
dcfg = DataConfig(seq_len=8, per_client_batch=1, vocab=64, seed=0,
                  n_clients=N)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
data = pipe.device_data()
sampler = device_sampler(dcfg, cfg, mesh)

def flat(tree, rows):
    return jnp.concatenate(
        [a.reshape(rows, -1) for a in jax.tree.leaves(tree)], axis=1)

for uplink in ("masked_psum", "block_rs"):
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=C, s=S, p=0.5,
                                      uplink=uplink)

    def mk_state():
        st = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg, n=N)
        # distinct per-client h so the control-variate term is non-trivial
        h0 = jax.tree.map(
            lambda a: 0.01 * jax.random.normal(
                jax.random.key(hash(a.shape) % 97), a.shape, jnp.float32),
            st.h)
        h0 = jax.tree.map(lambda a: a - a.mean(axis=0, keepdims=True), h0)
        return st._replace(h=h0)

    # two independent copies: the engine DONATES its carry (state0's
    # buffers die inside round_fn), the reference reads its own
    state0 = mk_state()

    round_fn = rounds.make_round_fn(cfg, tcfg, mesh, sample_batch=sampler,
                                    max_L=8, n=N)
    assert round_fn.elastic
    carry = rounds.init_carry(mk_state(), jax.random.key(11),
                              flush_every=1)
    dk = np.asarray(carry.data_key).copy()
    ck = np.asarray(carry.comm_key).copy()
    carry = round_fn(carry, data, L, 0)
    got = carry.state

    # ---- Algorithm-1 reference (mirrors repro.core.tamuna.round_step) --
    ckey = rounds.comm_round_key(ck, 0)
    cohort = np.asarray(tamuna_dp.round_cohort(ckey, N, C))
    nxt = np.asarray(tamuna_dp.round_cohort(rounds.comm_round_key(ck, 1),
                                            N, C))
    _, k2 = jax.random.split(tamuna_dp._as_key(ckey))

    # L local steps x <- x - gamma*(g - h) for the cohort only, batches
    # keyed by the ACTUAL client ids (tamuna lines 5-9; the local rule is
    # the engine's own step operator — pinned elsewhere against the
    # closed form — replayed per step on the gathered compact state, so
    # the comm-side transliteration below is compared at tight tolerance
    # instead of through f32 gradient-recompilation drift)
    local = jax.jit(tamuna_dp.make_local_step(cfg, tcfg))
    work = tamuna_dp.gather_cohort(state0, jnp.asarray(cohort))
    for t in range(L):
        batch = sampler(data, rounds.data_step_key(dk, t),
                        clients=jnp.asarray(cohort))
        work, _ = local(work, **batch)
    X = work.x

    # the round mask from the CORE's generator (tamuna line 11):
    # cyclic -> the comm key's permutation over cohort slots; blocked ->
    # the shift realized as a template column permutation.  Built PER
    # LEAF (the dist engine chunks/bands each leaf independently) and
    # concatenated in the same flat order.
    if uplink == "masked_psum":
        perm = jax.random.permutation(k2, C)
    else:
        off = jax.random.randint(k2, (), 0, C, jnp.int32)
        perm = masks.block_shift_permutation(off, C, S)
    eta = tcfg.eta_(N)
    xr = np.asarray(flat(state0.x, N), np.float64)
    hr = np.asarray(flat(state0.h, N), np.float64)
    Xf = np.asarray(flat(X, C), np.float64)
    D = xr.shape[1]
    q = np.concatenate([
        np.asarray(masks.mask_from_permutation(
            perm, int(np.prod(a.shape[1:])), C, S,
            blocked=(uplink == "block_rs")), np.float64)
        for a in jax.tree.leaves(state0.x)
    ], axis=0).T
    # aggregation + cohort h-update (tamuna lines 12-14), then the
    # DownCom to the NEXT round's cohort (line 4 of round r+1)
    x_bar = (q * Xf).sum(axis=0) / S
    hr[cohort] += (eta / tcfg.gamma) * q * (x_bar[None] - Xf)
    xr[cohort] = Xf
    xr[nxt] = x_bar[None]

    err_x = np.abs(np.asarray(flat(got.x, N), np.float64) - xr).max()
    err_h = np.abs(np.asarray(flat(got.h, N), np.float64) - hr).max()
    assert err_x <= 2e-6, (uplink, err_x)
    assert err_h <= 2e-6, (uplink, err_h)
    # sum_i h_i == 0 survives the cohort-only update
    assert np.abs(np.asarray(flat(got.h, N)).sum(axis=0)).max() < 1e-5
    # clients outside cohort(0) and cohort(1): bitwise untouched
    idle = sorted(set(range(N)) - set(cohort) - set(nxt))
    assert idle, (cohort, nxt)
    x0f, g_xf = np.asarray(flat(state0.x, N)), np.asarray(flat(got.x, N))
    h0f, g_hf = np.asarray(flat(state0.h, N)), np.asarray(flat(got.h, N))
    np.testing.assert_array_equal(g_xf[idle], x0f[idle])
    np.testing.assert_array_equal(g_hf[idle], h0f[idle])
    # h untouched for EVERY non-cohort client (DownCom only writes x)
    out = sorted(set(range(N)) - set(cohort))
    np.testing.assert_array_equal(g_hf[out], h0f[out])
    # float accounting on the COHORT template
    dims = [int(np.prod(a.shape[1:])) for a in jax.tree.leaves(state0.x)]
    if uplink == "block_rs":
        up = sum(masks.block_column_nnz(d_, C, S) for d_ in dims)
    else:
        up = sum(masks.column_nnz(d_, C, S) for d_ in dims)
    assert float(got.up_floats) == float(up), uplink
print("OK")
""", devices=1, timeout=1500)


def test_idle_clients_do_zero_gradient_compute(subproc):
    """FLOP regression: compiled elastic-round FLOPs scale with the cohort.
    At n=8, c=2 the elastic program must cost well under half the all-rows
    program (grads dominate; c/n = 0.25), and AdamW moments of clients
    sitting out stay bitwise frozen through a plan-driven round."""
    subproc("""
import numpy as np
import jax, jax.numpy as jnp
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import rounds, tamuna_dp

mesh = jax.make_mesh((1, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32,
                  remat=False)
N, C = 8, 2
dcfg = DataConfig(seq_len=16, per_client_batch=2, vocab=64, seed=0,
                  n_clients=N)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
sampler = device_sampler(dcfg, cfg, mesh)
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=C, s=2, p=0.5)

def flops_of(elastic):
    fn = rounds.make_fused_round(cfg, tcfg, mesh, sample_batch=sampler,
                                 L=4, n=N, elastic=elastic)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg, n=N)
    compiled = jax.jit(fn).lower(
        state, jax.random.key_data(jax.random.key(1)), pipe.device_data()
    ).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: [dict]
        cost = cost[0] if cost else {}
    return float(cost.get("flops", 0.0))

fe, fa = flops_of(True), flops_of(False)
assert fe > 0 and fa > 0, (fe, fa)
# c/n = 0.25 of the gradient work + comm/gather overhead; anything near
# parity means idle rows are still doing gradient compute
assert fe < 0.6 * fa, (fe, fa, fe / fa)

# AdamW moments of sat-out clients stay bitwise frozen under an explicit
# host plan (cohort AND down pinned)
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.01, c=C, s=2, p=0.5,
                                  local_opt="adamw", uplink="block_rs")
round_fn = rounds.make_round_fn(cfg, tcfg, mesh, sample_batch=sampler,
                                max_L=4, n=N)
state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg, n=N)
carry = rounds.init_carry(state, jax.random.key(3), flush_every=1)
before = jax.tree.map(np.asarray, carry.state)
cohort = jnp.asarray([1, 4], jnp.int32)
down = jnp.zeros((N,), bool).at[jnp.asarray([2, 4])].set(True)
carry = round_fn(carry, pipe.device_data(), 3, 0, cohort=cohort,
                 down=down)
after = jax.tree.map(np.asarray, carry.state)
idle = [0, 3, 5, 6, 7]  # not in cohort, not DownCom'd
for name in ("x", "h"):
    for a, b in zip(jax.tree.leaves(getattr(before, name)),
                    jax.tree.leaves(getattr(after, name))):
        np.testing.assert_array_equal(a[idle], b[idle])
for tree in ("mu", "nu"):
    for a, b in zip(jax.tree.leaves(getattr(before.opt, tree)),
                    jax.tree.leaves(getattr(after.opt, tree))):
        np.testing.assert_array_equal(a[[0, 2, 3, 5, 6, 7]],
                                      b[[0, 2, 3, 5, 6, 7]])
# ...and the DownCom'd rows DID receive the new server model
xa = jax.tree.leaves(after.x)[0]
np.testing.assert_array_equal(xa[2], xa[4])
assert not np.array_equal(xa[2], jax.tree.leaves(before.x)[0][2])
print("OK")
""", devices=1, timeout=1500)


def test_run_rounds_plan_checkpoint_roundtrip(subproc):
    """Mid-``run_rounds`` checkpoint with an availability-driven
    ``CohortPlan``: bit-exact state round-trip, and the continuation
    indexes the plan by the GLOBAL round counter — clients the plan
    leaves idle in the continued round stay bitwise frozen."""
    subproc("""
import os, tempfile
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import checkpoint
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import cohort as cm
from repro.dist import rounds, sharding, tamuna_dp

mesh = jax.make_mesh((4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
dcfg = DataConfig(seq_len=8, per_client_batch=1, vocab=64, seed=0,
                  n_clients=n)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=2, s=2, p=0.5)
plan = cm.CohortPlan(
    seed=17, n=n, c=2,
    availability=cm.MarkovAvailability(p_fail=0.3, p_recover=0.6, n=n,
                                       seed=4),
)
state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                  tamuna_dp.state_pspecs(state, cfg, mesh),
                  is_leaf=lambda x: isinstance(x, P))
state = jax.device_put(state, sh)
round_fn = rounds.make_round_fn(
    cfg, tcfg, mesh, sample_batch=device_sampler(dcfg, cfg, mesh), max_L=4,
    elastic=True)  # forced: one client per shard here (default = all-rows)
d = tempfile.mkdtemp()
final, last = rounds.run_rounds(
    state, round_fn=round_fn, data=pipe.device_data(),
    key=jax.random.key(3), rounds=2, rng=np.random.default_rng(0),
    p=tcfg.p, flush_every=2, checkpoint_dir=d, checkpoint_every=2,
    plan=plan)
assert int(final.round) == 2

like = jax.tree.map(jnp.zeros_like, final)
restored = checkpoint.restore(os.path.join(d, 'step_2'), like)
for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# continuation: round index resumes at state.round == 2, so the engine
# must consume plan.cohort(2)/plan.cohort(3) — anyone else stays frozen
restored = jax.device_put(restored, sh)
before = {k: np.asarray(v) for k, v in
          zip(['x', 'h'], [jax.tree.leaves(restored.x)[0],
                           jax.tree.leaves(restored.h)[0]])}
cont, _ = rounds.run_rounds(
    restored, round_fn=round_fn, data=pipe.device_data(),
    key=jax.random.key(4), rounds=1, rng=np.random.default_rng(1),
    p=tcfg.p, flush_every=1, plan=plan)
assert int(cont.round) == 3
active = set(plan.cohort(2).tolist()) | set(plan.cohort(3).tolist())
idle = sorted(set(range(n)) - active)
xa = np.asarray(jax.tree.leaves(cont.x)[0])
ha = np.asarray(jax.tree.leaves(cont.h)[0])
np.testing.assert_array_equal(xa[idle], before['x'][idle])
np.testing.assert_array_equal(ha[idle], before['h'][idle])
trained = sorted(set(plan.cohort(2).tolist()))
assert any(not np.array_equal(ha[i], before['h'][i]) for i in trained)
print("OK")
""", devices=4, timeout=1500)
