"""Every baseline the paper compares against must converge on the same
heterogeneous strongly convex problem (exact gradients)."""

import numpy as np
import pytest

from repro.core import baselines, problems


@pytest.fixture(scope="module")
def quad():
    return problems.make_quadratic_problem(n=16, d=32, kappa=50)


@pytest.fixture(scope="module")
def logreg():
    # the paper's regime: large-ish kappa and d, heterogeneous Hessians
    return problems.make_logreg_problem(
        n=64, d=256, samples_per_client=8, kappa=1000.0, seed=0
    )


def gamma(prob):
    return 2.0 / (prob.L + prob.mu)


def test_gd(quad):
    tr = baselines.run_gd(quad, gamma(quad), 400, record_every=100)
    assert tr["suboptimality"][-1] < 1e-10


def test_fedavg_has_client_drift_floor(logreg):
    # LocalSGD with exact gradients converges to a BIASED fixed point on
    # problems with heterogeneous curvature (client drift) — the motivation
    # for TAMUNA's control variates.  (NB: on shared-Hessian quadratics the
    # drift provably cancels, so this must be tested on logistic regression.)
    tr = baselines.run_fedavg(
        logreg, 0.3 * gamma(logreg), local_steps=8, num_rounds=600,
        record_every=200,
    )
    floor = tr["suboptimality"][-1]
    assert floor < 0.1  # converges...
    assert floor > 1e-8  # ...but not to the exact solution


def test_scaffold(quad):
    tr = baselines.run_scaffold(
        quad, 0.5 * gamma(quad), local_steps=5, num_rounds=500,
        record_every=100,
    )
    assert tr["suboptimality"][-1] < 1e-12


def test_scaffold_partial_participation(quad):
    tr = baselines.run_scaffold(
        quad, 0.5 * gamma(quad), local_steps=5, c=4, num_rounds=1500,
        record_every=300,
    )
    assert tr["suboptimality"][-1] < 1e-8


def test_scaffnew(quad):
    tr = baselines.run_scaffnew(
        quad, gamma(quad), p=0.3, num_iters=2000, record_every=500
    )
    assert tr["suboptimality"][-1] < 1e-12


def test_compressed_scaffnew(quad):
    tr = baselines.run_compressed_scaffnew(
        quad, gamma(quad), p=0.3, s=4, num_iters=3000, record_every=500
    )
    assert tr["suboptimality"][-1] < 1e-10


def test_diana(quad):
    tr = baselines.run_diana(
        quad, 0.5 / quad.L, k=4, num_rounds=3000, record_every=500
    )
    assert tr["suboptimality"][-1] < 1e-10


def test_ef21(quad):
    tr = baselines.run_ef21(
        quad, 0.5 / quad.L, k=4, num_rounds=3000, record_every=500
    )
    assert tr["suboptimality"][-1] < 1e-10


def test_5gcs(quad):
    tr = baselines.run_5gcs(
        quad, 0.25 / quad.mu, c=8, inner_steps=30, num_rounds=400,
        record_every=100,
    )
    assert tr["suboptimality"][-1] < 1e-9


def test_tamuna_beats_scaffold_on_upcom(logreg):
    """Headline claim (paper Fig. 2, Table 1): in the large-kappa/large-d
    regime, TAMUNA reaches target accuracy with several times fewer uploaded
    floats per client than the non-accelerated LT+PP baseline."""
    from repro.core import tamuna

    target = float(logreg.suboptimality(logreg.x_star * 0.0)) * 1e-6
    cfg = tamuna.TamunaConfig.tuned(logreg, c=16)
    tr_t = tamuna.run(logreg, cfg, num_rounds=3000, record_every=20)
    tr_s = baselines.run_scaffold(
        logreg, 0.5 * gamma(logreg), local_steps=max(1, int(1 / cfg.p)),
        c=16, num_rounds=3000, record_every=20,
    )

    def floats_to(tr):
        idx = np.argmax(tr["suboptimality"] < target)
        assert tr["suboptimality"][idx] < target, tr["algo"]
        return tr["up_floats"][idx]

    ft, fs = floats_to(tr_t), floats_to(tr_s)
    assert ft < fs / 3, (ft, fs)  # at least a 3x UpCom win
