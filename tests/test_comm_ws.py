"""Flat comm workspace + fused uplink invariants (DESIGN.md §9/§10):

* pack/unpack round-trips the stacked state bit-exactly (incl. bf16),
* the fused workspace paths (jnp ``ws``, Pallas ``pallas``, and the
  shard-resident meshed-pallas engine in both per-shard modes) match the
  per-leaf dense-mask reference to <= 1e-6 for ragged d, idle clients
  (c < n), s == c (no compression), tall-regime leaves, and both uplinks,
* exactness at consensus (the paper's zero-error property) holds on the
  fused paths,
* ``make_comm_step`` impls agree end to end (state + float accounting) and
  mid-``run_rounds`` for both uplinks,
* no dense ``(n, d)`` / ``(d, c)`` boolean mask appears in the lowered
  Pallas comm step (the dense reference is the positive control).

Multi-device mesh coverage of the shard engine (1x8 / 4x2 / 8x1 shapes,
HLO collective regression) lives in tests/test_comm_shard.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.dist import comm_ws


def _mesh_1x1():
    """Single-device mesh: exercises the shard-resident engine's full code
    path (pad, per-shard tables, psum) in-process under hypothesis."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )

ncs = st.tuples(
    st.integers(2, 9),  # n
    st.integers(2, 9),  # c
    st.integers(2, 9),  # s
    st.integers(0, 2**16),  # seed
).filter(lambda t: t[1] <= t[0] and t[2] <= t[1])


def _tree(rng, n):
    """Stacked tree with a reshaped leaf, ragged dims, a bf16 leaf, and a
    tall-regime candidate (D=1 so D*s < c whenever s < c)."""
    x = {
        "w": jnp.asarray(rng.normal(size=(n, 13, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 1)), jnp.bfloat16),
        "v": jnp.asarray(rng.normal(size=(n, 29)), jnp.float32),
    }
    h = {
        k: jnp.asarray(rng.normal(size=a.shape), jnp.float32)
        for k, a in x.items()
    }
    # center h so sum_i h_i == 0 going in (the invariant to preserve)
    h = jax.tree.map(lambda a: a - a.mean(axis=0, keepdims=True), h)
    return x, h


def _slot(rng, n, c):
    """Template column per client (perm of the cohort's slots, -1 idle)."""
    cohort = rng.choice(n, size=c, replace=False)
    out = np.full((n,), -1, np.int32)
    out[cohort] = rng.permutation(c)
    return jnp.asarray(out)


def _maxerr(a, b):
    return max(
        float(jnp.abs(u.astype(jnp.float32) - v.astype(jnp.float32)).max())
        for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# --------------------------------------------------------------------------
# pack / unpack
# --------------------------------------------------------------------------


def test_pack_unpack_roundtrip_bitexact():
    rng = np.random.default_rng(0)
    x, _ = _tree(rng, 5)
    leaves = jax.tree.leaves(x)
    spec = comm_ws.workspace_spec(leaves)
    assert spec.d_total == sum(spec.dims)
    assert spec.offsets == (0, 1, 30)  # sorted dict order: b(1), v(29), w(65)
    ws = comm_ws.pack(leaves, spec)
    assert ws.shape == (5, spec.d_total) and ws.dtype == jnp.float32
    back = comm_ws.unpack(ws, spec)
    for a, b in zip(leaves, back):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


# --------------------------------------------------------------------------
# fused paths == dense-mask reference
# --------------------------------------------------------------------------


@given(ncs)
@settings(max_examples=20, deadline=None)
def test_cyclic_ws_and_pallas_match_dense(t):
    n, c, s, seed = t
    rng = np.random.default_rng(seed)
    x, h = _tree(rng, n)
    slot = _slot(rng, n, c)
    xd, hd = jax.jit(
        lambda x, h: comm_ws.cyclic_comm(x, h, slot, c, s, 0.37,
                                         impl="dense")
    )(x, h)
    mesh = _mesh_1x1()
    for impl, meshed, kw in (
        ("ws", False, {}),
        ("ws", True, {}),
        ("pallas", False, {}),
        # the shard-resident engine, fused-jnp and kernel per-shard modes
        # (jit'd: an eager shard_map dispatches per-op and is ~20x the
        # compiled cost)
        ("pallas", True, {"mesh": mesh, "shard_kernels": False}),
        ("pallas", True, {"mesh": mesh, "shard_kernels": True}),
    ):
        xn, hn = jax.jit(
            lambda x, h, impl=impl, meshed=meshed, kw=kw:
                comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl=impl,
                                    block=32, meshed=meshed, **kw)
        )(x, h)
        assert _maxerr(xd, xn) <= 1e-6, (impl, meshed, n, c, s)
        assert _maxerr(hd, hn) <= 1e-6, (impl, meshed, n, c, s)
        # h-sum invariant survives the fused update
        hs = max(
            float(jnp.abs(a.sum(axis=0)).max())
            for a in jax.tree.leaves(hn)
        )
        assert hs < 1e-5, (impl, hs)


@given(ncs)
@settings(max_examples=20, deadline=None)
def test_blocked_ws_and_pallas_match_dense(t):
    n, _, s, seed = t
    rng = np.random.default_rng(seed)
    x, h = _tree(rng, n)
    off = jnp.asarray(int(rng.integers(0, n)), jnp.int32)
    xd, hd = jax.jit(
        lambda x, h: comm_ws.blocked_comm(x, h, off, n, s, 0.37,
                                          impl="dense")
    )(x, h)
    mesh = _mesh_1x1()
    for impl, meshed, kw in (
        ("ws", False, {}),
        ("ws", True, {}),
        ("pallas", False, {}),
        ("pallas", True, {"mesh": mesh, "shard_kernels": False}),
        ("pallas", True, {"mesh": mesh, "shard_kernels": True}),
    ):
        xn, hn = jax.jit(
            lambda x, h, impl=impl, meshed=meshed, kw=kw:
                comm_ws.blocked_comm(x, h, off, n, s, 0.37, impl=impl,
                                     block=32, meshed=meshed, **kw)
        )(x, h)
        assert _maxerr(xd, xn) <= 1e-6, (impl, meshed, n, s)
        assert _maxerr(hd, hn) <= 1e-6, (impl, meshed, n, s)


@given(st.integers(2, 8), st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_exact_at_consensus_all_impls(c, seed):
    """All clients equal + h == 0: the comm step is a no-op on x (the
    paper's zero-error-at-consensus property) on every impl, for s == c
    (no compression) and s == 2 (max compression)."""
    n = c
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(37,)).astype(np.float32)
    x = {"p": jnp.broadcast_to(jnp.asarray(v)[None], (n, 37))}
    h = {"p": jnp.zeros((n, 37), jnp.float32)}
    slot = _slot(rng, n, c)
    for s in (2, c):
        for impl in ("dense", "ws", "pallas"):
            xn, hn = comm_ws.cyclic_comm(
                x, h, slot, c, s, 0.5, impl=impl, block=16
            )
            np.testing.assert_allclose(
                np.asarray(xn["p"][0]), v, rtol=1e-6, atol=1e-6
            )
            assert float(jnp.abs(hn["p"]).max()) < 1e-6


# --------------------------------------------------------------------------
# make_comm_step: impl equivalence, accounting, mid-run_rounds
# --------------------------------------------------------------------------


def test_comm_step_impls_agree_and_account_statically(subproc):
    subproc("""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import dataclasses
from repro.core import masks
from repro.models.transformer import ModelConfig
from repro.dist import sharding, tamuna_dp

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
# block_rs twice: full participation AND c < n — the elastic blocked
# template must count COHORT columns (s chunks of ceil(D/c)), not the
# seed's n-based constant (ISSUE 5 satellite: at c=3 < n=4 the per-client
# uplink is ~n/c larger per leaf, so the wrong constant is far outside
# float roundoff and this test pins the fix)
for uplink, c in (("masked_psum", 3), ("block_rs", None),
                  ("block_rs", 3), ("masked_psum", None)):
    c = n if c is None else c
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=2, p=0.5,
                                      uplink=uplink)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    # distinct per-client params so aggregation is non-trivial
    state = state._replace(
        x=jax.tree.map(
            lambda a: a + 0.1 * jax.random.normal(
                jax.random.key(hash(a.shape) % 97), a.shape, jnp.float32),
            state.x),
        h=jax.tree.map(
            lambda a: 0.01 * jax.random.normal(
                jax.random.key(hash(a.shape) % 89), a.shape, jnp.float32),
            state.h))
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      tamuna_dp.state_pspecs(state, cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, sh)
    key = jax.random.key(11)
    outs = {}
    for impl in ("dense", "ws", "pallas"):
        t = dataclasses.replace(tcfg, comm_impl=impl)
        outs[impl] = jax.jit(tamuna_dp.make_comm_step(cfg, t, mesh))(
            state, key)
    for impl in ("ws", "pallas"):
        for name in ("x", "h"):
            err = max(jax.tree.leaves(jax.tree.map(
                lambda a, b: float(jnp.abs(
                    a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
                getattr(outs["dense"], name), getattr(outs[impl], name))))
            assert err <= 1e-6, (uplink, impl, name, err)
    # hoisted accounting matches the per-leaf formulas exactly — on the
    # COHORT size for both uplinks (blocked chunks are ceil(D/c))
    dims = [int(np.prod(a.shape[1:])) for a in jax.tree.leaves(state.x)]
    if uplink == "block_rs":
        up = sum(masks.block_column_nnz(D, c, 2) for D in dims)
    else:
        up = sum(masks.column_nnz(D, c, 2) for D in dims)
    for impl, st_out in outs.items():
        assert float(st_out.up_floats) == float(up), (uplink, impl)
        assert float(st_out.down_floats) == float(sum(dims))
print("OK")
""")


def test_run_rounds_ws_matches_dense_both_uplinks(subproc):
    subproc("""
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import rounds, sharding, tamuna_dp

mesh = jax.make_mesh((4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
dcfg = DataConfig(seq_len=8, per_client_batch=1, vocab=64, seed=0,
                  n_clients=n)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
sampler = device_sampler(dcfg, cfg, mesh)
for uplink in ("masked_psum", "block_rs"):
    c = 3  # < n: the elastic engine, for BOTH uplinks (block_rs too, §11)
    finals = {}
    for impl in ("dense", "ws", "pallas"):
        tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=2, p=0.5,
                                          uplink=uplink, comm_impl=impl)
        state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          tamuna_dp.state_pspecs(state, cfg, mesh),
                          is_leaf=lambda x: isinstance(x, P))
        state = jax.device_put(state, sh)
        round_fn = rounds.make_round_fn(cfg, tcfg, mesh,
                                        sample_batch=sampler, max_L=4,
                                        elastic=True)
        finals[impl], last = rounds.run_rounds(
            state, round_fn=round_fn, data=pipe.device_data(),
            key=jax.random.key(5), rounds=3, rng=np.random.default_rng(7),
            p=tcfg.p, flush_every=2)
        assert np.isfinite(last["loss"])
    for impl in ("ws", "pallas"):  # pallas = the shard-resident engine
        err = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
            finals["dense"], finals[impl])))
        # 5e-6, not 1e-6: the impls share cohorts/keys but sum the UpCom
        # in different float orders, and 3 ROUNDS of training amplify the
        # per-round <=1e-6 roundoff through the gradients (the one-round
        # bound stays 1e-6 — test_fused_round_equals_per_step)
        assert err <= 5e-6, (uplink, impl, err)
print("OK")
""", devices=4, timeout=1500)


def test_no_dense_mask_in_lowered_pallas_comm_step():
    """The Pallas kernel comm path must lower without any (n, d)- or
    (d, c)-shaped boolean mask anywhere in the module (tile-sized
    predicates only); the dense reference is the positive control (its
    lowering does contain one)."""
    n, c, s = 4, 3, 2
    rng = np.random.default_rng(0)
    x = {
        "w": jnp.zeros((n, 16, 16), jnp.float32),  # D = 256
        "v": jnp.zeros((n, 100), jnp.float32),
    }
    h = {k: jnp.zeros(a.shape, jnp.float32) for k, a in x.items()}
    slot = _slot(rng, n, c)
    dims = sorted({int(np.prod(a.shape[1:])) for a in jax.tree.leaves(x)})
    BLOCK = 48  # sub-leaf tiles; not equal to any leaf dim
    big = [D for D in dims if D > BLOCK]
    assert big, dims
    # every dense-mask shape the reference could materialize:
    # (clients, D) ownership and (D, c) templates
    bad = []
    for D in big:
        bad += [f"pred[{n},{D}]", f"pred[{D},{c}]", f"s8[{D},{c}]"]

    def compiled(impl):
        fn = jax.jit(
            lambda x, h: comm_ws.cyclic_comm(
                x, h, slot, c, s, 0.37, impl=impl, block=BLOCK
            )
        )
        return fn.lower(x, h).compile()

    pal = compiled("pallas").as_text()
    for b in bad:
        assert b not in pal, b
    assert any(b in compiled("dense").as_text() for b in bad), \
        "positive control"


def test_make_comm_step_pallas_on_mesh_runs_shard_engine(subproc):
    """On a device-sharded mesh, comm_impl='pallas' no longer demotes: it
    runs the shard-resident engine (shard_map'd per-shard uplinks + one
    d-sized psum of the partials) and agrees with the meshed 'ws' program
    to float roundoff.  A meshed call WITHOUT a mesh handle still falls
    back to ws — the pre-shard_map behaviour, pinned here."""
    subproc("""
import dataclasses
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import ModelConfig
from repro.dist import comm_ws, sharding, tamuna_dp

mesh = jax.make_mesh((4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
assert comm_ws.effective_impl("pallas", meshed=True, mesh=mesh) == "pallas"
assert comm_ws.effective_impl("pallas", meshed=True) == "ws"
assert comm_ws.effective_impl("pallas", meshed=False) == "pallas"
cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=3, s=2, p=0.5,
                                  comm_impl="pallas")
state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                  tamuna_dp.state_pspecs(state, cfg, mesh),
                  is_leaf=lambda x: isinstance(x, P))
state = jax.device_put(state, sh)
fn = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh))
out = fn(state, jax.random.key(0))
assert int(out.round) == 1
a = fn.lower(state, jax.random.key(0)).compile().as_text()
assert "shard_map" in a or "all-reduce" in a
# and it agrees with the meshed 'ws' program numerically
ws = dataclasses.replace(tcfg, comm_impl="ws")
outw = jax.jit(tamuna_dp.make_comm_step(cfg, ws, mesh))(
    state, jax.random.key(0))
err = max(jax.tree.leaves(jax.tree.map(
    lambda u, v: float(jnp.abs(
        u.astype(jnp.float32) - v.astype(jnp.float32)).max()),
    out.x, outw.x)))
assert err <= 1e-6, err
print("OK")
""", devices=4)
