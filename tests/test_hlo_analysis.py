"""Validate the trip-count-aware HLO analyzer against known programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H

X = jax.ShapeDtypeStruct((256, 256), jnp.float32)
MM_FLOPS = 2 * 256**3


def _analyze(fn, *specs):
    return H.analyze(jax.jit(fn).lower(*specs).compile().as_text())


def test_single_matmul_flops_exact():
    r = _analyze(lambda a, b: a @ b, X, X)
    assert r.flops == MM_FLOPS
    # traffic ~ 3 buffers of 256 KB
    assert 2 * 256 * 256 * 4 <= r.bytes_accessed <= 6 * 256 * 256 * 4


def test_scan_trip_count_multiplies():
    def g(a):
        def body(c, _):
            return c @ a, None
        return jax.lax.scan(body, a, None, length=10)[0]

    r = _analyze(g, X)
    assert r.flops == 10 * MM_FLOPS


def test_nested_scan():
    def g(a):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ a, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        return jax.lax.scan(outer, a, None, length=3)[0]

    r = _analyze(g, X)
    assert r.flops == 15 * MM_FLOPS


def test_fori_loop_trip_count():
    def g(a):
        return jax.lax.fori_loop(0, 7, lambda i, c: c @ a, a)

    r = _analyze(g, X)
    assert r.flops == 7 * MM_FLOPS


def test_dot_general_contracting_dims():
    def g(a, b):  # batched matmul with nonstandard dims
        return jax.lax.dot_general(a, b, (((2,), (1,)), ((0,), (0,))))

    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    r = _analyze(g, a, b)
    assert r.flops == 2 * 4 * 8 * 32 * 16


def test_collectives_counted_with_trips(subproc):
    subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch import hlo_analysis as H
mesh = jax.make_mesh((4,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
def g(a):
    def body(c, _):
        y = c @ a
        return y / y.sum(), None
    return jax.lax.scan(body, a, None, length=7)[0]
x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
sh = NamedSharding(mesh, P("x", None))
with mesh:
    c = jax.jit(g, in_shardings=sh, out_shardings=sh).lower(x).compile()
r = H.analyze(c.as_text())
# scalar all-reduce (4 bytes) x 7 trips
assert r.collective_bytes.get("all-reduce") == 28.0, r.collective_bytes
# per-device flops: 7 matmuls of (64,256)@(256,256)
assert r.flops == 7 * 2 * 64 * 256 * 256, r.flops
print("OK")
""", devices=4)


def test_sliced_fusion_not_charged_full_buffer():
    # gathering 2 rows from a big table must not count the whole table
    table = jax.ShapeDtypeStruct((4096, 512), jnp.float32)
    idx = jax.ShapeDtypeStruct((2,), jnp.int32)

    def g(t, i):
        return t[i] * 2.0

    r = _analyze(g, table, idx)
    assert r.bytes_accessed < 4096 * 512 * 4 / 4, r.bytes_accessed
