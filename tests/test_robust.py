"""Byzantine-robust aggregation (DESIGN.md §15).

* ``normalize_robust`` contract: ``mean`` / ``trimmed k=0`` normalize to
  ``None`` (the comm impls run the untouched mean path — bitwise
  identity), invalid specs raise,
* ``robust_combine_stack`` matches a per-coordinate numpy reference
  (trimmed / median, ragged validity masks, cnt == 0 coords, shallow-trim
  degradation when cnt < 2k+1),
* trimmed / median reject adversarial rows the plain mean absorbs,
* the adaptive magnitude guard flags finite blowup rows and nothing else;
  anomaly scores separate a hostile row from the honest cluster,
* ``Reputation``: escalating windows (base * 2**strikes, capped), EWMA
  reset on strike, non-arrived clients frozen, and a JSON ``state_dict``
  round-trip mid-stream replays the identical window schedule,
* fault-model determinism: the Byzantine set and ``adversarial_rows``
  are pure functions of the seed (honest rows pass through bit-exactly),
* comm-impl equivalence: all four impls (dense reference, ws, pallas,
  and the shard engine in both per-shard modes) agree under
  ``robust=("trimmed", k)`` / ``("median", 0)`` with an adversarial
  cohort member,
* quarantine composition (ISSUE 9 satellite): overlapping / repeated
  windows stack, cached draws inside a new window are purged, and the
  soft floor keeps the exactly-``c`` invariant even when quarantine +
  unavailability starve the healthy pool,
* e2e (subproc): the satellite-1 regression — a finite ``blowup`` fault
  with ``guard_max_abs`` unset is caught by the now-default adaptive
  guard, while ``guard_mode="nonfinite"`` (the old default) admits the
  rows and the run degenerates; weighted-plan bias warning; fresh-seed
  replay determinism of the fault/reputation schedule; pipelined tau=0
  bit-equivalence under adversaries + robust combiners,
* HLO regression (subproc): the robust shard engine exchanges
  ``(s, d_local)``-bounded owner-value stacks — no ``(n, d)`` collective
  ever lowers (the non-meshed ws gather on a dp-sharded axis is the
  positive control validating the parser).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.dist import cohort, comm_ws, faults, robust, tamuna_dp


def _mesh_1x1():
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def _tree(rng, n):
    x = {
        "w": jnp.asarray(rng.normal(size=(n, 13, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 1)), jnp.bfloat16),
        "v": jnp.asarray(rng.normal(size=(n, 29)), jnp.float32),
    }
    h = {
        k: jnp.asarray(rng.normal(size=a.shape), jnp.float32)
        for k, a in x.items()
    }
    h = jax.tree.map(lambda a: a - a.mean(axis=0, keepdims=True), h)
    return x, h


def _slot(rng, n, c):
    cohort_ids = rng.choice(n, size=c, replace=False)
    out = np.full((n,), -1, np.int32)
    out[cohort_ids] = rng.permutation(c)
    return jnp.asarray(out)


def _maxerr(a, b):
    return max(
        float(jnp.abs(u.astype(jnp.float32) - v.astype(jnp.float32)).max())
        for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# --------------------------------------------------------------------------
# combiner contract + numpy reference
# --------------------------------------------------------------------------


def test_normalize_robust_contract():
    # identity settings -> None: the impls run the mean path verbatim
    assert robust.normalize_robust("mean", 0, 4) is None
    assert robust.normalize_robust("trimmed", 0, 4) is None
    assert robust.normalize_robust("trimmed", 1, 4) == ("trimmed", 1)
    assert robust.normalize_robust("trimmed", 2, 5) == ("trimmed", 2)
    assert robust.normalize_robust("median", 0, 2) == ("median", 0)
    with pytest.raises(ValueError):
        robust.normalize_robust("krum", 0, 4)
    with pytest.raises(ValueError):
        robust.normalize_robust("trimmed", 2, 4)  # 2k >= s
    with pytest.raises(ValueError):
        robust.normalize_robust("trimmed", -1, 4)
    with pytest.raises(ValueError):
        robust.normalize_robust("mean", 1, 4)
    with pytest.raises(ValueError):
        robust.normalize_robust("median", 1, 4)


def test_config_identity_spec_is_none():
    tcfg = tamuna_dp.DistTamunaConfig(
        gamma=0.05, c=3, s=2, p=0.5, robust_agg="trimmed", trim_k=0
    )
    assert tcfg.robust_() is None
    tcfg = tamuna_dp.DistTamunaConfig(
        gamma=0.05, c=4, s=3, p=0.5, robust_agg="trimmed", trim_k=1
    )
    assert tcfg.robust_() == ("trimmed", 1)


def _np_combine(vals, ok, kind, k):
    m, d = vals.shape
    bar = np.zeros(d, vals.dtype)
    cnt = np.zeros(d, np.int32)
    for j in range(d):
        v = np.sort(vals[ok[:, j], j])
        c = len(v)
        cnt[j] = c
        if c == 0:
            continue
        if kind == "median":
            bar[j] = 0.5 * (v[(c - 1) // 2] + v[c // 2])
        else:
            ke = min(k, (c - 1) // 2)
            bar[j] = v[ke:c - ke].mean()
    return bar, cnt


_combos = st.tuples(
    st.integers(1, 7),            # stack size m
    st.integers(1, 33),           # width d
    st.integers(0, 3),            # trim k
    st.sampled_from(["trimmed", "median"]),
    st.integers(0, 2**16),        # seed
)


@given(_combos)
@settings(max_examples=30, deadline=None)
def test_robust_combine_stack_matches_numpy(t):
    m, d, k, kind, seed = t
    if kind == "median":
        k = 0
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=(m, d)).astype(np.float32)
    ok = rng.random((m, d)) < 0.7  # ragged validity incl. empty coords
    bar, cnt = robust.robust_combine_stack(
        jnp.asarray(vals), jnp.asarray(ok), kind, k
    )
    rbar, rcnt = _np_combine(vals, ok, kind, k)
    np.testing.assert_array_equal(np.asarray(cnt), rcnt)
    np.testing.assert_allclose(np.asarray(bar), rbar, rtol=1e-5, atol=1e-6)
    assert (np.asarray(bar)[rcnt == 0] == 0.0).all()


def test_trimmed_and_median_reject_adversarial_rows():
    rng = np.random.default_rng(0)
    honest = rng.normal(size=(5, 17)).astype(np.float32)
    vals = np.concatenate(
        [honest, np.full((1, 17), -50.0, np.float32)], axis=0
    )
    ok = np.ones((6, 17), bool)
    mean = vals.mean(axis=0)
    assert np.abs(mean).max() > 5.0  # the plain mean is dragged
    for kind, k in (("trimmed", 1), ("median", 0)):
        bar, _ = robust.robust_combine_stack(
            jnp.asarray(vals), jnp.asarray(ok), kind, k
        )
        assert np.abs(np.asarray(bar)).max() < 4.0, kind


# --------------------------------------------------------------------------
# adaptive guard + anomaly + reputation
# --------------------------------------------------------------------------


def test_magnitude_outliers_flags_blowup_only():
    rng = np.random.default_rng(1)
    x = {"w": jnp.asarray(rng.normal(size=(6, 40)), jnp.float32)}
    mask = np.ones(6, bool)
    mask[5] = False
    # a clean fleet never flags itself (relative floor on the MAD band)
    assert not np.asarray(
        robust.magnitude_outliers(x, jnp.asarray(mask))
    ).any()
    blown = jax.tree.map(lambda a: a.at[2].mul(1e8), x)
    out = np.asarray(robust.magnitude_outliers(blown, jnp.asarray(mask)))
    assert out.tolist() == [False, False, True, False, False, False]
    # a row outside the mask is never flagged, however large
    blown5 = jax.tree.map(lambda a: a.at[5].mul(1e8), x)
    assert not np.asarray(
        robust.magnitude_outliers(blown5, jnp.asarray(mask))
    ).any()


def test_anomaly_scores_separate_hostile_row():
    rng = np.random.default_rng(2)
    base = rng.normal(size=(8, 31)).astype(np.float32)
    base[3] = -20.0 * np.abs(base[3])
    mask = np.ones(8, bool)
    mask[7] = False
    sc = np.asarray(
        robust.anomaly_scores({"w": jnp.asarray(base)}, jnp.asarray(mask))
    )
    assert sc[7] == 0.0  # outside the mask
    honest = sc[np.array([0, 1, 2, 4, 5, 6])]
    assert sc[3] > 3.0 * honest.max()
    assert 0.2 < np.median(honest) < 2.5  # honest cluster scores ~1


def test_reputation_escalating_windows():
    rep = robust.Reputation(4, alpha=1.0, threshold=3.0, base_rounds=4,
                            max_doublings=2)
    anom = np.array([1.0, 1.0, 10.0, 1.0])
    arr = np.ones(4, bool)
    assert rep.update(anom, arr) == [(2, 4)]
    assert rep.scores[2] == 0.0  # EWMA resets after a strike
    assert rep.update(anom, arr) == [(2, 8)]
    assert rep.update(anom, arr) == [(2, 16)]
    assert rep.update(anom, arr) == [(2, 16)]  # capped at 2**max_doublings
    # non-arrived clients neither decay nor grow
    before = rep.scores.copy()
    assert rep.update(np.full(4, 100.0), np.zeros(4, bool)) == []
    assert (rep.scores == before).all()
    with pytest.raises(ValueError):
        robust.Reputation(4, threshold=0.5)
    with pytest.raises(ValueError):
        robust.Reputation(4, alpha=0.0)


def test_reputation_state_dict_resume_replays_bitexact():
    rng = np.random.default_rng(3)
    stream = [(rng.random(6) * 4.0, rng.random(6) < 0.8)
              for _ in range(30)]
    live = robust.Reputation(6, alpha=0.5, threshold=2.0, base_rounds=3)
    for a, m in stream[:15]:
        live.update(a, m)
    # snapshot through JSON: exactly what a checkpoint stores
    snap = json.loads(json.dumps(live.state_dict()))
    restored = robust.Reputation.from_state_dict(snap)
    tail_live = [live.update(a, m) for a, m in stream[15:]]
    tail_rest = [restored.update(a, m) for a, m in stream[15:]]
    assert tail_live == tail_rest
    assert (live.scores == restored.scores).all()
    assert (live.strikes == restored.strikes).all()


# --------------------------------------------------------------------------
# fault-model determinism
# --------------------------------------------------------------------------


def test_byzantine_set_deterministic_and_sized():
    mk = lambda: faults.FaultPlan(
        7, 12, model=faults.FaultModel(adversary="sign_flip", f_byz=0.25)
    )
    b1, b2 = mk().byzantine, mk().byzantine
    assert (b1 == b2).all() and b1.sum() == 3
    assert not faults.FaultPlan.zero(12).byzantine.any()
    assert faults.FaultPlan(
        7, 12, model=faults.FaultModel(adversary="inlier", f_byz=0.5)
    ).byzantine.sum() == 6
    with pytest.raises(ValueError):
        faults.FaultModel(f_byz=0.25)  # f_byz needs an adversary
    with pytest.raises(ValueError):
        faults.FaultModel(adversary="alie", f_byz=0.25)


def test_adversarial_rows_modes():
    rng = np.random.default_rng(4)
    x = {"w": jnp.asarray(rng.normal(size=(6, 9)), jnp.float32)}
    byz = np.zeros(6, bool)
    byz[[1, 4]] = True
    w = np.asarray(x["w"])
    flip = np.asarray(
        faults.adversarial_rows(x, byz, ~byz, "sign_flip")["w"]
    )
    np.testing.assert_array_equal(flip[byz], -w[byz])
    np.testing.assert_array_equal(flip[~byz], w[~byz])  # honest bit-exact
    scaled = np.asarray(
        faults.adversarial_rows(x, byz, ~byz, "scale", byz_scale=-3.0)["w"]
    )
    np.testing.assert_allclose(scaled[byz], -3.0 * w[byz], rtol=1e-6)
    inl = np.asarray(
        faults.adversarial_rows(x, byz, ~byz, "inlier", byz_z=1.5)["w"]
    )
    h = w[~byz]
    target = h.mean(axis=0) - 1.5 * h.std(axis=0)
    np.testing.assert_allclose(
        inl[byz], np.broadcast_to(target, (2, 9)), rtol=1e-4, atol=1e-5
    )
    assert np.isfinite(inl).all()  # inlier passes any magnitude guard
    with pytest.raises(ValueError):
        faults.adversarial_rows(x, byz, ~byz, "none")


# --------------------------------------------------------------------------
# comm-impl equivalence under robust combiners
# --------------------------------------------------------------------------

_IMPLS = (
    ("ws", False, {}),
    ("ws", True, {}),
    ("pallas", False, {}),
    ("pallas", True, {"shard_kernels": False}),
    ("pallas", True, {"shard_kernels": True}),
)

_ncs_robust = st.tuples(
    st.integers(3, 9),   # n
    st.integers(3, 9),   # c
    st.integers(3, 9),   # s (>= 3 so trimmed k=1 keeps a window)
    st.integers(0, 2**16),
    st.sampled_from([("trimmed", 1), ("median", 0)]),
).filter(lambda t: t[1] <= t[0] and t[2] <= t[1])


@given(_ncs_robust)
@settings(max_examples=12, deadline=None)
def test_cyclic_robust_impls_match_dense(t):
    n, c, s, seed, spec = t
    rng = np.random.default_rng(seed)
    x, h = _tree(rng, n)
    slot = _slot(rng, n, c)
    # one cohort member turns adversarial so the robust path actually
    # diverges from the mean (trimming must agree on what it discards)
    byz = np.zeros(n, bool)
    byz[np.nonzero(np.asarray(slot) >= 0)[0][0]] = True
    x = faults.adversarial_rows(x, byz, ~byz, "sign_flip")
    xd, hd = jax.jit(
        lambda x, h: comm_ws.cyclic_comm(x, h, slot, c, s, 0.37,
                                         impl="dense", robust=spec)
    )(x, h)
    mesh = _mesh_1x1()
    for impl, meshed, kw in _IMPLS:
        if meshed:
            kw = dict(kw, mesh=mesh, block=16)
        xn, hn = jax.jit(
            lambda x, h, impl=impl, meshed=meshed, kw=kw:
            comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl=impl,
                                meshed=meshed, robust=spec, **kw)
        )(x, h)
        assert _maxerr(xd, xn) <= 1e-6, (impl, meshed, kw, spec)
        assert _maxerr(hd, hn) <= 1e-6, (impl, meshed, kw, spec)


def test_identity_spec_bitwise_mean_all_impls():
    """``robust_agg="trimmed", trim_k=0`` must be bitwise-invisible: the
    normalized spec is ``None``, so every impl literally runs its mean
    path (a sort-based k=0 trim would reassociate the reduction).  Pins
    ``normalize_robust`` against ever leaking ``("trimmed", 0)``."""
    rng = np.random.default_rng(7)
    n, c, s = 6, 4, 3
    x, h = _tree(rng, n)
    slot = _slot(rng, n, c)
    spec = robust.normalize_robust("trimmed", 0, s)
    mesh = _mesh_1x1()
    for impl, meshed, kw in (("dense", False, {}),) + _IMPLS:
        if meshed:
            kw = dict(kw, mesh=mesh, block=16)
        run = lambda rb, impl=impl, meshed=meshed, kw=kw: jax.jit(
            lambda x, h: comm_ws.cyclic_comm(x, h, slot, c, s, 0.37,
                                             impl=impl, meshed=meshed,
                                             robust=rb, **kw)
        )(x, h)
        a, b = run(None), run(spec)
        for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(
                np.asarray(u, np.float32), np.asarray(v, np.float32)
            )


# --------------------------------------------------------------------------
# quarantine composition (satellite: overlapping windows / soft floor)
# --------------------------------------------------------------------------


def test_quarantine_overlap_purges_cache_and_stacks():
    plan = cohort.CohortPlan(0, 8, 3)
    plan.cohort(6)
    plan.cohort(9)
    plan.quarantine([1], 5, 10)
    assert (6, 0) not in plan._cache and (9, 0) not in plan._cache
    a = plan.cohort(8).copy()
    assert 1 not in a
    # overlapping second window for the same client: cached draws inside
    # the new window are purged again, the draw itself is unchanged (same
    # exclusion set, doubled penalty is still far below the floor)
    plan.quarantine([1], 7, 12)
    assert (8, 0) not in plan._cache
    np.testing.assert_array_equal(plan.cohort(8), a)
    for r in range(5, 13):
        got = plan.cohort(r)
        assert 1 not in got and len(got) == 3
    # outside the union of windows the client is eligible again
    assert any(1 in plan.cohort(r) for r in range(13, 40))
    # repeated identical window: idempotent on the selections
    plan.quarantine([1], 7, 12)
    np.testing.assert_array_equal(plan.cohort(8), a)


def test_quarantine_soft_floor_keeps_exactly_c():
    # quarantine + unavailability leave ONE healthy client; the plan must
    # still field exactly c participants by drafting floored clients
    avail = cohort.BernoulliAvailability(
        p_up=np.array([1.0, 1.0, 0.0, 1.0]), seed=5
    )
    plan = cohort.CohortPlan(0, 4, 3, availability=avail)
    plan.quarantine([0, 1], 0, 50)
    for r in range(8):
        got = plan.cohort(r)
        assert len(got) == 3 and len(set(got.tolist())) == 3
        assert 3 in got  # the sole healthy client always participates
    # hard-floor interplay: a busy client is NEVER drafted, quarantined
    # ones still are
    busy = np.zeros(4, bool)
    busy[3] = True
    got = plan.cohort_excluding(2, busy)
    assert 3 not in got and len(got) == 3


def test_cohort_plan_weighted_flag():
    assert not cohort.CohortPlan(0, 4, 2).weighted
    assert cohort.CohortPlan(0, 4, 2, weights=[1, 2, 3, 4]).weighted


# --------------------------------------------------------------------------
# e2e through the round engine (subproc: multi-device + fresh jax)
# --------------------------------------------------------------------------

_E2E_SETUP = """
import warnings
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import cohort as cm
from repro.dist import robust as rb
from repro.dist import rounds, sharding, tamuna_dp
from repro.dist.faults import FaultPlan, FaultModel

mesh = jax.make_mesh((4, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
dcfg = DataConfig(seq_len=8, per_client_batch=1, vocab=64, seed=0,
                  n_clients=n)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
sampler = device_sampler(dcfg, cfg, mesh)


def build(uplink, elastic=True, c=2, **tkw):
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=2, p=0.5,
                                      uplink=uplink, **tkw)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      tamuna_dp.state_pspecs(state, cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, sh)
    round_fn = rounds.make_round_fn(cfg, tcfg, mesh, sample_batch=sampler,
                                    max_L=4, elastic=elastic)
    return tcfg, state, round_fn


class Rows:
    def __init__(self):
        self.rows = []

    def log(self, step, m):
        self.rows.append(dict(m))
"""


def test_adaptive_guard_catches_finite_blowup(subproc):
    """ISSUE 9 satellite 1: ``corrupt_mode="blowup"`` with
    ``guard_max_abs`` unset used to sail through the nonfinite-only
    guard default and poison the aggregate.  The default is now the
    adaptive magnitude guard whenever the fault model corrupts; the
    old default is pinned as the poisoned contrast."""
    subproc(_E2E_SETUP + """
# seed 34: <= 1 of the 4 cohort members corrupted per round, keeping the
# corrupted fraction below the median/MAD 50% breakdown point
fp = FaultPlan(seed=34, n=n,
               model=FaultModel(p_corrupt=0.3, corrupt_mode="blowup",
                                blowup=1e8))
plan = cm.CohortPlan(seed=17, n=n, c=4)
log = Rows()
tcfg, state, round_fn = build("masked_psum", elastic=False, c=4)
final, last = rounds.run_rounds(
    state, round_fn=round_fn, data=pipe.device_data(),
    key=jax.random.key(3), rounds=4, rng=np.random.default_rng(0),
    p=tcfg.p, flush_every=2, logger=log, plan=plan, faults=fp,
    policy="quorum", quorum=1)
assert sum(r["corrupted"] for r in log.rows) > 0, log.rows
for leaf in jax.tree.leaves(final.x):
    a = np.asarray(leaf)
    assert np.isfinite(a).all() and np.abs(a).max() < 1e4, np.abs(a).max()

# contrast: the old nonfinite-only default admits the finite 1e8 rows
plan = cm.CohortPlan(seed=17, n=n, c=4)
log2 = Rows()
tcfg, state, round_fn = build("masked_psum", elastic=False, c=4)
rounds.run_rounds(
    state, round_fn=round_fn, data=pipe.device_data(),
    key=jax.random.key(3), rounds=4, rng=np.random.default_rng(0),
    p=tcfg.p, flush_every=2, logger=log2, plan=plan, faults=fp,
    policy="quorum", quorum=1, guard_mode="nonfinite")
# the corrupting round reported 0 corrupted (the guard saw nothing)...
assert log2.rows[0]["corrupted"] == 0 and fp.corrupts(0).any()
# ...and the poisoned aggregate degenerated downstream
assert any(not np.isfinite(r["loss"]) for r in log2.rows)
print("OK")
""", devices=4)


def test_reputation_weighted_and_replay_e2e(subproc):
    """Reputation rides the trace buffers (anomaly_max surfaces in the
    logs, final state stays finite), a weighted plan warns about the
    missing 1/(n p_i) reweighting, the zero-fault plan stays bitwise
    identical to the legacy path, and a fresh-seeded rerun replays the
    identical fault/reputation schedule bit-exactly."""
    subproc(_E2E_SETUP + """
def rep_run():
    fp = FaultPlan(seed=11, n=n,
                   model=FaultModel(adversary="sign_flip", f_byz=0.25))
    assert fp.byzantine.sum() == 1
    plan = cm.CohortPlan(seed=17, n=n, c=2)
    rep = rb.Reputation(n, threshold=1.5, base_rounds=2)
    log = Rows()
    tcfg, state, round_fn = build("masked_psum")
    final, _ = rounds.run_rounds(
        state, round_fn=round_fn, data=pipe.device_data(),
        key=jax.random.key(3), rounds=6, rng=np.random.default_rng(0),
        p=tcfg.p, flush_every=2, logger=log, plan=plan, faults=fp,
        reputation=rep)
    return final, log, plan, rep

final, log, plan, rep = rep_run()
assert "anomaly_max" in log.rows[0], log.rows[0]
for leaf in jax.tree.leaves(final.x):
    assert np.isfinite(np.asarray(leaf)).all()

# replay determinism: a fresh run from the same seeds emits the same
# quarantine windows, reputation state, and bitwise-identical params
final2, log2, plan2, rep2 = rep_run()
assert len(plan._quarantine) == len(plan2._quarantine)
for (i1, f1, l1), (i2, f2, l2) in zip(plan._quarantine, plan2._quarantine):
    assert (i1 == i2).all() and f1 == f2 and l1 == l2
assert (rep.scores == rep2.scores).all()
assert (rep.strikes == rep2.strikes).all()
for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(final2)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# satellite 2: weighted plan -> bias warning (no 1/(n p_i) reweighting)
planw = cm.CohortPlan(seed=17, n=n, c=2, weights=[1.0, 2.0, 3.0, 4.0])
tcfg, state, round_fn = build("masked_psum")
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    rounds.run_rounds(
        state, round_fn=round_fn, data=pipe.device_data(),
        key=jax.random.key(3), rounds=2, rng=np.random.default_rng(0),
        p=tcfg.p, flush_every=2, plan=planw)
assert any("1/(n p_i)" in str(x.message) for x in w), \\
    [str(x.message) for x in w]

# zero-fault plan: still bitwise identical to the legacy engine
plan = cm.CohortPlan(seed=17, n=n, c=2)
tcfg, state, round_fn = build("masked_psum")
legacy, _ = rounds.run_rounds(
    state, round_fn=round_fn, data=pipe.device_data(),
    key=jax.random.key(3), rounds=4, rng=np.random.default_rng(0),
    p=tcfg.p, flush_every=2, plan=plan)
plan = cm.CohortPlan(seed=17, n=n, c=2)
tcfg, state, round_fn = build("masked_psum")
faulted, _ = rounds.run_rounds(
    state, round_fn=round_fn, data=pipe.device_data(),
    key=jax.random.key(3), rounds=4, rng=np.random.default_rng(0),
    p=tcfg.p, flush_every=2, plan=plan, faults=FaultPlan.zero(n),
    policy="wait_all")
for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(faulted)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK")
""", devices=4)


def test_pipelined_tau0_equivalence_under_robust(subproc):
    """The pipelined driver at tau=0 reuses the synchronous resolver, so
    adversaries + adaptive guard + robust combiners stay bit-equivalent
    to ``run_rounds``; tau=1 under blowup faults stays finite."""
    subproc("""
import numpy as np
import jax, jax.numpy as jnp
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import rounds, tamuna_dp
from repro.dist.faults import FaultPlan, FaultModel

mesh = jax.make_mesh((1, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
n = 8
dcfg = DataConfig(seq_len=8, per_client_batch=1, vocab=64, seed=0,
                  n_clients=n)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
data = pipe.device_data()
sampler = device_sampler(dcfg, cfg, mesh)


def build(c, s, **tkw):
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=s, p=0.5,
                                      uplink="masked_psum", **tkw)
    sync_fn = rounds.make_round_fn(cfg, tcfg, mesh, sample_batch=sampler,
                                   max_L=8, n=n)
    eng = rounds.make_pipelined_round_fn(cfg, tcfg, mesh,
                                         sample_batch=sampler, max_L=8,
                                         n=n)
    mk = lambda: tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg,
                                      n=n)
    return mk, sync_fn, eng


def maxerr(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda u, v: float(jnp.max(jnp.abs(u.astype(jnp.float32)
                                           - v.astype(jnp.float32)))),
        a, b)), default=0.0)

# blowup + adaptive guard + trimmed combiner
fp = FaultPlan(seed=13, n=n,
               model=FaultModel(p_drop=0.2, p_corrupt=0.3,
                                corrupt_mode="blowup"))
mk, sync_fn, eng = build(4, 3, robust_agg="trimmed", trim_k=1)
kw = dict(data=data, key=jax.random.key(7), rounds=6, p=0.5,
          flush_every=3, faults=fp, policy="quorum", quorum=1)
st_s, last_s = rounds.run_rounds(mk(), round_fn=sync_fn,
                                 rng=np.random.default_rng(3), **kw)
st_p, last_p = rounds.run_rounds_pipelined(
    mk(), round_fn=eng, rng=np.random.default_rng(3), staleness=0, **kw)
err = maxerr((st_s.x, st_s.h, st_s.opt), (st_p.x, st_p.h, st_p.opt))
assert err <= 1e-6, err
assert last_s["corrupted"] == last_p["corrupted"]

# sign_flip adversary + median combiner
fp = FaultPlan(seed=21, n=n,
               model=FaultModel(adversary="sign_flip", f_byz=0.25))
mk, sync_fn, eng = build(4, 3, robust_agg="median")
kw = dict(data=data, key=jax.random.key(7), rounds=6, p=0.5,
          flush_every=3, faults=fp)
st_s, _ = rounds.run_rounds(mk(), round_fn=sync_fn,
                            rng=np.random.default_rng(3), **kw)
st_p, _ = rounds.run_rounds_pipelined(
    mk(), round_fn=eng, rng=np.random.default_rng(3), staleness=0, **kw)
assert maxerr((st_s.x, st_s.h), (st_p.x, st_p.h)) <= 1e-6

# tau=1 under blowup faults: in-flight rounds stay finite
fp = FaultPlan(seed=13, n=n,
               model=FaultModel(p_drop=0.2, p_corrupt=0.2,
                                corrupt_mode="blowup", delay_sigma=0.5))
mk, sync_fn, eng = build(3, 2, robust_agg="median")
st1, _ = rounds.run_rounds_pipelined(
    mk(), round_fn=eng, rng=np.random.default_rng(3), staleness=1,
    data=data, key=jax.random.key(7), rounds=6, p=0.5, flush_every=3,
    faults=fp, policy="quorum", quorum=1)
for leaf in jax.tree.leaves(st1.x):
    assert np.isfinite(np.asarray(leaf)).all()
print("OK")
""", devices=1, timeout=1500)


def test_robust_shard_engine_no_population_collective(subproc):
    """HLO regression for the robust shard engine: the owner-value
    exchange is ``(s, d_local)``-bounded — the largest lowered collective
    stays <= (s+1) * d_total elements, never the ``(n, d)`` population
    gather a naive robust aggregation would need.  The non-meshed ws
    gather on a dp-sharded client axis is the positive control that DOES
    lower a population-scaled collective, validating the parser."""
    subproc("""
import re
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import ModelConfig
from repro.dist import comm_ws, sharding, tamuna_dp

COLL = re.compile(
    r"= (?P<res>[^=]*?) (?:all-gather|all-reduce|reduce-scatter|"
    r"all-to-all)(?:-start)?\\(")
SHAPE = re.compile(r"(?:f|s|u|pred|bf)[0-9]*\\[([0-9,]*)\\]")

def max_coll_elems(hlo):
    worst = 0
    for line in hlo.splitlines():
        m = COLL.search(line)
        if not m or "-done" in line.split("(")[0]:
            continue
        for dims in SHAPE.findall(m.group("res")):
            els = 1
            for d in filter(None, dims.split(",")):
                els *= int(d)
            worst = max(worst, els)
    return worst

mesh = jax.make_mesh((8, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
assert n == 8
params = jax.eval_shape(
    lambda: __import__("repro.dist.model_api", fromlist=["init"]).init(
        jax.random.key(0), cfg))
d_total = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))
for agg, k, c, s in (("trimmed", 1, 4, 3), ("median", 0, 3, 2)):
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=s, p=0.5,
                                      uplink="masked_psum",
                                      comm_impl="pallas",
                                      robust_agg=agg, trim_k=k)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                      tamuna_dp.state_pspecs(state, cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, sh)
    fn = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh))
    hlo = fn.lower(state, jax.random.key(0)).compile().as_text()
    worst = max_coll_elems(hlo)
    # the owner-value stack psum is s * d_local per shard; with the
    # d-sized bookkeeping that stays within (s + 1) * d_total and far
    # below the n * d_total population gather (n = 8 here)
    assert 0 < worst <= (s + 1) * d_total, (agg, worst, d_total)
    assert worst < n * d_total // 2, (agg, worst, n * d_total)

# positive control: the parser DOES see population-scaled collectives
D = 1024
x = {"w": jnp.zeros((n, D), jnp.float32)}
h = {"w": jnp.zeros((n, D), jnp.float32)}
xs = jax.tree.map(
    lambda a: jax.device_put(a, NamedSharding(mesh, P("data"))), x)
hs = jax.tree.map(
    lambda a: jax.device_put(a, NamedSharding(mesh, P("data"))), h)
slot = jnp.asarray(np.r_[np.arange(3), [-1] * (n - 3)].astype(np.int32))
bad = jax.jit(lambda xs, hs: comm_ws.cyclic_comm(
    xs, hs, slot, 3, 2, 0.37, impl="ws", meshed=False, block=256))
worst = max_coll_elems(bad.lower(xs, hs).compile().as_text())
assert worst >= 2 * D, worst
print("OK")
""", devices=8, timeout=1500)
