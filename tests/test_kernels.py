"""Per-kernel shape/dtype sweeps asserting allclose against the ref.py
pure-jnp oracles (interpret mode executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# --------------------------------------------------------------------------
# compress
# --------------------------------------------------------------------------


@pytest.mark.parametrize("d", [64, 1000, 4096, 5001])
@pytest.mark.parametrize("c,s", [(8, 3), (16, 4), (12, 2)])
def test_compress_sweep(d, c, s):
    x = jax.random.normal(jax.random.key(d + c), (d,))
    for slot in [0, c // 2, c - 1, c, c + 3]:
        out = ops.compress(x, jnp.asarray([slot], jnp.int32), c, s, block=512)
        exp = ref.compress_ref(x, jnp.asarray(slot, jnp.int32), c, s)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_compress_covers_each_coordinate_s_times():
    d, c, s = 257, 8, 3
    x = jnp.ones((d,))
    total = sum(
        np.asarray(
            ops.compress(x, jnp.asarray([j], jnp.int32), c, s, block=128)
        )
        for j in range(c)
    )
    np.testing.assert_array_equal(total, np.full(d, s))


@given(
    st.integers(2, 20), st.integers(2, 20), st.integers(1, 600),
    st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_compress_property(c, s, d, seed):
    if s > c:
        s = c
    x = jax.random.normal(jax.random.key(seed), (d,))
    slot = seed % (c + 2)
    out = ops.compress(x, jnp.asarray([slot], jnp.int32), c, s, block=128)
    exp = ref.compress_ref(x, jnp.asarray(slot, jnp.int32), c, s)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


# --------------------------------------------------------------------------
# fused local step
# --------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64,), (33, 7), (4, 5, 6)])
def test_local_step_sweep(dtype, shape):
    ks = jax.random.split(jax.random.key(1), 3)
    x = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    g = jax.random.normal(ks[1], shape, jnp.float32)
    h = jax.random.normal(ks[2], shape, jnp.float32)
    out = ops.fused_local_step(x, g, h, 0.03, block=128)
    exp = ref.fused_local_step_ref(x, g, h, 0.03)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        rtol=1e-6, atol=1e-6,
    )


@given(st.integers(1, 3000), st.floats(1e-4, 1.0), st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_local_step_property(d, gamma, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(ks[0], (d,))
    g = jax.random.normal(ks[1], (d,))
    h = jax.random.normal(ks[2], (d,))
    out = ops.fused_local_step(x, g, h, gamma, block=256)
    exp = ref.fused_local_step_ref(x, g, h, gamma)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-6
    )


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,h,kvh,hd,S,bs",
    [
        (2, 8, 4, 64, 1024, 256),
        (1, 4, 1, 128, 2048, 512),
        (3, 6, 6, 32, 512, 128),   # MHA (whisper-like)
        (1, 8, 1, 64, 1024, 1024),  # single KV block
    ],
)
def test_decode_attention_sweep(b, h, kvh, hd, S, bs):
    ks = jax.random.split(jax.random.key(b * h + S), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, S, kvh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, S, kvh, hd), jnp.float32)
    for pos in [0, S // 3, S - 1]:
        out = ops.decode_attention(
            q, k, v, jnp.asarray(pos, jnp.int32), block_s=bs
        )
        exp = ref.decode_attention_ref(q, k, v, jnp.asarray(pos, jnp.int32))
        assert float(jnp.abs(out - exp).max()) < 2e-5, pos


@pytest.mark.parametrize("window", [16, 128])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_decode_attention_window_softcap(window, softcap):
    b, h, kvh, hd, S = 2, 4, 2, 64, 512
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, S, kvh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, S, kvh, hd), jnp.float32)
    pos = jnp.asarray(300, jnp.int32)
    out = ops.decode_attention(
        q, k, v, pos, window=window, softcap=softcap, block_s=128
    )
    exp = ref.decode_attention_ref(q, k, v, pos, window=window,
                                   softcap=softcap)
    assert float(jnp.abs(out - exp).max()) < 2e-5


def test_decode_attention_bf16():
    b, h, kvh, hd, S = 1, 4, 2, 64, 512
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, S, kvh, hd), jnp.float32).astype(
        jnp.bfloat16
    )
    v = jax.random.normal(ks[2], (b, S, kvh, hd), jnp.float32).astype(
        jnp.bfloat16
    )
    pos = jnp.asarray(S - 1, jnp.int32)
    out = ops.decode_attention(q, k, v, pos, block_s=128)
    exp = ref.decode_attention_ref(q, k, v, pos)
    assert out.dtype == jnp.bfloat16
    err = float(jnp.abs(
        out.astype(jnp.float32) - exp.astype(jnp.float32)
    ).max())
    assert err < 3e-2, err
