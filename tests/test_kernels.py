"""Per-kernel shape/dtype sweeps asserting allclose against the ref.py
pure-jnp oracles (interpret mode executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# --------------------------------------------------------------------------
# compress
# --------------------------------------------------------------------------


@pytest.mark.parametrize("d", [64, 1000, 4096, 5001])
@pytest.mark.parametrize("c,s", [(8, 3), (16, 4), (12, 2)])
def test_compress_sweep(d, c, s):
    x = jax.random.normal(jax.random.key(d + c), (d,))
    for slot in [0, c // 2, c - 1, c, c + 3]:
        out = ops.compress(x, jnp.asarray([slot], jnp.int32), c, s, block=512)
        exp = ref.compress_ref(x, jnp.asarray(slot, jnp.int32), c, s)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_compress_covers_each_coordinate_s_times():
    d, c, s = 257, 8, 3
    x = jnp.ones((d,))
    total = sum(
        np.asarray(
            ops.compress(x, jnp.asarray([j], jnp.int32), c, s, block=128)
        )
        for j in range(c)
    )
    np.testing.assert_array_equal(total, np.full(d, s))


@given(
    st.integers(2, 20), st.integers(2, 20), st.integers(1, 600),
    st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_compress_property(c, s, d, seed):
    if s > c:
        s = c
    x = jax.random.normal(jax.random.key(seed), (d,))
    slot = seed % (c + 2)
    out = ops.compress(x, jnp.asarray([slot], jnp.int32), c, s, block=128)
    exp = ref.compress_ref(x, jnp.asarray(slot, jnp.int32), c, s)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("n,d", [(4, 257), (8, 1024), (3, 4097)])
def test_compress_2d_matches_per_row(n, d):
    """The (n, d) form with a grid over clients equals n 1-D calls."""
    x = jax.random.normal(jax.random.key(n * d), (n, d))
    c, s = 8, 3
    slots = jnp.asarray([(3 * i) % (c + 2) for i in range(n)], jnp.int32)
    out = ops.compress(x, slots, c, s, block=128)
    for i in range(n):
        exp = ref.compress_ref(x[i], slots[i], c, s)
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(exp))


# --------------------------------------------------------------------------
# uplink kernels (the fused comm step, DESIGN.md §9): interpret smokes
# --------------------------------------------------------------------------


def _uplink_operands(n, d, m, seed):
    ks = jax.random.split(jax.random.key(seed), 2)
    x = jax.random.normal(ks[0], (n, d), jnp.float32)
    h = jax.random.normal(ks[1], (n, d), jnp.float32)
    rng = np.random.default_rng(seed)
    slot = np.full((n,), -1, np.int32)
    active = rng.choice(n, size=min(m, n), replace=False)
    slot[active] = rng.permutation(min(m, n))
    band = rng.integers(0, m, size=d).astype(np.int32)
    return x, h, jnp.asarray(slot), jnp.asarray(band)


@pytest.mark.parametrize("n,d,m,s", [
    (4, 257, 3, 2),     # ragged d, idle clients
    (8, 1024, 8, 8),    # s == m (no compression), exact block tiling
    (6, 4097, 5, 2),    # multi-block + ragged tail
])
def test_uplink_masked_sum_sweep(n, d, m, s):
    x, _, slot, band = _uplink_operands(n, d, m, n * d)
    out = ops.uplink_masked_sum(x, slot, band, m, s, block=256)
    exp = ref.uplink_masked_sum_ref(x, slot, band, m, s)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(exp), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("n,d,m,s", [
    (4, 257, 3, 2),
    (8, 1024, 8, 8),
    (6, 4097, 5, 2),
])
def test_uplink_h_update_sweep(n, d, m, s):
    x, h, slot, band = _uplink_operands(n, d, m, n + d)
    x_bar = ref.uplink_masked_sum_ref(x, slot, band, m, s)
    h_new, x_new = ops.uplink_h_update(
        x, h, x_bar, slot, band, m, s, 0.25, block=256
    )
    h_exp, x_exp = ref.uplink_h_update_ref(x, h, x_bar, slot, band, m, s,
                                           0.25)
    np.testing.assert_allclose(
        np.asarray(h_new), np.asarray(h_exp), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(x_new), np.asarray(x_exp))


@pytest.mark.parametrize("n,d,m,s", [
    (4, 257, 3, 2),
    (6, 4097, 5, 2),
])
def test_uplink_h_update_down_mask(n, d, m, s):
    """The DownCom row mask (elastic PP): masked rows get x_bar, the rest
    keep x bit-exactly, h-update unaffected."""
    x, h, slot, band = _uplink_operands(n, d, m, 3 * n + d)
    rng = np.random.default_rng(d)
    down = jnp.asarray(rng.integers(0, 2, size=n).astype(np.int32))
    x_bar = ref.uplink_masked_sum_ref(x, slot, band, m, s)
    h_new, x_new = ops.uplink_h_update(
        x, h, x_bar, slot, band, m, s, 0.25, down=down, block=256
    )
    h_exp, x_exp = ref.uplink_h_update_ref(x, h, x_bar, slot, band, m, s,
                                           0.25, down=down)
    np.testing.assert_allclose(
        np.asarray(h_new), np.asarray(h_exp), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(x_new), np.asarray(x_exp))
    dn = np.asarray(down).astype(bool)
    np.testing.assert_array_equal(np.asarray(x_new)[~dn],
                                  np.asarray(x)[~dn])
    np.testing.assert_array_equal(
        np.asarray(x_new)[dn],
        np.broadcast_to(np.asarray(x_bar), (int(dn.sum()), d)),
    )


@given(
    st.integers(2, 10), st.integers(2, 12), st.integers(2, 12),
    st.integers(1, 700), st.integers(0, 2**16),
)
@settings(max_examples=20, deadline=None)
def test_uplink_kernels_property(n, m, s, d, seed):
    if s > m:
        s = m
    x, h, slot, band = _uplink_operands(n, d, m, seed)
    x_bar = ops.uplink_masked_sum(x, slot, band, m, s, block=128)
    np.testing.assert_allclose(
        np.asarray(x_bar),
        np.asarray(ref.uplink_masked_sum_ref(x, slot, band, m, s)),
        rtol=1e-6, atol=1e-6,
    )
    h_new, x_new = ops.uplink_h_update(
        x, h, x_bar, slot, band, m, s, 0.5, block=128
    )
    h_exp, x_exp = ref.uplink_h_update_ref(
        x, h, x_bar, slot, band, m, s, 0.5
    )
    np.testing.assert_allclose(
        np.asarray(h_new), np.asarray(h_exp), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(x_new), np.asarray(x_exp))


# --------------------------------------------------------------------------
# fused local step
# --------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64,), (33, 7), (4, 5, 6)])
def test_local_step_sweep(dtype, shape):
    ks = jax.random.split(jax.random.key(1), 3)
    x = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    g = jax.random.normal(ks[1], shape, jnp.float32)
    h = jax.random.normal(ks[2], shape, jnp.float32)
    out = ops.fused_local_step(x, g, h, 0.03, block=128)
    exp = ref.fused_local_step_ref(x, g, h, 0.03)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        rtol=1e-6, atol=1e-6,
    )


def test_local_step_interpret_auto_detects_backend():
    """The raw kernel's default is now per-backend auto-detection (the
    seed hard-coded ``interpret=True``, which would have silently run the
    interpreter on real TPUs): ``None`` resolves via the shared
    ``compress.resolve_interpret`` policy, and the auto path is
    bit-identical to forced interpret mode off-TPU."""
    from repro.kernels import local_step
    from repro.kernels.compress import resolve_interpret

    assert resolve_interpret(None) == (jax.default_backend() != "tpu")
    ks = jax.random.split(jax.random.key(7), 3)
    x = jax.random.normal(ks[0], (1000,), jnp.float32).astype(jnp.bfloat16)
    g = jax.random.normal(ks[1], (1000,))
    h = jax.random.normal(ks[2], (1000,))
    auto = local_step.fused_local_step(x, g, h, 0.07, block=256)
    forced = local_step.fused_local_step(
        x, g, h, 0.07, block=256, interpret=True
    )
    assert auto.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(auto, np.float32), np.asarray(forced, np.float32)
    )


@given(st.integers(1, 3000), st.floats(1e-4, 1.0), st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_local_step_property(d, gamma, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(ks[0], (d,))
    g = jax.random.normal(ks[1], (d,))
    h = jax.random.normal(ks[2], (d,))
    out = ops.fused_local_step(x, g, h, gamma, block=256)
    exp = ref.fused_local_step_ref(x, g, h, gamma)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(exp), rtol=1e-5, atol=1e-6
    )


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,h,kvh,hd,S,bs",
    [
        (2, 8, 4, 64, 1024, 256),
        (1, 4, 1, 128, 2048, 512),
        (3, 6, 6, 32, 512, 128),   # MHA (whisper-like)
        (1, 8, 1, 64, 1024, 1024),  # single KV block
    ],
)
def test_decode_attention_sweep(b, h, kvh, hd, S, bs):
    ks = jax.random.split(jax.random.key(b * h + S), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, S, kvh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, S, kvh, hd), jnp.float32)
    for pos in [0, S // 3, S - 1]:
        out = ops.decode_attention(
            q, k, v, jnp.asarray(pos, jnp.int32), block_s=bs
        )
        exp = ref.decode_attention_ref(q, k, v, jnp.asarray(pos, jnp.int32))
        assert float(jnp.abs(out - exp).max()) < 2e-5, pos


@pytest.mark.parametrize("window", [16, 128])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_decode_attention_window_softcap(window, softcap):
    b, h, kvh, hd, S = 2, 4, 2, 64, 512
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, S, kvh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, S, kvh, hd), jnp.float32)
    pos = jnp.asarray(300, jnp.int32)
    out = ops.decode_attention(
        q, k, v, pos, window=window, softcap=softcap, block_s=128
    )
    exp = ref.decode_attention_ref(q, k, v, pos, window=window,
                                   softcap=softcap)
    assert float(jnp.abs(out - exp).max()) < 2e-5


def test_decode_attention_bf16():
    b, h, kvh, hd, S = 1, 4, 2, 64, 512
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, S, kvh, hd), jnp.float32).astype(
        jnp.bfloat16
    )
    v = jax.random.normal(ks[2], (b, S, kvh, hd), jnp.float32).astype(
        jnp.bfloat16
    )
    pos = jnp.asarray(S - 1, jnp.int32)
    out = ops.decode_attention(q, k, v, pos, block_s=128)
    exp = ref.decode_attention_ref(q, k, v, pos)
    assert out.dtype == jnp.bfloat16
    err = float(jnp.abs(
        out.astype(jnp.float32) - exp.astype(jnp.float32)
    ).max())
    assert err < 3e-2, err
