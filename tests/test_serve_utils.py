"""Generation/eval utilities + registry/cache structural consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve_utils
from repro.configs import registry
from repro.dist import model_api
from repro.models.transformer import ModelConfig

CFG = ModelConfig(
    family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=97, dtype=jnp.float32, remat=False,
)


def test_sample_token_greedy_and_temperature():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [3.0, 0.0, -1.0]])
    tok = serve_utils.sample_token(jax.random.key(0), logits, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(tok), [1, 0])
    # top-k=1 equals greedy regardless of temperature
    tok2 = serve_utils.sample_token(
        jax.random.key(1), logits, temperature=2.0, top_k=1
    )
    np.testing.assert_array_equal(np.asarray(tok2), [1, 0])


def test_top_p_restricts_support():
    logits = jnp.asarray([[10.0, 9.5, -10.0, -10.0]])
    toks = [
        int(serve_utils.sample_token(
            jax.random.key(i), logits, temperature=1.0, top_p=0.9
        )[0])
        for i in range(50)
    ]
    assert set(toks) <= {0, 1}


def test_generate_shapes_and_determinism():
    params = model_api.init(jax.random.key(0), CFG)
    prompts = jax.random.randint(jax.random.key(1), (2, 5), 0, CFG.vocab)
    out1, _ = serve_utils.generate(
        params, CFG, prompts, gen_len=4, key=jax.random.key(7),
        temperature=0.8, top_k=10,
    )
    out2, _ = serve_utils.generate(
        params, CFG, prompts, gen_len=4, key=jax.random.key(7),
        temperature=0.8, top_k=10,
    )
    assert out1.shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < CFG.vocab


def test_perplexity_finite_and_sane():
    params = model_api.init(jax.random.key(0), CFG)
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, CFG.vocab)
    ppl = serve_utils.perplexity(params, CFG, toks[:, :-1], toks[:, 1:])
    assert 1.0 < ppl < 10 * CFG.vocab


@pytest.mark.parametrize("arch", registry.list_archs())
def test_registry_cache_specs_match_model_cache(arch):
    """input_specs' decode cache structure must exactly match the cache the
    model actually builds (shape+dtype), for every architecture."""
    cfg = registry.get_config(arch, "decode_32k")
    B, S = 2, 64  # structural check at reduced batch/seq
    spec = registry.cache_specs(cfg, B, S, jnp.bfloat16)
    real = jax.eval_shape(
        lambda: model_api.make_cache(cfg, B, S, kv_dtype=jnp.bfloat16)
    )
    spec_flat = jax.tree_util.tree_flatten_with_path(spec)[0]
    real_flat = jax.tree_util.tree_flatten_with_path(real)[0]
    assert len(spec_flat) == len(real_flat), arch
    for (ps, s), (pr, r) in zip(spec_flat, real_flat):
        assert str(ps) == str(pr), (arch, ps, pr)
        assert s.shape == r.shape, (arch, ps, s.shape, r.shape)
        assert s.dtype == r.dtype, (arch, ps, s.dtype, r.dtype)
