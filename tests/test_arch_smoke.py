"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
variant of each assigned architecture, run one forward/train step and one
decode step on CPU, assert output shapes + finite values."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.dist import model_api

ARCHS = registry.list_archs()


def _tiny_batch(cfg, b=2, t=16, key=0):
    ks = jax.random.split(jax.random.key(key), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (b, t), 0, cfg.vocab),
    }
    if cfg.prefix_len:
        batch["prefix_embeds"] = jax.random.normal(
            ks[2], (b, cfg.prefix_len, cfg.d_model), jnp.float32
        ).astype(cfg.dtype)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[3], (b, cfg.n_frames, cfg.d_model), jnp.float32
        ).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = registry.get_reduced_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = model_api.init(jax.random.key(0), cfg)
    batch = _tiny_batch(cfg)

    def loss_fn(p):
        return model_api.loss(p, cfg, **batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, loss)
    gnorms = [float(jnp.abs(g).max()) for g in jax.tree.leaves(grads)]
    assert all(jnp.isfinite(g) for g in gnorms), arch
    assert max(gnorms) > 0.0, arch  # gradients actually flow

    # a small-enough SGD step decreases loss on the same batch
    decreased = False
    for lr in (0.2, 0.05, 0.01, 0.002):
        params2 = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype),
            params, grads,
        )
        if float(loss_fn(params2)) < float(loss):
            decreased = True
            break
    assert decreased, (arch, float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = registry.get_reduced_config(arch)
    b, max_seq = 2, 24
    params = model_api.init(jax.random.key(0), cfg)
    cache = model_api.make_cache(cfg, b, max_seq, kv_dtype=jnp.float32)
    if cfg.family == "encdec":
        from repro.models import encdec

        frames = jax.random.normal(
            jax.random.key(5), (b, cfg.n_frames, cfg.d_model), jnp.float32
        ).astype(cfg.dtype)
        enc = encdec.encode(params, cfg, frames)
        cache = encdec.precompute_cross_kv(params, cfg, enc, cache)
    tok = jax.random.randint(jax.random.key(1), (b, 1), 0, cfg.vocab)
    for pos in range(3):
        logits, cache = model_api.decode(
            params, cfg, tok, cache, jnp.asarray(pos, jnp.int32)
        )
        assert logits.shape == (b, cfg.vocab), arch
        assert jnp.isfinite(logits).all(), arch
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
    }[arch]
    cfg = registry.get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == spec, (arch, got, spec)
    assert cfg.source  # every config cites its source


def test_moe_extras():
    q2 = registry.get_config("qwen2-moe-a2.7b")
    assert (q2.num_experts, q2.top_k) == (60, 4) and q2.shared_d_ff == 5632
    q3 = registry.get_config("qwen3-moe-30b-a3b")
    assert (q3.num_experts, q3.top_k) == (128, 8) and q3.shared_d_ff == 0
    z = registry.get_config("zamba2-2.7b")
    assert z.d_state == 64 and z.family == "mamba_hybrid"


def test_long500k_policy():
    assert not registry.supported("whisper-tiny", "long_500k")
    g = registry.get_config("gemma2-2b", "long_500k")
    assert g.sliding_window_override is None  # native SWA, unmodified
    d = registry.get_config("deepseek-coder-33b", "long_500k")
    assert d.sliding_window_override == registry.LONG_OVERRIDE_WINDOW
