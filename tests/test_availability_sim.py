"""Regression tests for examples/availability_sim.py's wall-clock model.

The bug being pinned: the original loop drew ONE cohort + jitter per
*record point* (every ``record_every=10`` rounds) and multiplied that
single max by the whole window's local steps — sampling the
full-participation straggler tail 10x too rarely and understating the
crossover the example exists to show.  The fixed model draws per round.
"""

import importlib.util
import os
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_example():
    spec = importlib.util.spec_from_file_location(
        "availability_sim",
        os.path.join(REPO, "examples", "availability_sim.py"),
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_wallclock_draws_per_round_not_per_window():
    sim = _load_example()
    n, rounds = 10, 200
    base = np.ones(n)
    base[0] = 100.0  # one massive straggler
    steps = np.ones(rounds, int)

    # full participation: EVERY round must wait for the straggler —
    # possible only if every round gets its own cohort draw (the windowed
    # bug priced at most rounds/record_every draws)
    t_full = sim.wallclock_per_round(
        steps, n, n, base, np.random.default_rng(0)
    )
    assert len(t_full) == rounds
    assert (t_full > 50.0).all()

    # c = 2: the straggler lands in ~C(n-1,1)/C(n,2) = 2/n of the rounds;
    # a per-window sampler at record_every=10 could hit it at most
    # rounds/10 = 20 times, so a count well above that pins per-round
    # draws (deterministic under the fixed seed)
    t_pp = sim.wallclock_per_round(
        steps, n, 2, base, np.random.default_rng(0)
    )
    hits = int((t_pp > 50.0).sum())
    assert 25 <= hits <= 70, hits

    # deterministic replay
    again = sim.wallclock_per_round(
        steps, n, 2, base, np.random.default_rng(0)
    )
    np.testing.assert_array_equal(t_pp, again)

    # and the crossover direction the example prints: per-round cost of
    # the full fleet dominates the cohort's
    assert t_full.sum() > 5 * t_pp.sum()


def test_wallclock_replays_external_cohorts():
    sim = _load_example()
    n, rounds = 8, 50
    base = np.arange(1.0, n + 1.0)
    steps = np.full(rounds, 3)
    cohorts = [np.array([0, 1]) for _ in range(rounds)]  # fastest clients
    t = sim.wallclock_per_round(
        steps, n, 2, base, np.random.default_rng(1), cohorts=cohorts
    )
    # bounded by the slowest replayed cohort member * jitter * steps
    assert (t <= base[1] * 3 * 3.0).all()
    assert len(t) == rounds


def test_straggler_base_shape_and_tail():
    sim = _load_example()
    base = sim.straggler_base(1000, np.random.default_rng(0),
                              straggler_frac=0.1)
    assert base.shape == (1000,)
    frac = (base > 5.0).mean()
    assert 0.05 < frac < 0.2, frac


def test_faults_mode_runs_and_reports(subproc):
    """``--dist --faults`` (DESIGN.md §12): all three scenarios print,
    the quorum driver shows retries/backoff, and the fault rows carry
    the robustness metrics through the example's logger."""
    out = subproc(
        "import sys; sys.argv = ['availability_sim.py', '--dist', "
        "'--faults', '--rounds', '3']; "
        "exec(open('examples/availability_sim.py').read())",
        devices=1, timeout=1500,
    )
    assert "fault-tolerant dist engine" in out
    for scenario in ("fault-free", "quorum", "wait_all+drops"):
        assert scenario in out, out[-2000:]
    assert "sim wall-clock" in out


def test_dist_out_exports_measured_tail(subproc, tmp_path):
    """``--dist --dist-out`` (DESIGN.md §14): the example exports its
    measured per-step latency draws as JSON, and EmpiricalDelays
    bootstraps deterministic per-round fleet draws from them — the
    pipelined driver's clock input."""
    import json

    path = tmp_path / "latency_dist.json"
    out = subproc(
        "import sys; sys.argv = ['availability_sim.py', '--dist', "
        f"'--rounds', '2', '--dist-out', '{path}']; "
        "exec(open('examples/availability_sim.py').read())",
        devices=1, timeout=1500,
    )
    assert "[dist-out]" in out
    with open(path) as f:
        blob = json.load(f)
    samples = np.asarray(blob["per_step_latency_s"])
    assert samples.size > 0 and (samples > 0).all()
    assert np.isfinite(samples).all()
    q = blob["quantiles"]
    assert q["p50"] <= q["p90"] <= q["p99"]
    assert abs(q["p99"] - float(np.quantile(samples, 0.99))) < 1e-9

    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.dist.faults import EmpiricalDelays

    lat = EmpiricalDelays.from_json(str(path), n=6, seed=3)
    a, b = lat.delays(4), lat.delays(4)
    np.testing.assert_array_equal(a, b)  # deterministic in (seed, round)
    assert a.shape == (6,)
    assert set(np.round(a, 12)) <= set(np.round(samples, 12))
    assert not np.array_equal(lat.delays(5), a)  # fresh draw per round
