"""Shard-resident comm engine invariants (DESIGN.md §10).

Multi-device coverage of ``comm_ws`` meshed-pallas (the shard_map'd
engine), run through the ``subproc`` fixture (device counts must be fixed
before jax init):

* equivalence vs ``impl="dense"`` to <= 1e-6 across mesh shapes (1x8,
  4x2, 8x1), ragged leaf d, idle clients, s == c, a client axis that does
  NOT divide the dp extent (engine pads with idle rows), both uplinks,
  and both per-shard modes (fused-jnp gathers and interpret-mode Pallas
  kernels inside the shard_map),
* model-parallel ``pspecs``: leaves sharded over the model axis keep
  their shards (per-shard bands from the global coordinate index),
* HLO regression: the lowered meshed-pallas ``make_comm_step`` contains
  NO ``(n, d)``-sized all-gather / all-reduce — collectives stay d-sized
  — while the known-bad composition (whole-array pallas workspace on a
  dp-sharded client axis, the thing PR 3 demoted and this engine fixes)
  is the positive control that does all-gather ``(n, d)``.

Single-device hypothesis sweeps of the same engine live in
tests/test_comm_ws.py (1x1 mesh).
"""


def test_shard_engine_matches_dense_across_meshes(subproc):
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import comm_ws

def tree(rng, n):
    # ragged dims, a reshaped leaf, a bf16 leaf, a tall-regime candidate
    x = {"w": jnp.asarray(rng.normal(size=(n, 13, 5)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(n, 1)), jnp.bfloat16),
         "v": jnp.asarray(rng.normal(size=(n, 29)), jnp.float32)}
    h = {k: jnp.asarray(rng.normal(size=a.shape), jnp.float32)
         for k, a in x.items()}
    h = jax.tree.map(lambda a: a - a.mean(axis=0, keepdims=True), h)
    return x, h

def slot_of(rng, n, c):
    cohort = rng.choice(n, size=c, replace=False)
    out = np.full((n,), -1, np.int32)
    out[cohort] = rng.permutation(c)
    return jnp.asarray(out)

def maxerr(a, b):
    return max(
        float(jnp.abs(u.astype(jnp.float32) - v.astype(jnp.float32)).max())
        for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

# (n, c, s): idle clients (c < n), s == c (no compression), and client
# axes that do not divide the dp extent (6 and 9 on 4- and 8-way dp)
CASES = [(8, 5, 2), (6, 4, 4), (9, 3, 3), (2, 2, 2)]
for shape in [(1, 8), (4, 2), (8, 1)]:
    mesh = jax.make_mesh(shape, ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    dp = shape[0]
    for n, c, s in CASES:
        rng = np.random.default_rng(n * 100 + c * 10 + s + shape[0])
        x, h = tree(rng, n)
        sh = NamedSharding(mesh, P("data") if n % dp == 0 else P())
        xs = jax.tree.map(lambda a: jax.device_put(a, sh), x)
        hs = jax.tree.map(lambda a: jax.device_put(a, sh), h)
        slot = slot_of(rng, n, c)
        off = jnp.asarray(int(rng.integers(0, n)), jnp.int32)
        xd, hd = comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl="dense")
        bd = comm_ws.blocked_comm(x, h, off, n, s, 0.37, impl="dense")
        for sk in (False, True):  # jnp gathers / interpret kernels
            xn, hn = jax.jit(lambda xs, hs, sk=sk: comm_ws.cyclic_comm(
                xs, hs, slot, c, s, 0.37, impl="pallas", meshed=True,
                mesh=mesh, shard_kernels=sk, block=16))(xs, hs)
            assert maxerr(xd, xn) <= 1e-6, ("cyc", shape, n, c, s, sk)
            assert maxerr(hd, hn) <= 1e-6, ("cyc", shape, n, c, s, sk)
            xb, hb = jax.jit(lambda xs, hs, sk=sk: comm_ws.blocked_comm(
                xs, hs, off, n, s, 0.37, impl="pallas", meshed=True,
                mesh=mesh, shard_kernels=sk, block=16))(xs, hs)
            assert maxerr(bd[0], xb) <= 1e-6, ("blk", shape, n, c, s, sk)
            assert maxerr(bd[1], hb) <= 1e-6, ("blk", shape, n, c, s, sk)
print("OK")
""", devices=8, timeout=1500)


def test_shard_engine_model_parallel_pspecs(subproc):
    """Leaves sharded over the model axis enter the shard_map sharded
    (no resharding) and the per-shard bands come from the global
    coordinate index — equivalence vs dense stays exact."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import comm_ws

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
n, c, s = 4, 3, 2
rng = np.random.default_rng(1)
x = {"a": jnp.asarray(rng.normal(size=(n, 5, 8)), jnp.float32),
     "b": jnp.asarray(rng.normal(size=(n, 6, 7)), jnp.float32),
     "c": jnp.asarray(rng.normal(size=(n, 9)), jnp.float32)}
h = {k: jnp.asarray(rng.normal(size=a.shape), jnp.float32)
     for k, a in x.items()}
pspecs = {"a": P("data", None, "model"), "b": P("data", "model", None),
          "c": P("data", None)}
put = lambda t: jax.tree.map(
    lambda a, sp: jax.device_put(a, NamedSharding(mesh, sp)), t, pspecs)
xs, hs = put(x), put(h)
sl = np.full((n,), -1, np.int32)
cohort = rng.choice(n, size=c, replace=False)
sl[cohort] = rng.permutation(c)
slot = jnp.asarray(sl)
off = jnp.asarray(2, jnp.int32)

def maxerr(a, b):
    return max(
        float(jnp.abs(u.astype(jnp.float32) - v.astype(jnp.float32)).max())
        for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

xd, hd = comm_ws.cyclic_comm(x, h, slot, c, s, 0.37, impl="dense")
bd = comm_ws.blocked_comm(x, h, off, n, s, 0.37, impl="dense")
for sk in (False, True):
    xn, hn = jax.jit(lambda xs, hs, sk=sk: comm_ws.cyclic_comm(
        xs, hs, slot, c, s, 0.37, impl="pallas", meshed=True, mesh=mesh,
        pspecs=pspecs, shard_kernels=sk, block=8))(xs, hs)
    assert maxerr(xd, xn) <= 1e-6 and maxerr(hd, hn) <= 1e-6, sk
    xb, hb = jax.jit(lambda xs, hs, sk=sk: comm_ws.blocked_comm(
        xs, hs, off, n, s, 0.37, impl="pallas", meshed=True, mesh=mesh,
        pspecs=pspecs, shard_kernels=sk, block=8))(xs, hs)
    assert maxerr(bd[0], xb) <= 1e-6 and maxerr(bd[1], hb) <= 1e-6, sk
print("OK")
""", devices=8)


def test_no_population_sized_collective_in_meshed_pallas(subproc):
    """The point of the shard engine: the lowered meshed-pallas comm step
    moves d-sized partials only.  Parse every collective's result shape in
    the compiled HLO for both uplinks and assert the largest stays d-sized
    (never (n, d)-sized); the sparse-gather path run non-meshed on a
    dp-sharded client axis (what PR 3 measured as the gather-turned-
    all-reduce failure) is the positive control whose collective scales
    with s*d, validating the parser."""
    subproc("""
import re
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import ModelConfig
from repro.dist import comm_ws, sharding, tamuna_dp

COLL = re.compile(
    r"= (?P<res>[^=]*?) (?:all-gather|all-reduce|reduce-scatter|"
    r"all-to-all)(?:-start)?\\(")
SHAPE = re.compile(r"(?:f|s|u|pred|bf)[0-9]*\\[([0-9,]*)\\]")

def max_coll_elems(hlo):
    worst = 0
    for line in hlo.splitlines():
        m = COLL.search(line)
        if not m or "-done" in line.split("(")[0]:
            continue
        for dims in SHAPE.findall(m.group("res")):
            els = 1
            for d in filter(None, dims.split(",")):
                els *= int(d)
            worst = max(worst, els)
    return worst

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
params = jax.eval_shape(
    lambda: __import__("repro.dist.model_api", fromlist=["init"]).init(
        jax.random.key(0), cfg))
d_total = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))
for uplink in ("masked_psum", "block_rs"):
    c = n if uplink == "block_rs" else 3
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=2, p=0.5,
                                      uplink=uplink, comm_impl="pallas")
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      tamuna_dp.state_pspecs(state, cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, sh)
    fn = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh))
    hlo = fn.lower(state, jax.random.key(0)).compile().as_text()
    worst = max_coll_elems(hlo)
    # d-sized collectives only: the engine's psum of the concatenated
    # partials is <= d_total elements per model shard; allow 2x headroom
    # for key/slot bookkeeping, but nothing population-scaled (n*d here
    # is 4*d_total)
    assert 0 < worst <= 2 * d_total, (uplink, worst, d_total)

# positive control (parser + the failure this engine removes): the sparse
# gather run NON-meshed on a dp-sharded client axis lowers its UpCom to
# an s*D-sized all-reduce (PR 3's measured regression), not a d-sized one
D = 1024
x = {"w": jnp.zeros((n, D), jnp.float32)}
h = {"w": jnp.zeros((n, D), jnp.float32)}
xs = jax.tree.map(
    lambda a: jax.device_put(a, NamedSharding(mesh, P("data"))), x)
hs = jax.tree.map(
    lambda a: jax.device_put(a, NamedSharding(mesh, P("data"))), h)
slot = jnp.asarray(np.r_[np.arange(3), [-1] * (n - 3)].astype(np.int32))
bad = jax.jit(lambda xs, hs: comm_ws.cyclic_comm(
    xs, hs, slot, 3, 2, 0.37, impl="ws", meshed=False, block=256))
worst = max_coll_elems(bad.lower(xs, hs).compile().as_text())
assert worst >= 2 * D, worst  # s * D with s=2
print("OK")
""", devices=8)
