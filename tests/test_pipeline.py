"""Pipelined round engine (DESIGN.md §14): split-phase stage/commit,
bounded-staleness admission, the simulated straggler clock, and the
pipelined checkpoint carry.

The load-bearing guarantee is the first block: at ``staleness=0`` the
split-phase engine replays the synchronous driver's op sequence — same
cohorts, same L draws, same comm keys, same fault resolution — so every
equivalence is leaf-wise <= 1e-6 (float32 reduction-order slack), on both
uplinks, elastic and all-rows bodies, with and without a FaultPlan /
CohortPlan.  Everything the pipeline adds (overlap, admission, clocks)
is then tested as *structured metadata* on top of that anchored core.
"""

from __future__ import annotations

_SETUP = r"""
import numpy as np
import jax, jax.numpy as jnp
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import cohort as cm
from repro.dist import faults, rounds, tamuna_dp

mesh = jax.make_mesh((1, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                  remat=False)
n = 8
dcfg = DataConfig(seq_len=8, per_client_batch=1, vocab=64, seed=0,
                  n_clients=n)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
data = pipe.device_data()
sampler = device_sampler(dcfg, cfg, mesh)


def build(uplink, c=2, s=2, elastic=True):
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=s, p=0.5,
                                      uplink=uplink)
    sync_fn = rounds.make_round_fn(cfg, tcfg, mesh, sample_batch=sampler,
                                   max_L=8, n=n, elastic=elastic)
    eng = rounds.make_pipelined_round_fn(cfg, tcfg, mesh,
                                         sample_batch=sampler, max_L=8,
                                         n=n, elastic=elastic)
    mk = lambda: tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg,
                                      n=n)
    return tcfg, mk, sync_fn, eng


def maxerr(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda u, v: float(jnp.max(jnp.abs(u.astype(jnp.float32)
                                           - v.astype(jnp.float32)))),
        a, b)), default=0.0)


class RowLogger:
    def __init__(self):
        self.rows = []

    def log(self, step, m):
        self.rows.append(dict(m))
"""


def test_tau0_equivalent_to_sync_engine(subproc):
    # staleness=0 through the split-phase engine == run_rounds, leaf-wise
    # <= 1e-6: both uplinks x {elastic c<n, all-rows} — the ISSUE's
    # acceptance anchor
    subproc(_SETUP + r"""
for uplink in ("masked_psum", "block_rs"):
    for elastic in (True, False):
        _, mk, sync_fn, eng = build(uplink, elastic=elastic)
        kw = dict(data=data, key=jax.random.key(7), rounds=6, p=0.5,
                  flush_every=3)
        st_s, last_s = rounds.run_rounds(
            mk(), round_fn=sync_fn, rng=np.random.default_rng(3), **kw)
        st_p, last_p = rounds.run_rounds_pipelined(
            mk(), round_fn=eng, rng=np.random.default_rng(3),
            staleness=0, **kw)
        err = maxerr((st_s.x, st_s.h, st_s.opt), (st_p.x, st_p.h, st_p.opt))
        assert err <= 1e-6, (uplink, elastic, err)
        assert abs(last_s["loss"] - last_p["loss"]) <= 1e-6
        assert last_p["staleness"] == 0
print("OK")
""", devices=1, timeout=1500)


def test_tau0_equivalent_under_faults_and_plan(subproc):
    # the sync_equiv regime reuses the PR 6 fault resolver verbatim:
    # drops + NaN corruption + payload guard + quorum resample must
    # produce the identical arrived-mask aggregation; a CohortPlan must
    # drive the identical schedule through both drivers
    subproc(_SETUP + r"""
fp = faults.FaultPlan(11, n, p_drop=0.3, p_corrupt=0.2,
                      corrupt_mode="nan")
_, mk, sync_fn, eng = build("masked_psum")
kw = dict(data=data, key=jax.random.key(7), rounds=6, p=0.5,
          flush_every=3, faults=fp, policy="quorum", quorum=1)
st_s, last_s = rounds.run_rounds(mk(), round_fn=sync_fn,
                                 rng=np.random.default_rng(3), **kw)
st_p, last_p = rounds.run_rounds_pipelined(
    mk(), round_fn=eng, rng=np.random.default_rng(3), staleness=0, **kw)
err = maxerr((st_s.x, st_s.h, st_s.opt), (st_p.x, st_p.h, st_p.opt))
assert err <= 1e-6, err
assert last_s["arrivals"] == last_p["arrivals"]
assert last_s["corrupted"] == last_p["corrupted"]

# CohortPlan schedule (fresh plans: caches are per-object)
_, mk, sync_fn, eng = build("block_rs")
kw = dict(data=data, key=jax.random.key(7), rounds=5, p=0.5,
          flush_every=2)
st_s2, _ = rounds.run_rounds(mk(), round_fn=sync_fn,
                             rng=np.random.default_rng(3),
                             plan=cm.CohortPlan(5, n, 2), **kw)
st_p2, _ = rounds.run_rounds_pipelined(
    mk(), round_fn=eng, rng=np.random.default_rng(3), staleness=0,
    plan=cm.CohortPlan(5, n, 2), **kw)
assert maxerr((st_s2.x, st_s2.h), (st_p2.x, st_p2.h)) <= 1e-6
print("OK")
""", devices=1, timeout=1500)


def test_staleness_overlap_and_admission_properties(subproc):
    # tau=1 with a heavy-tailed latency model: (a) the clock actually
    # overlaps (round r+1 dispatches before round r commits, total clock
    # strictly below the sync schedule's); (b) wait_all admits every
    # cohort member; (c) quorum=c admits everyone too (all arrivals are
    # finite, ties land <= the cutoff) so it is BITWISE wait_all; (d) an
    # aggressive quorum drops late rows, and with s < c the dropped rows'
    # exclusively-owned coordinates show up in the uncovered trace
    subproc(_SETUP + r"""
lat = faults.EmpiricalDelays([0.05, 0.1, 3.0], n=n, seed=5)
_, mk, _, eng = build("masked_psum")
kw = dict(round_fn=eng, data=data, key=jax.random.key(7), rounds=8,
          p=0.5, flush_every=4, latency=lat)

log0, log1 = RowLogger(), RowLogger()
st0, last0 = rounds.run_rounds_pipelined(
    mk(), rng=np.random.default_rng(3), staleness=0, logger=log0,
    policy="wait_all", **kw)
st1, last1 = rounds.run_rounds_pipelined(
    mk(), rng=np.random.default_rng(3), staleness=1, logger=log1,
    policy="wait_all", **kw)
assert last1["commit_s"] < last0["commit_s"]  # the pipeline's point
commits = [r["commit_s"] for r in log1.rows]
dispatches = [r["dispatch_s"] for r in log1.rows]
assert all(a <= b for a, b in zip(commits, commits[1:]))  # clock monotone
# overlap evidence: some round dispatched before its predecessor committed
assert any(d < c for d, c in zip(dispatches[1:], commits[:-1]))
assert all(r["admitted"] == 2 and r["late_dropped"] == 0
           for r in log1.rows)

# quorum=c == wait_all bitwise at tau>=1
stq, _ = rounds.run_rounds_pipelined(
    mk(), rng=np.random.default_rng(3), staleness=1, policy="quorum",
    quorum=2, **kw)
for a, b in zip(jax.tree.leaves(st1), jax.tree.leaves(stq)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# aggressive quorum at s < c: late rows dropped; with s=2 of c=4
# owners per coordinate slot, a slot goes uncovered whenever BOTH its
# owners miss the cutoff — quorum=1 under a heavy tail drops up to 3
# rows a round, so the uncovered trace must light up
_, mk4, _, eng4 = build("masked_psum", c=4, s=2)
log4 = RowLogger()
st4, last4 = rounds.run_rounds_pipelined(
    mk4(), round_fn=eng4, data=data, key=jax.random.key(7), rounds=8,
    p=0.5, flush_every=4, latency=lat, rng=np.random.default_rng(3),
    staleness=1, policy="quorum", quorum=1, logger=log4)
drops = sum(r["late_dropped"] for r in log4.rows)
assert drops > 0
assert all(1 <= r["admitted"] <= 4
           and r["admitted"] + r["late_dropped"] <= 4 for r in log4.rows)
uncov = sum(r["uncovered"] for r in log4.rows)
assert uncov > 0  # s=1: every dropped row leaves its slot uncovered
assert all(np.isfinite(np.asarray(jax.tree.leaves(st4.x)[0])).all()
           for _ in [0])
print("OK")
""", devices=1, timeout=1500)


def test_inflight_cohorts_disjoint_and_depth_validation(subproc):
    # the no-overlap invariant: a client mid-round never joins a new
    # cohort, so consecutive cohorts at tau=1 are pairwise disjoint —
    # observed through a recording CohortPlan (the driver resolves busy-
    # aware cohorts via plan.cohort_excluding); plus the depth/engine
    # validation errors
    subproc(_SETUP + r"""
calls = []


class Recording(cm.CohortPlan):
    def cohort_excluding(self, rnd, busy, attempt=0):
        out = super().cohort_excluding(rnd, busy, attempt)
        calls.append((int(rnd), out.copy()))
        return out


_, mk, _, eng = build("masked_psum")
lat = faults.EmpiricalDelays([0.1, 2.0], n=n, seed=1)
st, _ = rounds.run_rounds_pipelined(
    mk(), round_fn=eng, data=data, key=jax.random.key(7), rounds=8,
    p=0.5, flush_every=4, rng=np.random.default_rng(3), staleness=1,
    latency=lat, policy="wait_all", plan=Recording(5, n, 2))
assert len(calls) >= 8
by_round = dict(calls)
# disjointness applies to rounds that are ever simultaneously in flight:
# cohort g vs g+1 for every STAGED g (cohorts past the horizon are
# resolved only as DownCom targets after their predecessor drained)
for g in range(8):
    if g + 1 in by_round:
        assert not set(by_round[g].tolist()) & set(by_round[g + 1].tolist())

# validation: depth needs c*(tau+1) <= n
import pytest

_, mk5, _, eng5 = build("masked_psum", c=5, s=2)
try:
    rounds.run_rounds_pipelined(
        mk5(), round_fn=eng5, data=data, key=jax.random.key(7), rounds=2,
        p=0.5, rng=np.random.default_rng(0), staleness=1)
    raise SystemExit("expected ValueError for c*(tau+1) > n")
except ValueError as e:
    assert "tau" in str(e) or "staleness" in str(e)

# validation: tau>=1 needs the elastic engine
_, mkf, _, engf = build("masked_psum", elastic=False)
try:
    rounds.run_rounds_pipelined(
        mkf(), round_fn=engf, data=data, key=jax.random.key(7), rounds=2,
        p=0.5, rng=np.random.default_rng(0), staleness=1)
    raise SystemExit("expected ValueError for all-rows at tau >= 1")
except ValueError as e:
    assert "elastic" in str(e)
print("OK")
""", devices=1, timeout=1500)


def test_pipeline_checkpoint_roundtrip_and_resume(subproc):
    # mid-run save with both buffers in flight: restore must round-trip
    # bit-exactly and a resumed run must land on the full run's state AND
    # clock exactly (the simulated schedule replays from the saved
    # dispatch/commit times); pipe_step_* dirs must be invisible to the
    # synchronous checkpoint scanner
    subproc(_SETUP + r"""
import shutil
from repro import checkpoint

lat = faults.EmpiricalDelays([0.1, 0.2, 1.5], n=n, seed=5)
_, mk, _, eng = build("masked_psum")
ckdir = "/tmp/pipe_ck_test"
shutil.rmtree(ckdir, ignore_errors=True)
kw = dict(round_fn=eng, data=data, key=jax.random.key(7), rounds=8,
          p=0.5, staleness=1, flush_every=2, latency=lat,
          policy="quorum", quorum=1)
st_full, last_full = rounds.run_rounds_pipelined(
    mk(), rng=np.random.default_rng(3), **kw)
st_a, _ = rounds.run_rounds_pipelined(
    mk(), rng=np.random.default_rng(3), checkpoint_dir=ckdir,
    checkpoint_every=4, **kw)
step = rounds.pipeline_latest_step(ckdir)
assert step is not None and 0 < step < 8
assert checkpoint.latest_step(ckdir) is None  # sync scanner ignores pipe
st_b, last_b = rounds.run_rounds_pipelined(
    mk(), rng=np.random.default_rng(3), checkpoint_dir=ckdir,
    resume=True, **kw)
err = maxerr((st_full.x, st_full.h, st_full.opt),
             (st_b.x, st_b.h, st_b.opt))
assert err == 0.0, err  # bit-exact continuation
assert last_b["commit_s"] == last_full["commit_s"]
assert last_b["local_steps"] == last_full["local_steps"]
print("OK")
""", devices=1, timeout=1500)


def test_tau1_equals_perstep_reference_with_delayed_updates(subproc):
    # the ISSUE's staleness-admission property, in its strongest form: at
    # tau=1 the pipelined engine must equal a per-step reference replay
    # in which every round's uplink/h-update/DownCom is applied ONE round
    # late — round u's cohort gathers from the state holding commits
    # <= u-2 and its trained rows sit in a pending buffer until commit.
    # Same key schedule (data_step_key by global step, comm_round_key by
    # commit index), same recorded cohorts, same geometric L draws.
    subproc(_SETUP + r"""
recorded = {}


class Recording(cm.CohortPlan):
    def cohort_excluding(self, rnd, busy, attempt=0):
        out = super().cohort_excluding(rnd, busy, attempt)
        recorded[int(rnd)] = out.copy()
        return out


tcfg, mk, _, eng = build("masked_psum")
ROUNDS = 6
st_p, _ = rounds.run_rounds_pipelined(
    mk(), round_fn=eng, data=data, key=jax.random.key(7), rounds=ROUNDS,
    p=0.5, flush_every=3, rng=np.random.default_rng(3), staleness=1,
    policy="wait_all", plan=Recording(5, n, 2))

# per-step reference on the identical schedule, updates delayed by one
carry0 = rounds.init_carry(mk(), jax.random.key(7), flush_every=3)
dk = np.asarray(carry0.data_key).copy()
ck = np.asarray(carry0.comm_key).copy()
local = jax.jit(tamuna_dp.make_local_step(cfg, tcfg))
comm = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh, n=n))
rng = np.random.default_rng(3)
ref = mk()
pend, tstep = {}, 0
for u in range(ROUNDS + 1):
    if u < ROUNDS:
        L = tamuna_dp.sample_round_length(rng, 0.5, max_L=8)
        cohort = recorded[u]
        work = tamuna_dp.gather_cohort(ref, cohort)
        for _ in range(L):
            batch = sampler(data, rounds.data_step_key(dk, tstep),
                            clients=cohort)
            work, _m = local(work, **batch)
            tstep += 1
        pend[u] = (work, cohort)
    rc = u - 1
    if rc >= 0:
        work, cohort = pend.pop(rc)
        ref = tamuna_dp.scatter_cohort(ref, work, cohort)
        down = tamuna_dp.member_mask(
            jnp.asarray(recorded[rc + 2], jnp.int32), n)
        ckey = rounds.comm_round_key(ck, rc)
        ref = comm(ref, jax.random.key_data(ckey), cohort=cohort,
                   down=down)

err = maxerr((st_p.x, st_p.h, st_p.opt), (ref.x, ref.h, ref.opt))
assert err <= 1e-6, err
print("OK")
""", devices=1, timeout=1500)
