"""Paper Figure 3 reproduction: logistic regression, d > n regime
(real-sim-like synthetic: d >> n), full + 10% participation, alpha in
{0, 0.1}.  Same claims as Fig. 2, in the regime where compression matters
most (d large -> s = 2 and the sqrt(d) acceleration is maximal)."""

from __future__ import annotations

import math

from benchmarks.common import floats_to_accuracy
from repro.core import baselines, problems, tamuna


def run(paper_scale: bool = False, seed: int = 0):
    n = 1000 if paper_scale else 64
    d = 20958 if paper_scale else 2048
    kappa = 1e4 if paper_scale else 1e3
    prob = problems.make_logreg_problem(
        n=n, d=d, samples_per_client=4, kappa=kappa, seed=seed,
        name="realsim-like",
    )
    gamma = 2.0 / (prob.L + prob.mu)
    target = float(prob.suboptimality(prob.x_star * 0.0)) * 1e-6

    rows = []
    for c_frac, tag in [(1.0, "full"), (0.1, "pp10")]:
        c = max(2, int(round(c_frac * prob.n)))
        rounds = 8000 if paper_scale else 4000
        traces = {}
        cfgT = tamuna.TamunaConfig.tuned(prob, c=c)
        traces["tamuna"] = tamuna.run(
            prob, cfgT, num_rounds=rounds, seed=seed, record_every=10
        )
        traces["scaffold"] = baselines.run_scaffold(
            prob, 0.5 * gamma, local_steps=max(1, int(1 / cfgT.p)), c=c,
            num_rounds=min(rounds, 2000), seed=seed, record_every=10,
        )
        if c == prob.n:
            traces["scaffnew"] = baselines.run_scaffnew(
                prob, gamma, p=cfgT.p, num_iters=12000,
                seed=seed, record_every=50,
            )
        for alpha in (0.0, 0.1):
            for name, tr in traces.items():
                rows.append({
                    "figure": "fig3", "regime": tag, "alpha": alpha,
                    "algo": name,
                    "floats_to_target": floats_to_accuracy(tr, target, alpha),
                    "final_subopt": float(tr["suboptimality"][-1]),
                })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
