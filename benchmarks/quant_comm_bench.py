"""Quantized-wire benchmark: wire bytes, round time, and convergence
floor vs payload width (DESIGN.md §13).

Three measurements, one artifact (``BENCH_quant_comm.json``):

  bytes        per-round UpCom/DownCom wire bytes per client, read off the
               comm step's dtype-aware accounting counters (NOT recomputed
               on the host) at reduced gemma2-2b on the 4x2 host mesh, for
               wire_precision in {f32, bf16, f16, int8, auto}.  Headline:
               ``up_bytes_ratio_int8_vs_f32`` (acceptance >= 3.5x — int8
               codes + one f32 scale per 256-coordinate chunk).
  timing       fused-round wall time (``rounds.make_round_fn``: L scanned
               local steps + comm step, donated state) f32 vs int8 on the
               same mesh.  Acceptance: round_time_ratio <= 1.10 — the
               quantize/dequant work amortizes over the local steps.  The
               comm-step-only ratio is recorded as an informational row:
               on CPU the int8 hash-draw + code packing is NOT free at the
               step level (the EXPERIMENTS.md negative result); the claim
               is about the round, which is what the trainer dispatches.
  convergence  the floor sweep: strongly convex logreg (Theorem-3 tuned
               TAMUNA, same problem family as BENCH_faults) run at
               wire_precision in {f32, f16, int8, int4} for the SAME
               number of rounds R (R = rounds for f32 to reach
               ``TARGET_REL`` x the initial gap).  Records the converged
               suboptimality floor per width (min over the trailing
               window).  Acceptance: floor(int8) <= 10 x floor(f32) at
               matched rounds; int4's higher floor is the expected
               variance-vs-bits tradeoff and is recorded, not gated.

``run(smoke=True)`` (or ``REPRO_BENCH_SMOKE=1``) shrinks every problem
and skips the artifact write — wired into tests/test_bench_tooling.py.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
ARTIFACT = os.path.join(REPO, "BENCH_quant_comm.json")

# --- meshed subprocess: byte accounting + fused-round timing (8 devices)
_MESHED_CODE = r"""
import json, os, time
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.data import DataConfig, SyntheticTokenPipeline, device_sampler
from repro.dist import rounds, sharding, tamuna_dp, wire
from repro.launch.mesh import make_host_mesh

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DP, MP = (2, 1) if SMOKE else (4, 2)
# L = round(1/p): the paper's local-training regime (many local steps
# per comm round) is what amortizes the wire codec over the round
L, ROUNDS, WARM = (2, 2, 1) if SMOKE else (8, 10, 3)
P_GEOM = 0.5 if SMOKE else 0.125
mesh = make_host_mesh(DP, MP)
cfg = registry.get_reduced_config("gemma2-2b")
n = sharding.n_clients(mesh)
dcfg = DataConfig(seq_len=64, per_client_batch=2,
                  vocab=min(cfg.vocab, 512), seed=0)

def tcfg_for(policy):
    return tamuna_dp.DistTamunaConfig(
        gamma=0.05, c=max(2, (3 * n) // 4), s=2, p=P_GEOM,
        wire_precision=policy)

def fresh_state(tcfg):
    st = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      tamuna_dp.state_pspecs(st, cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(st, sh)

# --- bytes: one comm step per policy, read the state counters
bytes_rows = []
for policy in ("f32", "bf16", "f16", "int8", "auto"):
    tcfg = tcfg_for(policy)
    st = fresh_state(tcfg)
    raw = tamuna_dp.make_comm_step(cfg, tcfg, mesh)
    out = jax.jit(raw)(st, jax.random.key_data(jax.random.key(7)))
    kinds = list(raw.wire_kinds)
    bytes_rows.append({
        "policy": policy,
        "up_bytes_per_round": float(out.up_bytes),
        "down_bytes_per_round": float(out.down_bytes),
        "up_floats_per_round": float(out.up_floats),
        "leaf_kind_counts": {k: kinds.count(k) for k in sorted(set(kinds))},
    })
    print(f"# bytes {policy}: up={float(out.up_bytes):.3e} "
          f"down={float(out.down_bytes):.3e} "
          f"(floats*4={float(out.up_floats)*4:.3e})", flush=True)
by_policy = {r["policy"]: r for r in bytes_rows}
up_ratio = (by_policy["f32"]["up_bytes_per_round"]
            / by_policy["int8"]["up_bytes_per_round"])

# --- timing: fused round f32 vs int8 (+ comm-step-only, informational)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
data = pipe.device_data()
round_us, comm_us = {}, {}
for policy in ("f32", "int8"):
    tcfg = tcfg_for(policy)
    round_fn = rounds.make_round_fn(
        cfg, tcfg, mesh, sample_batch=device_sampler(dcfg, cfg, mesh),
        max_L=8)
    carry = rounds.init_carry(fresh_state(tcfg), jax.random.key(1),
                              flush_every=8)
    for r in range(WARM):
        carry = round_fn(carry, data, L, r % 8)
    jax.block_until_ready(carry.state.round)
    ts = []
    for r in range(ROUNDS):
        t0 = time.perf_counter()
        carry = round_fn(carry, data, L, r % 8)
        jax.block_until_ready(carry.state.round)
        ts.append(time.perf_counter() - t0)
    round_us[policy] = float(np.min(ts)) * 1e6

    comm = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh),
                   donate_argnums=(0,))
    st = fresh_state(tcfg)
    for r in range(WARM):
        st = comm(st, jax.random.key_data(jax.random.key(r)))
    jax.block_until_ready(st.round)
    ts = []
    for r in range(ROUNDS):
        t0 = time.perf_counter()
        st = comm(st, jax.random.key_data(jax.random.key(r)))
        jax.block_until_ready(st.round)
        ts.append(time.perf_counter() - t0)
    comm_us[policy] = float(np.min(ts)) * 1e6
    print(f"# timing {policy}: round {round_us[policy]/1e3:.1f}ms "
          f"comm {comm_us[policy]/1e3:.1f}ms", flush=True)

out = {
    "bytes_rows": bytes_rows,
    "up_bytes_ratio_int8_vs_f32": up_ratio,
    "round_us": round_us,
    "comm_us": comm_us,
    "round_time_ratio_int8_vs_f32": round_us["int8"] / round_us["f32"],
    "comm_time_ratio_int8_vs_f32": comm_us["int8"] / comm_us["f32"],
    "config": {"arch": cfg.name, "mesh": f"{DP}x{MP}", "L": L,
               "rounds": ROUNDS, "n": n},
}
print(json.dumps(out))
"""

# --- convergence subprocess: floor vs bits on convex logreg (1 device)
_CONV_CODE = r"""
import json, os
import numpy as np
import jax, jax.numpy as jnp

from repro.core import problems, tamuna
from repro.dist import comm_ws, wire

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
N, D, SPC = (8, 16, 4) if SMOKE else (16, 32, 8)
KAPPA = 50.0 if SMOKE else 100.0
MAX_ROUNDS = 60 if SMOKE else 4000
TARGET_REL = 1e-1 if SMOKE else 1e-3
KINDS = ("f32", "int8") if SMOKE else ("f32", "f16", "int8", "int4")
TAIL = 5 if SMOKE else 20

prob = problems.make_logreg_problem(
    n=N, d=D, samples_per_client=SPC, kappa=KAPPA, seed=0
)
C = max(2, N // 4)
cfg = tamuna.TamunaConfig.tuned(prob, c=C)
L = max(1, round(1.0 / cfg.p))
scale = cfg.eta / cfg.gamma
gap0 = float(prob.suboptimality(jnp.zeros(D)))
target = gap0 * TARGET_REL


@jax.jit
def local_steps(x_bar, h, cohort):
    Xc = jnp.broadcast_to(x_bar, (C, D))
    hc = h[cohort]

    def body(i, Xc):
        return Xc - cfg.gamma * prob.cohort_grads(Xc, cohort) \
            + cfg.gamma * hc

    return jax.lax.fori_loop(0, L, body, Xc)


def comm_step(kind):
    wired = wire.is_wire(kind)

    @jax.jit
    def step(x_bar, h, Xc, cohort, slot, wseed):
        X = jnp.broadcast_to(x_bar, (N, D)).at[cohort].set(Xc)
        return comm_ws.cyclic_comm(
            X, h, slot, C, cfg.s, scale, impl="ws",
            wire=kind if wired else None,
            wire_seed=wseed if wired else None,
        )

    return step


def run_kind(kind, rounds, seed=3):
    step = comm_step(kind)
    rng = np.random.default_rng(seed)
    x_bar = jnp.zeros(D)
    h = jnp.zeros((N, D))
    subs = []
    hit = None
    for g in range(rounds):
        cohort = rng.choice(N, size=C, replace=False)
        slot_np = np.full(N, -1, np.int64)
        slot_np[cohort] = rng.permutation(C)
        slot = jnp.asarray(slot_np, jnp.int32)
        cohort_j = jnp.asarray(cohort, jnp.int32)
        wseed = wire.round_seed(
            jax.random.fold_in(jax.random.key(g), wire.WIRE_FOLD))
        Xc = local_steps(x_bar, h, cohort_j)
        x_new, h = step(x_bar, h, Xc, cohort_j, slot, wseed)
        idle = int(np.setdiff1d(np.arange(N), cohort)[0])
        x_bar = x_new[idle]
        subs.append(float(prob.suboptimality(x_bar)))
        if hit is None and subs[-1] < target:
            hit = g + 1
            if kind == "f32":
                break
    floor = float(np.min(subs[-TAIL:]))
    return {"kind": kind,
            "bits": {"f32": 32, "f16": 16, "int8": 8, "int4": 4}[kind],
            "rounds": len(subs), "rounds_to_target": hit,
            "final_suboptimality": subs[-1], "floor": floor}


# R = rounds for the f32 wire to hit target; every width runs exactly R
f32_probe = run_kind("f32", MAX_ROUNDS)
R = f32_probe["rounds_to_target"] or MAX_ROUNDS
rows = [run_kind(k, R) for k in KINDS]
for r in rows:
    print(f"# conv {r['kind']} ({r['bits']}b): floor={r['floor']:.3e} "
          f"final={r['final_suboptimality']:.3e} rounds={r['rounds']}",
          flush=True)
by = {r["kind"]: r for r in rows}
out = {
    "rows": rows,
    "matched_rounds": R,
    "target": target,
    "initial_gap": gap0,
    "floor_ratio_int8_vs_f32": by["int8"]["floor"] / by["f32"]["floor"],
    "config": {"n": N, "d": D, "c": C, "s": cfg.s, "L": L,
               "kappa": KAPPA, "target_rel": TARGET_REL,
               "kinds": list(KINDS), "tail": TAIL},
}
print(json.dumps(out))
"""


def _bench(code: str, devices: int = 0, smoke: bool = False) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}" if devices
        else ""  # single real CPU device
    )
    if smoke:
        env["REPRO_BENCH_SMOKE"] = "1"
    else:
        env.pop("REPRO_BENCH_SMOKE", None)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=1800, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        print(f"# quant_comm bench failed:\n{proc.stderr}",
              file=sys.stderr)
        return {}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(paper_scale: bool = False, smoke: bool = False):
    del paper_scale
    meshed = _bench(_MESHED_CODE, devices=2 if smoke else 8, smoke=smoke)
    conv = _bench(_CONV_CODE, smoke=smoke)
    if not meshed or not conv:
        return []
    art = {
        "meshed": meshed,
        "convergence": conv,
        "up_bytes_ratio_int8_vs_f32": meshed["up_bytes_ratio_int8_vs_f32"],
        "round_time_ratio_int8_vs_f32":
            meshed["round_time_ratio_int8_vs_f32"],
        "floor_ratio_int8_vs_f32": conv["floor_ratio_int8_vs_f32"],
        "acceptance": {"up_bytes_ratio_min": 3.5,
                       "round_time_ratio_max": 1.10,
                       "floor_ratio_max": 10.0},
    }
    if not smoke:  # smoke runs must not clobber the measured artifact
        with open(ARTIFACT, "w") as f:
            json.dump(art, f, indent=1)
    rows = []
    for r in meshed["bytes_rows"]:
        rows.append({
            "name": f"quant_comm/bytes/{r['policy']}",
            "us_per_call": r["up_bytes_per_round"],
            "derived": (f"down={r['down_bytes_per_round']:.3e} "
                        f"kinds={r['leaf_kind_counts']}"),
        })
    rows.append({
        "name": "quant_comm/up_bytes_ratio_int8_vs_f32",
        "us_per_call": round(art["up_bytes_ratio_int8_vs_f32"], 3),
        "derived": "acceptance: >= 3.5x",
    })
    for policy, us in meshed["round_us"].items():
        rows.append({
            "name": f"quant_comm/round/{policy}",
            "us_per_call": us,
            "derived": f"comm_only={meshed['comm_us'][policy]:.0f}us",
        })
    rows.append({
        "name": "quant_comm/round_time_ratio_int8_vs_f32",
        "us_per_call": round(art["round_time_ratio_int8_vs_f32"], 3),
        "derived": ("acceptance: <= 1.10 (fused round; comm-step-only "
                    f"ratio {meshed['comm_time_ratio_int8_vs_f32']:.2f} "
                    "is informational — CPU int8 packing is not free)"),
    })
    for r in conv["rows"]:
        rows.append({
            "name": f"quant_comm/floor/{r['kind']}",
            "us_per_call": r["floor"],
            "derived": (f"bits={r['bits']} rounds={r['rounds']} "
                        f"final={r['final_suboptimality']:.3e}"),
        })
    rows.append({
        "name": "quant_comm/floor_ratio_int8_vs_f32",
        "us_per_call": round(art["floor_ratio_int8_vs_f32"], 3),
        "derived": (f"acceptance: <= 10x at matched "
                    f"rounds={conv['matched_rounds']}"),
    })
    return rows


if __name__ == "__main__":
    for r in run(smoke=os.environ.get("REPRO_BENCH_SMOKE") == "1"):
        print(r)
