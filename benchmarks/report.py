"""Render EXPERIMENTS.md markdown tables from benchmark artifacts.

  # dry-run roofline / memory tables (needs benchmarks/artifacts/dryrun)
  PYTHONPATH=src:. python -m benchmarks.report > benchmarks/artifacts/roofline_table.md

  # perf-trajectory table: every BENCH_*.json acceptance metric in one
  # place, so a regression in any shipped benchmark is visible at a glance
  PYTHONPATH=src:. python -m benchmarks.report --trajectory
"""

from __future__ import annotations

import argparse
import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)


def memory_table(mesh: str) -> str:
    from benchmarks import roofline

    rows = []
    for path in sorted(glob.glob(os.path.join(
            roofline.ART, mesh, "*", "*", "*.json"))):
        r = json.load(open(path))
        m = r["memory_analysis"]
        rows.append((
            r["arch"], r["shape"], r["step"],
            (m["argument_bytes"] or 0) / 1e9,
            (m["temp_bytes"] or 0) / 1e9,
            (m["output_bytes"] or 0) / 1e9,
            r["compile_s"],
        ))
    out = [
        f"| arch | shape | step | args GB/dev | temp GB/dev | out GB/dev | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for a, s, st, ab, tb, ob, cs in rows:
        out.append(f"| {a} | {s} | {st} | {ab:.2f} | {tb:.2f} | {ob:.2f} | {cs} |")
    return "\n".join(out)


def roofline_table(mesh: str) -> str:
    from benchmarks import roofline

    rows = roofline.table(mesh)
    out = [
        "| arch | shape | step | compute s | memory s | collective s |"
        " dominant | useful FLOPs ratio |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        u = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} |"
            f" {r['compute_s']:.3e} | {r['memory_s']:.3e} |"
            f" {r['collective_s']:.3e} | {r['dominant']} |"
            f" {'' if u is None else f'{u:.3f}'} |"
        )
    return "\n".join(out)


# --------------------------------------------------------------------------
# perf trajectory: one table over every BENCH_*.json acceptance metric
# --------------------------------------------------------------------------


def _load(name: str):
    path = os.path.join(REPO, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def trajectory_rows() -> list:
    """(artifact, metric, value, target, ok) for every shipped benchmark
    artifact present in the repo root.  Missing artifacts are skipped, so
    the table degrades gracefully on fresh checkouts."""
    rows = []

    def add(artifact, metric, value, target, higher_is_better=True):
        ok = (value >= target) if higher_is_better else (value <= target)
        cmp = ">=" if higher_is_better else "<="
        rows.append((artifact, metric, value, f"{cmp} {target}", ok))

    dr = _load("BENCH_dist_round.json")
    if dr:
        add("dist_round", "cohort round time ratio n512/n16",
            dr["ratio_n512_over_n16"]["cohort"], 2.0,
            higher_is_better=False)
        # the seed's full-population path is the CONTRAST baseline: the
        # point is that it scales badly, so "ok" means it still shows
        # the O(n) growth the cohort path removed
        add("dist_round", "full-population prior ratio n512/n16 "
            "(contrast: the O(n) cost the cohort path removed)",
            dr["ratio_n512_over_n16"]["full_population"], 2.0)

    re_ = _load("BENCH_round_engine.json")
    if re_:
        add("round_engine", "fused+device-data speedup vs per-step",
            re_["speedup_fused_vs_per_step"], 1.0)
        add("round_engine", "distinct compiled programs",
            re_["distinct_compilations"], re_["compile_cache_bound"],
            higher_is_better=False)

    cs = _load("BENCH_comm_step.json")
    if cs:
        acc = cs["acceptance"]
        add("comm_step", "ws vs dense speedup, largest unsharded n",
            cs["largest_config_speedup"], acc["largest_config_min"])
        add("comm_step", "ws vs dense min speedup, any unsharded row",
            cs["min_speedup_any_config"], acc["any_config_min"])
        meshed = cs.get("meshed")
        if meshed:
            macc = meshed["acceptance"]
            add("comm_step", "shard engine vs meshed-ws, best at largest n",
                meshed["largest_n_best_speedup_vs_ws"],
                macc["largest_n_best_min"])
            add("comm_step", "shard engine vs meshed-ws, min any row",
                meshed["min_speedup_vs_ws_any_row"], macc["any_row_min"])

    el = _load("BENCH_elastic.json")
    if el:
        acc = el["acceptance"]
        add("elastic", "cohort-gathered round vs all-rows at c=n/4",
            el["speedup_at_quarter_cohort"], acc["quarter_cohort_min"])
        add("elastic", "min speedup vs all-rows, any c < n row",
            el["min_speedup_any_partial_row"], acc["any_partial_row_min"])

    fl = _load("BENCH_faults.json")
    if fl:
        acc = fl["acceptance"]
        ratio = fl.get("quorum_ratio_at_p02")
        add("faults", "quorum rounds-to-target vs fault-free at p=0.2",
            ratio if ratio is not None else float("inf"),
            acc["quorum_ratio_max"], higher_is_better=False)
        add("faults", "wait_all control stalls/biases at p=0.2 (1=yes)",
            float(bool(fl.get("wait_all_control_stalls_at_p02"))), 1.0)
        add("faults", "deterministic fault replay bitwise (1=yes)",
            float(bool(fl.get("deterministic_replay_ok"))), 1.0)

    qc = _load("BENCH_quant_comm.json")
    if qc:
        acc = qc["acceptance"]
        add("quant_comm", "up-bytes reduction int8 vs f32 wire",
            qc["up_bytes_ratio_int8_vs_f32"], acc["up_bytes_ratio_min"])
        add("quant_comm", "fused-round time ratio int8 vs f32",
            qc["round_time_ratio_int8_vs_f32"],
            acc["round_time_ratio_max"], higher_is_better=False)
        add("quant_comm", "convergence floor ratio int8 vs f32",
            qc["floor_ratio_int8_vs_f32"], acc["floor_ratio_max"],
            higher_is_better=False)

    pl = _load("BENCH_pipeline.json")
    if pl:
        acc = pl["acceptance"]
        add("pipeline", "wall-clock speedup at measured tail, best "
            f"wait_all tau={pl['speedup_tau']} vs sync",
            pl["speedup_at_tail"], acc["min_speedup_at_tail"])
        add("pipeline", "headline tau final loss within sync seed band "
            "(1=yes)",
            float(bool(pl["tail_loss_within_sync_band"])), 1.0)

    rb = _load("BENCH_robust.json")
    if rb:
        acc = rb["acceptance"]
        for key, ratio in sorted(rb["ratios"].items()):
            add("robust", f"rounds-to-target ratio vs fault-free, {key} "
                f"at f={rb['config']['f_byz']}",
                ratio if ratio is not None else float("inf"),
                acc["robust_ratio_max"], higher_is_better=False)
        for attack, stalls in sorted(rb["mean_control_stalls"].items()):
            add("robust", f"plain mean stalls under {attack} (1=yes)",
                float(bool(stalls)), 1.0)
        add("robust", "robust comm-step overhead vs mean, production "
            "uplink shape",
            rb["robust_overhead_ratio"], acc["overhead_ratio_max"],
            higher_is_better=False)
        add("robust", "trimmed k=0 bitwise == mean, all impls (1=yes)",
            float(bool(rb["identity_bitwise_ok"])), 1.0)
        add("robust", "fault/reputation schedule replay bitwise (1=yes)",
            float(bool(rb["deterministic_replay_ok"])), 1.0)
        add("robust", "int8-wire robust aggregate max dev vs f32 wire",
            rb["int8_wire_max_dev"], acc["int8_wire_dev_max"],
            higher_is_better=False)

    return rows


def wire_bytes_table() -> str:
    """Per-policy up/down wire bytes per round (the comm step's dtype-
    aware accounting counters, BENCH_quant_comm.json)."""
    qc = _load("BENCH_quant_comm.json")
    if not qc:
        return ""
    out = [
        "| wire policy | up bytes/round | down bytes/round | leaf kinds |",
        "|---|---|---|---|",
    ]
    for r in qc["meshed"]["bytes_rows"]:
        kinds = ", ".join(f"{k}:{v}"
                          for k, v in r["leaf_kind_counts"].items())
        out.append(
            f"| {r['policy']} | {r['up_bytes_per_round']:.3e} |"
            f" {r['down_bytes_per_round']:.3e} | {kinds} |"
        )
    return "\n".join(out)


def trajectory_table() -> str:
    rows = trajectory_rows()
    out = [
        "| artifact | metric | value | acceptance | ok |",
        "|---|---|---|---|---|",
    ]
    for artifact, metric, value, target, ok in rows:
        out.append(
            f"| {artifact} | {metric} | {value:.3f} | {target} |"
            f" {'yes' if ok else 'NO'} |"
        )
    return "\n".join(out)


def trajectory_json(path: str) -> None:
    """Machine-readable twin of the --trajectory table: the same
    (artifact, metric, value, acceptance, ok) rows as JSON, so CI and
    the next session can diff acceptance status without parsing
    markdown."""
    rows = [
        {"artifact": a, "metric": m, "value": v, "acceptance": t,
         "ok": bool(ok)}
        for a, m, v, t, ok in trajectory_rows()
    ]
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump({"rows": rows,
                   "all_ok": all(r["ok"] for r in rows)}, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trajectory", action="store_true",
                    help="print the BENCH_*.json trajectory table and "
                         "write its JSON twin to --trajectory-json")
    ap.add_argument("--trajectory-json",
                    default=os.path.join(HERE, "artifacts",
                                         "trajectory.json"),
                    help="where --trajectory writes the machine-readable "
                         "rows (empty string disables the write)")
    args = ap.parse_args(argv)
    if args.trajectory:
        print("\n## Perf trajectory — BENCH_*.json acceptance metrics\n")
        print(trajectory_table())
        if args.trajectory_json:
            trajectory_json(args.trajectory_json)
        wb = wire_bytes_table()
        if wb:
            print("\n## Wire bytes per round — BENCH_quant_comm.json\n")
            print(wb)
        return
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n## Roofline table — {mesh}\n")
        print(roofline_table(mesh))
    print("\n## Memory analysis — pod16x16 (per-device)\n")
    print(memory_table("pod16x16"))
    print("\n## Perf trajectory — BENCH_*.json acceptance metrics\n")
    print(trajectory_table())


if __name__ == "__main__":
    main()
