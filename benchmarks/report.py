"""Render EXPERIMENTS.md §Dry-run / §Roofline markdown tables from the
dry-run artifacts.

  PYTHONPATH=src:. python -m benchmarks.report > benchmarks/artifacts/roofline_table.md
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks import roofline


def memory_table(mesh: str) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(
            roofline.ART, mesh, "*", "*", "*.json"))):
        r = json.load(open(path))
        m = r["memory_analysis"]
        rows.append((
            r["arch"], r["shape"], r["step"],
            (m["argument_bytes"] or 0) / 1e9,
            (m["temp_bytes"] or 0) / 1e9,
            (m["output_bytes"] or 0) / 1e9,
            r["compile_s"],
        ))
    out = [
        f"| arch | shape | step | args GB/dev | temp GB/dev | out GB/dev | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for a, s, st, ab, tb, ob, cs in rows:
        out.append(f"| {a} | {s} | {st} | {ab:.2f} | {tb:.2f} | {ob:.2f} | {cs} |")
    return "\n".join(out)


def roofline_table(mesh: str) -> str:
    rows = roofline.table(mesh)
    out = [
        "| arch | shape | step | compute s | memory s | collective s |"
        " dominant | useful FLOPs ratio |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        u = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} |"
            f" {r['compute_s']:.3e} | {r['memory_s']:.3e} |"
            f" {r['collective_s']:.3e} | {r['dominant']} |"
            f" {'' if u is None else f'{u:.3f}'} |"
        )
    return "\n".join(out)


def main():
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n## Roofline table — {mesh}\n")
        print(roofline_table(mesh))
    print("\n## Memory analysis — pod16x16 (per-device)\n")
    print(memory_table("pod16x16"))


if __name__ == "__main__":
    main()
