"""Benchmark harness driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call is the natural scalar
of each benchmark: wall time for kernels, communicated floats for the
convex-experiment reproductions, roofline compute-seconds for the dry-run
table).  Full row dicts are dumped to benchmarks/artifacts/results.json.

  PYTHONPATH=src python -m benchmarks.run [--only fig2,fig3,...] [--paper-scale]

``--smoke`` runs tiny-shape versions of the benches that support it (a
``smoke=`` kwarg on their ``run``) and SKIPS the rest — a seconds-scale
correctness pass over the bench code itself (wired into the test suite so
bench modules cannot rot), never a perf measurement and never a
BENCH_*.json write.
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: fig2,fig3,table1,table2,kernels,"
                         "dist_round,round_engine,comm_step,elastic,"
                         "faults,quant_comm,pipeline,robust,roofline")
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no artifact writes; skips benches "
                         "without smoke support")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(here)
    for p in (repo, os.path.join(repo, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

    all_rows = {}
    csv_rows = []

    def emit(name, us, derived):
        csv_rows.append((name, us, derived))

    def smoke_call(run_fn, *fn_args):
        """Thread smoke= into run() when supported; in smoke mode a bench
        without smoke support is skipped (full-cost runs defeat the
        point of a seconds-scale rot check)."""
        if not args.smoke:
            return run_fn(*fn_args)
        if "smoke" in inspect.signature(run_fn).parameters:
            return run_fn(*fn_args, smoke=True)
        return None

    def section(key, fn):
        if only and key not in only:
            return
        t0 = time.time()
        rows = fn()
        if rows is None:
            print(f"# {key}: skipped (no --smoke support)",
                  file=sys.stderr)
            return
        all_rows[key] = rows
        print(f"# {key}: {len(rows)} rows in {time.time()-t0:.1f}s",
              file=sys.stderr)
        return rows

    rows = section("fig2", lambda: smoke_call(__import__(
        "benchmarks.paper_fig2", fromlist=["run"]).run, args.paper_scale))
    if rows:
        for r in rows:
            emit(
                f"fig2/{r['regime']}/a{r['alpha']}/{r['algo']}",
                r["floats_to_target"] if r["floats_to_target"] else -1,
                f"final_subopt={r['final_subopt']:.3e}",
            )

    rows = section("fig3", lambda: smoke_call(__import__(
        "benchmarks.paper_fig3", fromlist=["run"]).run, args.paper_scale))
    if rows:
        for r in rows:
            emit(
                f"fig3/{r['regime']}/a{r['alpha']}/{r['algo']}",
                r["floats_to_target"] if r["floats_to_target"] else -1,
                f"final_subopt={r['final_subopt']:.3e}",
            )

    rows = section("table1", lambda: smoke_call(__import__(
        "benchmarks.paper_table1", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(
                f"table1/{r['algo']}",
                r["upcom_measured"] if r["upcom_measured"] else -1,
                f"theory={r['upcom_theory']:.3e}",
            )

    rows = section("table2", lambda: smoke_call(__import__(
        "benchmarks.paper_table2", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(
                f"table2/a{r['alpha']}/{r['algo']}",
                r["totalcom_measured"] if r["totalcom_measured"] else -1,
                f"theory_a0={r['totalcom_theory_alpha0']:.3e}",
            )

    rows = section("kernels", lambda: smoke_call(__import__(
        "benchmarks.kernel_bench", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(r["name"], r["us_per_call"], r["derived"])

    rows = section("dist_round", lambda: smoke_call(__import__(
        "benchmarks.dist_round_bench", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(r["name"], r["us_per_call"], r["derived"])

    rows = section("round_engine", lambda: smoke_call(__import__(
        "benchmarks.round_engine_bench", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(r["name"], r["us_per_call"], r["derived"])

    rows = section("comm_step", lambda: smoke_call(__import__(
        "benchmarks.comm_step_bench", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(r["name"], r["us_per_call"], r["derived"])

    rows = section("elastic", lambda: smoke_call(__import__(
        "benchmarks.elastic_bench", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(r["name"], r["us_per_call"], r["derived"])

    rows = section("faults", lambda: smoke_call(__import__(
        "benchmarks.faults_bench", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(r["name"], r["us_per_call"], r["derived"])

    rows = section("quant_comm", lambda: smoke_call(__import__(
        "benchmarks.quant_comm_bench", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(r["name"], r["us_per_call"], r["derived"])

    rows = section("pipeline", lambda: smoke_call(__import__(
        "benchmarks.pipeline_bench", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(r["name"], r["us_per_call"], r["derived"])

    rows = section("robust", lambda: smoke_call(__import__(
        "benchmarks.robust_bench", fromlist=["run"]).run))
    if rows:
        for r in rows:
            emit(r["name"], r["us_per_call"], r["derived"])

    def _roofline():
        if args.smoke:  # reads dry-run artifacts; nothing to smoke
            return None
        from benchmarks import roofline

        try:
            return roofline.run()
        except Exception as e:  # artifacts may not exist yet
            print(f"# roofline skipped: {e}", file=sys.stderr)
            return []

    rows = section("roofline", _roofline)
    if rows:
        for r in rows:
            emit(r["name"], r["us_per_call"], r["derived"])

    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us},{derived}")

    if not args.smoke:  # smoke is a rot check: never touch artifacts
        os.makedirs(os.path.join(here, "artifacts"), exist_ok=True)
        with open(os.path.join(here, "artifacts", "results.json"),
                  "w") as f:
            json.dump(all_rows, f, indent=1, default=str)
    return 0


if __name__ == "__main__":
    sys.exit(main())
