"""Paper Figure 2 reproduction: logistic regression, n > d regime
(w8a-like synthetic: d=300), full + ~10% participation, alpha in {0, 0.1}.

Claims validated (EXPERIMENTS.md §Fig2):
  * every variance-reduced algorithm converges linearly to the exact
    solution; TAMUNA reaches machine precision,
  * full participation: TAMUNA < Scaffnew < {Scaffold, 5GCS} in TotalCom
    floats to target accuracy (TAMUNA wins via CC on top of LT),
  * ~10% participation: TAMUNA still converges and beats Scaffold/5GCS,
  * the TAMUNA-Scaffnew gap narrows as alpha grows (CC compresses UpCom
    only; DownCom stays d floats).

Scaled-down by default (n=64, kappa=1e3) so the harness runs on one CPU
core in minutes; --paper-scale restores n=1000, kappa=1e4.
"""

from __future__ import annotations

import math

from benchmarks.common import floats_to_accuracy
from repro.core import baselines, problems, tamuna


def run(paper_scale: bool = False, seed: int = 0):
    n = 1000 if paper_scale else 64
    kappa = 1e4 if paper_scale else 1e3
    d = 300
    prob = problems.make_logreg_problem(
        n=n, d=d, samples_per_client=8, kappa=kappa, seed=seed,
        name="w8a-like",
    )
    gamma = 2.0 / (prob.L + prob.mu)
    gamma_5gcs = 1.0 / math.sqrt(prob.mu * prob.L)
    target = float(prob.suboptimality(prob.x_star * 0.0)) * 1e-6

    rows = []
    for c_frac, tag in [(1.0, "full"), (0.1, "pp10")]:
        c = max(2, int(round(c_frac * prob.n)))
        rounds = 8000 if paper_scale else 4000

        traces = {}
        cfgT = tamuna.TamunaConfig.tuned(prob, c=c)
        traces["tamuna"] = tamuna.run(
            prob, cfgT, num_rounds=rounds, seed=seed, record_every=10
        )
        traces["scaffold"] = baselines.run_scaffold(
            prob, 0.5 * gamma, local_steps=max(1, int(1 / cfgT.p)), c=c,
            num_rounds=min(rounds, 2000), seed=seed, record_every=10,
        )
        traces["5gcs"] = baselines.run_5gcs(
            prob, gamma_5gcs, c=c, inner_steps=300,
            num_rounds=500, seed=seed, record_every=10,
        )
        if c == prob.n:
            traces["scaffnew"] = baselines.run_scaffnew(
                prob, gamma, p=cfgT.p, num_iters=12000, seed=seed,
                record_every=50,
            )
        for alpha in (0.0, 0.1):
            for name, tr in traces.items():
                fta = floats_to_accuracy(tr, target, alpha)
                rows.append({
                    "figure": "fig2", "regime": tag, "alpha": alpha,
                    "algo": name,
                    "floats_to_target": fta,
                    "final_subopt": float(tr["suboptimality"][-1]),
                })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
