"""Fault-tolerance benchmark: convergence under mid-round dropout.

Runs the strongly-convex logistic-regression TAMUNA loop (Theorem-3 tuned
parameters) with the *dist* comm step — ``comm_ws.cyclic_comm`` on the
flat client-stacked vector state — under a deterministic ``FaultPlan``
(DESIGN.md §12), sweeping Bernoulli uplink dropout p_fail in
{0, 0.1, 0.2, 0.4} across three drivers:

  fault-free  no drops: the reference rounds-to-target,
  quorum      survivor-aware aggregation (per-coordinate arrived-owner
              means, uncovered coordinates hold the previous server
              model) + cohort resample with capped exponential backoff
              when arrivals fall below c//2 + 1,
  wait_all    the biased control: whatever arrived is aggregated at the
              legacy 1/s scale, so dropped owners pull their coordinates
              toward zero — the failure mode survivor correction exists
              to fix.

Per scenario the artifact records rounds-to-target (suboptimality below
``target_rel`` x the initial gap), retries, quorum misses, and simulated
wall clock (unit step cost + retry backoff).  Acceptance: at
p_fail = 0.2 the quorum driver reaches target within 2x the fault-free
round count, while the wait_all control either never reaches it or ends
with a suboptimality >= 10x the target.  Deterministic replay: the
p_fail = 0.2 quorum run is executed twice and must match bitwise.

Writes ``BENCH_faults.json``; ``run(smoke=True)`` (or
``REPRO_BENCH_SMOKE=1``) shrinks the problem and skips the artifact
write — wired into tests/test_bench_tooling.py.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
ARTIFACT = os.path.join(REPO, "BENCH_faults.json")

_CODE = r"""
import json, os
import numpy as np
import jax, jax.numpy as jnp

from repro.core import problems, tamuna
from repro.dist import comm_ws
from repro.dist.cohort import CohortPlan
from repro.dist.faults import FaultModel, FaultPlan

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
N, D, SPC = (8, 16, 4) if SMOKE else (16, 32, 8)
KAPPA = 50.0 if SMOKE else 100.0
MAX_ROUNDS = 80 if SMOKE else 4000
TARGET_REL = 1e-1 if SMOKE else 1e-3
P_FAILS = (0.0, 0.2) if SMOKE else (0.0, 0.1, 0.2, 0.4)
MAX_RETRIES, BACKOFF0 = 3, 1.0

prob = problems.make_logreg_problem(
    n=N, d=D, samples_per_client=SPC, kappa=KAPPA, seed=0
)
C = max(2, N // 4)
cfg = tamuna.TamunaConfig.tuned(prob, c=C)
L = max(1, round(1.0 / cfg.p))
Q = C // 2 + 1
scale = cfg.eta / cfg.gamma
target = float(prob.suboptimality(jnp.zeros(D))) * TARGET_REL


@jax.jit
def local_steps(x_bar, h, cohort):
    Xc = jnp.broadcast_to(x_bar, (C, D))
    hc = h[cohort]

    def body(i, Xc):
        return Xc - cfg.gamma * prob.cohort_grads(Xc, cohort) \
            + cfg.gamma * hc

    return jax.lax.fori_loop(0, L, body, Xc)


def comm_step(correct):
    @jax.jit
    def step(x_bar, h, Xc, cohort, slot, arrived):
        # non-cohort rows sit at x_bar, so after the comm any idle row
        # reads back as "covered coords updated, uncovered keep the old
        # server model" -- exactly the survivor-aware server state
        X = jnp.broadcast_to(x_bar, (N, D)).at[cohort].set(Xc)
        x_new, h_new = comm_ws.cyclic_comm(
            X, h, slot, C, cfg.s, scale, impl="ws",
            arrived=arrived, correct=correct,
        )
        return x_new, h_new

    return step


def comm_step_clean():
    @jax.jit
    def step(x_bar, h, Xc, cohort, slot):
        X = jnp.broadcast_to(x_bar, (N, D)).at[cohort].set(Xc)
        return comm_ws.cyclic_comm(X, h, slot, C, cfg.s, scale, impl="ws")

    return step


def run_driver(p_fail, policy, seed=3):
    faults = FaultPlan(seed=seed, n=N, model=FaultModel(p_drop=p_fail))
    plan = CohortPlan(seed=7, n=N, c=C)
    faulted = p_fail > 0.0
    step = (comm_step(policy == "quorum") if faulted
            else comm_step_clean())
    x_bar = jnp.zeros(D)
    h = jnp.zeros((N, D))
    retries = quorum_miss = 0
    clock = 0.0
    hit = None
    subs = []
    for g in range(MAX_ROUNDS):
        attempt, backoff = 0, 0.0
        while True:
            cohort = np.asarray(plan.cohort(g, attempt))
            member = np.zeros(N, bool)
            member[cohort] = True
            arrived = member & ~faults.drops(g, attempt)
            if (policy == "quorum" and int(arrived.sum()) < Q
                    and attempt < MAX_RETRIES):
                quorum_miss += 1
                backoff += BACKOFF0 * (2.0 ** attempt)
                attempt += 1
                continue
            break
        retries += attempt
        clock += float(L) + backoff
        cohort_j = jnp.asarray(cohort, jnp.int32)
        # fresh ownership permutation per round (paper Alg. 1 line 10:
        # the unbiasedness of the compressed aggregate needs it; a fixed
        # template stalls ~4 orders of magnitude above the target)
        perm = np.random.default_rng(
            np.random.SeedSequence([7, 97, g, attempt])
        ).permutation(C)
        slot_np = np.full(N, -1, np.int64)
        slot_np[cohort] = perm
        slot = jnp.asarray(slot_np, jnp.int32)
        Xc = local_steps(x_bar, h, cohort_j)
        if faulted:
            x_new, h = step(x_bar, h, Xc, cohort_j, slot,
                            jnp.asarray(arrived))
        else:
            x_new, h = step(x_bar, h, Xc, cohort_j, slot)
        # read the server model off an idle row: covered coords carry the
        # aggregate, uncovered coords kept that row's x_bar
        idle = int(np.setdiff1d(np.arange(N), cohort)[0])
        x_bar = x_new[idle]
        sub = float(prob.suboptimality(x_bar))
        subs.append(sub)
        if hit is None and sub < target:
            hit = g + 1
            break
    return {
        "p_fail": p_fail, "policy": policy,
        "rounds_to_target": hit, "final_suboptimality": subs[-1],
        "retries": retries, "quorum_miss": quorum_miss,
        "sim_clock": clock,
        "x_fingerprint": [float(v) for v in np.asarray(x_bar)[:4]],
    }


rows = [run_driver(0.0, "fault_free")]
base = rows[0]["rounds_to_target"]
for pf in P_FAILS:
    if pf == 0.0:
        continue
    for policy in ("quorum", "wait_all"):
        rows.append(run_driver(pf, policy))
for r in rows:
    print(f"# p_fail={r['p_fail']} {r['policy']}: rounds="
          f"{r['rounds_to_target']} final={r['final_suboptimality']:.3e} "
          f"retries={r['retries']} clock={r['sim_clock']:.0f}",
          flush=True)

# deterministic replay: identical seeds => bitwise-identical trajectory
pf_chk = 0.2 if 0.2 in P_FAILS else max(P_FAILS)
a = run_driver(pf_chk, "quorum")
b = run_driver(pf_chk, "quorum")
replay_ok = (a["rounds_to_target"] == b["rounds_to_target"]
             and a["x_fingerprint"] == b["x_fingerprint"])

by = {(r["p_fail"], r["policy"]): r for r in rows}
q02 = by.get((0.2, "quorum"))
w02 = by.get((0.2, "wait_all"))
ratio = (q02["rounds_to_target"] / base
         if q02 and q02["rounds_to_target"] and base else None)
control_fails = (w02 is not None and (
    w02["rounds_to_target"] is None
    or w02["final_suboptimality"] >= 10 * target))
out = {
    "rows": rows,
    "target": target,
    "fault_free_rounds": base,
    "quorum_ratio_at_p02": ratio,
    "wait_all_control_stalls_at_p02": control_fails,
    "deterministic_replay_ok": replay_ok,
    "acceptance": {"quorum_ratio_max": 2.0,
                   "control_must_stall_or_bias": True,
                   "replay_bitwise": True},
    "config": {"n": N, "d": D, "c": C, "s": cfg.s, "L": L, "quorum": Q,
               "kappa": KAPPA, "target_rel": TARGET_REL,
               "max_rounds": MAX_ROUNDS, "p_fails": list(P_FAILS),
               "max_retries": MAX_RETRIES, "backoff0": BACKOFF0},
}
print(json.dumps(out))
"""


def _bench(smoke: bool) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = ""  # single real CPU device
    if smoke:
        env["REPRO_BENCH_SMOKE"] = "1"
    else:
        env.pop("REPRO_BENCH_SMOKE", None)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _CODE],
        capture_output=True, text=True, timeout=1800, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        print(f"# faults bench failed:\n{proc.stderr}", file=sys.stderr)
        return {}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(paper_scale: bool = False, smoke: bool = False):
    del paper_scale
    art = _bench(smoke=smoke)
    if not art:
        return []
    if not smoke:  # smoke runs must not clobber the measured artifact
        with open(ARTIFACT, "w") as f:
            json.dump(art, f, indent=1)
    rows = []
    for r in art["rows"]:
        tag = f"faults/p{r['p_fail']}/{r['policy']}"
        reached = r["rounds_to_target"]
        rows.append({
            "name": tag,
            "us_per_call": float(reached if reached is not None else -1),
            "derived": (f"rounds_to_target={reached} "
                        f"final={r['final_suboptimality']:.2e} "
                        f"retries={r['retries']} "
                        f"clock={r['sim_clock']:.0f}"),
        })
    ratio = art.get("quorum_ratio_at_p02")
    rows.append({
        "name": "faults/quorum_ratio_at_p02",
        "us_per_call": round(ratio, 3) if ratio is not None else -1.0,
        "derived": ("acceptance: <= 2.0x fault-free rounds; control "
                    f"stalls={art.get('wait_all_control_stalls_at_p02')} "
                    f"replay_ok={art.get('deterministic_replay_ok')}"),
    })
    return rows


if __name__ == "__main__":
    for r in run(smoke=os.environ.get("REPRO_BENCH_SMOKE") == "1"):
        print(r)
