"""Comm-step benchmark: dense-mask reference vs flat-workspace fused paths.

Times ONE comm-step aggregation (UpCom + h-update + DownCom, the only
communication of the algorithm) over client-stacked reduced gemma2-2b
leaf shapes (13 leaves, d_total ~1.31M), swept over the population size
``n``, for both uplinks:

  dense    the dense-mask reference: materialized ``(n, D)`` ownership
           mask reduced over all n client rows (what the seed masked_psum
           comm step shipped),
  ws       the sparse fused path (``dist/comm_ws.py``): UpCom as ``s``
           closed-form row-gathers (O(s d) reads, independent of n) + one
           mask-free fused h-update/broadcast pass — the production path
           for unsharded stacked state,
  ws_meshed  the same fused path in meshed mode (psum-shaped UpCom with
           the ownership predicate fused into the partial sum) — the
           aggregation shape ``make_comm_step`` runs when the client axis
           is sharded over devices (see DESIGN.md §9 for the host-mesh
           wall-clock comparison including collectives),
  prior    block_rs only: PR 1's ``block_uplink._leaf_aggregate``
           ((n, n, chunk) pad + advanced-indexing gather) — the
           no-regression baseline for the already-optimized blocked path,
  pallas   the flat-workspace Pallas kernels (``kernels/uplink.py``),
           timed in interpret mode on the smallest config only — a
           correctness smoke, NOT a perf claim (interpret unrolls the
           grid; on TPU the kernels compile via Mosaic and are the
           production path).

All impls are timed as donated jits chaining their own output state — the
production setting (the fused round engine donates the whole carry), and
what lets XLA alias the ``(n, d)`` outputs into the input buffers instead
of allocating fresh ones every round.

Writes ``BENCH_comm_step.json`` (same shape as ``BENCH_round_engine.json``:
flat metrics + config + acceptance) and emits CSV rows via
``benchmarks/run.py``.  Acceptance (ISSUE 3): fused ``ws`` >= 1.5x dense on
the largest swept config and never slower on any config.

Runs in a subprocess so this process keeps the single real CPU device; run
on an idle box (a concurrent pytest run skews CPU timings 2-4x).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
ARTIFACT = os.path.join(REPO, "BENCH_comm_step.json")

_CODE = r"""
import json, time
import numpy as np
import jax, jax.numpy as jnp

from repro.configs import registry
from repro.dist import block_uplink, comm_ws, model_api

NS = (4, 8, 16, 32)
WARM, REPS = 2, 12
S = 2
cfg = registry.get_reduced_config("gemma2-2b")
params = model_api.init(jax.random.key(0), cfg)
dims = [int(np.prod(a.shape)) for a in jax.tree.leaves(params)]
d_total = int(sum(dims))

def stacked(n, seed):
    ks = jax.random.split(jax.random.key(seed), 2)
    x = jax.tree.map(
        lambda a: (jnp.broadcast_to(a[None], (n,) + a.shape)
                   + 0.01 * jax.random.normal(ks[0], (n,) + a.shape,
                                              jnp.float32).astype(a.dtype)),
        params)
    h = jax.tree.map(
        lambda a: 0.01 * jax.random.normal(ks[1], (n,) + a.shape,
                                           jnp.float32), params)
    return jax.device_put(x), jax.device_put(h)

def time_interleaved(fns, n, seed):
    # donated state chains (the production setting: the round engine
    # donates the whole carry, so outputs alias inputs and no fresh
    # (n, d) buffers are allocated per round); min-of-reps per fn, reps
    # interleaved across fns so slow drift (cpu frequency, co-tenants)
    # hits every impl equally.  Feeding each fn its own output back is
    # valid: shapes/dtypes are state-preserving and the comm math is
    # data-independent.
    states = {}
    for k, fn in fns.items():
        st = stacked(n, seed)
        for _ in range(WARM):
            st = fn(*st)
        jax.block_until_ready(st)
        states[k] = st
    ts = {k: [] for k in fns}
    for _ in range(REPS):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            states[k] = fn(*states[k])
            jax.block_until_ready(states[k])
            ts[k].append(time.perf_counter() - t0)
    return {k: float(np.min(v)) * 1e6 for k, v in ts.items()}

rows = []
for n in NS:
    c = max(2, (3 * n) // 4)
    rng = np.random.default_rng(n)
    slot_np = np.full((n,), -1, np.int32)
    cohort = rng.choice(n, size=c, replace=False)
    slot_np[cohort] = rng.permutation(c)
    slot = jnp.asarray(slot_np)
    off = jnp.asarray(int(rng.integers(0, n)), jnp.int32)
    for uplink in ("masked_psum", "block_rs"):
        row = {"n": n, "c": (n if uplink == "block_rs" else c), "s": S,
               "uplink": uplink}
        fns = {}
        for name, impl, meshed in (("dense", "dense", False),
                                   ("ws", "ws", False),
                                   ("ws_meshed", "ws", True)):
            if uplink == "masked_psum":
                fns[name] = jax.jit(
                    lambda x, h, impl=impl, meshed=meshed, c=c:
                        comm_ws.cyclic_comm(x, h, slot, c, S, 0.37,
                                            impl=impl, meshed=meshed),
                    donate_argnums=(0, 1))
            else:
                fns[name] = jax.jit(
                    lambda x, h, impl=impl, meshed=meshed, n=n:
                        comm_ws.blocked_comm(x, h, off, n, S, 0.37,
                                             impl=impl, meshed=meshed),
                    donate_argnums=(0, 1))
        if uplink == "block_rs":
            def prior(x, h, n=n):
                xf, td = jax.tree.flatten(x)
                pairs = [block_uplink._leaf_aggregate(a, b, off, n, S, 0.37)
                         for a, b in zip(xf, jax.tree.leaves(h))]
                return (jax.tree.unflatten(td, [p[0] for p in pairs]),
                        jax.tree.unflatten(td, [p[1] for p in pairs]))
            fns["prior"] = jax.jit(prior, donate_argnums=(0, 1))
        timed = time_interleaved(fns, n, n)
        row["dense_us"], row["ws_us"] = timed["dense"], timed["ws"]
        row["ws_meshed_us"] = timed["ws_meshed"]
        row["speedup_ws_vs_dense"] = row["dense_us"] / row["ws_us"]
        row["speedup_ws_meshed_vs_dense"] = (
            row["dense_us"] / row["ws_meshed_us"]
        )
        msg = (f"# n={n} {uplink}: dense {row['dense_us']/1e3:.1f}ms "
               f"ws {row['ws_us']/1e3:.1f}ms "
               f"({row['speedup_ws_vs_dense']:.2f}x) "
               f"meshed {row['ws_meshed_us']/1e3:.1f}ms "
               f"({row['speedup_ws_meshed_vs_dense']:.2f}x)")
        if "prior" in timed:
            row["prior_us"] = timed["prior"]
            row["speedup_ws_vs_prior"] = row["prior_us"] / row["ws_us"]
            msg += (f" prior {row['prior_us']/1e3:.1f}ms "
                    f"({row['speedup_ws_vs_prior']:.2f}x)")
        rows.append(row)
        print(msg, flush=True)

# Pallas interpret smoke timing at the smallest n (correctness-path cost,
# not a perf claim -- interpret mode unrolls the grid on CPU)
n = NS[0]
c = max(2, (3 * n) // 4)
slot = jnp.asarray(
    np.concatenate([np.random.default_rng(0).permutation(c),
                    -np.ones(n - c, np.int32)]).astype(np.int32))
pallas_us = time_interleaved(
    {"pallas": jax.jit(lambda x, h: comm_ws.cyclic_comm(
        x, h, slot, c, S, 0.37, impl="pallas", block=65536),
        donate_argnums=(0, 1))},
    n, n)["pallas"]

# conservative: the acceptance number is the WORST uplink at the largest n
largest = min(
    (r for r in rows if r["n"] == max(NS)),
    key=lambda r: r["speedup_ws_vs_dense"])
out = {
    "rows": rows,
    "pallas_interpret_us_smallest": pallas_us,
    "largest_config_speedup": largest["speedup_ws_vs_dense"],
    "min_speedup_any_config": min(r["speedup_ws_vs_dense"] for r in rows),
    "acceptance": {"largest_config_min": 1.5, "any_config_min": 1.0},
    "config": {"arch": cfg.name, "d_total": d_total, "leaves": len(dims),
               "s": S, "ns": list(NS), "reps": REPS,
               "dims_min": min(dims), "dims_max": max(dims)},
}
print(json.dumps(out))
"""


def _bench() -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = ""  # single real CPU device
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _CODE],
        capture_output=True, text=True, timeout=1800, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        print(f"# comm_step bench failed:\n{proc.stderr}", file=sys.stderr)
        return {}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(paper_scale: bool = False):
    del paper_scale
    art = _bench()
    if not art:
        return []
    with open(ARTIFACT, "w") as f:
        json.dump(art, f, indent=1)
    cfg = art["config"]
    rows = []
    for r in art["rows"]:
        tag = f"comm_step/n{r['n']}/{r['uplink']}"
        derived = (f"arch={cfg['arch']},d={cfg['d_total']},c={r['c']},"
                   f"s={r['s']}")
        rows.append({"name": f"{tag}/dense", "us_per_call": r["dense_us"],
                     "derived": derived})
        rows.append({"name": f"{tag}/ws", "us_per_call": r["ws_us"],
                     "derived": derived})
        rows.append({
            "name": f"{tag}/speedup_ws_vs_dense",
            "us_per_call": round(r["speedup_ws_vs_dense"], 3),
            "derived": "acceptance: >= 1.5 at largest n, >= 1.0 everywhere",
        })
        rows.append({
            "name": f"{tag}/speedup_ws_meshed_vs_dense",
            "us_per_call": round(r["speedup_ws_meshed_vs_dense"], 3),
            "derived": "psum-shaped mode make_comm_step runs on meshes",
        })
        if "prior_us" in r:
            rows.append({
                "name": f"{tag}/speedup_ws_vs_prior",
                "us_per_call": round(r["speedup_ws_vs_prior"], 3),
                "derived": "vs PR1 _leaf_aggregate (no-regression check)",
            })
    rows.append({
        "name": "comm_step/pallas_interpret_us_smallest",
        "us_per_call": art["pallas_interpret_us_smallest"],
        "derived": "interpret-mode smoke (grid unrolled on CPU); "
                   "Mosaic-compiled on TPU",
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
