"""Comm-step benchmark: dense-mask reference vs flat-workspace fused paths.

Times ONE comm-step aggregation (UpCom + h-update + DownCom, the only
communication of the algorithm) over client-stacked reduced gemma2-2b
leaf shapes (13 leaves, d_total ~1.31M), swept over the population size
``n``, for both uplinks, in two placements:

Single device (the unsharded regime — simulators, benches):

  dense    the dense-mask reference: materialized ``(n, D)`` ownership
           mask reduced over all n client rows (what the seed masked_psum
           comm step shipped),
  ws       the sparse fused path (``dist/comm_ws.py``): UpCom as ``s``
           closed-form row-gathers (O(s d) reads, independent of n) + one
           mask-free fused h-update/broadcast pass — the production path
           for unsharded stacked state,
  ws_meshed  the same fused path in meshed mode (psum-shaped UpCom with
           the ownership predicate fused into the partial sum) — timed on
           unsharded state for the shape comparison only,
  prior    block_rs only: PR 1's ``block_uplink._leaf_aggregate``
           ((n, n, chunk) pad + advanced-indexing gather) — the
           no-regression baseline for the already-optimized blocked path,
  pallas   the flat-workspace Pallas kernels (``kernels/uplink.py``),
           timed in interpret mode on the smallest config only — a
           correctness smoke, NOT a perf claim (interpret unrolls the
           grid; on TPU the kernels compile via Mosaic and are the
           production path).

4x2 host mesh (8 devices, client axis dp-sharded — the trainer's
placement, ISSUE 4):

  dense    the dense reference under GSPMD (sharded mask + d-sized psum),
  ws       meshed-ws under GSPMD: the psum-shaped fused partial — what
           ``make_comm_step`` ran before the shard engine,
  shard    the shard-resident engine (``comm_ws`` meshed ``pallas``):
           shard_map'd sparse owner-row gathers over each shard's LOCAL
           rows + ONE psum of the concatenated d-sized 1/s-folded
           partials (off-TPU the per-shard math is the fused-jnp body;
           on TPU it is the uplink kernels).

All impls are timed as donated jits chaining their own output state — the
production setting (the fused round engine donates the whole carry), and
what lets XLA alias the ``(n, d)`` outputs into the input buffers instead
of allocating fresh ones every round.

Writes ``BENCH_comm_step.json`` (flat metrics + config + acceptance) and
emits CSV rows via ``benchmarks/run.py``.  Acceptance: ISSUE 3 — fused
``ws`` >= 1.5x dense on the largest unsharded config, never slower; ISSUE
4 — ``shard`` >= 1.3x meshed-ws on at least one uplink at n=32 on the
mesh and never slower on any measured row.

Runs in subprocesses so this process keeps the single real CPU device
(the meshed sweep forces 8 host devices); run on an idle box (a
concurrent pytest run skews CPU timings 2-4x).  ``run(smoke=True)`` (or
``REPRO_BENCH_SMOKE=1``) shrinks the sweep to tiny shapes and skips the
artifact write — wired into CI so the bench code cannot rot.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
ARTIFACT = os.path.join(REPO, "BENCH_comm_step.json")

_CODE = r"""
import json, os, time
import numpy as np
import jax, jax.numpy as jnp

from repro.configs import registry
from repro.dist import block_uplink, comm_ws, model_api

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
NS = (2, 4) if SMOKE else (4, 8, 16, 32)
WARM, REPS = (1, 2) if SMOKE else (2, 12)
S = 2
cfg = registry.get_reduced_config("gemma2-2b")
params = model_api.init(jax.random.key(0), cfg)
dims = [int(np.prod(a.shape)) for a in jax.tree.leaves(params)]
d_total = int(sum(dims))

def stacked(n, seed):
    ks = jax.random.split(jax.random.key(seed), 2)
    x = jax.tree.map(
        lambda a: (jnp.broadcast_to(a[None], (n,) + a.shape)
                   + 0.01 * jax.random.normal(ks[0], (n,) + a.shape,
                                              jnp.float32).astype(a.dtype)),
        params)
    h = jax.tree.map(
        lambda a: 0.01 * jax.random.normal(ks[1], (n,) + a.shape,
                                           jnp.float32), params)
    return jax.device_put(x), jax.device_put(h)

def time_interleaved(fns, n, seed):
    # donated state chains (the production setting: the round engine
    # donates the whole carry, so outputs alias inputs and no fresh
    # (n, d) buffers are allocated per round); min-of-reps per fn, reps
    # interleaved across fns so slow drift (cpu frequency, co-tenants)
    # hits every impl equally.  Feeding each fn its own output back is
    # valid: shapes/dtypes are state-preserving and the comm math is
    # data-independent.
    states = {}
    for k, fn in fns.items():
        st = stacked(n, seed)
        for _ in range(WARM):
            st = fn(*st)
        jax.block_until_ready(st)
        states[k] = st
    ts = {k: [] for k in fns}
    for _ in range(REPS):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            states[k] = fn(*states[k])
            jax.block_until_ready(states[k])
            ts[k].append(time.perf_counter() - t0)
    return {k: float(np.min(v)) * 1e6 for k, v in ts.items()}

rows = []
for n in NS:
    c = max(2, (3 * n) // 4)
    rng = np.random.default_rng(n)
    slot_np = np.full((n,), -1, np.int32)
    cohort = rng.choice(n, size=c, replace=False)
    slot_np[cohort] = rng.permutation(c)
    slot = jnp.asarray(slot_np)
    off = jnp.asarray(int(rng.integers(0, n)), jnp.int32)
    for uplink in ("masked_psum", "block_rs"):
        row = {"n": n, "c": (n if uplink == "block_rs" else c), "s": S,
               "uplink": uplink}
        fns = {}
        for name, impl, meshed in (("dense", "dense", False),
                                   ("ws", "ws", False),
                                   ("ws_meshed", "ws", True)):
            if uplink == "masked_psum":
                fns[name] = jax.jit(
                    lambda x, h, impl=impl, meshed=meshed, c=c:
                        comm_ws.cyclic_comm(x, h, slot, c, S, 0.37,
                                            impl=impl, meshed=meshed),
                    donate_argnums=(0, 1))
            else:
                fns[name] = jax.jit(
                    lambda x, h, impl=impl, meshed=meshed, n=n:
                        comm_ws.blocked_comm(x, h, off, n, S, 0.37,
                                             impl=impl, meshed=meshed),
                    donate_argnums=(0, 1))
        if uplink == "block_rs":
            def prior(x, h, n=n):
                xf, td = jax.tree.flatten(x)
                pairs = [block_uplink._leaf_aggregate(a, b, off, n, S, 0.37)
                         for a, b in zip(xf, jax.tree.leaves(h))]
                return (jax.tree.unflatten(td, [p[0] for p in pairs]),
                        jax.tree.unflatten(td, [p[1] for p in pairs]))
            fns["prior"] = jax.jit(prior, donate_argnums=(0, 1))
        timed = time_interleaved(fns, n, n)
        row["dense_us"], row["ws_us"] = timed["dense"], timed["ws"]
        row["ws_meshed_us"] = timed["ws_meshed"]
        row["speedup_ws_vs_dense"] = row["dense_us"] / row["ws_us"]
        row["speedup_ws_meshed_vs_dense"] = (
            row["dense_us"] / row["ws_meshed_us"]
        )
        msg = (f"# n={n} {uplink}: dense {row['dense_us']/1e3:.1f}ms "
               f"ws {row['ws_us']/1e3:.1f}ms "
               f"({row['speedup_ws_vs_dense']:.2f}x) "
               f"meshed {row['ws_meshed_us']/1e3:.1f}ms "
               f"({row['speedup_ws_meshed_vs_dense']:.2f}x)")
        if "prior" in timed:
            row["prior_us"] = timed["prior"]
            row["speedup_ws_vs_prior"] = row["prior_us"] / row["ws_us"]
            msg += (f" prior {row['prior_us']/1e3:.1f}ms "
                    f"({row['speedup_ws_vs_prior']:.2f}x)")
        rows.append(row)
        print(msg, flush=True)

# Pallas interpret smoke timing at the smallest n (correctness-path cost,
# not a perf claim -- interpret mode unrolls the grid on CPU)
n = NS[0]
c = max(2, (3 * n) // 4)
slot = jnp.asarray(
    np.concatenate([np.random.default_rng(0).permutation(c),
                    -np.ones(n - c, np.int32)]).astype(np.int32))
pallas_us = time_interleaved(
    {"pallas": jax.jit(lambda x, h: comm_ws.cyclic_comm(
        x, h, slot, c, S, 0.37, impl="pallas", block=65536),
        donate_argnums=(0, 1))},
    n, n)["pallas"]

# conservative: the acceptance number is the WORST uplink at the largest n
largest = min(
    (r for r in rows if r["n"] == max(NS)),
    key=lambda r: r["speedup_ws_vs_dense"])
out = {
    "rows": rows,
    "pallas_interpret_us_smallest": pallas_us,
    "largest_config_speedup": largest["speedup_ws_vs_dense"],
    "min_speedup_any_config": min(r["speedup_ws_vs_dense"] for r in rows),
    "acceptance": {"largest_config_min": 1.5, "any_config_min": 1.0},
    "config": {"arch": cfg.name, "d_total": d_total, "leaves": len(dims),
               "s": S, "ns": list(NS), "reps": REPS,
               "dims_min": min(dims), "dims_max": max(dims)},
}
print(json.dumps(out))
"""

# The meshed sweep: the trainer's placement (client axis dp-sharded over a
# 4x2 host mesh), comparing GSPMD dense / GSPMD meshed-ws / the
# shard-resident engine.  Separate subprocess: needs 8 host devices.
_MESHED_CODE = r"""
import json, os, time
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.dist import comm_ws, model_api

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DP, MP = (2, 1) if SMOKE else (4, 2)
NS = (2, 4) if SMOKE else (4, 8, 16, 32)
WARM, REPS = (1, 2) if SMOKE else (2, 12)
S = 2
mesh = jax.make_mesh((DP, MP), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = registry.get_reduced_config("gemma2-2b")
params = model_api.init(jax.random.key(0), cfg)
dims = [int(np.prod(a.shape)) for a in jax.tree.leaves(params)]
d_total = int(sum(dims))
row_sh = NamedSharding(mesh, P("data"))

def stacked(n, seed):
    ks = jax.random.split(jax.random.key(seed), 2)
    x = jax.tree.map(
        lambda a: (jnp.broadcast_to(a[None], (n,) + a.shape)
                   + 0.01 * jax.random.normal(ks[0], (n,) + a.shape,
                                              jnp.float32).astype(a.dtype)),
        params)
    h = jax.tree.map(
        lambda a: 0.01 * jax.random.normal(ks[1], (n,) + a.shape,
                                           jnp.float32), params)
    put = lambda t: jax.tree.map(lambda a: jax.device_put(a, row_sh), t)
    return put(x), put(h)

def shardings_of(tree):
    return jax.tree.map(lambda a: row_sh, tree)

def time_interleaved(fns, n, seed):
    # donated chains as in the unsharded sweep; out_shardings pinned to
    # the input placement so the chain never re-specializes on a drifting
    # output sharding (GSPMD may otherwise emit x_new replicated)
    states = {}
    for k, fn in fns.items():
        st = stacked(n, seed)
        for _ in range(WARM):
            st = fn(*st)
        jax.block_until_ready(st)
        states[k] = st
    ts = {k: [] for k in fns}
    for _ in range(REPS):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            states[k] = fn(*states[k])
            jax.block_until_ready(states[k])
            ts[k].append(time.perf_counter() - t0)
    return {k: float(np.min(v)) * 1e6 for k, v in ts.items()}

rows = []
for n in NS:
    c = max(2, (3 * n) // 4)
    rng = np.random.default_rng(n)
    slot_np = np.full((n,), -1, np.int32)
    cohort = rng.choice(n, size=c, replace=False)
    slot_np[cohort] = rng.permutation(c)
    slot = jnp.asarray(slot_np)
    off = jnp.asarray(int(rng.integers(0, n)), jnp.int32)
    xp, hp = stacked(n, 0)
    osh = (shardings_of(xp), shardings_of(hp))
    del xp, hp
    for uplink in ("masked_psum", "block_rs"):
        row = {"n": n, "c": (n if uplink == "block_rs" else c), "s": S,
               "uplink": uplink, "mesh": f"{DP}x{MP}"}
        fns = {}
        for name, impl, kw in (
                ("dense", "dense", {}),
                ("ws", "ws", {}),
                ("shard", "pallas", {"mesh": mesh})):
            if uplink == "masked_psum":
                fns[name] = jax.jit(
                    lambda x, h, impl=impl, kw=kw, c=c:
                        comm_ws.cyclic_comm(x, h, slot, c, S, 0.37,
                                            impl=impl, meshed=True, **kw),
                    donate_argnums=(0, 1), out_shardings=osh)
            else:
                fns[name] = jax.jit(
                    lambda x, h, impl=impl, kw=kw, n=n:
                        comm_ws.blocked_comm(x, h, off, n, S, 0.37,
                                             impl=impl, meshed=True, **kw),
                    donate_argnums=(0, 1), out_shardings=osh)
        timed = time_interleaved(fns, n, n)
        row["dense_us"], row["ws_us"] = timed["dense"], timed["ws"]
        row["shard_us"] = timed["shard"]
        row["speedup_shard_vs_ws"] = row["ws_us"] / row["shard_us"]
        row["speedup_shard_vs_dense"] = row["dense_us"] / row["shard_us"]
        rows.append(row)
        print(f"# mesh {DP}x{MP} n={n} {uplink}: "
              f"dense {row['dense_us']/1e3:.1f}ms "
              f"ws {row['ws_us']/1e3:.1f}ms "
              f"shard {row['shard_us']/1e3:.1f}ms "
              f"({row['speedup_shard_vs_ws']:.2f}x vs ws, "
              f"{row['speedup_shard_vs_dense']:.2f}x vs dense)",
              flush=True)

best_largest = max(
    (r["speedup_shard_vs_ws"] for r in rows if r["n"] == max(NS)),
    default=0.0)
out = {
    "rows": rows,
    "largest_n_best_speedup_vs_ws": best_largest,
    "min_speedup_vs_ws_any_row": min(
        (r["speedup_shard_vs_ws"] for r in rows), default=0.0),
    # any_row_min is 0.95, not 1.0: the cyclic rows are *parity* by
    # construction (the per-shard masked partial is the same math GSPMD
    # runs for ws), and this box's interleaved min-of-12 still swings
    # +-5% run to run (measured: the same row lands 0.94 and 1.03 in
    # consecutive idle-box runs; EXPERIMENTS.md #Perf 8).  The blocked
    # rows carry the structural >= 1.3x claim.
    "acceptance": {"largest_n_best_min": 1.3, "any_row_min": 0.95},
    "config": {"arch": cfg.name, "d_total": d_total, "mesh": f"{DP}x{MP}",
               "s": S, "ns": list(NS), "reps": REPS},
}
print(json.dumps(out))
"""


def _bench(code: str, devices: int = 0, smoke: bool = False) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}" if devices
        else ""  # single real CPU device
    )
    if smoke:
        env["REPRO_BENCH_SMOKE"] = "1"
    else:
        env.pop("REPRO_BENCH_SMOKE", None)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=1800, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        print(f"# comm_step bench failed:\n{proc.stderr}", file=sys.stderr)
        return {}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(paper_scale: bool = False, smoke: bool = False):
    del paper_scale
    art = _bench(_CODE, smoke=smoke)
    if not art:
        return []
    meshed = _bench(_MESHED_CODE, devices=2 if smoke else 8, smoke=smoke)
    if meshed:
        art["meshed"] = meshed
    if not smoke:  # smoke runs must not clobber the measured artifact
        with open(ARTIFACT, "w") as f:
            json.dump(art, f, indent=1)
    cfg = art["config"]
    rows = []
    for r in art["rows"]:
        tag = f"comm_step/n{r['n']}/{r['uplink']}"
        derived = (f"arch={cfg['arch']},d={cfg['d_total']},c={r['c']},"
                   f"s={r['s']}")
        rows.append({"name": f"{tag}/dense", "us_per_call": r["dense_us"],
                     "derived": derived})
        rows.append({"name": f"{tag}/ws", "us_per_call": r["ws_us"],
                     "derived": derived})
        rows.append({
            "name": f"{tag}/speedup_ws_vs_dense",
            "us_per_call": round(r["speedup_ws_vs_dense"], 3),
            "derived": "acceptance: >= 1.5 at largest n, >= 1.0 everywhere",
        })
        rows.append({
            "name": f"{tag}/speedup_ws_meshed_vs_dense",
            "us_per_call": round(r["speedup_ws_meshed_vs_dense"], 3),
            "derived": "psum-shaped mode, unsharded-state timing",
        })
        if "prior_us" in r:
            rows.append({
                "name": f"{tag}/speedup_ws_vs_prior",
                "us_per_call": round(r["speedup_ws_vs_prior"], 3),
                "derived": "vs PR1 _leaf_aggregate (no-regression check)",
            })
    for r in meshed.get("rows", []):
        tag = f"comm_step_meshed/n{r['n']}/{r['uplink']}"
        derived = f"mesh={r['mesh']},c={r['c']},s={r['s']}"
        for k in ("dense", "ws", "shard"):
            rows.append({"name": f"{tag}/{k}", "us_per_call": r[f"{k}_us"],
                         "derived": derived})
        rows.append({
            "name": f"{tag}/speedup_shard_vs_ws",
            "us_per_call": round(r["speedup_shard_vs_ws"], 3),
            "derived": "shard engine vs meshed-ws (>= 1.3 on one uplink "
                       "at largest n; cyclic rows are parity within the "
                       "box's +-5% noise floor, acceptance >= 0.95)",
        })
    rows.append({
        "name": "comm_step/pallas_interpret_us_smallest",
        "us_per_call": art["pallas_interpret_us_smallest"],
        "derived": "interpret-mode smoke (grid unrolled on CPU); "
                   "Mosaic-compiled on TPU",
    })
    return rows


if __name__ == "__main__":
    for r in run(smoke=os.environ.get("REPRO_BENCH_SMOKE") == "1"):
        print(r)
