"""Round-engine benchmark: rounds must cost O(cohort), not O(population).

Two measurements, written to ``BENCH_dist_round.json`` at the repo root and
emitted as CSV rows via ``benchmarks/run.py``:

  ref_round    reference-core ``round_step`` wall time vs population size n
               at fixed cohort c, for the cohort-only gradient path
               (``FiniteSumProblem.grad_cohort``) against the seed's
               full-population scatter path (``grad_cohort=None`` fallback).
               The cohort path must stay ~flat in n (acceptance: n=512
               within 2x of n=16); the seed path grows ~linearly.

  dist_uplink  TAMUNA-DP comm-step wall time for the masked-psum uplink vs
               the blocked reduce-scatter-shaped uplink, on a forced
               8-device host mesh (spawned in a subprocess so this process
               keeps the single real CPU device, like the test suite does).
               Each uplink also gets a ``+fused_round_L4`` row timing one
               whole engine round (4 scanned local steps with on-device
               data + the comm step, donated; ``us_per_round``, not
               comparable to the comm-only rows).
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
ARTIFACT = os.path.join(REPO, "BENCH_dist_round.json")

REF_NS = (16, 64, 128, 512)
REF_C, REF_S, REF_D = 8, 4, 4096
ITERS = 60


def _bench_ref_round(n: int, cohort_path: bool) -> float:
    """us per round_step call, steady state, donated state buffers."""
    import jax

    from repro.core import problems, tamuna

    prob = problems.make_quadratic_problem(n=n, d=REF_D, kappa=100)
    if not cohort_path:
        # the seed path: scatter cohort models into (n, d), grad everything
        prob = dataclasses.replace(prob, grad_cohort=None)
    cfg = tamuna.TamunaConfig(
        gamma=2.0 / (prob.L + prob.mu), eta=0.1, p=0.2, c=REF_C, s=REF_S,
        geometric_L=False,  # fixed L = 5 local steps: deterministic work
    )
    step = jax.jit(
        lambda st, k: tamuna.round_step(prob, cfg, st, k),
        donate_argnums=(0,),
    )
    state = tamuna.init(prob)
    keys = jax.random.split(jax.random.key(0), ITERS + 10)
    for i in range(10):  # compile + warm caches
        state = step(state, keys[i])
    jax.block_until_ready(state.x_bar)
    t0 = time.perf_counter()
    for i in range(10, 10 + ITERS):
        state = step(state, keys[i])
    jax.block_until_ready(state.x_bar)
    return (time.perf_counter() - t0) / ITERS * 1e6


_DIST_CODE = r"""
import json, sys, time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import rounds, sharding, tamuna_dp

mesh = jax.make_mesh((8, 1), ("data", "model"))
cfg = ModelConfig(family="dense", n_layers=2, d_model=128, n_heads=4,
                  n_kv_heads=2, d_ff=256, vocab=256, dtype=jnp.float32,
                  remat=False)
n = sharding.n_clients(mesh)
dcfg = DataConfig(seq_len=32, per_client_batch=2, vocab=cfg.vocab, seed=0)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
rows = []
for uplink in ("masked_psum", "block_rs"):
    tcfg = tamuna_dp.DistTamunaConfig(
        gamma=0.02, c=n, s=2, p=0.25, uplink=uplink)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      tamuna_dp.state_pspecs(state, cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, sh)
    comm = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh))
    keys = [jax.random.key(i) for i in range(40)]
    for k in keys[:5]:
        state = comm(state, k)
    jax.block_until_ready(state.round)
    t0 = time.perf_counter()
    for k in keys[5:]:
        state = comm(state, k)
    jax.block_until_ready(state.round)
    us = (time.perf_counter() - t0) / 35 * 1e6
    d = sum(int(jnp.size(a)) // n for a in jax.tree.leaves(state.x))
    rows.append({"uplink": uplink, "us_per_comm": us, "n": n,
                 "s": tcfg.s, "d_per_client": d})
    # the same comm step inside the fused round engine program (L=4
    # scanned local steps with on-device data + comm, donated)
    fused = jax.jit(rounds.make_fused_round(
        cfg, tcfg, mesh, sample_batch=device_sampler(dcfg, cfg, mesh),
        L=4), donate_argnums=(0,))
    data = pipe.device_data()
    state = jax.device_put(
        tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg), sh)
    for i in range(3):
        state, _ = fused(state, jax.random.key_data(jax.random.key(i)),
                         data)
    jax.block_until_ready(state.round)
    t0 = time.perf_counter()
    for i in range(3, 13):
        state, _ = fused(state, jax.random.key_data(jax.random.key(i)),
                         data)
    jax.block_until_ready(state.round)
    rows.append({"uplink": uplink + "+fused_round_L4",
                 "us_per_round": (time.perf_counter() - t0) / 10 * 1e6,
                 "n": n, "s": tcfg.s, "d_per_client": d})
print(json.dumps(rows))
"""


def _bench_dist_uplink():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _DIST_CODE],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        print(f"# dist_uplink bench failed:\n{proc.stderr}", file=sys.stderr)
        return []
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(paper_scale: bool = False):
    del paper_scale
    rows = []
    ref = {"cohort": {}, "full_population": {}}
    for n in REF_NS:
        for mode, cohort in (("cohort", True), ("full_population", False)):
            us = _bench_ref_round(n, cohort)
            ref[mode][n] = us
            rows.append({
                "name": f"dist_round/ref_round/{mode}/n{n}",
                "us_per_call": us,
                "derived": f"c={REF_C},s={REF_S},d={REF_D},L=5",
            })
    ratio_cohort = ref["cohort"][512] / ref["cohort"][16]
    ratio_full = ref["full_population"][512] / ref["full_population"][16]
    rows.append({
        "name": "dist_round/ref_round/n512_over_n16(cohort)",
        "us_per_call": round(ratio_cohort, 3),
        "derived": "acceptance: <= 2.0 (round cost is O(c), not O(n))",
    })
    rows.append({
        "name": "dist_round/ref_round/n512_over_n16(full_population)",
        "us_per_call": round(ratio_full, 3),
        "derived": "seed path: grows ~linearly in n",
    })

    uplink = _bench_dist_uplink()
    for r in uplink:
        # comm-only rows time one comm step; fused rows time a whole
        # engine round (4 local fwd+bwd steps + comm) — different units,
        # keyed apart so the artifact is not read as a comm regression
        us = r.get("us_per_comm", r.get("us_per_round"))
        what = "round(L=4 local + comm)" if "us_per_round" in r else "comm"
        rows.append({
            "name": f"dist_round/dist_uplink/{r['uplink']}",
            "us_per_call": us,
            "derived": (f"{what},n={r['n']},s={r['s']},"
                        f"d_per_client={r['d_per_client']}"),
        })

    artifact = {
        "config": {"c": REF_C, "s": REF_S, "d": REF_D, "local_steps": 5,
                   "iters": ITERS, "populations": list(REF_NS)},
        "ref_round_us": ref,
        "ratio_n512_over_n16": {"cohort": ratio_cohort,
                                "full_population": ratio_full},
        "dist_uplink": uplink,
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
