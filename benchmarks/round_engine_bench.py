"""Round-engine benchmark: the fused scanned round vs the seed per-step
driver, at reduced gemma2-2b on the 8-device host mesh.

Three per-round wall-time measurements at fixed L = 4 local steps, written
to ``BENCH_round_engine.json`` at the repo root and emitted as CSV rows via
``benchmarks/run.py``:

  per_step           the seed driver: one un-donated jit dispatch per local
                     step, host-side Markov sampling between steps, comm
                     step dispatched separately.
  fused_host_data    the engine's scanned round (donated state, comm step in
                     the same program) fed a host-sampled stacked batch once
                     per round — isolates the scan + donation win.
  fused_device_data  the full engine (`rounds.make_round_fn`): data sampled
                     on device inside the scan from carried PRNG keys; zero
                     steady-state host->device transfers.

Also records the compile-cache footprint across 30 geometric rounds
(acceptance: <= log2(max_L) + 1 distinct programs).

Runs in a subprocess so this process keeps the single real CPU device.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
ARTIFACT = os.path.join(REPO, "BENCH_round_engine.json")

_CODE = r"""
import json, math, sys, time
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.data import DataConfig, SyntheticTokenPipeline, device_sampler
from repro.dist import rounds, sharding, tamuna_dp
from repro.launch.mesh import make_host_mesh

L, ROUNDS, WARM, MAX_L = 4, 10, 3, 16
mesh = make_host_mesh(4, 2)
cfg = registry.get_reduced_config("gemma2-2b")
n = sharding.n_clients(mesh)
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=3, s=2, p=0.34)
dcfg = DataConfig(seq_len=64, per_client_batch=2, vocab=min(cfg.vocab, 512),
                  seed=0)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)

def fresh_state():
    st = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      tamuna_dp.state_pspecs(st, cfg, mesh),
                      is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(st, sh)

out = {}

# --- per_step: the seed driver (un-donated jits, host sampling per step)
state = fresh_state()
local = jax.jit(tamuna_dp.make_local_step(cfg, tcfg))
comm = jax.jit(tamuna_dp.make_comm_step(cfg, tcfg, mesh))

def per_step_round(state, r):
    for _ in range(L):
        state, m = local(state, **pipe.next_batch())
    return comm(state, jax.random.key_data(jax.random.key(r)))

for r in range(WARM):
    state = per_step_round(state, r)
jax.block_until_ready(state.round)
t0 = time.perf_counter()
for r in range(WARM, WARM + ROUNDS):
    state = per_step_round(state, r)
jax.block_until_ready(state.round)
out["per_step"] = (time.perf_counter() - t0) / ROUNDS * 1e6

# --- fused_host_data: scanned donated round fed stacked host batches
def make_fused_host(cfg, tcfg, mesh):
    local_raw = tamuna_dp.make_local_step(cfg, tcfg)
    comm_raw = tamuna_dp.make_comm_step(cfg, tcfg, mesh)
    def fn(state, batches, key_data):
        def body(st, batch):
            st, m = local_raw(st, **batch)
            return st, m["loss"]
        state, losses = jax.lax.scan(body, state, batches)
        return comm_raw(state, key_data), losses.mean()
    return jax.jit(fn, donate_argnums=(0,))

fused_host = make_fused_host(cfg, tcfg, mesh)

def stack_batches():
    bs = [pipe.next_batch() for _ in range(L)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)

state = fresh_state()
for r in range(WARM):
    state, _ = fused_host(state, stack_batches(),
                          jax.random.key_data(jax.random.key(r)))
jax.block_until_ready(state.round)
t0 = time.perf_counter()
for r in range(WARM, WARM + ROUNDS):
    state, _ = fused_host(state, stack_batches(),
                          jax.random.key_data(jax.random.key(r)))
jax.block_until_ready(state.round)
out["fused_host_data"] = (time.perf_counter() - t0) / ROUNDS * 1e6

# --- fused_device_data: the full engine, on-device sampling from the carry
round_fn = rounds.make_round_fn(
    cfg, tcfg, mesh, sample_batch=device_sampler(dcfg, cfg, mesh),
    max_L=MAX_L)
data = pipe.device_data()
carry = rounds.init_carry(fresh_state(), jax.random.key(1), flush_every=8)
for r in range(WARM):
    carry = round_fn(carry, data, L, r % 8)
jax.block_until_ready(carry.state.round)
t0 = time.perf_counter()
for r in range(WARM, WARM + ROUNDS):
    carry = round_fn(carry, data, L, r % 8)
jax.block_until_ready(carry.state.round)
out["fused_device_data"] = (time.perf_counter() - t0) / ROUNDS * 1e6

# --- compile-cache bound across geometric round lengths
rng = np.random.default_rng(0)
for r in range(30):
    Lr = tamuna_dp.sample_round_length(rng, tcfg.p, max_L=MAX_L)
    carry = round_fn(carry, data, Lr, 0)
jax.block_until_ready(carry.state.round)
out["distinct_compilations"] = len(round_fn.cache)
out["compile_cache_bound"] = int(math.log2(MAX_L)) + 1
out["config"] = {"arch": cfg.name, "n": n, "L": L, "rounds": ROUNDS,
                 "max_L": MAX_L, "c": tcfg.c, "s": tcfg.s,
                 "seq_len": dcfg.seq_len,
                 "per_client_batch": dcfg.per_client_batch}
out["speedup_fused_vs_per_step"] = out["per_step"] / out["fused_device_data"]
print(json.dumps(out))
"""


def _bench() -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _CODE],
        capture_output=True, text=True, timeout=1800, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        print(f"# round_engine bench failed:\n{proc.stderr}",
              file=sys.stderr)
        return {}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(paper_scale: bool = False):
    del paper_scale
    art = _bench()
    if not art:
        return []
    with open(ARTIFACT, "w") as f:
        json.dump(art, f, indent=1)
    cfg = art["config"]
    derived = (f"arch={cfg['arch']},n={cfg['n']},L={cfg['L']},"
               f"seq={cfg['seq_len']}")
    rows = [
        {"name": f"round_engine/{k}", "us_per_call": art[k],
         "derived": derived}
        for k in ("per_step", "fused_host_data", "fused_device_data")
    ]
    rows.append({
        "name": "round_engine/speedup_fused_vs_per_step",
        "us_per_call": round(art["speedup_fused_vs_per_step"], 3),
        "derived": "acceptance: >= 2.0",
    })
    rows.append({
        "name": "round_engine/distinct_compilations",
        "us_per_call": art["distinct_compilations"],
        "derived": (f"30 geometric rounds, max_L={cfg['max_L']}; "
                    f"acceptance: <= {art['compile_cache_bound']}"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
