"""Roofline table assembly: reads the dry-run artifacts and emits the
per-(arch x shape x mesh) three-term analysis of EXPERIMENTS.md §Roofline.

For train shapes the amortized round is  E[L] * local + comm  with
E[L] = 1/p (Remark 2); the dominant term is reported for the amortized
round as well as for each step separately.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ART = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "artifacts", "dryrun"
)
EXPECTED_L = 4.0  # 1/p with the dry-run default p = 0.25


def load(mesh: str = "pod16x16", art_dir: str = ART) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, mesh, "*", "*", "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def amortize(local: dict, comm: dict, L: float = EXPECTED_L) -> dict:
    r = {}
    for term in ("compute_s", "memory_s", "collective_s"):
        r[term] = L * local["roofline"][term] + comm["roofline"][term]
    r["dominant"] = max(
        ("compute", r["compute_s"]), ("memory", r["memory_s"]),
        ("collective", r["collective_s"]), key=lambda kv: kv[1],
    )[0]
    mf = local["roofline"]["model_flops_per_chip"]
    hlo = local["cost_analysis"]["flops"]
    r["useful_flops_ratio"] = mf / hlo if hlo else None
    return r


def table(mesh: str = "pod16x16", art_dir: str = ART) -> List[dict]:
    rows = load(mesh, art_dir)
    by_pair: Dict[tuple, Dict[str, dict]] = {}
    for r in rows:
        by_pair.setdefault((r["arch"], r["shape"]), {})[r["step"]] = r

    out = []
    for (arch, shape), steps in sorted(by_pair.items()):
        if "round" in steps:
            # the fused scanned round the production trainer dispatches
            r = steps["round"]
            rl = r["roofline"]
            out.append({
                "arch": arch, "shape": shape, "mesh": mesh,
                "step": "round(fused)",
                "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
                "collective_s": rl["collective_s"],
                "dominant": rl["dominant"],
                "useful_flops_ratio": rl["useful_flops_ratio"],
            })
        if "local" in steps and "comm" in steps:
            am = amortize(steps["local"], steps["comm"])
            rec = {
                "arch": arch, "shape": shape, "mesh": mesh,
                "step": "round(amortized)",
                **{k: am[k] for k in
                   ("compute_s", "memory_s", "collective_s", "dominant",
                    "useful_flops_ratio")},
                "local_dominant": steps["local"]["roofline"]["dominant"],
                "comm_dominant": steps["comm"]["roofline"]["dominant"],
            }
            out.append(rec)
        else:
            for step, r in steps.items():
                rl = r["roofline"]
                out.append({
                    "arch": arch, "shape": shape, "mesh": mesh, "step": step,
                    "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
                    "collective_s": rl["collective_s"],
                    "dominant": rl["dominant"],
                    "useful_flops_ratio": rl["useful_flops_ratio"],
                })
    return out


def pick_hillclimb_candidates(rows: List[dict]) -> List[dict]:
    """worst useful-flops ratio, most collective-bound, most paper-central."""
    cands = []
    with_ratio = [r for r in rows if r.get("useful_flops_ratio")]
    if with_ratio:
        cands.append({
            "why": "worst useful-flops ratio",
            **min(with_ratio, key=lambda r: r["useful_flops_ratio"]),
        })
    coll = [
        r for r in rows
        if r["collective_s"] > 0 and r["dominant"] == "collective"
    ] or rows
    cands.append({
        "why": "most collective-bound",
        **max(coll, key=lambda r: r["collective_s"] /
              max(r["compute_s"] + r["memory_s"], 1e-30)),
    })
    train = [r for r in rows if r["shape"] == "train_4k"]
    if train:
        cands.append({
            "why": "paper-central (TAMUNA train round, largest model)",
            **max(train, key=lambda r: r["compute_s"]),
        })
    return cands


def run():
    rows = table("pod16x16")
    out = []
    for r in rows:
        out.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{r['step']}",
            "us_per_call": r["compute_s"] * 1e6,  # compute term, us
            "derived": (
                f"mem_us={r['memory_s']*1e6:.1f} "
                f"coll_us={r['collective_s']*1e6:.1f} "
                f"dominant={r['dominant']}"
            ),
        })
    return out


if __name__ == "__main__":
    import pprint

    rows = table("pod16x16")
    pprint.pprint(rows)
    print("\nhillclimb candidates:")
    pprint.pprint(pick_hillclimb_candidates(rows))
