"""Kernel microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode, so wall
times measure the REFERENCE path + interpreter overhead, not TPU speed; the
structural win (HBM reads/writes per element) is reported as `derived`.
On a TPU backend the same harness times the Mosaic-compiled kernels.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run():
    rows = []
    d = 1 << 20
    x = jax.random.normal(jax.random.key(0), (d,))
    g = jax.random.normal(jax.random.key(1), (d,))
    h = jax.random.normal(jax.random.key(2), (d,))
    slot = jnp.asarray([3], jnp.int32)

    # reference paths are jit'd so ref-vs-kernel compares compiled XLA
    # against the (interpreted, on CPU) kernel — not eager dispatch overhead
    compress_ref_j = jax.jit(lambda x, s: ref.compress_ref(x, s, 16, 4))
    us = _time(lambda: compress_ref_j(x, slot[0]))
    rows.append({"name": "compress_ref_1M(jit)", "us_per_call": us,
                 "derived": "reads=1,writes=1 per elem (oracle)"})
    us = _time(lambda: ops.compress(x, slot, 16, 4))
    rows.append({"name": "compress_kernel_1M(interpret)", "us_per_call": us,
                 "derived": "fused mask-gen: no mask tensor in HBM"})

    local_ref_j = jax.jit(
        lambda x, g, h: ref.fused_local_step_ref(x, g, h, 0.01)
    )
    us = _time(lambda: local_ref_j(x, g, h))
    rows.append({"name": "local_step_ref_1M(jit)", "us_per_call": us,
                 "derived": "unfused: up to 5 reads + 2 writes"})
    us = _time(lambda: ops.fused_local_step(x, g, h, 0.01))
    rows.append({"name": "local_step_kernel_1M(interpret)",
                 "us_per_call": us,
                 "derived": "fused: 3 reads + 1 write (HBM floor)"})

    b, hq, kvh, hd, S = 2, 8, 2, 128, 8192
    q = jax.random.normal(jax.random.key(3), (b, hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(4), (b, S, kvh, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(5), (b, S, kvh, hd), jnp.float32)
    pos = jnp.asarray(S - 1, jnp.int32)
    decode_ref_j = jax.jit(ref.decode_attention_ref)
    us = _time(lambda: decode_ref_j(q, k, v, pos))
    rows.append({"name": "decode_attn_ref_8k(jit)", "us_per_call": us,
                 "derived": "materializes (b,kvh,g,S) logits"})
    us = _time(lambda: ops.decode_attention(q, k, v, pos, block_s=1024))
    rows.append({"name": "decode_attn_kernel_8k(interpret)",
                 "us_per_call": us,
                 "derived": "online softmax: O(block_s*hd) VMEM"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
