"""Paper Table 1: UpCom complexity (alpha = 0) of linearly converging
algorithms with LT/CC + partial participation.

Two columns per algorithm:
  * theoretical complexity (the table's formula, log factor dropped),
  * measured uplink floats per client to reach target accuracy on the
    shared logistic-regression problem with c = n/4 participation.
"""

from __future__ import annotations

import math

from benchmarks.common import floats_to_accuracy
from repro.core import baselines, problems, tamuna, theory


def run(seed: int = 0):
    n, d, kappa = 64, 300, 1e3
    prob = problems.make_logreg_problem(
        n=n, d=d, samples_per_client=8, kappa=kappa, seed=seed
    )
    c = n // 4
    k = prob.kappa
    gamma = 2.0 / (prob.L + prob.mu)
    s = theory.recommended_s(c, d, 0.0)
    p = theory.recommended_p(n, s, k)

    theo = {
        "diana-pp": (1 + d / c) * k + d * n / c,
        "scaffold": d * k + d * n / c,
        "5gcs": d * math.sqrt(k) * math.sqrt(n / c) + d * n / c,
        "tamuna": (
            math.sqrt(d * k * n / c)
            + d * math.sqrt(k) * math.sqrt(n) / c
            + d * n / c
        ),
    }

    target = float(prob.suboptimality(prob.x_star * 0.0)) * 1e-6
    cfgT = tamuna.TamunaConfig.tuned(prob, c=c)
    traces = {
        "tamuna": tamuna.run(prob, cfgT, num_rounds=2500, seed=seed,
                             record_every=10),
        "scaffold": baselines.run_scaffold(
            prob, 0.5 * gamma, local_steps=max(1, int(1 / cfgT.p)), c=c,
            num_rounds=2500, seed=seed, record_every=10,
        ),
        "5gcs": baselines.run_5gcs(
            prob, 1.0 / math.sqrt(prob.mu * prob.L), c=c, inner_steps=300,
            num_rounds=500, seed=seed, record_every=10,
        ),
        "diana-pp": baselines.run_diana(
            prob, 0.5 / prob.L, k=8, num_rounds=10000, seed=seed,
            record_every=50,
        ),
    }
    rows = []
    for name in theo:
        tr = traces.get(name)
        rows.append({
            "table": "table1", "algo": name,
            "upcom_theory": theo[name],
            "upcom_measured": (
                floats_to_accuracy(tr, target, alpha=0.0) if tr else None
            ),
            "final_subopt": float(tr["suboptimality"][-1]) if tr else None,
        })
    # headline: TAMUNA's theoretical UpCom is the best of the table
    assert theo["tamuna"] == min(theo.values())
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
