"""Shared helpers for the paper-experiment benchmarks.

Communication accounting follows paper Section 1.2: UpCom/DownCom are floats
per participating client per round; TotalCom = UpCom + alpha * DownCom.
"""

from __future__ import annotations

import numpy as np


def totalcom(trace: dict, alpha: float) -> np.ndarray:
    return trace["up_floats"] + alpha * trace["down_floats"]


def floats_to_accuracy(trace: dict, target: float, alpha: float):
    """First TotalCom value at which suboptimality <= target (None if never)."""
    sub = trace["suboptimality"]
    idx = np.argmax(sub <= target)
    if sub[idx] > target:
        return None
    return float(totalcom(trace, alpha)[idx])


def summarize(traces: dict, target: float, alpha: float) -> dict:
    out = {}
    for name, tr in traces.items():
        out[name] = floats_to_accuracy(tr, target, alpha)
    return out
