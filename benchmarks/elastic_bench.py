"""Elastic-round benchmark: cohort-gathered local compute vs all-rows.

Times ONE fused round (L=4 scanned local steps + comm, donated) of the
dist engine at n=16 stacked clients on a single device (the n-override
placement: the client axis is state rows, not mesh shards, so total
gradient work is what the wall clock sees), sweeping the cohort size
c in {n, n/2, n/4} for both uplinks:

  allrows  the pre-elastic engine (PR 4 behaviour): every round runs the
           L local steps on ALL n client rows regardless of c
           (``make_fused_round(..., elastic=False)``),
  gather   the elastic engine (DESIGN.md §11): gather the round's c
           cohort rows, run the L steps on the compact (c, ...) state
           with cohort-only batches, scatter back, comm — O(c·L) local
           compute, idle clients do nothing.

This is real compute reduction (fewer gradient FLOPs), not driver
overhead, so it benches on this 2-core box; the c = n row times the pure
gather/scatter overhead of the elastic path (expected ~1x: two extra
O(n·d) copies against L full fwd+bwd passes).

All variants are donated jits chaining their own output state,
interleaved min-of-reps (the box has multi-minute throughput phases).
Writes ``BENCH_elastic.json``; acceptance: gather >= 1.8x allrows at
n=16, c=n/4 on the WORST uplink, and never slower at any c < n.
``run(smoke=True)`` (or ``REPRO_BENCH_SMOKE=1``) shrinks to tiny shapes
and skips the artifact write — wired into tests/test_bench_tooling.py.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
ARTIFACT = os.path.join(REPO, "BENCH_elastic.json")

_CODE = r"""
import json, os, time
import numpy as np
import jax, jax.numpy as jnp

from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import rounds, tamuna_dp

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
N = 4 if SMOKE else 16
CS = (4, 2) if SMOKE else (16, 8, 4)
WARM, REPS = (1, 2) if SMOKE else (2, 10)
L, S = (2, 2) if SMOKE else (4, 2)

mesh = jax.make_mesh((1, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=64 if SMOKE else 128,
                  n_heads=4, n_kv_heads=2, d_ff=128 if SMOKE else 256,
                  vocab=256, dtype=jnp.float32, remat=False)
dcfg = DataConfig(seq_len=16 if SMOKE else 32, per_client_batch=2,
                  vocab=256, seed=0, n_clients=N)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
data = pipe.device_data()
sampler = device_sampler(dcfg, cfg, mesh)


def time_interleaved(fns, tcfg):
    states, ts = {}, {k: [] for k in fns}
    for k, fn in fns.items():
        st = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg, n=N)
        for w in range(WARM):
            st, _ = fn(st, jax.random.key_data(jax.random.key(w)), data)
        jax.block_until_ready(st.round)
        states[k] = st
    for r in range(REPS):
        kd = jax.random.key_data(jax.random.key(100 + r))
        for k, fn in fns.items():
            t0 = time.perf_counter()
            states[k] = fn(states[k], kd, data)[0]
            jax.block_until_ready(states[k].round)
            ts[k].append(time.perf_counter() - t0)
    return {k: float(np.min(v)) * 1e6 for k, v in ts.items()}


rows = []
for uplink in ("masked_psum", "block_rs"):
    for c in CS:
        tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=min(S, c),
                                          p=1.0 / L, uplink=uplink)
        fns = {}
        for name, elastic in (("allrows", False), ("gather", True)):
            fns[name] = jax.jit(
                rounds.make_fused_round(cfg, tcfg, mesh,
                                        sample_batch=sampler, L=L, n=N,
                                        elastic=elastic),
                donate_argnums=(0,))
        timed = time_interleaved(fns, tcfg)
        row = {"n": N, "c": c, "s": tcfg.s, "L": L, "uplink": uplink,
               "allrows_us": timed["allrows"],
               "gather_us": timed["gather"],
               "speedup_gather_vs_allrows":
                   timed["allrows"] / timed["gather"]}
        rows.append(row)
        print(f"# n={N} c={c} {uplink}: allrows "
              f"{row['allrows_us']/1e3:.1f}ms gather "
              f"{row['gather_us']/1e3:.1f}ms "
              f"({row['speedup_gather_vs_allrows']:.2f}x)", flush=True)

smallest_c = min(CS)
accept = min(r["speedup_gather_vs_allrows"] for r in rows
             if r["c"] == smallest_c)
min_sub = min((r["speedup_gather_vs_allrows"] for r in rows
               if r["c"] < N), default=0.0)
out = {
    "rows": rows,
    "speedup_at_quarter_cohort": accept,
    "min_speedup_any_partial_row": min_sub,
    # the c == n gather rows time pure gather/scatter overhead; recorded,
    # not gated (expected ~1x)
    "full_cohort_gather_ratio": [
        r["speedup_gather_vs_allrows"] for r in rows if r["c"] == N
    ],
    "acceptance": {"quarter_cohort_min": 1.8, "any_partial_row_min": 1.0},
    "config": {"n": N, "cs": list(CS), "L": L, "s": S, "arch": "dense",
               "d_model": cfg.d_model, "n_layers": cfg.n_layers,
               "seq_len": dcfg.seq_len,
               "per_client_batch": dcfg.per_client_batch, "reps": REPS},
}
print(json.dumps(out))
"""


def _bench(smoke: bool) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = ""  # single real CPU device
    if smoke:
        env["REPRO_BENCH_SMOKE"] = "1"
    else:
        env.pop("REPRO_BENCH_SMOKE", None)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _CODE],
        capture_output=True, text=True, timeout=1800, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        print(f"# elastic bench failed:\n{proc.stderr}", file=sys.stderr)
        return {}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(paper_scale: bool = False, smoke: bool = False):
    del paper_scale
    art = _bench(smoke=smoke)
    if not art:
        return []
    if not smoke:  # smoke runs must not clobber the measured artifact
        with open(ARTIFACT, "w") as f:
            json.dump(art, f, indent=1)
    rows = []
    for r in art["rows"]:
        tag = f"elastic/n{r['n']}/c{r['c']}/{r['uplink']}"
        derived = f"L={r['L']},s={r['s']}"
        rows.append({"name": f"{tag}/allrows",
                     "us_per_call": r["allrows_us"], "derived": derived})
        rows.append({"name": f"{tag}/gather",
                     "us_per_call": r["gather_us"], "derived": derived})
        rows.append({
            "name": f"{tag}/speedup_gather_vs_allrows",
            "us_per_call": round(r["speedup_gather_vs_allrows"], 3),
            "derived": ("acceptance: >= 1.8 at c=n/4, >= 1.0 at any c < n;"
                        " c == n rows record gather/scatter overhead"),
        })
    return rows


if __name__ == "__main__":
    for r in run(smoke=os.environ.get("REPRO_BENCH_SMOKE") == "1"):
        print(r)
