"""Pipelined-rounds benchmark: simulated straggler wall-clock vs staleness.

Drives the split-phase round engine (``rounds.make_pipelined_round_fn`` +
``run_rounds_pipelined``, DESIGN.md §14) on the tiny dense model at n=8
stacked clients, c=2, with the simulated clock priced by the MEASURED
straggler-tail distribution exported by ``examples/availability_sim.py
--dist --dist-out`` (per-step latency draws of its lognormal + 10x
straggler mixture, bootstrapped per round through
``faults.EmpiricalDelays``) — not a parametric stand-in.  The sweep:

  sync      τ=0, wait_all — the bulk-synchronous baseline: every round
            pays its slowest cohort member (identical op sequence to
            ``run_rounds``, equivalence-tested in tests/test_pipeline.py).
            Run at three seeds to measure the convergence noise band.
  τ=1,2 wait_all   bounded staleness, no admission cut: every uplink is
            still aggregated, but a round's commit barrier is deferred τ
            rounds, so consecutive rounds' straggler waits overlap — the
            wall-clock win with a bit-identical per-round aggregation
            (only the ORDER local compute sees x_bar changes).
  τ=1,2 quorum=1   additionally cut at the first arrival: late uplinks
            are dropped (their coordinates untouched) — the aggressive
            end of the staleness/quality trade.

Headline: ``speedup_at_tail`` = sync clock / best wait_all τ>=1 clock
among the τ whose final loss stays inside the sync seed band (widened by
one band-width) — the deepest staleness that costs no convergence.
Acceptance: >= 1.5x.  Also records per-scenario admitted /
late-dropped / uncovered-coordinate totals — the quality signals the
staleness sweep in EXPERIMENTS.md §Perf 10 discusses.

Writes ``BENCH_pipeline.json``.  ``run(smoke=True)`` (or
``REPRO_BENCH_SMOKE=1``) shrinks rounds/taus, writes the latency
distribution to a temp path, and skips all artifact writes.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
ARTIFACT = os.path.join(REPO, "BENCH_pipeline.json")
LATENCY_DIST = os.path.join(HERE, "artifacts", "latency_dist.json")

_CODE = r"""
import json, os
import numpy as np
import jax, jax.numpy as jnp

from repro.models.transformer import ModelConfig
from repro.data import DataConfig, device_sampler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist import faults, rounds, tamuna_dp

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DIST = os.environ["REPRO_LATENCY_DIST"]
N, C, S = 8, 2, 2
ROUNDS = 6 if SMOKE else 40
TAUS = (1,) if SMOKE else (1, 2)
SYNC_SEEDS = (0,) if SMOKE else (0, 1, 2)

mesh = jax.make_mesh((1, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = ModelConfig(family="dense", n_layers=2, d_model=32 if SMOKE else 64,
                  n_heads=2 if SMOKE else 4, n_kv_heads=2,
                  d_ff=64 if SMOKE else 128, vocab=128,
                  dtype=jnp.float32, remat=False)
dcfg = DataConfig(seq_len=16, per_client_batch=2, vocab=128, seed=0,
                  n_clients=N)
pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
data = pipe.device_data()
sampler = device_sampler(dcfg, cfg, mesh)
tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=C, s=S, p=0.5,
                                  uplink="masked_psum")
lat = faults.EmpiricalDelays.from_json(DIST, n=N, seed=0)
engine = rounds.make_pipelined_round_fn(cfg, tcfg, mesh,
                                        sample_batch=sampler, max_L=8,
                                        n=N, elastic=True)


class RowLogger:
    def __init__(self):
        self.rows = []

    def log(self, step, m):
        self.rows.append(dict(m))


def run_one(tau, policy, quorum=None, seed=0):
    st = tamuna_dp.init_state(jax.random.key(seed), cfg, mesh, tcfg, n=N)
    logger = RowLogger()
    st, last = rounds.run_rounds_pipelined(
        st, round_fn=engine, data=data, key=jax.random.key(seed + 10),
        rounds=ROUNDS, rng=np.random.default_rng(seed), p=tcfg.p,
        staleness=tau, flush_every=10, logger=logger, latency=lat,
        policy=policy, quorum=quorum,
    )
    rows = logger.rows
    return {
        "tau": tau, "policy": policy, "quorum": quorum, "seed": seed,
        "clock_s": float(last["commit_s"]),
        "loss": float(last["loss"]),
        "admitted_total": int(sum(r.get("admitted", C) for r in rows)),
        "late_dropped_total": int(sum(r.get("late_dropped", 0)
                                      for r in rows)),
        "uncovered_total": int(sum(r.get("uncovered", 0) for r in rows)),
        "local_steps": int(last["local_steps"]),
    }


sync_runs = [run_one(0, "wait_all", seed=s) for s in SYNC_SEEDS]
sync = sync_runs[0]
scenarios = [sync]
for tau in TAUS:
    scenarios.append(run_one(tau, "wait_all"))
for tau in TAUS:
    scenarios.append(run_one(tau, "quorum", quorum=1))
for r in scenarios:
    print(f"# tau={r['tau']} {r['policy']}"
          f"{'' if r['quorum'] is None else r['quorum']}: "
          f"clock {r['clock_s']:.1f}s loss {r['loss']:.4f} "
          f"late_dropped {r['late_dropped_total']}", flush=True)

losses = [r["loss"] for r in sync_runs]
band = max(losses) - min(losses)


def within(loss):
    # inside the sync seed band widened by one band-width on each side
    return min(losses) - band <= loss <= max(losses) + band


# headline: the deepest wait_all tau whose final loss stays within the
# sync noise band — the wall-clock win that costs no admission drops and
# no convergence (staleness is the only knob turned)
candidates = [r for r in scenarios if r["tau"] >= 1
              and r["policy"] == "wait_all" and within(r["loss"])]
best = (max(candidates, key=lambda r: sync["clock_s"] / r["clock_s"])
        if candidates else
        next(r for r in scenarios if r["tau"] == TAUS[0]
             and r["policy"] == "wait_all"))
speedup = sync["clock_s"] / max(best["clock_s"], 1e-12)
converged = within(best["loss"])
with open(DIST) as f:
    dist_meta = {k: v for k, v in json.load(f).items()
                 if not isinstance(v, list)}
out = {
    "rows": scenarios,
    "sync_seeds": sync_runs,
    "sync_loss_band": [min(losses), max(losses)],
    "speedup_at_tail": speedup,
    "speedup_tau": best["tau"],
    "tail_loss_within_sync_band": bool(converged),
    "per_tau_speedup": {str(r["tau"]): sync["clock_s"] / r["clock_s"]
                        for r in scenarios if r["policy"] == "wait_all"
                        and r["tau"] >= 1},
    "latency_dist": dist_meta,
    "acceptance": {"min_speedup_at_tail": 1.5,
                   "tail_within_sync_band": True},
    "config": {"n": N, "c": C, "s": S, "rounds": ROUNDS,
               "taus": list(TAUS), "uplink": tcfg.uplink,
               "p": tcfg.p, "max_L": 8, "arch": "dense",
               "d_model": cfg.d_model, "seq_len": dcfg.seq_len,
               "sync_seeds": list(SYNC_SEEDS)},
}
print(json.dumps(out))
"""


def _ensure_latency_dist(smoke: bool) -> str:
    """Run the availability example's --dist-out export (the measured
    straggler tail).  Smoke writes to a temp path — the checked-in
    artifact is never clobbered by a rot check."""
    if smoke:
        path = os.path.join(tempfile.mkdtemp(prefix="pipe_bench_"),
                            "latency_dist.json")
        rounds = 2
    else:
        path = LATENCY_DIST
        rounds = 12
    env = dict(os.environ)
    env["XLA_FLAGS"] = ""
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples",
                                      "availability_sim.py"),
         "--dist", "--rounds", str(rounds), "--dist-out", path],
        capture_output=True, text=True, timeout=1800, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        print(f"# latency-dist export failed:\n{proc.stderr}",
              file=sys.stderr)
        return ""
    return path


def _bench(smoke: bool, dist_path: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = ""  # single real CPU device
    env["REPRO_LATENCY_DIST"] = dist_path
    if smoke:
        env["REPRO_BENCH_SMOKE"] = "1"
    else:
        env.pop("REPRO_BENCH_SMOKE", None)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _CODE],
        capture_output=True, text=True, timeout=1800, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        print(f"# pipeline bench failed:\n{proc.stderr}", file=sys.stderr)
        return {}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(paper_scale: bool = False, smoke: bool = False,
        latency_dist: str = ""):
    """``latency_dist`` overrides the measured-distribution input (any
    availability_sim --dist-out export); by default the bench re-exports
    it so the clock is always priced at the current measured tail."""
    del paper_scale
    dist_path = latency_dist or _ensure_latency_dist(smoke=smoke)
    if not dist_path:
        return []
    art = _bench(smoke=smoke, dist_path=dist_path)
    if not art:
        return []
    if not smoke:  # smoke runs must not clobber the measured artifact
        with open(ARTIFACT, "w") as f:
            json.dump(art, f, indent=1)
    rows = []
    for r in art["rows"]:
        pol = r["policy"] + ("" if r["quorum"] is None else str(r["quorum"]))
        tag = f"pipeline/n{art['config']['n']}/c{art['config']['c']}"
        rows.append({
            "name": f"{tag}/tau{r['tau']}/{pol}/clock_s",
            "us_per_call": round(r["clock_s"], 3),
            "derived": (f"loss={r['loss']:.4f},"
                        f"late_dropped={r['late_dropped_total']},"
                        f"uncovered={r['uncovered_total']}"),
        })
    rows.append({
        "name": "pipeline/speedup_at_tail",
        "us_per_call": round(art["speedup_at_tail"], 3),
        "derived": (f"acceptance: >= 1.5 with loss in sync band; "
                    f"tau={art['speedup_tau']}, "
                    f"band={art['sync_loss_band']}, "
                    f"within={art['tail_loss_within_sync_band']}"),
    })
    return rows


if __name__ == "__main__":
    for r in run(smoke=os.environ.get("REPRO_BENCH_SMOKE") == "1"):
        print(r)
