"""Paper Table 2: TotalCom complexity under full participation, for
LT-only, CC-only, and LT+CC algorithms (alpha in {0, 0.1}).

Theoretical column uses the table's formulas; measured column is TotalCom
floats per client to target accuracy on the shared problem.
"""

from __future__ import annotations

import math

from benchmarks.common import floats_to_accuracy
from repro.core import baselines, problems, tamuna, theory


def run(seed: int = 0):
    n, d, kappa = 64, 300, 1e3
    prob = problems.make_logreg_problem(
        n=n, d=d, samples_per_client=8, kappa=kappa, seed=seed
    )
    k = prob.kappa
    gamma = 2.0 / (prob.L + prob.mu)
    target = float(prob.suboptimality(prob.x_star * 0.0)) * 1e-6

    cfgT = tamuna.TamunaConfig.tuned(prob, c=n)
    traces = {
        "gd": baselines.run_gd(prob, gamma, 60000, record_every=200),
        "scaffnew": baselines.run_scaffnew(
            prob, gamma, p=cfgT.p, num_iters=20000, seed=seed,
            record_every=100,
        ),
        "compressed_scaffnew": baselines.run_compressed_scaffnew(
            prob, gamma, p=cfgT.p, s=cfgT.s, num_iters=20000, seed=seed,
            record_every=100,
        ),
        "diana": baselines.run_diana(
            prob, 0.5 / prob.L, k=8, num_rounds=10000, seed=seed,
            record_every=50,
        ),
        "ef21": baselines.run_ef21(
            prob, 0.5 / prob.L, k=1, num_rounds=6000, seed=seed,
            record_every=50,
        ),
        "tamuna": tamuna.run(prob, cfgT, num_rounds=4000, seed=seed,
                             record_every=20),
    }
    theo0 = {
        "gd": theory.gd_totalcom(k, d, 0.0),
        "scaffnew": theory.scaffnew_totalcom(k, d, 0.0),
        "diana": (1 + d / n) * k + d,
        "ef21": d * k,
        "compressed_scaffnew": math.sqrt(d) * math.sqrt(k)
        + d * math.sqrt(k) / math.sqrt(n) + d,
        "tamuna": math.sqrt(d) * math.sqrt(k)
        + d * math.sqrt(k) / math.sqrt(n) + d,
    }
    rows = []
    for alpha in (0.0, 0.1):
        for name, tr in traces.items():
            rows.append({
                "table": "table2", "algo": name, "alpha": alpha,
                "totalcom_theory_alpha0": theo0[name],
                "totalcom_measured": floats_to_accuracy(tr, target, alpha),
                "final_subopt": float(tr["suboptimality"][-1]),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
