"""Byzantine-robustness benchmark: convergence under adversarial uplinks
(DESIGN.md §15).

Runs the strongly-convex logistic-regression TAMUNA loop with the *dist*
comm step (``comm_ws.cyclic_comm`` on the flat client-stacked state)
against a persistent Byzantine fraction ``f = 0.25`` of the fleet under
two attacks:

  sign_flip  adversaries negate their payload — norm-preserving, so no
             magnitude guard can see it; only the robust combiner helps,
  blowup     adversaries scale their payload by 1e8 — finite, so the
             nonfinite-only guard admits it; the adaptive magnitude
             guard (median + 6 * 1.4826 * MAD of arrived payload norms)
             demotes the rows before aggregation.

Per attack, three aggregators: ``mean`` (the plain survivor mean with
the nonfinite-only guard — the control that stalls or diverges),
``trimmed`` (k = c/3 per side, adaptive guard) and ``median`` (adaptive
guard).  The robust scenarios run in the redundancy regime ``s = c``
(no sparsification): per-coordinate order statistics need the honest
majority *inside every owner stack*, so under attack the loop trades
the compression knob for robustness — k = c/3 per side then tolerates
the worst-case per-round Byzantine fraction (all f*n adversaries drawn
into the cohort gives f*n/c = 1/3) even before reputation quarantines
the persistent offenders.

Attack rows are scored against the *honest-subset* optimum (solved to
machine precision by deterministic full-gradient descent over the
non-Byzantine clients): a persistent adversary never contributes its
honest data, so the full-problem optimum is unreachable in principle
and the honest-subset minimizer is the correct floor.  Fault-free rows
use the full optimum; both use the relative squared distance
``||x - x*||^2 / ||x0 - x*||^2 < TARGET_REL`` as the hit criterion.

Aggregation alone is not enough: a robust combiner breaks TAMUNA's
``sum_i h_i = 0`` control-variate invariant (the mean-combiner identity
that pins the fixed point to the optimizer), leaving a *permanent* bias
even after every adversary is quarantined.  The driver therefore
re-centers ``h`` over the active clients each round
(``robust.recenter_h``) — without it the robust runs plateau ~10x above
target; with it they converge to the honest optimum at machine
precision.

Acceptance: both robust aggregators reach their target within 2x the
fault-free round count while the mean control never does (or ends
>= 10x above target / nonfinite); the robust comm step costs <= 1.5x
the mean comm step at the production sparsified uplink shape (s=4,
TIME_D-wide payloads — the s=c redundancy regime is reported
alongside); ``trimmed k=0`` at ``f=0`` is bitwise identical to
``mean`` in all four comm impls (dense / ws / pallas / shard engine); a
robust scenario replayed from the same seeds matches bitwise; the int8
quantized wire composes (robust stats run on the dequantized values,
deviation stays at quantization scale).

Writes ``BENCH_robust.json``; ``run(smoke=True)`` (or
``REPRO_BENCH_SMOKE=1``) shrinks the problem and skips the artifact
write — wired into tests/test_bench_tooling.py and benchmarks/run.py
(``--only robust``).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
ARTIFACT = os.path.join(REPO, "BENCH_robust.json")

_CODE = r"""
import json, os, time
import numpy as np
import jax, jax.numpy as jnp

from repro.core import problems, tamuna
from repro.dist import comm_ws, robust, wire
from repro.dist.cohort import CohortPlan
from repro.dist.faults import FaultModel, FaultPlan, adversarial_rows

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
N, D, SPC = (8, 16, 4) if SMOKE else (16, 32, 8)
KAPPA = 50.0 if SMOKE else 100.0
MAX_ROUNDS = 80 if SMOKE else 4000
TARGET_REL = 1e-1 if SMOKE else 1e-3
# cohort large enough that the worst-case Byzantine fraction of a round
# (all f*N adversaries drawn) stays below the 50% breakdown point of the
# median/MAD guard and the per-coordinate order statistics
C = max(4, (3 * N) // 4)
# robustness regime: s = c (no sparsification) so every coordinate's
# owner stack carries the full cohort redundancy; k = c/3 per side then
# survives the worst-case per-round Byzantine draw (f*n/c = 1/3)
S = C
TRIM_K = C // 3
F_BYZ = 0.25
TIME_D = 4096 if SMOKE else 65536
TIME_ITERS = 10 if SMOKE else 30

prob = problems.make_logreg_problem(
    n=N, d=D, samples_per_client=SPC, kappa=KAPPA, seed=0
)
cfg = tamuna.TamunaConfig.tuned(prob, c=C, s=S)
L = max(1, round(1.0 / cfg.p))
scale = cfg.eta / cfg.gamma

# Byzantine set is a function of the fault seed alone — shared by every
# attack row so the honest-subset reference is computed once
BYZ = FaultPlan(
    seed=3, n=N, model=FaultModel(adversary="sign_flip", f_byz=F_BYZ)
).byzantine
HONEST = np.flatnonzero(~BYZ)


def solve_subset(idx, iters=20000):
    # full-gradient descent on the subset mean objective; each f_i is
    # L-smooth and mu-strongly convex, so step 1/L contracts linearly
    idx_j = jnp.asarray(idx, jnp.int32)

    @jax.jit
    def gd(x):
        def body(i, x):
            G = prob.grad_all_local(jnp.broadcast_to(x, (N, D)))
            return x - (1.0 / prob.L) * G[idx_j].mean(axis=0)

        return jax.lax.fori_loop(0, iters, body, x)

    return gd(jnp.zeros_like(prob.x_star))


X_STAR_FULL = prob.x_star
X_STAR_HONEST = solve_subset(HONEST)


@jax.jit
def local_steps(x_bar, h, cohort):
    Xc = jnp.broadcast_to(x_bar, (C, D))
    hc = h[cohort]

    def body(i, Xc):
        return Xc - cfg.gamma * prob.cohort_grads(Xc, cohort) \
            + cfg.gamma * hc

    return jax.lax.fori_loop(0, L, body, Xc)


def comm_step(spec):
    @jax.jit
    def step(x_bar, h, Xc, cohort, slot, arrived):
        X = jnp.broadcast_to(x_bar, (N, D)).at[cohort].set(Xc)
        return comm_ws.cyclic_comm(
            X, h, slot, C, S, scale, impl="ws",
            arrived=arrived, correct=True, robust=spec,
        )

    return step


def attack_rows(X, byz_member, member, attack):
    if attack == "sign_flip":
        return adversarial_rows(
            {"x": X}, byz_member, member & ~byz_member, "sign_flip"
        )["x"]
    return adversarial_rows(  # finite blowup: scale by 1e8
        {"x": X}, byz_member, member & ~byz_member, "scale",
        byz_scale=1e8,
    )["x"]


def run_driver(attack, agg):
    spec = robust.normalize_robust(
        agg, TRIM_K if agg == "trimmed" else 0, S
    )
    robust_run = attack != "none" and agg != "mean"
    guard = "adaptive" if robust_run else "nonfinite"
    byz = BYZ if attack != "none" else np.zeros(N, bool)
    # attack rows chase the honest-subset optimum (the reachable floor);
    # fault-free rows chase the full optimum
    x_ref = X_STAR_HONEST if attack != "none" else X_STAR_FULL
    err0 = float(jnp.sum(x_ref * x_ref))
    plan = CohortPlan(seed=7, n=N, c=C)
    # the full §15 stack for robust runs: combiner + adaptive guard +
    # anomaly-driven reputation quarantining persistent adversaries (the
    # combiner alone bounds per-round damage; quarantine removes the
    # variance floor a persistent f=0.25 attack would otherwise leave)
    rep = robust.Reputation(N, threshold=3.0, base_rounds=16,
                            max_doublings=6) if robust_run else None
    quarantined_ever = np.zeros(N, bool)
    step = comm_step(spec)
    x_bar = jnp.zeros(D)
    h = jnp.zeros((N, D))
    hit = None
    diverged = False
    guarded = 0
    err = float("nan")
    sub = float("nan")
    for g in range(MAX_ROUNDS):
        cohort = np.asarray(plan.cohort(g))
        member = np.zeros(N, bool)
        member[cohort] = True
        cohort_j = jnp.asarray(cohort, jnp.int32)
        perm = np.random.default_rng(
            np.random.SeedSequence([7, 97, g])
        ).permutation(C)
        slot_np = np.full(N, -1, np.int64)
        slot_np[cohort] = perm
        slot = jnp.asarray(slot_np, jnp.int32)
        Xc = local_steps(x_bar, h, cohort_j)
        X = jnp.broadcast_to(x_bar, (N, D)).at[cohort_j].set(Xc)
        arrived = member.copy()
        bad = np.zeros(N, bool)
        if attack != "none" and (byz & member).any():
            X = attack_rows(X, jnp.asarray(byz & member),
                            jnp.asarray(member), attack)
            Xc = X[cohort_j]
            if guard == "adaptive":
                bad = np.asarray(robust.magnitude_outliers(
                    {"x": X}, jnp.asarray(arrived)))
                guarded += int(bad.sum())
                arrived &= ~bad
        x_new, h = step(x_bar, h, Xc, cohort_j, slot,
                        jnp.asarray(arrived))
        if rep is not None:
            anom = np.asarray(robust.anomaly_scores(
                {"x": X}, jnp.asarray(arrived)))
            # a guard hit is hard evidence: score it above threshold so
            # guarded rows (excluded from the anomaly stats) still
            # accumulate reputation strikes
            an = anom.copy()
            an[bad] = 2.0 * rep.threshold
            for cid, w in rep.update(an, arrived | bad):
                plan.quarantine([cid], g + 1, g + w)
                quarantined_ever[cid] = True
            # robust combining breaks the sum(h)=0 invariant that pins
            # the fixed point to the optimizer; repair it each round
            # over the clients still in play (see robust.recenter_h)
            h = robust.recenter_h(h, jnp.asarray(~quarantined_ever))
        idle = np.setdiff1d(np.arange(N), cohort)
        x_bar = x_new[int(idle[0])] if idle.size else x_new[0]
        delta = x_bar.astype(x_ref.dtype) - x_ref
        err = float(jnp.sum(delta * delta)) / err0
        sub = float(prob.suboptimality(x_bar))
        if not np.isfinite(err):
            diverged = True
            break
        if err < TARGET_REL:
            hit = g + 1
            break
    qids = sorted({int(i) for ids, _, _ in plan._quarantine
                   for i in ids})
    return {
        "attack": attack, "agg": agg, "f_byz": F_BYZ if attack != "none"
        else 0.0, "guard": guard,
        "rounds_to_target": hit, "final_err_rel": err,
        "final_suboptimality": sub,
        "diverged": diverged, "guarded_rows": guarded,
        "quarantine_windows": len(plan._quarantine),
        "quarantined_byz_only": bool(all(byz[i] for i in qids))
        if qids else None,
        "x_fingerprint": [float(v) for v in np.asarray(x_bar)[:4]]
        if np.isfinite(np.asarray(x_bar)).all() else None,
    }


rows = [run_driver("none", "mean")]
base = rows[0]["rounds_to_target"]
for attack in ("sign_flip", "blowup"):
    for agg in ("mean", "trimmed", "median"):
        rows.append(run_driver(attack, agg))
for r in rows:
    print(f"# {r['attack']}/{r['agg']}: rounds={r['rounds_to_target']} "
          f"err_rel={r['final_err_rel']:.3e} "
          f"sub={r['final_suboptimality']:.3e} "
          f"diverged={r['diverged']} guarded={r['guarded_rows']}",
          flush=True)

# deterministic replay: same seeds => bitwise-identical trajectory
a = run_driver("sign_flip", "trimmed")
b = run_driver("sign_flip", "trimmed")
replay_ok = (a["rounds_to_target"] == b["rounds_to_target"]
             and a["x_fingerprint"] == b["x_fingerprint"])

# robust comm-step overhead vs the mean path (the ws impl the loop uses)
rngt = np.random.default_rng(11)
Xt = jnp.asarray(rngt.normal(size=(N, TIME_D)), jnp.float32)
ht = jnp.asarray(rngt.normal(size=(N, TIME_D)), jnp.float32)
slot_t = np.full(N, -1, np.int64)
coh_t = rngt.choice(N, size=C, replace=False)
slot_t[coh_t] = rngt.permutation(C)
slot_t = jnp.asarray(slot_t, jnp.int32)


def timed(spec, s):
    fn = jax.jit(lambda X, h: comm_ws.cyclic_comm(
        X, h, slot_t, C, s, 0.37, impl="ws", robust=spec))
    jax.block_until_ready(fn(Xt, ht))
    best = float("inf")
    for _ in range(3):  # best-of-3: scheduler noise only ever adds time
        t0 = time.perf_counter()
        for _ in range(TIME_ITERS):
            out = fn(Xt, ht)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / TIME_ITERS * 1e6)
    return best


# acceptance overhead is measured at the production comm-engine shape:
# the sparsified uplink (s << c) on TIME_D-wide payloads, where the
# robust combine rides the same s-row owner stacks the masked-sum mean
# already materializes.  The s = c redundancy regime the convergence
# rows run in is reported alongside (sorting c values per coordinate
# vs summing them is intrinsically super-1.5x there — that regime
# trades comm time for Byzantine tolerance by design).
S_PROD = min(4, C)
t_mean = timed(None, S_PROD)
t_trim = timed(("trimmed", 1), S_PROD)
t_med = timed(("median", 0), S_PROD)
overhead = max(t_trim, t_med) / t_mean
t_mean_sc = timed(None, S)
overhead_sc = max(timed(("trimmed", TRIM_K), S),
                  timed(("median", 0), S)) / t_mean_sc

# identity contract: trimmed k=0 == mean bitwise, all four impls
mesh = jax.make_mesh((1, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
spec0 = robust.normalize_robust("trimmed", 0, S)
identity_ok = spec0 is None
for impl, meshed, kw in (("dense", False, {}), ("ws", False, {}),
                         ("pallas", False, {}),
                         ("pallas", True,
                          {"mesh": mesh, "shard_kernels": False})):
    f = lambda rb: jax.jit(lambda X, h: comm_ws.cyclic_comm(
        X, h, slot_t, C, S, 0.37, impl=impl, meshed=meshed, robust=rb,
        **kw))(Xt, ht)
    (xa, ha), (xb, hb) = f(None), f(spec0)
    identity_ok &= bool(
        (np.asarray(xa) == np.asarray(xb)).all()
        and (np.asarray(ha) == np.asarray(hb)).all())

# int8 wire interplay: robust stats on the dequantized values stay at
# quantization scale of the f32-wire robust aggregate
seed_w = wire.round_seed(jax.random.key(5))
xw, _ = jax.jit(lambda X, h: comm_ws.cyclic_comm(
    X, h, slot_t, C, S, 0.37, impl="ws", robust=("trimmed", TRIM_K),
    wire="int8", wire_seed=seed_w))(Xt, ht)
xf, _ = jax.jit(lambda X, h: comm_ws.cyclic_comm(
    X, h, slot_t, C, S, 0.37, impl="ws",
    robust=("trimmed", TRIM_K)))(Xt, ht)
wire_dev = float(jnp.abs(xw - xf).max())

by = {(r["attack"], r["agg"]): r for r in rows}


def ratio(attack, agg):
    r = by[(attack, agg)]["rounds_to_target"]
    return (r / base) if (r and base) else None


def control_stalls(attack):
    r = by[(attack, "mean")]
    return (r["diverged"] or r["rounds_to_target"] is None
            or not np.isfinite(r["final_err_rel"])
            or r["final_err_rel"] >= 10 * TARGET_REL)


out = {
    "rows": rows,
    "target_rel": TARGET_REL,
    "fault_free_rounds": base,
    "ratios": {f"{a}/{g}": ratio(a, g)
               for a in ("sign_flip", "blowup")
               for g in ("trimmed", "median")},
    "mean_control_stalls": {a: control_stalls(a)
                            for a in ("sign_flip", "blowup")},
    "comm_step_us": {"mean": t_mean, "trimmed": t_trim, "median": t_med},
    "robust_overhead_ratio": overhead,
    "robust_overhead_ratio_s_eq_c": overhead_sc,
    "overhead_shape": {"s": S_PROD, "trim_k": 1, "d": TIME_D},
    "identity_bitwise_ok": identity_ok,
    "deterministic_replay_ok": replay_ok,
    "int8_wire_max_dev": wire_dev,
    "acceptance": {"robust_ratio_max": 2.0, "overhead_ratio_max": 1.5,
                   "mean_control_must_stall": True,
                   "identity_bitwise": True, "replay_bitwise": True,
                   "int8_wire_dev_max": 0.25},
    "config": {"n": N, "d": D, "c": C, "s": S, "trim_k": TRIM_K,
               "L": L, "f_byz": F_BYZ, "kappa": KAPPA,
               "target_rel": TARGET_REL, "max_rounds": MAX_ROUNDS,
               "time_d": TIME_D,
               "attack_metric": "rel_sq_dist_to_honest_subset_optimum",
               "byzantine": [int(i) for i in np.flatnonzero(BYZ)]},
}
print(json.dumps(out))
"""


def _bench(smoke: bool) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = ""  # single real CPU device
    if smoke:
        env["REPRO_BENCH_SMOKE"] = "1"
    else:
        env.pop("REPRO_BENCH_SMOKE", None)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _CODE],
        capture_output=True, text=True, timeout=1800, env=env, cwd=REPO,
    )
    if proc.returncode != 0:
        print(f"# robust bench failed:\n{proc.stderr}", file=sys.stderr)
        return {}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(paper_scale: bool = False, smoke: bool = False):
    del paper_scale
    art = _bench(smoke=smoke)
    if not art:
        return []
    if not smoke:  # smoke runs must not clobber the measured artifact
        with open(ARTIFACT, "w") as f:
            json.dump(art, f, indent=1)
    rows = []
    for r in art["rows"]:
        tag = f"robust/{r['attack']}/{r['agg']}"
        reached = r["rounds_to_target"]
        rows.append({
            "name": tag,
            "us_per_call": float(reached if reached is not None else -1),
            "derived": (f"rounds_to_target={reached} "
                        f"err_rel={r['final_err_rel']:.2e} "
                        f"diverged={r['diverged']} "
                        f"guarded={r['guarded_rows']}"),
        })
    rows.append({
        "name": "robust/comm_overhead_ratio",
        "us_per_call": round(art["robust_overhead_ratio"], 3),
        "derived": (f"acceptance: <= 1.5x mean comm step at the "
                    f"production uplink {art['overhead_shape']}; "
                    f"mean={art['comm_step_us']['mean']:.0f}us "
                    f"trimmed={art['comm_step_us']['trimmed']:.0f}us "
                    f"median={art['comm_step_us']['median']:.0f}us "
                    f"(s=c redundancy regime: "
                    f"{art['robust_overhead_ratio_s_eq_c']:.2f}x)"),
    })
    ratios = art.get("ratios", {})
    stalls = art.get("mean_control_stalls", {})
    rows.append({
        "name": "robust/acceptance",
        "us_per_call": max(
            [v for v in ratios.values() if v is not None] or [-1.0]),
        "derived": (f"ratios={ratios} mean_stalls={stalls} "
                    f"identity={art.get('identity_bitwise_ok')} "
                    f"replay={art.get('deterministic_replay_ok')} "
                    f"wire_dev={art.get('int8_wire_max_dev'):.3g}"),
    })
    return rows


if __name__ == "__main__":
    for r in run(smoke=os.environ.get("REPRO_BENCH_SMOKE") == "1"):
        print(r)
