"""End-to-end driver: pretrain a language model with TAMUNA-DP.

Trains a reduced gemma2-style model on the synthetic heterogeneous token
pipeline over a (data=4, model=2) host mesh — through the fused round
engine (`repro.dist.rounds`): each round is one donated scanned program
with on-device data generation, so steady-state training does zero
host->device transfers.

  PYTHONPATH=src python examples/train_lm.py [--rounds 60] [--big]

``--big`` uses a ~100M-parameter config (slow on 1 CPU core; the default is
a fast smoke-scale run of the identical code path).
"""

import argparse
import os
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--big", action="store_true",
                    help="~100M params (much slower on CPU)")
    ap.add_argument("--seq-len", type=int, default=0)
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )
    import dataclasses

    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import metrics
    from repro.configs import registry
    from repro.data import DataConfig, SyntheticTokenPipeline, device_sampler
    from repro.dist import rounds, tamuna_dp
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(4, 2)
    cfg = registry.get_reduced_config("gemma2-2b")
    seq = args.seq_len or (256 if args.big else 64)
    if args.big:
        # ~100M params: 8 layers x d_model 768 x d_ff 3072, vocab 32768
        cfg = dataclasses.replace(
            cfg, n_layers=8, d_model=768, n_heads=8, n_kv_heads=4,
            head_dim=96, d_ff=3072, vocab=32768, sliding_window=1024,
        )

    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=4, s=3, p=0.34)
    state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
    n_params = sum(x.size for x in jax.tree.leaves(state.x)) // 4
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params/client), "
          f"mesh {dict(mesh.shape)}, clients=4, cohort={tcfg.c}, "
          f"s={tcfg.s}, p={tcfg.p}")

    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tamuna_dp.state_pspecs(state, cfg, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )
    state = jax.device_put(state, shardings)

    pipe = SyntheticTokenPipeline(
        DataConfig(seq_len=seq, per_client_batch=2, vocab=512), cfg, mesh
    )
    round_fn = rounds.make_round_fn(
        cfg, tcfg, mesh,
        sample_batch=device_sampler(pipe.dcfg, cfg, mesh),
        max_L=8,
    )
    state, last = rounds.run_rounds(
        state,
        round_fn=round_fn,
        data=pipe.device_data(),
        key=jax.random.key(1),
        rounds=args.rounds,
        rng=np.random.default_rng(0),
        p=tcfg.p,
        flush_every=5,
        logger=metrics.MetricLogger(print_every=5),
    )
    print(f"round {last['round']:4d}  local_steps {last['local_steps']:5d}  "
          f"loss {last['loss']:.4f}")
    print("done — loss should have dropped well below ln(vocab) ="
          f" {np.log(512):.2f}")


if __name__ == "__main__":
    main()
