"""Federated-learning scenario: device heterogeneity, stragglers, and the
PP + CC knobs of TAMUNA, compared on the same problem.

Default (convex reference core): sweeps cohort size c (partial
participation) and sparsity s (compression) and prints the TotalCom cost to
target accuracy for each setting, showing:
  * convergence holds down to c = 2 (the paper's minimum),
  * the communication sweet spot follows Theorem 3's  s = max(2, c/d),
  * TotalCom is roughly flat in c (complexity ~ n/c rounds x c clients),
    which is why PP is "free" robustness.

``--lm`` runs the same partial-participation sweep on the *system* engine
instead: the fused round engine (`repro.dist.rounds`) over a reduced LM on
an 8-client host mesh, printing per-cohort loss and measured uplink floats.
(The convex core forces jax x64 globally, so the two modes never import
each other's stack — each mode imports lazily.)

  PYTHONPATH=src python examples/federated_sim.py [--lm]
"""

import argparse
import os
import sys

sys.path.insert(0, "src")


def convex_sweep():
    import numpy as np

    from repro.core import problems, tamuna, theory

    prob = problems.make_logreg_problem(
        n=48, d=128, samples_per_client=8, kappa=500.0, seed=3
    )
    target = float(prob.suboptimality(prob.x_star * 0.0)) * 1e-5
    print(f"n={prob.n} d={prob.d} kappa={prob.kappa:.0f} "
          f"target={target:.2e}\n")

    print(f"{'c':>4} {'s':>4} {'p':>7} {'rounds':>7} {'UpCom':>10} "
          f"{'TotalCom(a=0.05)':>17}")
    for c in (2, 6, 12, 24, 48):
        for s in (2, 4) if c >= 4 else (2,):
            if s > c:
                continue
            cfg = tamuna.TamunaConfig.tuned(prob, c=c, s=s)
            tr = tamuna.run(prob, cfg, num_rounds=6000, record_every=25)
            sub = tr["suboptimality"]
            idx = int(np.argmax(sub < target))
            if sub[idx] >= target:
                print(f"{c:>4} {s:>4} {cfg.p:>7.3f} {'—':>7} (not reached)")
                continue
            up = tr["up_floats"][idx]
            total = up + 0.05 * tr["down_floats"][idx]
            print(f"{c:>4} {s:>4} {cfg.p:>7.3f} {tr['rounds'][idx]:>7} "
                  f"{up:>10} {total:>17.0f}")
    s_star = theory.recommended_s(c=48, d=prob.d, alpha=0.05)
    print(f"\nTheorem 3 recommends s = {s_star} at c = 48, alpha = 0.05")


def lm_sweep(num_rounds: int):
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import registry
    from repro.data import DataConfig, SyntheticTokenPipeline, device_sampler
    from repro.dist import rounds, sharding, tamuna_dp
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(8, 1)
    n = sharding.n_clients(mesh)
    cfg = registry.get_reduced_config("gemma2-2b")
    dcfg = DataConfig(seq_len=32, per_client_batch=2, vocab=512, seed=0)
    pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
    print(f"LM partial-participation sweep: n={n} clients, "
          f"{cfg.name}, {num_rounds} rounds each\n")
    print(f"{'c':>4} {'s':>4} {'rounds':>7} {'steps':>6} {'loss':>8} "
          f"{'UpCom/client':>13}")
    for c in (2, 4, 8):
        tcfg = tamuna_dp.DistTamunaConfig(
            gamma=0.05, c=c, s=2, p=0.34
        )
        state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg)
        sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            tamuna_dp.state_pspecs(state, cfg, mesh),
            is_leaf=lambda x: isinstance(x, P),
        )
        state = jax.device_put(state, sh)
        round_fn = rounds.make_round_fn(
            cfg, tcfg, mesh,
            sample_batch=device_sampler(dcfg, cfg, mesh),
            max_L=8,
        )
        state, last = rounds.run_rounds(
            state,
            round_fn=round_fn,
            data=pipe.device_data(),
            key=jax.random.key(1),
            rounds=num_rounds,
            rng=np.random.default_rng(c),
            p=tcfg.p,
            flush_every=num_rounds,
        )
        print(f"{c:>4} {tcfg.s:>4} {num_rounds:>7} "
              f"{last['local_steps']:>6} {last['loss']:>8.4f} "
              f"{last['up_floats']:>13.3e}")
    print("\nloss falls for every cohort size down to c = 2 — partial "
          "participation is free robustness (rounds ~ n/c, cost ~ c).")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lm", action="store_true",
                    help="sweep cohort sizes on the fused dist round engine")
    ap.add_argument("--rounds", type=int, default=12,
                    help="rounds per setting in --lm mode")
    args = ap.parse_args()
    if args.lm:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
        )
        lm_sweep(args.rounds)
    else:
        convex_sweep()


if __name__ == "__main__":
    main()
