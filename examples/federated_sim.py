"""Federated-learning scenario: device heterogeneity, stragglers, and the
PP + CC knobs of TAMUNA, compared on the same problem.

Sweeps cohort size c (partial participation) and sparsity s (compression)
and prints the TotalCom cost to target accuracy for each setting, showing:
  * convergence holds down to c = 2 (the paper's minimum),
  * the communication sweet spot follows Theorem 3's  s = max(2, c/d),
  * TotalCom is roughly flat in c (complexity ~ n/c rounds x c clients),
    which is why PP is "free" robustness.

  PYTHONPATH=src python examples/federated_sim.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import problems, tamuna, theory


def main():
    prob = problems.make_logreg_problem(
        n=48, d=128, samples_per_client=8, kappa=500.0, seed=3
    )
    target = float(prob.suboptimality(prob.x_star * 0.0)) * 1e-5
    print(f"n={prob.n} d={prob.d} kappa={prob.kappa:.0f} "
          f"target={target:.2e}\n")

    print(f"{'c':>4} {'s':>4} {'p':>7} {'rounds':>7} {'UpCom':>10} "
          f"{'TotalCom(a=0.05)':>17}")
    for c in (2, 6, 12, 24, 48):
        for s in (2, 4) if c >= 4 else (2,):
            if s > c:
                continue
            cfg = tamuna.TamunaConfig.tuned(prob, c=c, s=s)
            tr = tamuna.run(prob, cfg, num_rounds=6000, record_every=25)
            sub = tr["suboptimality"]
            idx = int(np.argmax(sub < target))
            if sub[idx] >= target:
                print(f"{c:>4} {s:>4} {cfg.p:>7.3f} {'—':>7} (not reached)")
                continue
            up = tr["up_floats"][idx]
            total = up + 0.05 * tr["down_floats"][idx]
            print(f"{c:>4} {s:>4} {cfg.p:>7.3f} {tr['rounds'][idx]:>7} "
                  f"{up:>10} {total:>17.0f}")
    s_star = theory.recommended_s(c=48, d=prob.d, alpha=0.05)
    print(f"\nTheorem 3 recommends s = {s_star} at c = 48, alpha = 0.05")


if __name__ == "__main__":
    main()
