"""Partial participation as robustness: stragglers and availability.

The paper motivates PP operationally: real fleets always have slow or
unavailable workers.  This example attaches a latency model to every client
(log-normal, with a heavy-tailed straggler mixture) and compares

  * full participation (c = n): every round waits for the SLOWEST client,
  * TAMUNA with c = n/4: each round samples a cohort and waits only for the
    slowest cohort member,

on simulated wall-clock time to target accuracy.  Convergence needs more
rounds at small c, but each round is much faster — the crossover the paper
predicts (complexity ~n/c rounds but per-round cost ~max over c draws).

The wall-clock model draws the cohort and the per-client jitter ONCE PER
ROUND (``wallclock_per_round``).  An earlier version drew once per *record
point* (every ``record_every=10`` rounds) and multiplied a single max by
the whole window's local steps, sampling the full-participation straggler
tail 10x too rarely and understating exactly the crossover this example
exists to show — regression-tested in tests/test_availability_sim.py.

``--dist`` runs the same straggler story on the *dist engine*: a Markov
up/down availability model plus inverse-latency weights drive non-uniform
cohort sampling through ``repro.dist.cohort.CohortPlan`` into the elastic
round engine (``rounds.run_rounds(plan=...)``, DESIGN.md §11), and the
plan's own cohorts price the simulated wall clock.

``--dist --faults`` goes one step further into the fault-tolerant driver
(DESIGN.md §12): a deterministic ``FaultPlan`` drops uplinks mid-round,
and the table compares the fault-free run against the quorum policy
(survivor-aware aggregation, cohort resample + backoff on a quorum miss)
and the wait_all control (biased 1/s aggregation of whatever arrived) —
reporting retries, quorum misses, and the simulated wall clock including
retry backoff.

``--dist --dist-out PATH`` additionally exports the wall-clock model's
measured per-step latency draws (the straggler tail as drawn, not a
parametric fit) as JSON — ``repro.dist.faults.EmpiricalDelays.from_json``
bootstraps per-round fleet latencies from it, and
``benchmarks/pipeline_bench.py`` prices the pipelined round engine's
simulated clock with exactly this distribution.

  PYTHONPATH=src python examples/availability_sim.py [--dist [--faults]]
"""

import argparse
import os
import sys

sys.path.insert(0, "src")

import numpy as np


def straggler_base(n, rng, straggler_frac=0.1):
    """Per-client base latency; ``straggler_frac`` of the fleet is 10x."""
    base = rng.lognormal(mean=0.0, sigma=0.3, size=n)
    base[rng.random(n) < straggler_frac] *= 10.0
    return base


def wallclock_per_round(steps, n, c, base, rng, jitter_sigma=0.2,
                        cohorts=None, samples_out=None):
    """Per-round wall-clock costs: round ``k`` waits for the slowest of
    ITS OWN cohort draw with ITS OWN jitter, scaled by its local steps.

    ``steps`` is the per-round local-step count; ``cohorts`` (optional,
    per-round client-id arrays) replays an externally chosen schedule
    (e.g. a ``CohortPlan``) instead of uniform draws.  Returns the
    ``(rounds,)`` per-round times; the cumulative clock is their cumsum.

    ``samples_out`` (optional list) collects every per-client PER-STEP
    latency draw (``base[cohort] * jitter``, before the ``L`` scaling) —
    the measured straggler-tail distribution the ``--dist-out`` export
    writes and ``repro.dist.faults.EmpiricalDelays`` bootstraps from.
    """
    times = np.empty(len(steps))
    for k, L in enumerate(steps):
        cohort = (rng.choice(n, size=c, replace=False)
                  if cohorts is None else np.asarray(cohorts[k]))
        jitter = rng.lognormal(0.0, jitter_sigma, size=len(cohort))
        draws = base[cohort] * jitter
        if samples_out is not None:
            samples_out.extend(draws.tolist())
        times[k] = draws.max() * max(int(L), 1)
    return times


def simulate(prob, c, seed=0, rounds=3000, straggler_frac=0.1):
    from repro.core import tamuna

    rng = np.random.default_rng(seed)
    base = straggler_base(prob.n, rng, straggler_frac)

    cfg = tamuna.TamunaConfig.tuned(prob, c=c)
    # record_every=1: the wall-clock model needs the PER-ROUND local-step
    # counts, not window totals
    tr = tamuna.run(prob, cfg, num_rounds=rounds, record_every=1)
    steps = np.diff(np.concatenate([[0], tr["local_steps"]]))
    times = wallclock_per_round(steps, prob.n, c, base, rng)
    return tr, np.cumsum(times)


def convex_main(rounds):
    from repro.core import problems

    prob = problems.make_logreg_problem(
        n=64, d=256, samples_per_client=8, kappa=1000.0, seed=0
    )
    target = float(prob.suboptimality(prob.x_star * 0.0)) * 1e-6
    print(f"n={prob.n} kappa={prob.kappa:.0f} target={target:.2e}")
    print(f"{'c':>5} {'rounds':>8} {'UpCom floats':>13} {'sim wall-clock':>15}")
    for c in (prob.n, prob.n // 4, prob.n // 8):
        tr, clock = simulate(prob, c, rounds=rounds)
        sub = tr["suboptimality"]
        idx = int(np.argmax(sub < target))
        if sub[idx] >= target:
            print(f"{c:>5} {'—':>8} (not reached)")
            continue
        print(f"{c:>5} {tr['rounds'][idx]:>8} {tr['up_floats'][idx]:>13} "
              f"{clock[idx]:>15.1f}")
    print("\nPP trades more rounds for much cheaper rounds: with 10% "
          "stragglers, waiting for the full fleet every round dominates "
          "the cost at c = n.")


class _RowLogger:
    """Collects per-round metric rows (the example needs per-round L)."""

    def __init__(self):
        self.rows = []

    def log(self, step, metrics):
        self.rows.append(dict(metrics))


def dist_main(rounds, dist_out=None):
    import jax

    from repro.configs import registry
    from repro.data import DataConfig, SyntheticTokenPipeline, device_sampler
    from repro.dist import cohort as cohort_mod
    from repro.dist import rounds as rounds_mod
    from repro.dist import tamuna_dp
    from repro.launch.mesh import make_host_mesh

    # single-device mesh, n stacked client rows (the n-override
    # placement): here the elastic engine's gather genuinely removes the
    # idle clients' gradient work — with one client per device the
    # default engine keeps the all-rows body instead (DESIGN.md §11)
    mesh = make_host_mesh(1, 1)
    n = 8
    cfg = registry.get_reduced_config("gemma2-2b")
    dcfg = DataConfig(seq_len=32, per_client_batch=2, vocab=512, seed=0,
                      n_clients=n)
    pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)

    host = np.random.default_rng(0)
    base = straggler_base(n, host, straggler_frac=0.25)
    # stragglers also churn: slow clients fail often and recover slowly
    slow = base > np.median(base)
    avail = cohort_mod.MarkovAvailability(
        p_fail=np.where(slow, 0.3, 0.05),
        p_recover=np.where(slow, 0.3, 0.9),
        seed=1,
    )
    print(f"dist engine: n={n} clients ({cfg.name}), {rounds} rounds, "
          f"Markov availability + inverse-latency weighting\n")
    print(f"{'c':>4} {'steps':>6} {'loss':>8} {'UpCom/client':>13} "
          f"{'sim wall-clock':>15}")
    samples = [] if dist_out else None
    per_round = []
    for c in (n, n // 4):
        tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=2, p=0.34)
        plan = cohort_mod.CohortPlan(
            seed=7, n=n, c=c, availability=avail, weights=1.0 / base
        )
        state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg,
                                     n=n)
        round_fn = rounds_mod.make_round_fn(
            cfg, tcfg, mesh,
            sample_batch=device_sampler(dcfg, cfg, mesh), max_L=8, n=n,
        )
        logger = _RowLogger()
        state, last = rounds_mod.run_rounds(
            state, round_fn=round_fn, data=pipe.device_data(),
            key=jax.random.key(1), rounds=rounds,
            rng=np.random.default_rng(c), p=tcfg.p,
            flush_every=min(10, rounds), logger=logger, plan=plan,
        )
        steps = [row["L"] for row in logger.rows]
        times = wallclock_per_round(
            steps, n, c, base, np.random.default_rng(3),
            cohorts=[plan.cohort(k) for k in range(len(steps))],
            samples_out=samples,
        )
        per_round.extend(times.tolist())
        print(f"{c:>4} {last['local_steps']:>6} {last['loss']:>8.4f} "
              f"{last['up_floats']:>13.3e} {times.sum():>15.1f}")
    if dist_out:
        import json

        arr = np.asarray(samples, np.float64)
        blob = {
            "per_step_latency_s": samples,
            "per_round_s": per_round,
            "n": n,
            "straggler_frac": 0.25,
            "quantiles": {
                f"p{int(q * 100)}": float(np.quantile(arr, q))
                for q in (0.5, 0.9, 0.99)
            },
        }
        parent = os.path.dirname(os.path.abspath(dist_out))
        os.makedirs(parent, exist_ok=True)
        with open(dist_out, "w") as f:
            json.dump(blob, f)
        print(f"\n[dist-out] {len(samples)} per-step latency samples "
              f"(p50={blob['quantiles']['p50']:.2f}s "
              f"p99={blob['quantiles']['p99']:.2f}s) -> {dist_out}")
    print("\nidle clients do no work in the elastic engine, and the plan "
          "routes rounds away from slow/offline clients — the same "
          "crossover as the convex story, now on the system engine.")


def faults_main(rounds):
    import jax

    from repro.configs import registry
    from repro.data import DataConfig, SyntheticTokenPipeline, device_sampler
    from repro.dist import cohort as cohort_mod
    from repro.dist import faults as faults_mod
    from repro.dist import rounds as rounds_mod
    from repro.dist import tamuna_dp
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    n, c = 8, 2
    cfg = registry.get_reduced_config("gemma2-2b")
    dcfg = DataConfig(seq_len=32, per_client_batch=2, vocab=512, seed=0,
                      n_clients=n)
    pipe = SyntheticTokenPipeline(dcfg, cfg, mesh)
    tcfg = tamuna_dp.DistTamunaConfig(gamma=0.05, c=c, s=2, p=0.34)
    fp = faults_mod.FaultPlan(
        seed=11, n=n, model=faults_mod.FaultModel(p_drop=0.25)
    )

    scenarios = [
        ("fault-free", dict()),
        ("quorum", dict(faults=fp, policy="quorum", max_retries=3,
                        backoff0=0.5)),
        ("wait_all+drops", dict(faults=fp, policy="wait_all")),
    ]
    print(f"fault-tolerant dist engine: n={n} c={c} ({cfg.name}), "
          f"{rounds} rounds, Bernoulli dropout p_drop=0.25 "
          f"(deterministic, seed=11)\n")
    print(f"{'scenario':>15} {'loss':>8} {'arrivals':>9} {'retries':>8} "
          f"{'q-miss':>7} {'sim wall-clock':>15}")
    for name, kw in scenarios:
        plan = cohort_mod.CohortPlan(seed=7, n=n, c=c)
        state = tamuna_dp.init_state(jax.random.key(0), cfg, mesh, tcfg,
                                     n=n)
        round_fn = rounds_mod.make_round_fn(
            cfg, tcfg, mesh,
            sample_batch=device_sampler(dcfg, cfg, mesh), max_L=8, n=n,
        )
        logger = _RowLogger()
        state, last = rounds_mod.run_rounds(
            state, round_fn=round_fn, data=pipe.device_data(),
            key=jax.random.key(1), rounds=rounds,
            rng=np.random.default_rng(0), p=tcfg.p,
            flush_every=min(10, rounds), logger=logger, plan=plan, **kw,
        )
        arr = sum(r.get("arrivals", c) for r in logger.rows)
        ret = sum(r.get("retries", 0) for r in logger.rows)
        miss = sum(r.get("quorum_miss", 0) for r in logger.rows)
        clock = sum(
            r.get("round_latency_s", 0.0) + max(int(r["L"]), 1) * 1.0
            for r in logger.rows
        )
        print(f"{name:>15} {last['loss']:>8.4f} {arr:>9} {ret:>8} "
              f"{miss:>7} {clock:>15.1f}")
    print("\nthe quorum policy pays retries/backoff to keep every round "
          "above quorum with unbiased survivor means; the wait_all "
          "control aggregates whatever arrived at the legacy 1/s scale — "
          "the bias BENCH_faults.json quantifies on the convex problem.")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", action="store_true",
                    help="run the straggler story on the dist round engine "
                         "with an availability-driven cohort plan")
    ap.add_argument("--faults", action="store_true",
                    help="with --dist: run the fault-tolerant driver "
                         "(dropout + quorum vs wait_all) — DESIGN.md §12")
    ap.add_argument("--rounds", type=int, default=0,
                    help="rounds per setting (default: 3000 convex, "
                         "12 dist)")
    ap.add_argument("--dist-out", default="",
                    help="with --dist: export the measured per-step "
                         "latency-tail distribution (JSON) — the input "
                         "benchmarks/pipeline_bench.py prices the "
                         "pipelined clock with")
    args = ap.parse_args()
    if args.dist and args.faults:
        faults_main(args.rounds or 12)
    elif args.dist:
        dist_main(args.rounds or 12, dist_out=args.dist_out or None)
    else:
        convex_main(args.rounds or 3000)


if __name__ == "__main__":
    main()
