"""Partial participation as robustness: stragglers and availability.

The paper motivates PP operationally: real fleets always have slow or
unavailable workers.  This example attaches a latency model to every client
(log-normal, with a heavy-tailed straggler mixture) and compares

  * full participation (c = n): every round waits for the SLOWEST client,
  * TAMUNA with c = n/4: each round samples a cohort and waits only for the
    slowest cohort member,

on simulated wall-clock time to target accuracy.  Convergence needs more
rounds at small c, but each round is much faster — the crossover the paper
predicts (complexity ~n/c rounds but per-round cost ~max over c draws).

  PYTHONPATH=src python examples/availability_sim.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import problems, tamuna


def simulate(prob, c, seed=0, rounds=4000, straggler_frac=0.1):
    rng = np.random.default_rng(seed)
    # per-client base speed; 10% of the fleet are 10x stragglers
    base = rng.lognormal(mean=0.0, sigma=0.3, size=prob.n)
    base[rng.random(prob.n) < straggler_frac] *= 10.0

    cfg = tamuna.TamunaConfig.tuned(prob, c=c)
    tr = tamuna.run(prob, cfg, num_rounds=rounds, record_every=10)

    # wall-clock: each round waits for the slowest of a uniform cohort,
    # with per-round jitter, scaled by the number of local steps
    steps = np.diff(np.concatenate([[0], tr["local_steps"]]))
    clock = []
    t = 0.0
    for k in range(len(tr["rounds"])):
        cohort = rng.choice(prob.n, size=c, replace=False)
        jitter = rng.lognormal(0.0, 0.2, size=c)
        t += (base[cohort] * jitter).max() * max(steps[k], 1)
        clock.append(t)
    return tr, np.array(clock)


def main():
    prob = problems.make_logreg_problem(
        n=64, d=256, samples_per_client=8, kappa=1000.0, seed=0
    )
    target = float(prob.suboptimality(prob.x_star * 0.0)) * 1e-6
    print(f"n={prob.n} kappa={prob.kappa:.0f} target={target:.2e}")
    print(f"{'c':>5} {'rounds':>8} {'UpCom floats':>13} {'sim wall-clock':>15}")
    for c in (prob.n, prob.n // 4, prob.n // 8):
        tr, clock = simulate(prob, c)
        sub = tr["suboptimality"]
        idx = int(np.argmax(sub < target))
        if sub[idx] >= target:
            print(f"{c:>5} {'—':>8} (not reached)")
            continue
        print(f"{c:>5} {tr['rounds'][idx]:>8} {tr['up_floats'][idx]:>13} "
              f"{clock[idx]:>15.1f}")
    print("\nPP trades more rounds for much cheaper rounds: with 10% "
          "stragglers, waiting for the full fleet every round dominates "
          "the cost at c = n.")


if __name__ == "__main__":
    main()
