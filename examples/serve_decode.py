"""Serving scenario: batched decode with a KV cache across model families.

Runs the reduced rwkv6 (O(1)-state), gemma2 (sliding-window KV), and
qwen3-moe (top-8 routing) configs through the same serving runtime used by
the decode dry-run shapes, and reports per-family state sizes — the reason
long_500k is natural for SSMs and needs context-parallel KV for dense archs.

  PYTHONPATH=src python examples/serve_decode.py
"""

import sys

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.dist import model_api


def main():
    B, prompt, gen = 2, 16, 12
    for arch in ("rwkv6-7b", "gemma2-2b", "qwen3-moe-30b-a3b"):
        cfg = registry.get_reduced_config(arch)
        params = model_api.init(jax.random.key(0), cfg)
        cache = model_api.make_cache(cfg, B, prompt + gen,
                                     kv_dtype=jnp.float32)
        cache_bytes = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)
        )
        step = jax.jit(
            lambda p, t, c, pos: model_api.decode(p, cfg, t, c, pos)
        )
        toks = jax.random.randint(jax.random.key(1), (B, prompt), 0,
                                  cfg.vocab, jnp.int32)
        # warm up on a throwaway cache: the first call pays jit compile,
        # which must not land inside the tok/s window
        warm = model_api.make_cache(cfg, B, prompt + gen,
                                    kv_dtype=jnp.float32)
        wl, _ = step(params, toks[:, :1], warm, jnp.asarray(0, jnp.int32))
        jax.block_until_ready(wl)
        del warm
        # feed the prompt (prefill-by-decode; untimed — we report decode
        # throughput, not prompt ingestion)
        for i in range(prompt):
            logits, cache = step(params, toks[:, i:i+1], cache,
                                 jnp.asarray(i, jnp.int32))
        out = []
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        t0 = time.time()
        for i in range(prompt, prompt + gen):
            out.append(int(tok[0, 0]))
            logits, cache = step(params, tok, cache,
                                 jnp.asarray(i, jnp.int32))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        # sync on the final logits BEFORE reading the clock: jax dispatch
        # is async, so without this the window closes early
        jax.block_until_ready(logits)
        dt = time.time() - t0
        grows = "O(1) in context" if cfg.family in ("rwkv",) else \
            "O(context) KV"
        print(f"{arch:>20}: cache {cache_bytes/1e6:6.2f} MB ({grows}), "
              f"{gen*B/dt:6.1f} decode tok/s, sample {out[:6]}")


if __name__ == "__main__":
    main()
