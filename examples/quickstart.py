"""Quickstart: the paper's algorithm on the paper's problem, in ~40 lines.

Reproduces the core claim of TAMUNA on a synthetic w8a-like logistic
regression: linear convergence to the exact solution with compressed uplink
(only ceil(s*d/c) floats per client per round) and 25% client participation
— and fewer communicated floats than Scaffold to the same accuracy.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import baselines, problems, tamuna


def main():
    # a heterogeneous logistic-regression problem split over 64 clients
    prob = problems.make_logreg_problem(
        n=64, d=256, samples_per_client=8, kappa=1000.0, seed=0
    )
    print(f"problem: n={prob.n} clients, d={prob.d}, kappa={prob.kappa:.0f}")

    # TAMUNA with Theorem-3 tuned parameters, 25% participation
    cfg = tamuna.TamunaConfig.tuned(prob, c=16)
    print(f"tuned: gamma={cfg.gamma:.2e} p={cfg.p:.3f} s={cfg.s} c={cfg.c}"
          f"  (uplink floats/round/client = {max(1, -(-cfg.s*prob.d//cfg.c))},"
          f" vs d={prob.d} uncompressed)")

    trace = tamuna.run(prob, cfg, num_rounds=3000, record_every=250)
    for r, sub, up in zip(trace["rounds"], trace["suboptimality"],
                          trace["up_floats"]):
        print(f"  round {r:5d}  f(x)-f* = {sub:.3e}  "
              f"uplink floats/client = {up}")

    # versus Scaffold (LT + PP, no acceleration) at the same participation
    target = float(prob.suboptimality(prob.x_star * 0.0)) * 1e-6
    sc = baselines.run_scaffold(
        prob, 1.0 / (prob.L + prob.mu), local_steps=int(1 / cfg.p),
        c=16, num_rounds=3000, record_every=20,
    )

    def floats_to(tr):
        idx = np.argmax(tr["suboptimality"] < target)
        return tr["up_floats"][idx] if tr["suboptimality"][idx] < target \
            else None

    ft, fs = floats_to(trace), floats_to(sc)
    print(f"\nuplink floats to reach {target:.1e}: "
          f"TAMUNA={ft}  Scaffold={fs}"
          + (f"  (speedup {fs/ft:.1f}x)" if ft and fs else ""))


if __name__ == "__main__":
    main()
